"""End-to-end driver: train the paper's JPEG-domain ResNet for a few
hundred steps on the synthetic corpus, with checkpointing and resume.

This is the framework's full training path (fault-tolerant trainer,
checkpoint manager, data pipeline) pointed at the paper's own
architecture — losses drop well below chance within ~100 steps.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import sys

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/jpeg_resnet_e2e")
    args = ap.parse_args()

    ns = argparse.Namespace(
        arch="jpeg-resnet", reduced=True, steps=args.steps,
        batch=args.batch, seq=0, lr=3e-3, optimizer="adamw", seed=0,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, keep=3, resume=True,
        log_every=20, straggler_factor=3.0, metrics_out=None,
    )
    result = train_loop(ns)
    first = result["losses"][0][1]
    last = result["losses"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} over {result['steps_run']} steps "
          f"({result['wall_s']:.0f}s); stragglers logged: "
          f"{len(result['stragglers'])}")
    if last >= first:
        sys.exit("loss did not improve")


if __name__ == "__main__":
    main()
