"""Batched JPEG-classification service (the paper's deployment story):
clients ship entropy-decoded JPEG coefficients; the service never
decompresses.

    PYTHONPATH=src python examples/serve_jpeg.py
"""
import argparse

from repro.launch.serve import serve_jpeg_resnet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    ns = argparse.Namespace(arch="jpeg-resnet", reduced=True,
                            batch=args.batch, requests=args.requests,
                            ctx=0, max_new=0, seed=0)
    out = serve_jpeg_resnet(ns)
    print(f"served {out['images']} images at {out['images_per_s']:.1f} img/s")


if __name__ == "__main__":
    main()
