"""Batched JPEG-classification service (the paper's deployment story):
clients ship entropy-decoded JPEG coefficients; the service never
decompresses — and never re-explodes: serving is plan-backed.  The first
run builds an ``InferencePlan`` (batch norm fused into the Ξ operators,
per-layer bands autotuned from the quantization table), saves it through
the checkpoint manager, and restores it; later runs restore the saved
plan directly and skip conversion entirely.

    PYTHONPATH=src python examples/serve_jpeg.py
    PYTHONPATH=src python examples/serve_jpeg.py --plan-dir /tmp/jpeg_plan
"""
import argparse

from repro.launch.serve import serve_jpeg_resnet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-images", type=int, default=4,
                    help="max images per request (random budget per slot)")
    ap.add_argument("--plan-dir", default=None,
                    help="where the serving plan is saved/restored "
                         "(default plans/<arch>)")
    args = ap.parse_args()
    ns = argparse.Namespace(arch="jpeg-resnet", reduced=True,
                            batch=args.batch, requests=args.requests,
                            ctx=0, max_new=args.max_images, seed=0,
                            dispatch=None, bands=None,
                            plan_dir=args.plan_dir, autotune_bands=True,
                            compiled=None)
    out = serve_jpeg_resnet(ns)
    plan = out["plan"]
    how = ("compiled fused-block schedule" if plan["compiled"]
           else "per-layer plan walk")
    print(f"served {out['images']} images / {out['completed']} requests at "
          f"{out['images_per_s']:.1f} img/s from "
          f"{'freshly built' if plan['built'] else 'restored'} plan in "
          f"{plan['dir']} via the {how} "
          f"(bands: {sorted(set(plan['bands'].values()))})")


if __name__ == "__main__":
    main()
