"""Quickstart: residual-network inference directly on JPEG coefficients.

Builds the paper's small ResNet (Fig. 3), evaluates it in the spatial
domain, converts it with one call, and runs the converted network on
entropy-decoded JPEG coefficients — identical logits, no decompression.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import convert, jpeg, resnet
from repro.data.synthetic import image_batch


def main() -> None:
    spec = resnet.ResNetSpec(widths=(16, 32, 64), num_classes=10)
    params, state = resnet.init_resnet(jax.random.PRNGKey(0), spec)

    batch = image_batch(seed=0, index=0, batch=8, size=32)
    images = jnp.asarray(batch["images"])  # (8, 3, 32, 32) pixels

    # --- spatial-domain network (the source model) -------------------------
    logits_spatial, _ = resnet.spatial_apply(params, state, images,
                                             training=False, spec=spec)

    # --- model conversion (paper §4.6): one call, exact --------------------
    model, deviation = convert.convert_and_verify(params, state, spec, images)
    print(f"conversion verified: max logit deviation = {deviation:.2e}")

    # --- JPEG-domain inference: consume step-4 coefficients ----------------
    coef = jpeg.jpeg_encode(images, quality=spec.quality, scaled=True)
    coef = jnp.moveaxis(coef, 1, 3)  # (N, bh, bw, C, 64)
    logits_jpeg = model(coef)

    print("spatial predictions:", np.asarray(jnp.argmax(logits_spatial, -1)))
    print("jpeg    predictions:", np.asarray(jnp.argmax(logits_jpeg, -1)))
    assert np.allclose(logits_spatial, logits_jpeg, atol=1e-4)
    print("OK — the JPEG-domain network is the spatial network.")


if __name__ == "__main__":
    main()
