"""Band-elastic QoS serving walkthrough (``repro.serving``).

Builds the reduced jpeg-resnet's convert-once plan, compiles it into a
ladder of band tiers, and serves a saturating burst of single-image
requests through the async scheduler — watching the QoS policy degrade
bands as the queue builds and recover as it drains:

    PYTHONPATH=src python examples/serve_qos.py
    PYTHONPATH=src python examples/serve_qos.py --ingest bytes --requests 64

Everything here is the same code path ``launch/serve.py --qos`` drives;
this script just narrates the report.
"""
import argparse

from repro.launch.serve import serve_jpeg_resnet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=48,
                    help="single-image requests, submitted as one burst")
    ap.add_argument("--tiers", default=None,
                    help="ladder caps, e.g. 'auto,48,32,24' (default)")
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--ingest", default="coefficients",
                    choices=("coefficients", "bytes"))
    ap.add_argument("--plan-dir", default=None)
    ap.add_argument("--chaos", action="store_true",
                    help="fault-drill the run (needs --ingest bytes): "
                         "corrupt 20%% of requests, kill an ingest "
                         "worker, fail two executor dispatches")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable Chrome trace of the run")
    ap.add_argument("--metrics-out", default=None,
                    help="periodically snapshot Prometheus-style metrics")
    args = ap.parse_args()
    ns = argparse.Namespace(arch="jpeg-resnet", reduced=True, qos=True,
                            batch=args.batch, requests=args.requests,
                            ctx=0, max_new=1, seed=0, dispatch=None,
                            bands=None, plan_dir=args.plan_dir,
                            autotune_bands=False, compiled=None,
                            ingest=args.ingest, jpeg_dir=None,
                            tiers=args.tiers, deadline_ms=args.deadline_ms,
                            max_queue=None, report_out=None,
                            chaos=args.chaos, trace_out=args.trace_out,
                            trace_capacity=65536,
                            metrics_out=args.metrics_out,
                            metrics_interval=1.0, jax_profile=None)
    out = serve_jpeg_resnet(ns)
    qos = out["qos"]
    lat = out["latency_ms"]
    print(f"\nserved {out['images']} requests at "
          f"{out['images_per_s']:.1f} img/s "
          f"(p50 {lat['p50_ms']:.0f}ms / p95 {lat['p95_ms']:.0f}ms / "
          f"p99 {lat['p99_ms']:.0f}ms), {out['rejected']} rejected")
    for t in qos["tiers"]:
        stats = qos["per_tier"].get(t["name"])
        if stats:
            print(f"  tier {t['name']:<4} (bands {t['bands']}): "
                  f"{stats['images']} images in {stats['batches']} batches "
                  f"at {stats['images_per_s']:.1f} img/s")
    for sw in qos["tier_switches"]:
        print(f"  switch @batch {sw['batch']}: {sw['from']} -> {sw['to']} "
              f"({sw['reason']})")
    print(f"  top-tier top-1 agreement vs plan walk: "
          f"{qos['top1_agree_top_tier']}")
    health = out["health"]
    print(f"  health: breaker {health['breaker']['state']}, "
          f"failures {qos['failures_total'] or '{}'}, "
          f"pool restarts {qos['pool_restarts']}")
    for ev in qos["breaker_timeline"]:
        print(f"  breaker @{ev['seq']}: {ev['from']} -> {ev['to']} "
              f"({ev['reason']})")
    if "trace" in out:
        tr = out["trace"]
        print(f"  trace: {tr['events']} events -> {tr['path']} "
              f"({tr['dropped']} dropped of {tr['capacity']} capacity) — "
              f"open in https://ui.perfetto.dev")
    if "chaos" in out:
        ch = out["chaos"]
        print(f"  chaos: {ch['corrupted']} corrupted "
              f"({ch['corrupt_modes']}), worker kill pid "
              f"{ch['killed_worker_pid']}, failed by stage "
              f"{ch['failed_by_stage']}, healthy "
              f"{ch['healthy_completed']}/{ch['healthy_total']} completed")


if __name__ == "__main__":
    main()
