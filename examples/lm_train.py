"""Train one of the assigned LM architectures (reduced config) on the
synthetic bigram corpus — the same trainer the production mesh uses.

    PYTHONPATH=src python examples/lm_train.py --arch mixtral-8x7b --steps 60
"""
import argparse

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    ns = argparse.Namespace(
        arch=args.arch, reduced=True, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=2e-3, optimizer="adamw", seed=0,
        ckpt_dir=f"/tmp/lm_{args.arch}", ckpt_every=0, keep=2, resume=False,
        log_every=10, straggler_factor=3.0, metrics_out=None,
    )
    result = train_loop(ns)
    print(f"{args.arch}: loss {result['losses'][0][1]:.3f} -> "
          f"{result['losses'][-1][1]:.3f}")


if __name__ == "__main__":
    main()
