"""Model conversion from a foreign (torch-layout) checkpoint.

Simulates a pretrained spatial ResNet exported as a ``{name: array}``
state dict (OIHW convs, BN running stats), maps it into the framework via
``from_torch_layout``, verifies JPEG-domain equivalence — the paper's
"apply pretrained spatial domain networks to JPEG images" workflow — and
finishes with the deployment step: save the fused ``InferencePlan`` and
serve from the restored artifact (convert once, load anywhere).

    PYTHONPATH=src python examples/convert_pretrained.py
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import convert, jpeg, plan as planlib, resnet


def export_torch_style(params, state, spec):
    """What a torch training run would hand us."""
    t = {"stem.weight": np.asarray(params["stem"]["kernel"])}

    def bn(src, dst):
        t[f"{dst}.weight"] = np.asarray(params[src]["gamma"])
        t[f"{dst}.bias"] = np.asarray(params[src]["beta"])
        t[f"{dst}.running_mean"] = np.asarray(state[src]["mean"])
        t[f"{dst}.running_var"] = np.asarray(state[src]["var"])

    bn("stem_bn", "stem_bn")
    for name, s, cin, w in resnet._stages(spec):
        t[f"{name}.conv1.weight"] = np.asarray(params[name]["conv1"])
        t[f"{name}.conv2.weight"] = np.asarray(params[name]["conv2"])
        if "proj" in params[name]:
            t[f"{name}.proj.weight"] = np.asarray(params[name]["proj"])
        bn(f"{name}_bn1", f"{name}.bn1")
        bn(f"{name}_bn2", f"{name}.bn2")
    t["head.weight"] = np.asarray(params["head"]["w"]).T
    t["head.bias"] = np.asarray(params["head"]["b"])
    return t


def main() -> None:
    spec = resnet.ResNetSpec(widths=(16, 32, 64), num_classes=10)
    params, state = resnet.init_resnet(jax.random.PRNGKey(42), spec)
    tensors = export_torch_style(params, state, spec)
    print(f"imported {len(tensors)} tensors from the torch-layout dict")

    p2, s2 = convert.from_torch_layout(tensors, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32)) * 0.4
    model, dev = convert.convert_and_verify(p2, s2, spec, x)
    print(f"converted; spatial/JPEG deviation = {dev:.2e}")
    coef = jnp.moveaxis(jpeg.jpeg_encode(x, quality=spec.quality,
                                         scaled=True), 1, 3)
    print("JPEG-domain predictions:", np.asarray(jnp.argmax(model(coef), -1)))

    # save-plan -> serve-plan: persist the fused operators through the
    # checkpoint manager; a serving process restores them and never
    # re-explodes (repro.launch.serve --arch jpeg-resnet does this too).
    with tempfile.TemporaryDirectory() as plan_dir:
        planlib.save_plan(model.plan, plan_dir)
        served = planlib.load_plan(plan_dir)
        restored_logits = planlib.apply_plan(served, coef)
        same = bool(jnp.array_equal(model(coef), restored_logits))
        print(f"restored plan from {plan_dir}; bit-identical logits: {same}")
        print("per-layer bands:", served.bands)


if __name__ == "__main__":
    main()
