"""Mamba (S6) block — the SSM half of Jamba [arXiv:2312.00752, 2403.19887].

Prefill/train uses a *chunked* selective scan: the sequence is cut into
``chunk``-sized pieces; within a chunk the diagonal linear recurrence

    h_t = a_t * h_{t-1} + b_t,   a_t = exp(Δ_t ⊙ A),  b_t = Δ_t B_t x_t

is evaluated with ``lax.associative_scan`` (log-depth, vectorised), and an
outer ``lax.scan`` carries the boundary state — so HLO work is
matmul/elementwise-shaped rather than a 32k-deep sequential loop.

Decode is the single-step recurrence over (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import shard

__all__ = ["init_mamba", "mamba_forward", "mamba_decode_step", "init_mamba_cache"]

CHUNK = 128


def _dt_rank(cfg: ModelConfig) -> int:
    return -(-cfg.d_model // 16)


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    ds, dc, dtr = cfg.d_state, cfg.d_conv, _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) * dc ** -0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * ds), dtype) * di ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * dtr ** -0.5,
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "a_log": jnp.log(
            jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * di ** -0.5,
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, di) with kernel (dc, di)."""
    dc = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(dc))
    return out + b


def _selective_scan(delta: jnp.ndarray, a: jnp.ndarray, bmat: jnp.ndarray,
                    xbar: jnp.ndarray, cmat: jnp.ndarray,
                    h0: jnp.ndarray, chunk: int = CHUNK
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Selective diagonal SSM over (B, S):  h_t = ā_t h_{t-1} + b̄_t.

    ``delta``/``xbar`` are (B, S, di); ``a`` is (di, ds); ``bmat``/``cmat``
    are (B, S, ds).  The discretised ā = exp(Δ⊙A) and b̄ = (Δ⊙x)Bᵀ tensors
    of shape (B, S, di, ds) are formed *one chunk at a time inside the
    scan* and the state history is contracted in-chunk with the readout —
    materialising either whole measured 4-9 GB/device on jamba cells.

    Returns (y = C_t·h_t of shape (B, S, di), final h).
    """
    bsz, s, di = delta.shape
    ds = a.shape[-1]
    n = max(s // chunk, 1)
    c = s // n

    def split(x):
        return x.reshape(bsz, n, c, x.shape[-1]).transpose(1, 0, 2, 3)

    xs = (split(delta), split(bmat), split(xbar), split(cmat))

    def combine(l, r):
        al, bl = l
        ar_, br_ = r
        return al * ar_, bl * ar_ + br_

    def outer(h, xs_c):
        dc, bc_, xc_, cc = xs_c  # (B, c, di) / (B, c, ds)
        ac = jnp.exp(dc[..., None] * a[None, None])          # (B, c, di, ds)
        bc = xc_[..., None] * bc_[:, :, None, :]             # (B, c, di, ds)
        cum_a, cum_b = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = cum_b + cum_a * h[:, None]
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cc)
        return h_all[:, -1], y

    # Checkpoint the chunk body: the associative scan's intermediates are
    # recomputed in backward rather than stored per chunk (SSD-style).
    h_last, y_chunks = jax.lax.scan(jax.checkpoint(outer), h0, xs)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y, h_last


def mamba_forward(x: jnp.ndarray, params: dict, cfg: ModelConfig,
                  cache: dict | None = None) -> tuple[jnp.ndarray, dict | None]:
    """(B, S, D) -> (B, S, D); optionally fills a decode cache at the end."""
    bsz, s, d = x.shape
    di = cfg.expand * d
    ds, dtr = cfg.d_state, _dt_rank(cfg)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", None, "model")
    conv_init = None if cache is None else cache["conv"]
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"], params["conv_b"], conv_init))

    proj = xc @ params["x_proj"]  # (B, S, dtr + 2 ds)
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"])  # (di, ds)
    xbar = delta * xc.astype(jnp.float32)

    h0 = (jnp.zeros((bsz, di, ds), jnp.float32) if cache is None
          else cache["ssm"])
    y, h_last = _selective_scan(delta, a, bmat.astype(jnp.float32), xbar,
                                cmat.astype(jnp.float32), h0)
    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = None
    if cache is not None:
        dc = params["conv_w"].shape[0]
        new_cache = {"conv": xin[:, s - (dc - 1):, :] if s >= dc - 1 else
                     jnp.concatenate([cache["conv"][:, s:], xin], axis=1),
                     "ssm": h_last}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    di = cfg.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.d_state), jnp.float32),
    }


def mamba_decode_step(x: jnp.ndarray, params: dict, cfg: ModelConfig,
                      cache: dict) -> tuple[jnp.ndarray, dict]:
    """Single-token step.  ``x``: (B, 1, D)."""
    bsz = x.shape[0]
    di = cfg.expand * cfg.d_model
    ds, dtr = cfg.d_state, _dt_rank(cfg)
    xz = x[:, 0] @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    conv_buf = jnp.concatenate([cache["conv"], xin[:, None]], axis=1)  # (B, dc, di)
    xc = jax.nn.silu(jnp.einsum("bcd,cd->bd", conv_buf, params["conv_w"])
                     + params["conv_b"])
    proj = xc @ params["x_proj"]
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(params["a_log"])
    abar = jnp.exp(delta[..., None] * a[None])  # (B, di, ds)
    bbar = (delta * xc.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, None, :]
    h = abar * cache["ssm"] + bbar
    y = jnp.einsum("bdn,bn->bd", h, cmat.astype(jnp.float32))
    y = y + params["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": conv_buf[:, 1:], "ssm": h}
