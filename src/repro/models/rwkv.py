"""RWKV-6 "Finch" blocks [arXiv:2404.05892] — data-dependent decay.

Time-mix: data-dependent token-shift (DDLerp with a shared low-rank
projection), per-channel decay ``w = exp(-exp(w0 + lora(x)))``, and the
per-head WKV matrix recurrence

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    y_t = r_t (diag(u) k_tᵀ v_t + S_{t-1})

evaluated with a chunked scan (outer ``lax.scan`` over chunks carrying S,
inner within-chunk computation in matmul form) so prefill work is
MXU-shaped.  Channel-mix: squared-ReLU MLP with token shift.

Decode is the single-token recurrence over (shift states, S).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel.sharding import shard

__all__ = ["init_rwkv_layer", "rwkv_time_mix", "rwkv_channel_mix",
           "init_rwkv_cache", "rwkv_time_mix_decode", "rwkv_channel_mix_decode"]

LORA_R = 32
CHUNK = 32
# Per-step log-decay clamp: the chunked factorisation exp(cum_t - cum_j)
# is evaluated as exp(cum_t)·exp(-cum_j); bounding |log w| <= MAX_NEG_LOGW
# keeps the per-chunk exponent range inside fp32 (32 · 2 = 64 < 88).  A
# decay faster than e^-2 per step zeroes the state within ~3 tokens anyway
# (the official RWKV CUDA kernel applies similar numerical guards).
MAX_NEG_LOGW = 2.0


def init_rwkv_layer(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    ks = jax.random.split(key, 14)
    s = d ** -0.5
    return {
        "tm": {
            "mu_base": jax.random.uniform(ks[0], (d,), dtype),
            "mu": jax.random.uniform(ks[1], (5, d), dtype),
            "ddlerp_w1": jax.random.normal(ks[2], (d, 5 * LORA_R), dtype) * s,
            "ddlerp_w2": jax.random.normal(ks[3], (5, LORA_R, d), dtype) * LORA_R ** -0.5,
            "receptance": jax.random.normal(ks[4], (d, d), dtype) * s,
            "key": jax.random.normal(ks[5], (d, d), dtype) * s,
            "value": jax.random.normal(ks[6], (d, d), dtype) * s,
            "gate": jax.random.normal(ks[7], (d, d), dtype) * s,
            "output": jax.random.normal(ks[8], (d, d), dtype) * s,
            "decay_base": jnp.full((d,), -6.0, jnp.float32),
            "decay_w1": jax.random.normal(ks[9], (d, 64), dtype) * s,
            "decay_w2": jax.random.normal(ks[10], (64, d), dtype) * 64 ** -0.5,
            "bonus": jax.random.normal(ks[11], (nh, hs), jnp.float32) * 0.1,
            "ln_w": jnp.ones((d,), jnp.float32),  # per-head group norm
            "ln_b": jnp.zeros((d,), jnp.float32),
        },
        "cm": {
            "mu_k": jax.random.uniform(ks[12], (d,), dtype),
            "mu_r": jax.random.uniform(ks[13], (d,), dtype),
            "key": jax.random.normal(ks[4], (d, cfg.d_ff), dtype) * s,
            "value": jax.random.normal(ks[5], (cfg.d_ff, d), dtype) * cfg.d_ff ** -0.5,
            "receptance": jax.random.normal(ks[6], (d, d), dtype) * s,
        },
    }


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray | None) -> jnp.ndarray:
    """Shift right by one along seq; ``prev`` (B, 1, D) seeds position 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(x, xx, p):
    """Finch data-dependent lerp -> the five mixed inputs (w,k,v,r,g)."""
    dx = xx - x
    base = x + dx * p["mu_base"]
    lora = jnp.tanh(base @ p["ddlerp_w1"])
    b, s, _ = x.shape
    lora = lora.reshape(b, s, 5, LORA_R)
    dyn = jnp.einsum("bsfr,frd->fbsd", lora, p["ddlerp_w2"])
    mixed = x[None] + dx[None] * (p["mu"][:, None, None, :] + dyn)
    return mixed  # (5, B, S, D): w, k, v, r, g


def _wkv_chunked(r, k, v, w, u, s0, chunk: int = CHUNK):
    """WKV recurrence over (B, S, H, hs) tensors; returns (y, S_last).

    Within a chunk, cumulative decay products turn the recurrence into
    matmuls:  y_t = r_t S_in D_{<t} + intra-chunk attention-like term.
    For clarity and correctness we evaluate the intra-chunk part with a
    (chunk × chunk) decay-weighted score matrix — O(S·chunk) like SWA.
    """
    b, s, h, hs = r.shape
    n = max(s // chunk, 1)
    c = s // n
    rs = r.reshape(b, n, c, h, hs).transpose(1, 0, 2, 3, 4)
    ks_ = k.reshape(b, n, c, h, hs).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n, c, h, hs).transpose(1, 0, 2, 3, 4)
    ws = w.reshape(b, n, c, h, hs).transpose(1, 0, 2, 3, 4)

    def body(s_in, xs):
        rc, kc, vc, wc = xs  # (B, c, H, hs)
        logw = jnp.log(wc)  # decays in (e^-MAX_NEG_LOGW, 1), clamped at source
        cum = jnp.cumsum(logw, axis=1)  # log prod of w_1..w_t
        # carry-in term: y_t += r_t @ (D_t S_in) with D_t = prod_{i<=t-1} w_i
        dec_in = jnp.exp(cum - logw)  # prod w_1..w_{t-1}
        y_in = jnp.einsum("bthk,bhkv->bthv", rc * dec_in, s_in)
        # intra-chunk: y_t += sum_{j<t} (r_t·k_j · prod_{j<i<t} w) v_j.
        # score[t, j] = sum_k r_t[k] k_j[k] exp(cum[t-1,k] - cum[j,k]), j < t.
        att = jnp.einsum("bthk,bjhk->bhtj", rc * dec_in, kc * jnp.exp(-cum))
        tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(tri[None, None], att, 0.0)
        y_intra = jnp.einsum("bhtj,bjhv->bthv", att, vc)
        # diagonal "bonus": y_t += (r_t · (u ⊙ k_t)) v_t
        diag_coef = jnp.einsum("bthk,bthk->bth", rc, kc * u[None, None])
        y = y_in + y_intra + diag_coef[..., None] * vc
        # state update: S_out = D_c S_in + sum_j (prod_{j<i<=c} w) k_j v_j
        dec_full = jnp.exp(cum[:, -1][:, None] - cum)  # prod_{j<i<=c}
        s_out = jnp.exp(cum[:, -1])[..., None] * s_in + jnp.einsum(
            "bjhk,bjhv->bhkv", kc * dec_full, vc
        )
        return s_out, y

    # Checkpoint the chunk body: backward recomputes the intra-chunk decay
    # matrices instead of storing them per chunk (linear-attention flash
    # semantics; without this, train memory is O(S·c) per layer).
    s_last, ys = jax.lax.scan(jax.checkpoint(body), s0, (rs, ks_, vs, ws))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hs)
    return y, s_last


def _group_norm_heads(x: jnp.ndarray, w, bias, nh: int, eps: float = 64e-5):
    b, s, d = x.shape
    xh = x.reshape(b, s, nh, d // nh).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return xh.reshape(b, s, d) * w + bias


def rwkv_time_mix(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                  cache: dict | None = None):
    """(B, S, D) -> (B, S, D); cache carries (shift, wkv state)."""
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    nh = d // hs
    prev = None if cache is None else cache["shift_tm"]
    xx = _token_shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(x, xx, p)
    r = (xr @ p["receptance"]).reshape(b, s, nh, hs)
    k = (xk @ p["key"]).reshape(b, s, nh, hs)
    v = (xv @ p["value"]).reshape(b, s, nh, hs)
    g = jax.nn.silu(xg @ p["gate"])
    decay = p["decay_base"] + jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32))).reshape(b, s, nh, hs)
    w = jnp.maximum(w, float(np.exp(-MAX_NEG_LOGW)))  # numerical guard
    s0 = (jnp.zeros((b, nh, hs, hs), jnp.float32) if cache is None
          else cache["wkv"])
    y, s_last = _wkv_chunked(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), w, p["bonus"], s0)
    y = _group_norm_heads(y.reshape(b, s, d), p["ln_w"], p["ln_b"], nh)
    out = (y.astype(x.dtype) * g) @ p["output"]
    new_cache = None
    if cache is not None:
        new_cache = {"shift_tm": x[:, -1:], "wkv": s_last}
    return out, new_cache


def rwkv_channel_mix(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                     cache: dict | None = None):
    prev = None if cache is None else cache["shift_cm"]
    xx = _token_shift(x, prev)
    xk = x + (xx - x) * p["mu_k"]
    xr = x + (xx - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["key"]))
    k = shard(k, "batch", None, "model")
    kv = k @ p["value"]
    out = jax.nn.sigmoid(xr @ p["receptance"]) * kv
    new_cache = {"shift_cm": x[:, -1:]} if cache is not None else None
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    return {
        "shift_tm": jnp.zeros((batch, 1, d), dtype),
        "shift_cm": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, nh, hs, hs), jnp.float32),
    }


def rwkv_time_mix_decode(x, p, cfg, cache):
    return rwkv_time_mix(x, p, cfg, cache)


def rwkv_channel_mix_decode(x, p, cfg, cache):
    return rwkv_channel_mix(x, p, cfg, cache)
