"""Shared transformer layers: norms, RoPE, GQA/SWA attention, gated MLPs.

Conventions
-----------
* Activations ``(B, S, D)``; attention heads materialised as
  ``(B, S, H, head_dim)``; KV caches ``(B, T, KVH, head_dim)``.
* GQA: ``H = KVH * G`` query heads grouped per KV head.
* Softmax/norm statistics in fp32 regardless of activation dtype.
* Long sequences (> ``CHUNK_THRESHOLD``) use an online-softmax KV-chunk
  scan (pure-JAX flash attention) to bound the score working set; the
  Pallas kernel in ``repro.kernels.flash_attention`` is the TPU-target
  twin of this routine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard

__all__ = [
    "resolve_dtype", "rms_norm", "layer_norm", "apply_rope", "sinusoidal_positions",
    "attention", "decode_attention", "swiglu_mlp", "gelu_mlp",
    "DENSE_ATTN_ELEMS", "KV_CHUNK",
]

DENSE_ATTN_ELEMS = 2048 * 2048  # dense path for S·T up to this
KV_CHUNK = 1024
MAX_Q_CHUNKS = 32  # bound on python-unrolled query chunks (HLO size)


def resolve_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, dim: int) -> jnp.ndarray:
    """(S,) -> (S, dim) sinusoidal embeddings (whisper-style)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embeddings.  ``x``: (B, S, H, hd); ``positions``: (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q (B,S,KVH,G,hd) x k (B,T,KVH,hd) -> (B,KVH,G,S,T) fp32 scores."""
    return jnp.einsum("bsngd,btnd->bngst", q, k,
                      preferred_element_type=jnp.float32)


def attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Full-sequence attention with bounded working set and exact FLOPs.

    ``q``: (B, S, H, hd); ``k``/``v``: (B, T, KVH, hd).  ``q_offset`` is the
    absolute position of q[0] relative to k[0] (prefill: 0).

    Small problems take the dense masked path.  Large ones are processed as
    python-unrolled *query chunks*, each running an online-softmax scan over
    only the KV chunks its causal/window footprint actually touches — the
    flash-attention schedule in pure JAX (the Pallas kernel in
    ``repro.kernels.flash_attention`` is the TPU-native twin).  Working set
    per chunk pair is (B, H, q_chunk, kv_chunk) instead of (B, H, S, T), and
    fully-masked chunk pairs are skipped (no fake FLOPs in the roofline).
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd) * (hd ** -0.5)
    if s * t <= DENSE_ATTN_ELEMS:
        scores = _gqa_scores(qg, k)  # (B, KVH, G, S, T)
        qpos = jnp.arange(s) + q_offset
        kpos = jnp.arange(t)
        mask = jnp.ones((s, t), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bngst,btnd->bsngd", probs, v)
        return out.reshape(b, s, h, hd)

    qc = max(KV_CHUNK, s // MAX_Q_CHUNKS)
    n_q = -(-s // qc)
    outs = []
    for i in range(n_q):
        q_i = qg[:, i * qc: (i + 1) * qc]
        sc = q_i.shape[1]
        lo_pos = i * qc + q_offset
        hi_pos = lo_pos + sc - 1
        lo = 0
        if window is not None:
            lo = max(0, (lo_pos - window + 1) // KV_CHUNK)
        hi = -(-min(hi_pos + 1, t) // KV_CHUNK) if causal else -(-t // KV_CHUNK)
        hi = max(min(hi, -(-t // KV_CHUNK)), lo + 1)
        k_i = k[:, lo * KV_CHUNK: hi * KV_CHUNK]
        v_i = v[:, lo * KV_CHUNK: hi * KV_CHUNK]
        o = _attention_kv_chunked(
            q_i, k_i, v_i, causal=causal, window=window,
            q_offset=lo_pos - lo * KV_CHUNK)
        outs.append(o.reshape(b, sc, h, hd))
    return jnp.concatenate(outs, axis=1)


def _attention_kv_chunked(qg, k, v, *, causal, window, q_offset,
                          chunk: int = KV_CHUNK):
    """Online-softmax scan over KV chunks (flash-attention recurrence)."""
    b, s, kvh, g, hd = qg.shape
    t = k.shape[1]
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(s) + q_offset

    def body(carry, xs):
        m, l, acc = carry
        ci, kci, vci = xs
        scores = _gqa_scores(qg, kci)  # (B, KVH, G, S, chunk)
        kpos = ci * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < t  # padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bngst,btnd->bngsd", p.astype(qg.dtype), vci)
        acc_new = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    acc0 = jnp.zeros((b, kvh, g, s, hd), qg.dtype)
    # Checkpoint the chunk body: the scan's backward then recomputes scores
    # per tile instead of storing S×T probabilities — flash-attention
    # backward semantics (without this, backward memory is quadratic).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4)  # (B, S, KVH, G, hd)


def decode_attention(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
    cache_len: jnp.ndarray, *, window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token attention against a padded cache.

    ``q``: (B, 1, H, hd); caches (B, T, KVH, hd); ``cache_len`` scalar/int32 —
    number of valid entries (the new token's k/v already written).  With a
    ring-buffer SWA cache every slot is valid; pass ``window=None`` and a
    full ``cache_len``.
    """
    b, _, h, hd = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd) * (hd ** -0.5)
    scores = _gqa_scores(qg, k_cache)  # (B, KVH, G, 1, T)
    valid = jnp.arange(t) < cache_len
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v_cache)
    return out.reshape(b, 1, h, hd)


def swiglu_mlp(x: jnp.ndarray, w_gate: jnp.ndarray, w_in: jnp.ndarray,
               w_out: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU: (silu(x @ w_gate) * (x @ w_in)) @ w_out."""
    gate = jax.nn.silu(x @ w_gate)
    h = gate * (x @ w_in)
    h = shard(h, "batch", None, "model")
    return h @ w_out


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, b_in: jnp.ndarray,
             w_out: jnp.ndarray, b_out: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.gelu(x @ w_in + b_in)
    h = shard(h, "batch", None, "model")
    return h @ w_out + b_out
