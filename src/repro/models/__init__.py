"""Model zoo: shared layers + family modules + registry."""
from repro.models.registry import (  # noqa: F401
    Model,
    build_model,
    cell_is_skipped,
    count_params,
    input_specs,
)
