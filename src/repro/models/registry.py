"""Architecture registry: one interface over every family.

``build_model(cfg)`` returns a :class:`Model` bundle of pure functions; the
launcher/dry-run only ever talks to this interface.

``input_specs(cfg, shape, for_dryrun)`` produces either concrete host
batches (smoke tests / training) or ``jax.ShapeDtypeStruct`` stand-ins (the
dry-run — weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["Model", "build_model", "input_specs", "decode_lengths",
           "cell_is_skipped", "count_params", "jpeg_resnet_spec"]


class Model(NamedTuple):
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    loss_fn: Callable[..., Any]            # (params, batch) -> (loss, metrics)
    forward: Callable[..., Any]            # (params, batch) -> outputs
    init_cache: Callable[[int, int], Any] | None   # (batch, seq) -> cache
    decode_step: Callable[..., Any] | None  # (params, cache, batch)
    prefill: Callable[..., Any] | None = None  # (params, batch) -> (logits, cache)


# --------------------------------------------------------------------------
# LM families
# --------------------------------------------------------------------------


def _lm_model(cfg: ModelConfig, remat: str = "none") -> Model:
    def init_params(key):
        return T.init_params(key, cfg)

    def loss(params, batch):
        return T.loss_fn(params, cfg, batch, remat=remat)

    def fwd(params, batch):
        return T.forward(params, cfg, batch, training=False)

    def init_cache(batch, seq):
        return T.init_cache(cfg, batch, seq)

    def dstep(params, cache, batch):
        return T.decode_step(params, cfg, cache, batch)

    def pfill(params, batch, pad_to=None):
        return T.prefill(params, cfg, batch, pad_to=pad_to)

    return Model(cfg, init_params, loss, fwd, init_cache, dstep, pfill)


# --------------------------------------------------------------------------
# JPEG-ResNet family (the paper's own architecture)
# --------------------------------------------------------------------------


def jpeg_resnet_spec(cfg: ModelConfig):
    """The ``ResNetSpec`` a jpeg_resnet ``ModelConfig`` describes — the one
    place the field mapping lives (the model builder and the plan-backed
    serving path both resolve specs through it)."""
    from repro.core import resnet as R

    return R.ResNetSpec(
        in_channels=cfg.in_channels, widths=tuple(cfg.widths),
        blocks_per_stage=cfg.blocks_per_stage, num_classes=cfg.num_classes,
        phi=cfg.asm_phi,
    )


def _jpeg_resnet_model(cfg: ModelConfig, remat: str = "none") -> Model:
    from repro.core import resnet as R

    spec = jpeg_resnet_spec(cfg)
    use_remat = remat != "none"

    def init_params(key):
        params, state = R.init_resnet(key, spec, L.resolve_dtype(cfg.dtype))
        return {"params": params, "bn_state": state}

    def loss(bundle, batch):
        logits, new_state = R.jpeg_apply(
            bundle["params"], bundle["bn_state"], batch["coefficients"],
            training=True, spec=spec, remat=use_remat)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
        loss = nll.mean()
        return loss, {"loss": loss, "bn_state": new_state}

    def fwd(bundle, batch):
        logits, _ = R.jpeg_apply(
            bundle["params"], bundle["bn_state"], batch["coefficients"],
            training=False, spec=spec)
        return logits, 0.0

    return Model(cfg, init_params, loss, fwd, None, None)


def build_model(cfg: ModelConfig, remat: str = "none") -> Model:
    if cfg.family == "jpeg_resnet":
        return _jpeg_resnet_model(cfg, remat)
    return _lm_model(cfg, remat)


# --------------------------------------------------------------------------
# Input specs per (family, shape kind)
# --------------------------------------------------------------------------


def decode_lengths(cfg: ModelConfig, shape: ShapeConfig) -> tuple[int, int]:
    """(encoder_len, decoder_len) convention for enc-dec shapes."""
    return shape.seq_len, max(shape.seq_len // 8, 8)


def cell_is_skipped(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """Returns a skip reason or None (DESIGN.md §Arch-applicability)."""
    if cfg.family == "jpeg_resnet" and shape.kind != "train":
        return "skip(no-decode: classification net)"
    if shape.name == "long_500k" and not cfg.sub_quadratic():
        return "skip(full-attn)"
    return None


def _tok(batch, seq, dryrun):
    if dryrun:
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return np.zeros((batch, seq), np.int32)


def _f(shape, dtype, dryrun):
    if dryrun:
        return jax.ShapeDtypeStruct(shape, dtype)
    return np.zeros(shape, np.float32).astype(dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *,
                dryrun: bool = True) -> dict[str, Any]:
    """Model inputs for one (arch × shape) cell.

    train/prefill: full-sequence batch; decode: one-token batch (the KV
    cache is created separately via ``Model.init_cache``).
    """
    b, s = shape.global_batch, shape.seq_len
    dtype = L.resolve_dtype(cfg.dtype)
    if cfg.family == "jpeg_resnet":
        n_blocks = cfg.image_size // 8
        labels = (jax.ShapeDtypeStruct((b,), jnp.int32) if dryrun
                  else np.zeros((b,), np.int32))
        return {
            "coefficients": _f((b, n_blocks, n_blocks, cfg.in_channels, 64),
                               jnp.float32, dryrun),
            "labels": labels,
        }
    if shape.kind == "decode":
        batch = {"tokens": _tok(b, 1, dryrun)}
        return batch
    # train / prefill
    if cfg.family == "audio":
        enc_len, dec_len = decode_lengths(cfg, shape)
        batch = {
            "frames": _f((b, enc_len, cfg.d_model), dtype, dryrun),
            "tokens": _tok(b, dec_len, dryrun),
        }
        if shape.kind == "train":
            batch["labels"] = _tok(b, dec_len, dryrun)
        return batch
    if cfg.family == "vlm":
        text_len = s - cfg.vision_prefix_len
        batch = {
            "tokens": _tok(b, text_len, dryrun),
            "vision_embeds": _f((b, cfg.vision_prefix_len, cfg.d_model),
                                dtype, dryrun),
        }
        if shape.kind == "train":
            batch["labels"] = _tok(b, text_len, dryrun)
        return batch
    batch = {"tokens": _tok(b, s, dryrun)}
    if shape.kind == "train":
        batch["labels"] = _tok(b, s, dryrun)
    return batch


def count_params(params: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
