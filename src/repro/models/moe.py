"""Mixture-of-Experts FFN: sort-based grouped dispatch with static capacity.

Dispatch is gather/scatter (zero FLOPs) rather than the GShard one-hot
einsum — the one-hot dispatch costs O(T²k·d) which would swamp the roofline
at 1M-token batches (DESIGN.md §5).  Compute is three grouped einsums over
``(E, C, d)`` buffers, so HLO FLOPs equal *active* FLOPs × capacity_factor.

Two code paths:

* **pjit path** (no mesh rules active — smoke tests): global dispatch.
* **shard_map EP path** (under ``sharding_rules`` with a mesh): tokens stay
  local to their (pod, data) shard, capacity is per-shard (exactly the
  GShard/Switch "local group" formulation), expert d_ff is sliced over
  ``model`` and the partial expert outputs are psum'd over ``model`` —
  Megatron-style TP on experts.  Nothing about the dispatch is ever
  materialised globally, which is what keeps 1M-token MoE batches inside
  HBM (the replicated-dispatch version measured 98-270 GB/device).

No divisibility constraint on the expert count — works for 8, 16 and 40
experts on a 16-wide model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map

from repro.configs.base import ModelConfig
from repro.parallel.sharding import batch_pspec, current_rules

__all__ = ["init_moe", "moe_ffn"]

GROUP = 8192  # tokens per dispatch group (GShard "group size")


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = d ** -0.5
    s_out = f ** -0.5
    return {
        "router": jax.random.normal(k1, (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(k2, (e, d, f), dtype) * s_in,
        "w_in": jax.random.normal(k3, (e, d, f), dtype) * s_in,
        "w_out": jax.random.normal(k4, (e, f, d), dtype) * s_out,
    }


def _dispatch_compute_combine(xf, params, cfg: ModelConfig,
                              f_sharded: bool, model_axes=()):
    """Core algorithm over a (T, d) token block and (maybe f-sliced) experts.

    Returns (out (T, d), counts (E,), probs_sum (E,), T).
    """
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = int(-(-t * k * cfg.capacity_factor // e))
    cap = max(min(cap, t), 1)

    logits = xf.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    pair_e = top_e.reshape(-1)
    pair_tok = jnp.repeat(jnp.arange(t), k)
    pair_w = top_w.reshape(-1)
    order = jnp.argsort(pair_e, stable=True)
    sorted_e = pair_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[pair_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)

    sorted_tok = pair_tok[order]
    buf = jnp.zeros((e * cap + 1, d), xf.dtype)
    buf = buf.at[slot].set(xf[sorted_tok])
    grouped = buf[: e * cap].reshape(e, cap, d)

    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", grouped, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", grouped, params["w_in"])
    y_grouped = jnp.einsum("ecf,efd->ecd", gate * up, params["w_out"])

    y_pad = jnp.concatenate(
        [y_grouped.reshape(e * cap, d), jnp.zeros((1, d), xf.dtype)], axis=0)
    y_pairs = y_pad[slot] * pair_w[order][:, None].astype(xf.dtype)
    out = jnp.zeros((t, d), xf.dtype).at[sorted_tok].add(y_pairs)
    if f_sharded:
        # Expert d_ff is sliced over `model`, so `out` holds partial sums.
        # Reducing *after* the (linear) combine moves (T, d) instead of
        # (E, C, d) — k·capacity_factor× fewer collective bytes.
        out = jax.lax.psum(out, model_axes)
    return out, counts, probs.sum(axis=0), jnp.asarray(t, jnp.float32)


def _aux_loss(counts, probs_sum, t_total, e: int, k: int) -> jnp.ndarray:
    frac_tokens = counts.astype(jnp.float32) / jnp.maximum(t_total * k, 1.0)
    frac_probs = probs_sum / jnp.maximum(t_total, 1.0)
    return e * jnp.sum(frac_tokens * frac_probs)


def moe_ffn(x: jnp.ndarray, params: dict, cfg: ModelConfig
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``x``: (B, S, D) -> (out, aux_loss).  Top-k, renormalised weights
    (Mixtral convention); per-(shard-)group capacity with overflow drop."""
    b, s, d = x.shape
    rules = current_rules()
    if rules is None or rules.mesh is None:
        out, counts, probs_sum, t = _dispatch_compute_combine(
            x.reshape(b * s, d), params, cfg, f_sharded=False)
        return (out.reshape(b, s, d),
                _aux_loss(counts, probs_sum, t, cfg.n_experts,
                          cfg.experts_per_token))

    # ---- shard_map EP path ------------------------------------------------
    mesh = rules.mesh
    baxes = batch_pspec(rules, b)
    bspec = baxes if baxes else None
    maxes = rules.axes("model")
    mspec = (maxes if len(maxes) != 1 else maxes[0]) if maxes else None
    all_axes = tuple(mesh.axis_names)
    unused = tuple(a for a in all_axes
                   if a not in (baxes or ()) and a not in maxes)

    daxes = rules.axes("data")
    dspec = daxes if len(daxes) != 1 else (daxes[0] if daxes else None)
    fsdp = bool(daxes) and cfg.d_model % max(rules.size("data"), 1) == 0
    param_specs = {
        "router": P(None, None),
        "w_gate": P(None, dspec if fsdp else None, mspec),
        "w_in": P(None, dspec if fsdp else None, mspec),
        "w_out": P(None, mspec, dspec if fsdp else None),
    }

    def local_fn(x_loc, p_loc):
        if fsdp:
            # ZeRO-3 expert storage: gather the d_model shards over `data`
            # just-in-time (the gathered slice is f-sliced, so it is tiny);
            # autodiff transposes this into a reduce-scatter of the weight
            # grads — no expert tensor is ever data-replicated.
            p_loc = dict(
                p_loc,
                w_gate=jax.lax.all_gather(p_loc["w_gate"], daxes, axis=1,
                                          tiled=True),
                w_in=jax.lax.all_gather(p_loc["w_in"], daxes, axis=1,
                                        tiled=True),
                w_out=jax.lax.all_gather(p_loc["w_out"], daxes, axis=2,
                                         tiled=True),
            )
        bl, sl, dl = x_loc.shape
        t_loc = bl * sl
        xf = x_loc.reshape(t_loc, dl)
        # GShard-style token groups: dispatch in groups of <= GROUP tokens
        # (lax.scan) so the (E, C, d) buffers stay group-sized — an
        # ungrouped 65k-token dispatch measured ~8 GB of transients.
        n_groups = max(t_loc // GROUP, 1)
        if t_loc % GROUP:
            n_groups = 1
        if n_groups > 1:
            xg = xf.reshape(n_groups, t_loc // n_groups, dl)

            def body(_, xgi):
                o, c, p, _t = _dispatch_compute_combine(
                    xgi, p_loc, cfg, f_sharded=bool(maxes), model_axes=maxes)
                return 0, (o, c, p)

            _, (outs, counts_g, probs_g) = jax.lax.scan(
                jax.checkpoint(body), 0, xg)
            out = outs.reshape(t_loc, dl)
            counts = counts_g.sum(axis=0)
            probs_sum = probs_g.sum(axis=0)
            t_val = jnp.asarray(t_loc, jnp.float32)
        else:
            out, counts, probs_sum, t_val = _dispatch_compute_combine(
                xf, p_loc, cfg, f_sharded=bool(maxes), model_axes=maxes)
        # global load-balance statistics across token shards
        reduce_axes = tuple(baxes) + unused
        if reduce_axes:
            counts = jax.lax.psum(counts, reduce_axes)
            probs_sum = jax.lax.psum(probs_sum, reduce_axes)
            t_tot = jax.lax.psum(t_val, reduce_axes)
        else:
            t_tot = t_val
        aux = _aux_loss(counts, probs_sum, t_tot, cfg.n_experts,
                        cfg.experts_per_token)
        return out.reshape(bl, sl, dl), aux

    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), param_specs),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )
    return fn(x, params)
