"""Unified LM stack covering dense / MoE / hybrid / SSM / VLM / enc-dec.

Layer heterogeneity (Jamba's 1:7 attn:mamba with MoE every other layer) is
handled by a *repeating period*: layers are grouped into
``n_layers // period`` pattern repetitions; parameters are stacked over
repetitions and the repetitions are driven by ``lax.scan`` (HLO size O(1)
in depth — a compile-time requirement at 512 devices), while the ``period``
positions inside the body are unrolled Python (their parameter *structures*
differ).

Caches: every mixer kind exposes ``init`` + single-token ``step``; decode
scans over (stacked params, stacked caches) so the serve step is also
O(1)-sized HLO.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.parallel.sharding import shard

__all__ = [
    "layer_kinds", "pattern_period", "init_params", "forward", "loss_fn",
    "init_cache", "decode_step",
]


# --------------------------------------------------------------------------
# Layer pattern
# --------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[tuple[str, str]]:
    """Per-layer (mixer, ffn) kinds for the decoder stack."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.ssm_kind == "rwkv6":
            mixer = "rwkv"
        elif cfg.ssm_kind == "mamba":
            mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
        else:
            mixer = "attn"
        if mixer == "rwkv":
            ffn = "rwkv_cm"  # channel-mix plays the FFN role
        elif cfg.is_moe_layer(i):
            ffn = "moe"
        else:
            ffn = "dense"
        kinds.append((mixer, ffn))
    return kinds


def pattern_period(cfg: ModelConfig) -> int:
    kinds = layer_kinds(cfg)
    for p in range(1, len(kinds) + 1):
        if len(kinds) % p == 0 and all(
            kinds[i] == kinds[i % p] for i in range(len(kinds))
        ):
            return p
    return len(kinds)


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so TP in_shardings divide evenly
    (the standard production practice; logits are sliced back to the true
    vocab before the loss, padded embedding rows are never gathered)."""
    return -(-cfg.vocab_size // 256) * 256


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------


def _dense_proj(key, shape, dtype, scale=None):
    scale = (shape[0] ** -0.5) if scale is None else scale
    return jax.random.normal(key, shape, dtype) * scale


def _init_attn(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "q_proj": _dense_proj(ks[0], (d, cfg.q_dim), dtype),
        "k_proj": _dense_proj(ks[1], (d, cfg.kv_dim), dtype),
        "v_proj": _dense_proj(ks[2], (d, cfg.kv_dim), dtype),
        "o_proj": _dense_proj(ks[3], (cfg.q_dim, d), dtype),
    }
    return p


def _init_dense_ffn(key, cfg: ModelConfig, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.family == "audio":  # gelu MLP with biases (whisper)
        return {
            "wi": _dense_proj(ks[0], (d, f), dtype),
            "bi": jnp.zeros((f,), dtype),
            "wo": _dense_proj(ks[1], (f, d), dtype),
            "bo": jnp.zeros((d,), dtype),
        }
    return {
        "w_gate": _dense_proj(ks[0], (d, f), dtype),
        "w_in": _dense_proj(ks[1], (d, f), dtype),
        "w_out": _dense_proj(ks[2], (f, d), dtype),
    }


def _init_layer(key, cfg: ModelConfig, kind: tuple[str, str], dtype,
                cross_attention: bool = False) -> dict:
    mixer, ffn = kind
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = mamba_lib.init_mamba(ks[0], cfg, dtype)
    elif mixer == "rwkv":
        p.update(rwkv_lib.init_rwkv_layer(ks[0], cfg, dtype))
    if cross_attention:
        p["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["cross"] = _init_attn(ks[1], cfg, dtype, cross=True)
    p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
    if mixer != "rwkv":
        if ffn == "moe":
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
        else:
            p["ffn"] = _init_dense_ffn(ks[2], cfg, dtype)
    if cfg.family == "audio":  # layernorm biases
        p["ln1_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if "ln2" in p:
            p["ln2_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        if cross_attention:
            p["ln_cross_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def _stacked_blocks(key, cfg: ModelConfig, dtype, *, n_layers: int,
                    kinds: list[tuple[str, str]], period: int,
                    cross_attention: bool = False):
    n_periods = n_layers // period
    out = {}
    for j in range(period):
        keys = jax.random.split(jax.random.fold_in(key, j), n_periods)
        out[f"pos{j}"] = jax.vmap(
            lambda k: _init_layer(k, cfg, kinds[j], dtype,
                                  cross_attention=cross_attention)
        )(keys)
    return out


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    dtype = L.resolve_dtype(cfg.dtype)
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (padded_vocab(cfg), cfg.d_model),
                                   dtype) * cfg.d_model ** -0.5,
        "blocks": _stacked_blocks(ks[1], cfg, dtype, n_layers=cfg.n_layers,
                                  kinds=kinds, period=period,
                                  cross_attention=cfg.cross_attention),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.family == "audio":
        params["ln_f_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            ks[2], (cfg.d_model, padded_vocab(cfg)), dtype) * cfg.d_model ** -0.5
    if cfg.encoder_decoder:
        params["encoder"] = {
            "blocks": _stacked_blocks(
                ks[3], cfg, dtype, n_layers=cfg.n_encoder_layers,
                kinds=[("attn", "dense")] * cfg.n_encoder_layers, period=1),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_f_b": jnp.zeros((cfg.d_model,), jnp.float32),
        }
    return params


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------


def _norm(x, w, b=None, eps=1e-5):
    if b is not None:
        return L.layer_norm(x, w, b, eps)
    return L.rms_norm(x, w, eps)


def _apply_ffn(h, p, cfg: ModelConfig, kind: str):
    """Returns (out, aux_loss)."""
    if kind == "moe":
        return moe_lib.moe_ffn(h, p["moe"], cfg)
    f = p["ffn"]
    if cfg.family == "audio":
        return L.gelu_mlp(h, f["wi"], f["bi"], f["wo"], f["bo"]), 0.0
    return L.swiglu_mlp(h, f["w_gate"], f["w_in"], f["w_out"]), 0.0


def _attn_block(h, p, cfg: ModelConfig, positions, *, causal, window,
                kv_override=None, want_cache=False):
    b, s, d = h.shape
    q = (h @ p["q_proj"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    kv_cache = None
    if kv_override is None:
        k = (h @ p["k_proj"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ p["v_proj"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        if cfg.use_rope:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        if want_cache:
            t = s if window is None else min(s, window)
            kv_cache = {"k": k[:, s - t:], "v": v[:, s - t:]}
    else:
        k, v = kv_override  # cross-attention: precomputed encoder k/v
    out = L.attention(q, k, v, causal=causal and kv_override is None,
                      window=window)
    out = out.reshape(b, s, cfg.q_dim)
    return out @ p["o_proj"], kv_cache


def _apply_layer(h, p, cfg: ModelConfig, kind: tuple[str, str], positions,
                 *, causal=True, enc_kv=None, want_cache=False):
    """Full-sequence layer application (train / prefill).

    Returns (h, aux_loss, cache_contribution-or-None).
    """
    from jax.ad_checkpoint import checkpoint_name

    mixer, ffn = kind
    aux = 0.0
    lb = p.get("ln1_b")
    cache = None
    if mixer == "attn":
        a, cache = _attn_block(
            _norm(h, p["ln1"], lb, cfg.norm_eps), p["attn"], cfg,
            positions, causal=causal, window=cfg.sliding_window,
            want_cache=want_cache)
        a = checkpoint_name(a, "mixer_out")
        h = h + a
    elif mixer == "mamba":
        c0 = (mamba_lib.init_mamba_cache(cfg, h.shape[0], h.dtype)
              if want_cache else None)
        a, cache = mamba_lib.mamba_forward(
            _norm(h, p["ln1"], lb, cfg.norm_eps), p["mamba"], cfg, c0)
        h = h + a
    elif mixer == "rwkv":
        c0 = (rwkv_lib.init_rwkv_cache(cfg, h.shape[0], h.dtype)
              if want_cache else None)
        a, c1 = rwkv_lib.rwkv_time_mix(
            _norm(h, p["ln1"], lb, cfg.norm_eps), p["tm"], cfg, c0)
        h = h + a
        c, c2 = rwkv_lib.rwkv_channel_mix(
            _norm(h, p["ln2"], None, cfg.norm_eps), p["cm"], cfg, c0)
        if want_cache:
            cache = {"shift_tm": c1["shift_tm"], "wkv": c1["wkv"],
                     "shift_cm": c2["shift_cm"]}
        return h + c, aux, cache
    if enc_kv is not None and "cross" in p:
        ca, _ = _attn_block(_norm(h, p["ln_cross"], p.get("ln_cross_b"),
                                  cfg.norm_eps), p["cross"], cfg, positions,
                            causal=False, window=None, kv_override=enc_kv)
        h = h + ca
    f, aux = _apply_ffn(_norm(h, p["ln2"], p.get("ln2_b"), cfg.norm_eps),
                        p, cfg, ffn)
    f = checkpoint_name(f, "ffn_out")
    return h + f, aux, cache


def _run_stack(h, blocks, cfg: ModelConfig, kinds, period, positions, *,
               causal=True, enc_kv=None, remat: str = "none",
               want_cache=False):
    """lax.scan over pattern repetitions; returns (h, total_aux, caches).

    With ``want_cache`` the per-layer cache contributions come out as scan
    ``ys`` — already stacked (n_periods, ...), the decode-cache layout.
    """

    def body(carry, blk):
        hh, aux = carry
        caches = {}
        for j in range(period):
            hh, a, c = _apply_layer(hh, blk[f"pos{j}"], cfg, kinds[j],
                                    positions, causal=causal, enc_kv=enc_kv,
                                    want_cache=want_cache)
            aux = aux + a
            if want_cache:
                caches[f"pos{j}"] = c
        hh = shard(hh, "batch", None, None)
        return (hh, aux), (caches if want_cache else None)

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    elif remat == "outputs":
        # Save each sub-block's post-collective output: the backward pass
        # reuses them instead of recomputing the TP all-reduces (collective
        # term down ~1/3, memory term up — the §Perf remat trade).
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.save_only_these_names(
                "mixer_out", "ffn_out"))
    (h, aux), caches = jax.lax.scan(
        body, (h, jnp.zeros((), jnp.float32)), blocks)
    return h, aux, caches


# --------------------------------------------------------------------------
# Forward (train / prefill) and loss
# --------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "audio":
        pos = L.sinusoidal_positions(jnp.arange(tokens.shape[1]), cfg.d_model)
        e = e + pos[None].astype(e.dtype)
    return shard(e, "batch", None, None)


def _lm_head(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (h @ w).astype(jnp.float32)
    logits = shard(logits, "batch", None, "model")
    if logits.shape[-1] != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits


def _encode(params, cfg: ModelConfig, frames, remat="none"):
    """Whisper-style encoder over stub frame embeddings (B, S, D)."""
    pos = L.sinusoidal_positions(jnp.arange(frames.shape[1]), cfg.d_model)
    h = frames + pos[None].astype(frames.dtype)
    kinds = [("attn", "dense")] * cfg.n_encoder_layers
    h, _, _ = _run_stack(h, params["encoder"]["blocks"], cfg, kinds, 1,
                         jnp.arange(frames.shape[1])[None], causal=False,
                         remat=remat)
    return _norm(h, params["encoder"]["ln_f"], params["encoder"]["ln_f_b"],
                 cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: dict, *, training: bool,
            remat: str = "none"):
    """Full-sequence forward.  Returns (logits, aux_loss).

    ``batch`` keys: 'tokens' (B, S); VLM: + 'vision_embeds' (B, Sv, D);
    audio: 'frames' (B, Se, D) + 'tokens' (B, Sd).
    """
    tokens = batch["tokens"]
    h = _embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        vis = batch["vision_embeds"].astype(h.dtype)
        h = jnp.concatenate([vis, h], axis=1)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)

    enc_kv = None
    if cfg.encoder_decoder:
        enc_out = _encode(params, cfg, batch["frames"], remat=remat)
        # Cross K/V are recomputed inside each scanned layer from enc_out
        # (cheaper to re-project than to stack T_enc·L activations).
        enc_kv = enc_out

    if enc_kv is not None:
        h, aux = _run_stack_crossattn(h, params["blocks"], cfg, kinds, period,
                                      positions, enc_out=enc_kv, remat=remat)
    else:
        h, aux, _ = _run_stack(h, params["blocks"], cfg, kinds, period,
                               positions, causal=True, remat=remat)
    h = _norm(h, params["ln_f"], params.get("ln_f_b"), cfg.norm_eps)
    if cfg.family == "vlm":
        h = h[:, batch["vision_embeds"].shape[1]:]
    return _lm_head(params, cfg, h), aux


def prefill(params, cfg: ModelConfig, batch: dict, pad_to: int | None = None):
    """Serving prefill: full-sequence pass -> (last-token logits, cache).

    The decode cache comes out of the layer scan as stacked ``ys`` — KV for
    attention layers (ring-truncated for SWA), final conv/SSM/WKV states for
    Mamba/RWKV layers — plus the position index, matching ``init_cache``.

    ``pad_to`` grows full-attention KV caches beyond the prompt length so
    subsequent decode steps have slots to write into (SWA caches are ring
    buffers of size ``window`` and are never padded; ring alignment requires
    ``prompt_len % window == 0``, which all assigned shapes satisfy).
    """
    if cfg.encoder_decoder:
        # Audio prefill = encoder forward (DESIGN.md shape mapping).
        enc = _encode(params, cfg, batch["frames"])
        return enc, None
    tokens = batch["tokens"]
    h = _embed_tokens(params, cfg, tokens)
    if cfg.family == "vlm":
        h = jnp.concatenate([batch["vision_embeds"].astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)
    h, _, caches = _run_stack(h, params["blocks"], cfg, kinds, period,
                              positions, causal=True, want_cache=True)
    if pad_to is not None and cfg.sliding_window is None:
        def grow(path, leaf):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name in ("k", "v") and leaf.shape[2] < pad_to:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, pad_to - leaf.shape[2])
                return jnp.pad(leaf, pad)
            return leaf
        caches = jax.tree_util.tree_map_with_path(grow, caches)
    h = _norm(h[:, -1:], params["ln_f"], params.get("ln_f_b"), cfg.norm_eps)
    caches["index"] = jnp.asarray(s, jnp.int32)
    return _lm_head(params, cfg, h), caches


def _run_stack_crossattn(h, blocks, cfg, kinds, period, positions, *,
                         enc_out, remat):
    def body(carry, blk):
        hh, aux = carry
        for j in range(period):
            p = blk[f"pos{j}"]
            b, t = enc_out.shape[0], enc_out.shape[1]
            k = (enc_out @ p["cross"]["k_proj"]).reshape(
                b, t, cfg.n_kv_heads, cfg.head_dim)
            v = (enc_out @ p["cross"]["v_proj"]).reshape(
                b, t, cfg.n_kv_heads, cfg.head_dim)
            hh, a, _ = _apply_layer(hh, p, cfg, kinds[j], positions,
                                    causal=True, enc_kv=(k, v))
            aux = aux + a
        return (hh, aux), None

    if remat == "full":
        body = jax.checkpoint(body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), blocks)
    return h, aux


def loss_fn(params, cfg: ModelConfig, batch: dict, *, training: bool = True,
            remat: str = "none", aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch, training=training, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        nll = nll * mask
        loss = nll.sum() / jnp.maximum(mask.sum(), 1)
    else:
        loss = nll.mean()
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


# --------------------------------------------------------------------------
# Decode (serving)
# --------------------------------------------------------------------------


def _attn_cache_init(cfg: ModelConfig, batch: int, seq: int, dtype) -> dict:
    t = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, t, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Decode cache pytree: stacked per pattern repetition (for scan)."""
    dtype = L.resolve_dtype(cfg.dtype)
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)
    n_periods = cfg.n_layers // period

    def one(kind):
        mixer, _ = kind
        if mixer == "attn":
            c = _attn_cache_init(cfg, batch, seq, dtype)
        elif mixer == "mamba":
            c = mamba_lib.init_mamba_cache(cfg, batch, dtype)
        else:
            c = rwkv_lib.init_rwkv_cache(cfg, batch, dtype)
        return c

    def stack(c):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape), c)

    cache: dict[str, Any] = {
        f"pos{j}": stack(one(kinds[j])) for j in range(period)
    }
    cache["index"] = jnp.zeros((), jnp.int32)
    if cfg.encoder_decoder:
        cache["cross"] = {
            "pos0": jax.tree.map(
                lambda x: jnp.zeros(
                    (n_periods, batch, cfg.encoder_context_len,
                     cfg.n_kv_heads, cfg.head_dim), dtype),
                {"k": 0, "v": 0}),
        }
    return cache


def _attn_decode(h, p, cfg: ModelConfig, cache, index, cross_kv=None):
    """One-token attention with cache write.  h: (B, 1, D)."""
    b = h.shape[0]
    q = (h @ p["q_proj"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = (h @ p["k_proj"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ p["v_proj"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    pos = jnp.broadcast_to(index[None, None], (b, 1))
    if cfg.use_rope:
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
    t = cache["k"].shape[1]
    write_at = index % t  # ring buffer for SWA; plain index otherwise
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, write_at, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, write_at, 0, 0))
    cache_len = jnp.minimum(index + 1, t)
    out = L.decode_attention(q, k_cache, v_cache, cache_len)
    out = out.reshape(b, 1, cfg.q_dim) @ p["o_proj"]
    return out, {"k": k_cache, "v": v_cache}


def _decode_layer(h, p, cfg: ModelConfig, kind, cache, index, cross_kv=None):
    mixer, ffn = kind
    lb = p.get("ln1_b")
    if mixer == "attn":
        a, new_c = _attn_decode(_norm(h, p["ln1"], lb, cfg.norm_eps),
                                p["attn"], cfg, cache, index)
        h = h + a
    elif mixer == "mamba":
        a, new_c = mamba_lib.mamba_decode_step(
            _norm(h, p["ln1"], lb, cfg.norm_eps), p["mamba"], cfg, cache)
        h = h + a
    else:  # rwkv
        a, c1 = rwkv_lib.rwkv_time_mix(
            _norm(h, p["ln1"], lb, cfg.norm_eps), p["tm"], cfg, cache)
        h = h + a
        c, c2 = rwkv_lib.rwkv_channel_mix(
            _norm(h, p["ln2"], None, cfg.norm_eps), p["cm"], cfg, cache)
        new_c = {**c1, **c2, "wkv": c1["wkv"]}
        return h + c, new_c
    if cross_kv is not None and "cross" in p:
        b = h.shape[0]
        q = (_norm(h, p["ln_cross"], p.get("ln_cross_b"), cfg.norm_eps)
             @ p["cross"]["q_proj"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        t_enc = cross_kv["k"].shape[1]
        ca = L.decode_attention(q, cross_kv["k"], cross_kv["v"],
                                jnp.asarray(t_enc, jnp.int32))
        h = h + ca.reshape(b, 1, cfg.q_dim) @ p["cross"]["o_proj"]
    f, _ = _apply_ffn(_norm(h, p["ln2"], p.get("ln2_b"), cfg.norm_eps),
                      p, cfg, ffn)
    return h + f, new_c


def decode_step(params, cfg: ModelConfig, cache: dict, batch: dict):
    """One token for every sequence.  batch: {'tokens': (B, 1)}.

    Returns (logits (B, 1, V), new_cache).
    """
    tokens = batch["tokens"]
    h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "audio":
        pe = L.sinusoidal_positions(cache["index"][None], cfg.d_model)
        h = h + pe[None].astype(h.dtype)
    h = shard(h, "batch", None, None)
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)
    index = cache["index"]

    def body(hh, xs):
        blk, ccs = xs[0], xs[1]
        cross = xs[2] if len(xs) > 2 else None
        new_ccs = {}
        for j in range(period):
            ck = f"pos{j}"
            cross_kv = cross["pos0"] if cross is not None else None
            hh, nc = _decode_layer(hh, blk[ck], cfg, kinds[j], ccs[ck], index,
                                   cross_kv=cross_kv)
            new_ccs[ck] = nc
        return hh, new_ccs

    layer_caches = {k: v for k, v in cache.items() if k.startswith("pos")}
    xs = (params["blocks"], layer_caches)
    if cfg.encoder_decoder:
        xs = xs + (cache["cross"],)
    h, new_layer_caches = jax.lax.scan(body, h, xs)
    h = _norm(h, params["ln_f"], params.get("ln_f_b"), cfg.norm_eps)
    logits = _lm_head(params, cfg, h)
    new_cache = dict(cache)
    new_cache.update(new_layer_caches)
    new_cache["index"] = index + 1
    return logits, new_cache
