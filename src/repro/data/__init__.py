"""Synthetic data + checkpointable input pipeline."""
from repro.data.pipeline import (  # noqa: F401
    DataIterator,
    image_iterator,
    jpeg_file_iterator,
    jpeg_iterator,
    list_jpeg_files,
    prefetch,
    token_iterator,
)
