"""Synthetic data + checkpointable input pipeline."""
from repro.data.pipeline import (  # noqa: F401
    DataIterator,
    image_iterator,
    jpeg_iterator,
    prefetch,
    token_iterator,
)
