"""Deterministic synthetic corpora (offline container — no downloads).

* Token streams: zipfian unigrams with injected bigram structure so a small
  LM can visibly learn (loss drops below unigram entropy).
* Image corpora: frequency-shaped Gaussian fields (power-law spectra per
  class) whose statistics resemble natural images — the paper's Fig. 4a
  notes fully-random blocks are a DCT worst case, so class-dependent
  low-frequency structure makes classification learnable and keeps DCT
  energy compaction realistic.

Everything is a pure function of (seed, index): infinitely re-playable and
exactly resumable from an iterator checkpoint.
"""
from __future__ import annotations

import numpy as np

__all__ = ["token_batch", "image_batch", "unigram_entropy"]


def _rng(seed: int, index: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, index]))


def token_batch(seed: int, index: int, batch: int, seq_len: int,
                vocab: int) -> dict[str, np.ndarray]:
    """Returns {'tokens': (B, S+1) int32} — shift for inputs/labels."""
    rng = _rng(seed, index)
    v = max(vocab - 2, 2)
    # zipf-ish unigram distribution over the vocab
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    toks = rng.choice(v, size=(batch, seq_len + 1), p=probs).astype(np.int32)
    # deterministic bigram structure: after token t comes (t*7+3) % v
    # with probability 1/2 — a learnable signal.  Applied sequentially so
    # the relation holds against the *final* previous token.
    mask = rng.random((batch, seq_len)) < 0.5
    for t in range(seq_len):
        follow = (toks[:, t] * 7 + 3) % v
        toks[:, t + 1] = np.where(mask[:, t], follow, toks[:, t + 1])
    return {"tokens": toks}


def unigram_entropy(vocab: int) -> float:
    v = max(vocab - 2, 2)
    p = 1.0 / np.arange(1, v + 1)
    p /= p.sum()
    return float(-(p * np.log(p)).sum())


def image_batch(seed: int, index: int, batch: int, size: int,
                channels: int = 3, num_classes: int = 10) -> dict[str, np.ndarray]:
    """Returns {'images': (B, C, H, W) f32 in ~[-1,1], 'labels': (B,) i32}.

    Class y tilts the power spectrum (exponent 1 + y/num_classes) and adds a
    class-specific low-frequency template, so labels are recoverable from
    low frequencies — matching the JPEG energy-compaction regime.
    """
    rng = _rng(seed, index)
    labels = rng.integers(0, num_classes, size=(batch,)).astype(np.int32)
    fy = np.fft.fftfreq(size)[:, None]
    fx = np.fft.fftfreq(size)[None, :]
    rad = np.sqrt(fy * fy + fx * fx) + 1.0 / size
    # class templates are a global constant (independent of the data seed):
    # train and eval splits must share the class structure.
    template_rng = np.random.default_rng(np.random.SeedSequence([7777]))
    templates = template_rng.normal(size=(num_classes, channels, 4, 4)).astype(np.float32)
    images = np.empty((batch, channels, size, size), np.float32)
    for i in range(batch):
        y = int(labels[i])
        expo = 1.0 + y / max(num_classes, 1)
        spec = rng.normal(size=(channels, size, size)) + 1j * rng.normal(size=(channels, size, size))
        spec *= rad[None] ** (-expo)
        img = np.real(np.fft.ifft2(spec, axes=(-2, -1)))
        img /= (np.abs(img).max(axis=(-1, -2), keepdims=True) + 1e-8)
        tpl = np.kron(templates[y], np.ones((size // 4, size // 4), np.float32))
        images[i] = 0.6 * img + 0.4 * np.tanh(tpl)
    return {"images": images, "labels": labels}
