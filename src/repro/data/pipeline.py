"""Checkpointable, shardable input pipeline.

``DataIterator`` is a pure function of (seed, step): its checkpoint state is
two integers, giving exactly-once semantics across restarts and *elastic*
re-sharding (a restarted job with a different data-parallel size replays
from the same global step).  ``prefetch`` overlaps host batch synthesis
with device compute via a background thread.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.data import synthetic
from repro.core import jpeg as jpeglib

__all__ = ["DataIterator", "token_iterator", "image_iterator", "jpeg_iterator",
           "prefetch"]


@dataclass
class DataIterator:
    """Stateful wrapper over a pure (seed, index) -> batch function."""

    fn: Callable[[int, int], dict[str, np.ndarray]]
    seed: int
    step: int = 0

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        batch = self.fn(self.seed, self.step)
        self.step += 1
        return batch

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, state: dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])


def token_iterator(seed: int, batch: int, seq_len: int, vocab: int) -> DataIterator:
    def fn(s, i):
        b = synthetic.token_batch(s, i, batch, seq_len, vocab)
        toks = b["tokens"]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return DataIterator(fn, seed)


def image_iterator(seed: int, batch: int, size: int, channels: int = 3,
                   num_classes: int = 10) -> DataIterator:
    def fn(s, i):
        return synthetic.image_batch(s, i, batch, size, channels, num_classes)
    return DataIterator(fn, seed)


def jpeg_iterator(seed: int, batch: int, size: int, channels: int = 3,
                  num_classes: int = 10, quality: int = 50,
                  lossy: bool = False) -> DataIterator:
    """Images pre-encoded to step-4 JPEG coefficients (N, bh, bw, C, 64).

    ``lossy=True`` applies step-5 rounding — the real-data regime; the
    paper's parity experiments use lossless coefficients.
    """
    def fn(s, i):
        b = synthetic.image_batch(s, i, batch, size, channels, num_classes)
        coef = jpeglib.jpeg_encode(b["images"], quality=quality, scaled=True)
        if lossy:
            coef = np.round(coef)
        coef = np.moveaxis(np.asarray(coef, np.float32), 1, 3)
        return {"coefficients": coef, "labels": b["labels"]}
    return DataIterator(fn, seed)


def prefetch(it: Iterator[Any], depth: int = 2) -> Iterator[Any]:
    """Background-thread prefetch — overlaps host data synthesis with step."""
    q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
    sentinel = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(sentinel)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is sentinel:
            return
        yield item
