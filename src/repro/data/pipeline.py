"""Checkpointable, shardable input pipeline.

``DataIterator`` is a pure function of (seed, step): its checkpoint state is
two integers, giving exactly-once semantics across restarts and *elastic*
re-sharding (a restarted job with a different data-parallel size replays
from the same global step).  This holds for the real-file iterator too:
``jpeg_file_iterator`` samples from a *frozen* file list, so (seed, step)
fully determines a batch as long as the files themselves are immutable.
``prefetch`` overlaps host batch synthesis with device compute via a
background thread (joined and drained on close — no leaked producers).
"""
from __future__ import annotations

import os
import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Sequence

import jax
import numpy as np

from repro.data import synthetic
from repro.core import jpeg as jpeglib

__all__ = ["DataIterator", "token_iterator", "image_iterator", "jpeg_iterator",
           "jpeg_file_iterator", "list_jpeg_files", "prefetch"]


@dataclass
class DataIterator:
    """Stateful wrapper over a pure (seed, index) -> batch function."""

    fn: Callable[[int, int], dict[str, np.ndarray]]
    seed: int
    step: int = 0

    def __iter__(self) -> "DataIterator":
        return self

    def __next__(self) -> dict[str, np.ndarray]:
        batch = self.fn(self.seed, self.step)
        self.step += 1
        return batch

    # -- checkpointing ----------------------------------------------------
    def state_dict(self) -> dict[str, int]:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, state: dict[str, int]) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])


def token_iterator(seed: int, batch: int, seq_len: int, vocab: int) -> DataIterator:
    def fn(s, i):
        b = synthetic.token_batch(s, i, batch, seq_len, vocab)
        toks = b["tokens"]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return DataIterator(fn, seed)


def image_iterator(seed: int, batch: int, size: int, channels: int = 3,
                   num_classes: int = 10) -> DataIterator:
    def fn(s, i):
        return synthetic.image_batch(s, i, batch, size, channels, num_classes)
    return DataIterator(fn, seed)


def jpeg_iterator(seed: int, batch: int, size: int, channels: int = 3,
                  num_classes: int = 10, quality: int = 50,
                  lossy: bool = False) -> DataIterator:
    """Images pre-encoded to step-4 JPEG coefficients (N, bh, bw, C, 64).

    ``lossy=True`` applies step-5 rounding — the real-data regime; the
    paper's parity experiments use lossless coefficients.
    """
    def fn(s, i):
        b = synthetic.image_batch(s, i, batch, size, channels, num_classes)
        coef = jpeglib.jpeg_encode(b["images"], quality=quality, scaled=True)
        if lossy:
            coef = np.round(coef)
        coef = np.moveaxis(np.asarray(coef, np.float32), 1, 3)
        return {"coefficients": coef, "labels": b["labels"]}
    return DataIterator(fn, seed)


def list_jpeg_files(directory: str) -> list[str]:
    """Sorted JPEG paths under ``directory`` (recursive) — sorted so the
    list, and therefore every (seed, step) batch, is reproducible."""
    out = []
    for root, _, names in os.walk(directory):
        for name in names:
            if name.lower().endswith((".jpg", ".jpeg", ".jfif")):
                out.append(os.path.join(root, name))
    return sorted(out)


def jpeg_file_iterator(paths: Sequence[str] | str, batch: int, *,
                       grid: tuple[int, int], channels: int = 3,
                       quality: int = 50, seed: int = 0,
                       label_fn: Callable[[str], int] | None = None,
                       pack_width: int | None = None) -> DataIterator:
    """Real JPEG files → canonical network coefficients, checkpointably.

    ``paths`` is a directory (walked once, sorted) or an explicit
    sequence; each batch samples ``batch`` files with the same pure
    (seed, step) semantics as the synthetic iterators — the checkpoint
    state stays two integers, and a restarted job replays the exact
    batch.  Files go through the full codec ingest (entropy decode →
    per-image quantization normalization → ``grid`` fit); no pixels are
    materialised.  ``label_fn`` maps a path to its class id (default −1:
    unlabeled serving traffic); ``pack_width`` emits the tile-packed
    ``(N, bh, bw, C·w)`` layout instead of ``(N, bh, bw, C, 64)``.
    """
    from repro.codec import ingest as ingestlib

    if isinstance(paths, str):
        paths = list_jpeg_files(paths)
    paths = list(paths)
    if not paths:
        raise ValueError("jpeg_file_iterator: no files")

    def fn(s, i):
        rng = synthetic._rng(s, i)
        idx = rng.integers(0, len(paths), size=batch)
        datas = []
        for j in idx:
            with open(paths[j], "rb") as f:
                datas.append(f.read())
        coef, _ = ingestlib.ingest_batch(
            datas, quality=quality, grid=grid, channels=channels,
            pack_width=pack_width, with_stats=False)
        labels = np.asarray([label_fn(paths[j]) if label_fn else -1
                             for j in idx], np.int32)
        return {"coefficients": coef, "labels": labels}

    return DataIterator(fn, seed)


def prefetch(it: Iterator[Any], depth: int = 2) -> Iterator[Any]:
    """Background-thread prefetch — overlaps host data synthesis with step.

    The producer thread is *owned* by the generator: closing it early
    (``close()``, ``break``, an exception in the consumer) or exhausting
    it joins the thread and drains the queue, so no producer outlives its
    consumer and no batch is left pinned in the queue.  An exception in
    the source iterator is re-raised at the consumer's next pull instead
    of killing the thread silently.
    """
    q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
    stop = threading.Event()
    sentinel = object()

    def worker():
        try:
            for item in it:
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                if stop.is_set():
                    return
            _put_final(sentinel)
        except BaseException as e:  # re-raised on the consumer side
            _put_final(e)

    def _put_final(item):
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            item = q.get()
            if item is sentinel:
                return
            if isinstance(item, BaseException):
                raise item
            yield item
    finally:
        stop.set()
        while t.is_alive():
            try:  # unblock a producer stuck on a full queue
                q.get_nowait()
            except queue.Empty:
                pass
            t.join(timeout=0.05)
        t.join()
