"""Baseline JFIF entropy encoder — the bit-exact twin of ``codec.bitstream``.

Takes per-component quantized zigzag coefficient tensors (the same
integers :func:`codec.bitstream.decode_jpeg` produces) and emits a
spec-conformant baseline JFIF byte string: SOI, APP0, DQT, SOF0, DHT
(the ISO/IEC 10918-1 Annex K "typical" Huffman tables), optional DRI,
SOS with DC prediction / run-length / Huffman coding, EOI.

Round trip: ``decode_jpeg(encode_baseline(...))`` returns the input
coefficients **bit-exactly** (entropy coding is lossless), which is what
the codec conformance tests lean on; third-party decoders (libjpeg/PIL)
accept the output, which is what pins the bitstream format itself.

Value range: the Annex K tables cover DC difference size categories up to
11 and AC size categories up to 10, exactly the range reachable from
8-bit samples (|AC| ≤ 1023, |DC diff| ≤ 2047).  Out-of-range inputs raise
rather than emitting an undecodable stream.
"""
from __future__ import annotations

import numpy as np

from repro.core import dct as dctlib
from repro.codec import bitstream as bs

__all__ = ["encode_baseline", "encode_pixels", "quantize_pixels",
           "STD_HUFFMAN"]


# ISO/IEC 10918-1 Annex K.3 typical Huffman tables: (counts[16], symbols).
_STD = {
    # K.3.1 luminance DC
    ("dc", 0): ([0, 1, 5, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0],
                list(range(12))),
    # K.3.2 chrominance DC
    ("dc", 1): ([0, 3, 1, 1, 1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0],
                list(range(12))),
    # K.3.3.1 luminance AC
    ("ac", 0): ([0, 2, 1, 3, 3, 2, 4, 3, 5, 5, 4, 4, 0, 0, 1, 0x7D],
                [0x01, 0x02, 0x03, 0x00, 0x04, 0x11, 0x05, 0x12,
                 0x21, 0x31, 0x41, 0x06, 0x13, 0x51, 0x61, 0x07,
                 0x22, 0x71, 0x14, 0x32, 0x81, 0x91, 0xA1, 0x08,
                 0x23, 0x42, 0xB1, 0xC1, 0x15, 0x52, 0xD1, 0xF0,
                 0x24, 0x33, 0x62, 0x72, 0x82, 0x09, 0x0A, 0x16,
                 0x17, 0x18, 0x19, 0x1A, 0x25, 0x26, 0x27, 0x28,
                 0x29, 0x2A, 0x34, 0x35, 0x36, 0x37, 0x38, 0x39,
                 0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48, 0x49,
                 0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58, 0x59,
                 0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68, 0x69,
                 0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78, 0x79,
                 0x7A, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89,
                 0x8A, 0x92, 0x93, 0x94, 0x95, 0x96, 0x97, 0x98,
                 0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5, 0xA6, 0xA7,
                 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4, 0xB5, 0xB6,
                 0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3, 0xC4, 0xC5,
                 0xC6, 0xC7, 0xC8, 0xC9, 0xCA, 0xD2, 0xD3, 0xD4,
                 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA, 0xE1, 0xE2,
                 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9, 0xEA,
                 0xF1, 0xF2, 0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8,
                 0xF9, 0xFA]),
    # K.3.3.2 chrominance AC
    ("ac", 1): ([0, 2, 1, 2, 4, 4, 3, 4, 7, 5, 4, 4, 0, 1, 2, 0x77],
                [0x00, 0x01, 0x02, 0x03, 0x11, 0x04, 0x05, 0x21,
                 0x31, 0x06, 0x12, 0x41, 0x51, 0x07, 0x61, 0x71,
                 0x13, 0x22, 0x32, 0x81, 0x08, 0x14, 0x42, 0x91,
                 0xA1, 0xB1, 0xC1, 0x09, 0x23, 0x33, 0x52, 0xF0,
                 0x15, 0x62, 0x72, 0xD1, 0x0A, 0x16, 0x24, 0x34,
                 0xE1, 0x25, 0xF1, 0x17, 0x18, 0x19, 0x1A, 0x26,
                 0x27, 0x28, 0x29, 0x2A, 0x35, 0x36, 0x37, 0x38,
                 0x39, 0x3A, 0x43, 0x44, 0x45, 0x46, 0x47, 0x48,
                 0x49, 0x4A, 0x53, 0x54, 0x55, 0x56, 0x57, 0x58,
                 0x59, 0x5A, 0x63, 0x64, 0x65, 0x66, 0x67, 0x68,
                 0x69, 0x6A, 0x73, 0x74, 0x75, 0x76, 0x77, 0x78,
                 0x79, 0x7A, 0x82, 0x83, 0x84, 0x85, 0x86, 0x87,
                 0x88, 0x89, 0x8A, 0x92, 0x93, 0x94, 0x95, 0x96,
                 0x97, 0x98, 0x99, 0x9A, 0xA2, 0xA3, 0xA4, 0xA5,
                 0xA6, 0xA7, 0xA8, 0xA9, 0xAA, 0xB2, 0xB3, 0xB4,
                 0xB5, 0xB6, 0xB7, 0xB8, 0xB9, 0xBA, 0xC2, 0xC3,
                 0xC4, 0xC5, 0xC6, 0xC7, 0xC8, 0xC9, 0xCA, 0xD2,
                 0xD3, 0xD4, 0xD5, 0xD6, 0xD7, 0xD8, 0xD9, 0xDA,
                 0xE2, 0xE3, 0xE4, 0xE5, 0xE6, 0xE7, 0xE8, 0xE9,
                 0xEA, 0xF2, 0xF3, 0xF4, 0xF5, 0xF6, 0xF7, 0xF8,
                 0xF9, 0xFA]),
}

#: (counts, symbols) per (kind, class) — exported so tests can build
#: decoder LUTs from the exact tables the encoder writes.
STD_HUFFMAN = {k: (np.asarray(c, np.uint8), np.asarray(s, np.uint8))
               for k, (c, s) in _STD.items()}


def _code_map(kind: str, cls: int) -> dict[int, tuple[int, int]]:
    """symbol -> (code, length) for a standard table (canonical codes)."""
    counts, symbols = STD_HUFFMAN[(kind, cls)]
    out: dict[int, tuple[int, int]] = {}
    code, si = 0, 0
    for length in range(1, 17):
        for _ in range(int(counts[length - 1])):
            out[int(symbols[si])] = (code, length)
            si += 1
            code += 1
        code <<= 1
    return out


class _BitWriter:
    """MSB-first bit accumulator with JPEG 0xFF byte stuffing."""

    __slots__ = ("out", "acc", "nbits")

    def __init__(self) -> None:
        self.out = bytearray()
        self.acc = 0
        self.nbits = 0

    def put(self, value: int, length: int) -> None:
        if length == 0:
            return
        self.acc = (self.acc << length) | (value & ((1 << length) - 1))
        self.nbits += length
        while self.nbits >= 8:
            self.nbits -= 8
            byte = (self.acc >> self.nbits) & 0xFF
            self.out.append(byte)
            if byte == 0xFF:
                self.out.append(0x00)
        self.acc &= (1 << self.nbits) - 1

    def flush(self) -> bytes:
        if self.nbits:
            pad = 8 - self.nbits
            self.put((1 << pad) - 1, pad)  # pad with 1-bits (spec §F.1.2.3)
        return bytes(self.out)


def _size_category(v: int) -> int:
    return int(v).bit_length() if v >= 0 else int(-v).bit_length()


def _value_bits(v: int, s: int) -> int:
    """Inverse of EXTEND: the low ``s`` bits that encode signed ``v``."""
    return v if v >= 0 else v + (1 << s) - 1


def _encode_block(w: _BitWriter, zz: np.ndarray, pred: int,
                  dc_map, ac_map) -> int:
    diff = int(zz[0]) - pred
    s = _size_category(diff)
    if s > 11:
        raise ValueError(f"DC difference {diff} exceeds size category 11")
    code, length = dc_map[s]
    w.put(code, length)
    w.put(_value_bits(diff, s), s)
    run = 0
    last = int(np.max(np.nonzero(zz)[0])) if np.any(zz[1:]) else 0
    for k in range(1, dctlib.NFREQ):
        v = int(zz[k])
        if v == 0:
            run += 1
            continue
        while run > 15:
            code, length = ac_map[0xF0]  # ZRL
            w.put(code, length)
            run -= 16
        s = _size_category(v)
        if s > 10:
            raise ValueError(f"AC coefficient {v} exceeds size category 10")
        code, length = ac_map[(run << 4) | s]
        w.put(code, length)
        w.put(_value_bits(v, s), s)
        run = 0
    if last < dctlib.NFREQ - 1:
        code, length = ac_map[0x00]  # EOB
        w.put(code, length)
    return int(zz[0])


def _seg(marker: int, payload: bytes) -> bytes:
    return bytes([0xFF, marker]) + (len(payload) + 2).to_bytes(2, "big") \
        + payload


def encode_baseline(
    components: list[np.ndarray],
    qtables: list[np.ndarray],
    *,
    width: int | None = None,
    height: int | None = None,
    sampling: list[tuple[int, int]] | None = None,
    restart_interval: int = 0,
) -> bytes:
    """Entropy-encode quantized zigzag coefficients into baseline JFIF bytes.

    ``components[i]`` is ``(blocks_y, blocks_x, 64)`` integer zigzag
    coefficients on component ``i``'s sampling grid; ``qtables[i]`` its
    zigzag quantization vector (integer 1..65535; values > 255 use 16-bit
    DQT precision).  Component 0 is coded with the luminance Annex K
    tables, the rest with the chrominance ones.  ``sampling`` gives
    per-component (h, v) factors (default all (1, 1) = 4:4:4); grids must
    be full-MCU multiples of them.  ``width``/``height`` default to the
    full coefficient grid in pixels.
    """
    ncomp = len(components)
    if ncomp not in (1, 3):
        raise ValueError(f"1 or 3 components, got {ncomp}")
    if len(qtables) != ncomp:
        raise ValueError("need one quantization table per component")
    sampling = sampling or [(1, 1)] * ncomp
    hmax = max(h for h, _ in sampling)
    vmax = max(v for _, v in sampling)
    comps = [np.asarray(c) for c in components]
    for i, (c, (h, v)) in enumerate(zip(comps, sampling)):
        if c.ndim != 3 or c.shape[-1] != dctlib.NFREQ:
            raise ValueError(f"component {i}: want (by, bx, 64), "
                             f"got {c.shape}")
        if c.shape[0] % v or c.shape[1] % h:
            raise ValueError(f"component {i}: grid {c.shape[:2]} not a "
                             f"multiple of sampling ({v}, {h})")
    mcuy = comps[0].shape[0] // sampling[0][1]
    mcux = comps[0].shape[1] // sampling[0][0]
    for i, (c, (h, v)) in enumerate(zip(comps, sampling)):
        if (c.shape[0] // v, c.shape[1] // h) != (mcuy, mcux):
            raise ValueError(f"component {i}: MCU grid mismatch")
    if height is None:
        height = mcuy * vmax * dctlib.BLOCK
    if width is None:
        width = mcux * hmax * dctlib.BLOCK

    out = bytearray(b"\xff\xd8")  # SOI
    out += _seg(0xE0, b"JFIF\x00\x01\x01\x00\x00\x01\x00\x01\x00\x00")

    # DQT — dedupe identical tables; 16-bit precision when needed
    table_ids: list[int] = []
    seen: list[np.ndarray] = []
    for q in qtables:
        q = np.asarray(q, np.int64).reshape(dctlib.NFREQ)
        if np.any(q < 1) or np.any(q > 65535):
            raise ValueError("quantization entries must be in [1, 65535]")
        for tid, prev in enumerate(seen):
            if np.array_equal(prev, q):
                table_ids.append(tid)
                break
        else:
            table_ids.append(len(seen))
            seen.append(q)
    for tid, q in enumerate(seen):
        if q.max() > 255:
            body = bytes([0x10 | tid]) + b"".join(
                int(v).to_bytes(2, "big") for v in q)
        else:
            body = bytes([tid]) + bytes(int(v) for v in q)
        out += _seg(bs.DQT, body)

    # SOF0
    sof = bytearray([8])
    sof += int(height).to_bytes(2, "big") + int(width).to_bytes(2, "big")
    sof.append(ncomp)
    for i, (h, v) in enumerate(sampling):
        sof += bytes([i + 1, (h << 4) | v, table_ids[i]])
    out += _seg(bs.SOF0, sof)

    # DHT — the Annex K tables actually used
    classes = [0] if ncomp == 1 else [0, 1]
    for cls in classes:
        for tc, kind in ((0, "dc"), (1, "ac")):
            counts, symbols = STD_HUFFMAN[(kind, cls)]
            out += _seg(bs.DHT, bytes([(tc << 4) | cls]) + bytes(counts)
                        + bytes(symbols))

    if restart_interval:
        out += _seg(bs.DRI, int(restart_interval).to_bytes(2, "big"))

    # SOS header
    sos = bytearray([ncomp])
    for i in range(ncomp):
        cls = 0 if i == 0 else 1
        sos += bytes([i + 1, (cls << 4) | cls])
    sos += bytes([0, 63, 0])  # Ss, Se, Ah/Al — fixed for baseline
    out += _seg(bs.SOS, sos)

    # entropy-coded data
    maps = [( _code_map("dc", 0 if i == 0 else 1),
              _code_map("ac", 0 if i == 0 else 1)) for i in range(ncomp)]
    n_mcus = mcuy * mcux
    preds = [0] * ncomp
    w = _BitWriter()
    rst = 0
    for mcu in range(n_mcus):
        if restart_interval and mcu and mcu % restart_interval == 0:
            out += w.flush()
            out += bytes([0xFF, bs.RST0 + rst])
            rst = (rst + 1) % 8
            w = _BitWriter()
            preds = [0] * ncomp
        my, mx = divmod(mcu, mcux)
        for i, (c, (h, v)) in enumerate(zip(comps, sampling)):
            dc_map, ac_map = maps[i]
            for vy in range(v):
                for vx in range(h):
                    preds[i] = _encode_block(
                        w, c[my * v + vy, mx * h + vx], preds[i],
                        dc_map, ac_map)
    out += w.flush()
    out += b"\xff\xd9"  # EOI
    return bytes(out)


# --------------------------------------------------------------------------
# Pixel-level convenience encoder (synthetic corpora → real JPEG bytes)
# --------------------------------------------------------------------------


def quantize_pixels(img: np.ndarray, qtable: np.ndarray, *,
                    pixel_scale: float = 128.0) -> np.ndarray:
    """Steps 1–5 for one plane: ``(H, W)`` pixels in ~[-1, 1) → quantized
    zigzag integers ``(H/8, W/8, 64)`` under quantization vector ``qtable``.

    The orthonormal 8×8 DCT coincides with the JPEG standard's definition,
    and the network convention ``x = (p − 128)/128`` makes the file-domain
    coefficients exactly ``pixel_scale ·`` the network-domain ones — so
    this is ``round(DCT(x) · 128 / q)``, the bit-true file integers.
    """
    h, w = img.shape
    b = dctlib.BLOCK
    if h % b or w % b:
        raise ValueError(f"plane ({h}x{w}) not divisible into 8x8 blocks")
    blocks = img.reshape(h // b, b, w // b, b).transpose(0, 2, 1, 3)
    coef = dctlib.dct2(blocks.astype(np.float64)) * pixel_scale
    zz = coef.reshape(h // b, w // b, dctlib.NFREQ)[
        ..., dctlib.zigzag_permutation()]
    q = np.asarray(qtable, np.float64).reshape(dctlib.NFREQ)
    return np.rint(zz / q).astype(np.int32)


def encode_pixels(img: np.ndarray, *, quality: int = 50,
                  qtable: np.ndarray | None = None,
                  subsample: bool = False,
                  restart_interval: int = 0) -> bytes:
    """Encode ``(H, W)`` or ``(C, H, W)`` pixels in ~[-1, 1) to baseline
    JFIF bytes (the repo's canonical quantization table by default).

    This is how the synthetic corpora become *real compressed traffic*
    for the bytes-in serving path and the ingest benchmarks: channels are
    treated as the file's components directly (the network is
    colorspace-agnostic).  ``subsample=True`` writes 4:2:0 — chroma is
    2×2 box-averaged before the DCT, exercising the coefficient-domain
    upsampling on decode.
    """
    img = np.asarray(img, np.float64)
    if img.ndim == 2:
        img = img[None]
    c, h, w = img.shape
    q = (np.asarray(qtable, np.int64) if qtable is not None
         else np.rint(dctlib.quantization_table(quality)).astype(np.int64))
    if subsample and c > 1:
        if h % 16 or w % 16:
            raise ValueError("4:2:0 needs dims divisible by 16")
        comps = [quantize_pixels(img[0], q)]
        for i in range(1, c):
            sub = img[i].reshape(h // 2, 2, w // 2, 2).mean(axis=(1, 3))
            comps.append(quantize_pixels(sub, q))
        sampling = [(2, 2)] + [(1, 1)] * (c - 1)
    else:
        comps = [quantize_pixels(img[i], q) for i in range(c)]
        sampling = [(1, 1)] * c
    return encode_baseline(comps, [q] * c, width=w, height=h,
                           sampling=sampling,
                           restart_interval=restart_interval)
