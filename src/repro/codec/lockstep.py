"""Lockstep entropy decode: many restart segments advanced as one vector.

The per-symbol Huffman loop is the serving bottleneck (ROADMAP: the
``ingest`` benchmark row), and it cannot be vectorised *within* a stream
— the bit position after symbol ``k`` depends on symbol ``k``.  But DRI
restart segments are independently decodable by construction (each
resets the DC predictors and the bit alignment), so a *batch* of images
yields hundreds of independent bit streams: every image contributes one
stream per restart segment (a DRI-less image is one whole-file stream).

This module decodes all of them in lockstep: one numpy "iteration"
consumes exactly one Huffman code (plus its value bits) from **every**
still-active stream —

* peek 16 bits per stream from a concatenated 24-bit-window array
  (``bitstream._windows``), one gather + shift;
* one fused LUT gather ``luts[table_of_stream, peek]`` over the stacked
  per-table 2¹⁶ LUTs resolves symbol + code length for all streams;
* masked vector updates run the per-block state machine (DC size /
  EXTEND / AC run-length / ZRL / EOB) and scatter coefficients into a
  flat walk-ordered block matrix.

Python overhead is paid once per *symbol column* instead of once per
symbol: with ``S`` streams the interpreter cost drops by ``~S``, which
is what makes batched bytes→logits ingest faster than spatial
decompress-first serving even on one core.  Wall clock scales with the
longest stream, so restart intervals (balanced segments) help; skew only
costs idle lanes.

Correctness contract: **bit-exact** with the scalar reference
(``bitstream.decode_scan``).  Any stream that trips an error flag
(invalid code, overrun, bad DC size, AC run past end) aborts lockstep
for that *image only*, which is re-decoded on the scalar path so the
exact reference exception (or recovery) is reproduced.  Parity is
enforced by ``tests/test_codec_parallel.py`` across fixtures and
hypothesis round-trips.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import dct as dctlib
from repro.codec import bitstream as bs

__all__ = ["LOCKSTEP_MIN_STREAMS", "count_streams", "decode_scans"]

#: below this many independent streams the vector overhead outweighs the
#: amortisation and the scalar loop wins; callers use :func:`count_streams`
#: to pick a path.
LOCKSTEP_MIN_STREAMS = 8

_NF = dctlib.NFREQ


def _extend_lut() -> np.ndarray:
    """``ext[(s << 16) | peek16]`` = EXTEND(peek16 >> (16-s), s) — the
    spec §F.12 sign extension resolved straight from the 16-bit window,
    fusing RECEIVE+EXTEND into one gather (4 MiB, built once)."""
    lut = np.empty((16, 1 << 16), np.int32)
    peek = np.arange(1 << 16, dtype=np.int64)
    lut[0] = 0
    for s in range(1, 16):
        v = peek >> (16 - s)
        half = 1 << (s - 1)
        lut[s] = np.where(v >= half, v, v - 2 * half + 1)
    return lut.reshape(-1)


def _ac_luts() -> tuple[np.ndarray, np.ndarray]:
    """Per-AC-symbol ``k`` advance and EOB flag.

    ``adv[sym]``: 0 for EOB, 16 for ZRL, run+1 for a value symbol — the
    coefficient-index step after consuming the symbol (the value lands at
    ``k + run``, i.e. ``k_new - 1``).  ``eob[sym]``: size==0 and run<15.
    """
    sym = np.arange(256, dtype=np.int64)
    s, run = sym & 0x0F, sym >> 4
    adv = np.where(s > 0, run + 1, np.where(run == 15, 16, 0))
    return adv.astype(np.int32), ((s == 0) & (run < 15))


_EXT = _extend_lut()
_ADV, _EOB = _ac_luts()


def count_streams(scans: Sequence[bs.Scan]) -> int:
    """Total independently decodable bit streams across ``scans``."""
    return sum(len(s.segments) for s in scans)


def _scalar(scan: bs.Scan) -> bs.DecodedJpeg:
    return bs.decode_scan(scan)


def decode_scans(scans: Sequence[bs.Scan]) -> list[bs.DecodedJpeg]:
    """Decode prepared scans jointly, one vector step per symbol column.

    Returns one :class:`bitstream.DecodedJpeg` per scan, bit-exact with
    :func:`bitstream.decode_scan`; scans whose streams flag an error fall
    back to the scalar reference decoder individually (reproducing its
    exception behaviour without poisoning the rest of the batch).
    """
    n_scans = len(scans)
    if n_scans == 0:
        return []

    # ---------------------------------------------------------- stream build
    # Stack every distinct Huffman LUT once; streams address tables by
    # stack index so one fused gather serves mixed-table traffic.
    stack_ix: dict[int, int] = {}
    luts: list[np.ndarray] = []

    def _tix(table: bs.HuffmanTable) -> int:
        key = id(table.lut)
        if key not in stack_ix:
            stack_ix[key] = len(luts)
            luts.append(table.lut)
        return stack_ix[key]

    fallback = np.zeros(n_scans, bool)
    streams: list[tuple[int, np.ndarray, int, int, int]] = []
    scan_tbl: list[tuple[np.ndarray, np.ndarray] | None] = []
    for si, sc in enumerate(scans):
        try:
            walk = sc.walk
            dc_of_j = np.array([_tix(sc.tables[j][0])
                                for j in range(len(sc.tables))], np.int16)
            ac_of_j = np.array([_tix(sc.tables[j][1])
                                for j in range(len(sc.tables))], np.int16)
            scan_tbl.append((dc_of_j[walk.j], ac_of_j[walk.j]))
            per = walk.per_mcu
            built = []
            for seg, (m0, m1) in zip(sc.segments, sc.seg_mcus):
                if m1 <= m0:
                    continue
                w24, nbits = bs._windows(seg)
                built.append((si, w24, nbits, m0 * per, m1 * per))
            streams.extend(built)
        except bs.JpegError:
            # e.g. an unescaped marker inside a segment: let the scalar
            # path raise it for this image alone
            scan_tbl.append(None)
            fallback[si] = True

    S = len(streams)
    if S == 0:
        return [_scalar(sc) for sc in scans]

    nb = np.array([b1 - b0 for _, _, _, b0, b1 in streams], np.int64)
    nbmax = int(nb.max())
    scan_of = np.array([si for si, *_ in streams], np.int64)

    lut_flat = np.concatenate(luts)  # table t at [t << 16, (t+1) << 16)

    # per-(stream, block) constants packed into one gatherable word:
    # dc table | ac table << 8 | component << 16
    TBL = np.zeros((S, nbmax), np.int32)
    ROW0 = np.zeros(S, np.int64)
    off = np.zeros(S, np.int64)
    nbits_s = np.zeros(S, np.int64)
    scan_rows = np.zeros(n_scans + 1, np.int64)
    chunks = []
    row = pos_w = 0
    pad = np.full(4, 0xFFFFFF, np.int32)  # overrun slack: no index clamp
    for i, (si, w24, nbits, b0, b1) in enumerate(streams):
        n = b1 - b0
        dcb, acb = scan_tbl[si]
        ci = scans[si].walk.ci[b0:b1].astype(np.int32)
        TBL[i, :n] = (dcb[b0:b1].astype(np.int32)
                      | (acb[b0:b1].astype(np.int32) << 8) | (ci << 16))
        ROW0[i] = row
        row += n
        off[i] = pos_w
        nbits_s[i] = nbits
        pos_w += w24.shape[0] + pad.shape[0]
        chunks.append(w24.astype(np.int32))
        chunks.append(pad)
        scan_rows[si + 1] = row
    np.maximum.accumulate(scan_rows, out=scan_rows)
    W = np.concatenate(chunks)
    tbl_flat = TBL.reshape(-1)
    OUT = np.zeros((row, _NF), np.int32)
    out_flat = OUT.reshape(-1)

    ncomp_max = max(len(sc.comps) for sc in scans)
    preds_flat = np.zeros(S * ncomp_max, np.int64)

    # ------------------------------------------------------------- main loop
    # Dynamic state is kept *compressed* to the active streams — no
    # per-iteration state gathers, flat 1-D fancy indexing only; arrays
    # shrink as streams finish.
    sid = np.nonzero(nb > 0)[0].astype(np.int64)
    p = np.zeros(sid.size, np.int64)    # bit cursor
    b = np.zeros(sid.size, np.int64)    # current block within stream
    kc = np.zeros(sid.size, np.int64)   # next coefficient index
    acp = np.zeros(sid.size, bool)      # False: expect DC code; True: AC
    off_c = off[sid]
    nbits_c = nbits_s[sid]
    nb_c = nb[sid]
    row0_c = ROW0[sid]
    tb_base = sid * nbmax               # flat index bases, kept compressed
    pr_base = sid * ncomp_max
    err_sids: list[np.ndarray] = []

    while sid.size:
        peek = (W[off_c + (p >> 3)] >> (8 - (p & 7))) & 0xFFFF
        tblw = tbl_flat[tb_base + b]
        tbl = (tblw >> (acp << 3)) & 0xFF  # dc table, or ac table if acp
        packed = lut_flat[(tbl.astype(np.int64) << 16) + peek]
        bad = packed < 0
        sym = (packed >> 8) & 0xFF  # garbage when bad; flagged below
        s = sym & 0x0F              # == sym for every legal DC size (<= 15)
        nacp = ~acp
        bad |= nacp & (sym > 15)    # DC size category > 15

        # invalid codes pack -1: their low byte reads as 255, which would
        # drive the speculative peek2 past the stream's pad words — hold
        # those lanes at p (they are flagged and discarded this iteration)
        p2 = p + np.where(packed < 0, 0, packed & 0xFF)
        peek2 = (W[off_c + (p2 >> 3)] >> (8 - (p2 & 7))) & 0xFFFF
        ext = _EXT[(s << 16) + peek2]
        p3 = p2 + s
        # a read past the segment's real bits means the scalar reference
        # would have raised (exhausted / ran past end); flag, don't decode
        errnow = bad | (p3 > nbits_c)
        ok = ~errnow

        rows = row0_c + b
        dcm = ok & nacp
        # DC: unmasked writes are safe — AC-phase lanes rewrite the value
        # their block's DC pass already stored (preds unchanged since),
        # and errored lanes' scans are discarded to the scalar fallback.
        pidx = pr_base + (tblw >> 16)
        preds_flat[pidx] += ext * dcm
        out_flat[rows << 6] = preds_flat[pidx]

        # AC bookkeeping via per-symbol LUTs: adv = 0 (EOB) / 16 (ZRL) /
        # run+1 (value, which lands at column k_new - 1)
        knew = kc + np.where(acp, _ADV[sym], 1)
        acok = ok & acp
        val = acok & (s > 0)
        run_err = val & (knew > _NF)  # k + run >= 64: run past block end
        val &= ~run_err
        out_flat[(rows[val] << 6) + knew[val] - 1] = ext[val]

        done = acok & (_EOB[sym] | (knew >= _NF))
        errnow |= run_err

        p = p3
        kc = knew * ~done
        acp = (acp | dcm) & ~done
        b = b + done
        rem = errnow | (done & (b == nb_c))
        if rem.any():
            if errnow.any():
                err_sids.append(sid[errnow])
            keep = ~rem
            sid, p, b, kc, acp = (sid[keep], p[keep], b[keep], kc[keep],
                                  acp[keep])
            off_c, nbits_c, nb_c, row0_c, tb_base, pr_base = (
                off_c[keep], nbits_c[keep], nb_c[keep], row0_c[keep],
                tb_base[keep], pr_base[keep])

    # ------------------------------------------------------------- assemble
    if err_sids:
        fallback[scan_of[np.concatenate(err_sids)]] = True
    out: list[bs.DecodedJpeg] = []
    for si, sc in enumerate(scans):
        if fallback[si]:
            out.append(_scalar(sc))
        else:
            out.append(bs.assemble_blocks(
                sc, OUT[scan_rows[si]:scan_rows[si + 1]]))
    return out
