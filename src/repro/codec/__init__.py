"""Compressed-ingest codec: real JPEG bytes → the compiled plan.

The paper's "consume compressed images as input" made real: a baseline
JFIF parser + vectorized numpy Huffman entropy decoder
(:mod:`~repro.codec.bitstream`), the matching bit-exact entropy encoder
(:mod:`~repro.codec.encode`), per-image quantization-table normalization
with coefficient-domain chroma upsampling (:mod:`~repro.codec.normalize`),
and batched ingest into the plan / tile-packed layouts with empirical
band statistics (:mod:`~repro.codec.ingest`).  Numpy-pure — no jax, no
pixels, no external codec libraries.
"""
from repro.codec.bitstream import (  # noqa: F401
    CodecError, DecodedJpeg, EntropyError, HuffmanError, JpegError,
    MarkerError, TruncatedJpegError, UnsupportedJpegError, decode_jpeg,
    decode_scan, prepare_scan,
)
from repro.codec.encode import (  # noqa: F401
    encode_baseline, encode_pixels, quantize_pixels,
)
from repro.codec.lockstep import (  # noqa: F401
    LOCKSTEP_MIN_STREAMS, count_streams, decode_scans,
)
from repro.codec.normalize import normalize_image  # noqa: F401
from repro.codec.ingest import (  # noqa: F401
    IngestStats, decode_bytes, ingest_batch, ingest_pipeline,
    ingest_workers, merge_stats, pack_tiles, pool_restarts, shutdown_pool,
)

__all__ = [
    "CodecError", "DecodedJpeg", "EntropyError", "HuffmanError",
    "JpegError", "MarkerError", "TruncatedJpegError", "UnsupportedJpegError",
    "decode_jpeg", "decode_scan", "prepare_scan",
    "encode_baseline", "encode_pixels", "quantize_pixels",
    "LOCKSTEP_MIN_STREAMS", "count_streams", "decode_scans",
    "normalize_image",
    "IngestStats", "decode_bytes", "ingest_batch", "ingest_pipeline",
    "ingest_workers", "merge_stats", "pack_tiles", "pool_restarts",
    "shutdown_pool",
]
