"""Compressed-ingest codec: real JPEG bytes → the compiled plan.

The paper's "consume compressed images as input" made real: a baseline
JFIF parser + vectorized numpy Huffman entropy decoder
(:mod:`~repro.codec.bitstream`), the matching bit-exact entropy encoder
(:mod:`~repro.codec.encode`), per-image quantization-table normalization
with coefficient-domain chroma upsampling (:mod:`~repro.codec.normalize`),
and batched ingest into the plan / tile-packed layouts with empirical
band statistics (:mod:`~repro.codec.ingest`).  Numpy-pure — no jax, no
pixels, no external codec libraries.
"""
from repro.codec.bitstream import (  # noqa: F401
    DecodedJpeg, JpegError, UnsupportedJpegError, decode_jpeg,
)
from repro.codec.encode import (  # noqa: F401
    encode_baseline, encode_pixels, quantize_pixels,
)
from repro.codec.normalize import normalize_image  # noqa: F401
from repro.codec.ingest import (  # noqa: F401
    IngestStats, decode_bytes, ingest_batch, merge_stats, pack_tiles,
)

__all__ = [
    "DecodedJpeg", "JpegError", "UnsupportedJpegError", "decode_jpeg",
    "encode_baseline", "encode_pixels", "quantize_pixels",
    "normalize_image",
    "IngestStats", "decode_bytes", "ingest_batch", "merge_stats",
    "pack_tiles",
]
