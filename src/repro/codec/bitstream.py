"""Baseline JFIF bitstream parser + numpy Huffman entropy decoder.

This is the ingest half of the compressed-domain serving story: raw JPEG
bytes go to **quantized zigzag coefficients** — the file's own step-5
integers — without ever materialising pixels.  ``codec.normalize`` then
rescales them into the network's canonical quantization-table convention
and ``codec.ingest`` packs batches for the compiled plan.

Scope: baseline sequential DCT (SOF0), 8-bit precision, Huffman entropy
coding, optional restart intervals — i.e. the JFIF files libjpeg emits by
default.  Progressive (SOF2) and arithmetic coding raise
:class:`UnsupportedJpegError` loudly rather than mis-decoding.

Decoder shape
-------------
The entropy decode is structured for numpy rather than per-bit python:

* each entropy-coded segment is byte-unstuffed **vectorially** (drop the
  ``0x00`` after every ``0xFF``);
* a 24-bit window array over the unstuffed bytes is precomputed in one
  vector pass (8 bytes per input byte — never a per-bit expansion), so
  peeking the next 16 bits at any bit position is one index + shift;
* per Huffman table a flat 2¹⁶ lookup table maps the next 16 bits to
  ``(symbol, code length)`` — the canonical-code walk of spec §F.16
  collapses to ``lut[peek]``, and RECEIVE of ``s`` value bits is the
  same peek shifted.

Only the MCU walk itself (a few symbols per block) remains a python loop.

Coefficients come out in **zigzag order** (the file's native order, which
is also the repo-wide convention — ``core.dct.zigzag_permutation``), with
the DC prediction already undone, one ``(blocks_y, blocks_x, 64)`` int32
array per component on that component's own (MCU-padded) sampling grid.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core import dct as dctlib

__all__ = [
    "JpegError",
    "UnsupportedJpegError",
    "HuffmanTable",
    "FrameComponent",
    "DecodedJpeg",
    "build_huffman_lut",
    "parse_segments",
    "decode_jpeg",
]

# marker bytes (second byte after 0xFF)
SOI, EOI, SOS, DQT, DHT, DRI, COM = 0xD8, 0xD9, 0xDA, 0xDB, 0xC4, 0xDD, 0xFE
SOF0 = 0xC0
DAC = 0xCC  # arithmetic-coding conditioning — arithmetic streams only
RST0, RST7 = 0xD0, 0xD7
_SOF_ALL = set(range(0xC0, 0xD0)) - {0xC4, 0xC8, 0xCC}  # SOFn family
_SUPPORTED_SOF = {0xC0, 0xC1}  # baseline + extended sequential (Huffman)

#: human names for the SOFn variants this decoder rejects.
_SOF_KIND = {
    0xC2: "progressive (SOF2)",
    0xC3: "lossless (SOF3)",
    0xC5: "differential sequential (SOF5)",
    0xC6: "differential progressive (SOF6)",
    0xC7: "differential lossless (SOF7)",
    0xC9: "arithmetic-coded sequential (SOF9)",
    0xCA: "arithmetic-coded progressive (SOF10)",
    0xCB: "arithmetic-coded lossless (SOF11)",
    0xCD: "differential arithmetic-coded sequential (SOF13)",
    0xCE: "differential arithmetic-coded progressive (SOF14)",
    0xCF: "differential arithmetic-coded lossless (SOF15)",
}

_UNSUPPORTED_HINT = (
    "supported markers are SOF0 (baseline) and SOF1 (extended sequential "
    "Huffman) — re-encode the file as baseline (libjpeg/PIL defaults), or "
    'see the ROADMAP item "progressive (SOF2) decode" for the planned '
    "extension")


class JpegError(ValueError):
    """Malformed or truncated JPEG bitstream."""


class UnsupportedJpegError(JpegError):
    """Valid JPEG, but outside the baseline-sequential scope."""


class HuffmanTable(NamedTuple):
    """A decoded DHT table plus its flat 16-bit decode LUT.

    ``lut[peek]`` packs ``(symbol << 8) | code_length`` for the code that
    prefixes the 16-bit window ``peek``; ``-1`` marks invalid prefixes.
    """

    counts: np.ndarray   # (16,) codes per length 1..16
    symbols: np.ndarray  # (sum(counts),) symbol values
    lut: np.ndarray      # (65536,) int32


class FrameComponent(NamedTuple):
    ident: int   # component id from SOF (1=Y, 2=Cb, 3=Cr conventionally)
    h: int       # horizontal sampling factor
    v: int       # vertical sampling factor
    tq: int      # quantization table id


class DecodedJpeg(NamedTuple):
    """Entropy-decoded file: quantized zigzag coefficients, no pixels.

    ``coefficients[i]`` is ``(blocks_y, blocks_x, 64)`` int32 on component
    ``i``'s MCU-padded grid; ``blocks(i)`` gives the true (unpadded) block
    dims.  ``qtables`` are the file's zigzag-ordered DQT vectors.
    """

    width: int
    height: int
    components: tuple[FrameComponent, ...]
    qtables: dict[int, np.ndarray]
    coefficients: list[np.ndarray]
    restart_interval: int = 0

    def blocks(self, i: int) -> tuple[int, int]:
        """True (blocks_y, blocks_x) of component ``i`` before MCU padding."""
        c = self.components[i]
        hmax = max(fc.h for fc in self.components)
        vmax = max(fc.v for fc in self.components)
        w = -(-self.width * c.h // hmax)   # ceil(width * h / hmax)
        h = -(-self.height * c.v // vmax)
        return -(-h // dctlib.BLOCK), -(-w // dctlib.BLOCK)

    def qtable(self, i: int) -> np.ndarray:
        return self.qtables[self.components[i].tq]


# --------------------------------------------------------------------------
# Huffman tables
# --------------------------------------------------------------------------


def build_huffman_lut(counts: np.ndarray, symbols: np.ndarray) -> HuffmanTable:
    """Canonical-code LUT: every 16-bit window starting with code ``c`` of
    length ``l`` maps to that code's symbol (spec §C.2 code assignment)."""
    counts = np.asarray(counts, np.int64)
    symbols = np.asarray(symbols, np.int64)
    if counts.shape != (16,) or symbols.shape[0] != int(counts.sum()):
        raise JpegError("inconsistent DHT counts/symbols")
    lut = np.full(1 << 16, -1, np.int32)
    code = 0
    si = 0
    for length in range(1, 17):
        n = int(counts[length - 1])
        for _ in range(n):
            lo = code << (16 - length)
            hi = (code + 1) << (16 - length)
            if hi > (1 << 16):
                raise JpegError("Huffman code overflows 16 bits")
            lut[lo:hi] = (int(symbols[si]) << 8) | length
            si += 1
            code += 1
        code <<= 1
    return HuffmanTable(counts, symbols, lut)


# --------------------------------------------------------------------------
# Segment-level parsing
# --------------------------------------------------------------------------


def _u16(data: bytes, at: int) -> int:
    if at + 2 > len(data):
        raise JpegError("truncated segment length")
    return (data[at] << 8) | data[at + 1]


def parse_segments(data: bytes):
    """Yield ``(marker, payload, ecs)`` triples in file order.

    ``payload`` is the marker segment body (without the length field);
    ``ecs`` is the entropy-coded byte string following an SOS marker (up to
    but excluding the next non-RST marker), ``b""`` elsewhere.  RST markers
    stay embedded in ``ecs`` — the entropy decoder splits on them.
    """
    if data[:2] != b"\xff\xd8":
        raise JpegError("missing SOI marker — not a JPEG")
    yield SOI, b"", b""
    pos = 2
    n = len(data)
    while pos < n:
        if data[pos] != 0xFF:
            raise JpegError(f"expected marker at byte {pos}")
        while pos < n and data[pos] == 0xFF:  # fill bytes are legal
            pos += 1
        if pos >= n:
            raise JpegError("truncated marker")
        marker = data[pos]
        pos += 1
        if marker == EOI:
            yield EOI, b"", b""
            return
        if RST0 <= marker <= RST7 or marker == 0x01:
            yield marker, b"", b""
            continue
        length = _u16(data, pos)
        if length < 2 or pos + length > n:
            raise JpegError("bad segment length")
        payload = data[pos + 2: pos + length]
        pos += length
        ecs = b""
        if marker == SOS:
            start = pos
            while pos + 1 < n:
                if data[pos] == 0xFF and data[pos + 1] != 0x00 and not (
                        RST0 <= data[pos + 1] <= RST7):
                    break
                pos += 1
            else:
                raise JpegError("entropy-coded data ran past end of file")
            ecs = data[start:pos]
        yield marker, payload, ecs
    raise JpegError("missing EOI marker")


def _parse_dqt(payload: bytes, qtables: dict[int, np.ndarray]) -> None:
    at = 0
    while at < len(payload):
        pq, tq = payload[at] >> 4, payload[at] & 0x0F
        at += 1
        n = dctlib.NFREQ
        if pq == 0:
            vals = np.frombuffer(payload[at:at + n], np.uint8)
            at += n
        elif pq == 1:
            vals = np.frombuffer(payload[at:at + 2 * n],
                                 np.uint8).reshape(n, 2)
            vals = vals[:, 0].astype(np.int64) * 256 + vals[:, 1]
            at += 2 * n
        else:
            raise JpegError(f"bad DQT precision {pq}")
        if vals.shape[0] != n:
            raise JpegError("truncated DQT")
        qtables[tq] = vals.astype(np.int64)


def _parse_dht(payload: bytes, tables: dict[tuple[int, int], HuffmanTable]
               ) -> None:
    at = 0
    while at < len(payload):
        tc, th = payload[at] >> 4, payload[at] & 0x0F
        at += 1
        counts = np.frombuffer(payload[at:at + 16], np.uint8)
        if counts.shape[0] != 16:
            raise JpegError("truncated DHT")
        at += 16
        total = int(counts.sum())
        symbols = np.frombuffer(payload[at:at + total], np.uint8)
        if symbols.shape[0] != total:
            raise JpegError("truncated DHT symbols")
        at += total
        tables[(tc, th)] = build_huffman_lut(counts, symbols)


def _parse_sof(marker: int, payload: bytes):
    if marker not in _SUPPORTED_SOF:
        kind = _SOF_KIND.get(marker, f"SOF{marker - 0xC0}")
        raise UnsupportedJpegError(f"{kind} JPEG; {_UNSUPPORTED_HINT}")
    precision = payload[0]
    if precision != 8:
        raise UnsupportedJpegError(f"{precision}-bit precision (want 8)")
    height = (payload[1] << 8) | payload[2]
    width = (payload[3] << 8) | payload[4]
    ncomp = payload[5]
    if height == 0 or width == 0:
        raise UnsupportedJpegError("DNL-deferred dimensions not supported")
    comps = []
    for i in range(ncomp):
        cid, hv, tq = payload[6 + 3 * i: 9 + 3 * i]
        comps.append(FrameComponent(cid, hv >> 4, hv & 0x0F, tq))
    return width, height, tuple(comps)


# --------------------------------------------------------------------------
# Entropy decoding
# --------------------------------------------------------------------------


def _unstuff(ecs: np.ndarray) -> np.ndarray:
    """Drop the stuffed 0x00 after every 0xFF (vectorised)."""
    if ecs.size == 0:
        return ecs
    drop = np.zeros(ecs.shape[0], bool)
    ff = ecs[:-1] == 0xFF
    drop[1:] = ff & (ecs[1:] == 0x00)
    bad = ff & (ecs[1:] != 0x00)
    if bad.any():
        raise JpegError("unescaped marker inside entropy-coded segment")
    return ecs[~drop]


class _BitReader:
    """Bit cursor over unstuffed bytes via precomputed 24-bit windows.

    ``w24[i] = bytes[i:i+3]`` big-endian, so the 16 bits starting at bit
    position ``pos`` are ``(w24[pos >> 3] >> (8 - (pos & 7))) & 0xFFFF``
    — O(1) per peek, 8 bytes of table per input byte (no per-bit
    expansion, which would be 64–1024× the input size).
    """

    __slots__ = ("w24", "pos", "n")

    def __init__(self, raw: np.ndarray):
        data = _unstuff(raw)
        self.n = data.shape[0] * 8
        # pad with 1-bits (the spec's pad value) so end-of-stream windows
        # stay in range; reads past self.n are caught by the callers.
        padded = np.concatenate([data,
                                 np.full(3, 0xFF, np.uint8)]).astype(np.int64)
        self.w24 = (padded[:-2] << 16) | (padded[1:-1] << 8) | padded[2:]
        self.pos = 0

    def _peek16(self, pos: int) -> int:
        return (int(self.w24[pos >> 3]) >> (8 - (pos & 7))) & 0xFFFF

    def read_code(self, table: HuffmanTable) -> int:
        if self.pos >= self.n:
            raise JpegError("bit stream exhausted mid-block")
        packed = int(table.lut[self._peek16(self.pos)])
        if packed < 0:
            raise JpegError("invalid Huffman code")
        self.pos += packed & 0xFF
        if self.pos > self.n:
            raise JpegError("Huffman code ran past end of segment")
        return packed >> 8

    def receive(self, s: int) -> int:
        if s == 0:
            return 0
        if self.pos + s > self.n:
            raise JpegError("value bits ran past end of segment")
        v = self._peek16(self.pos) >> (16 - s)
        self.pos += s
        return v


def _extend(v: int, s: int) -> int:
    """Spec §F.12 EXTEND: map ``s`` received bits to a signed value."""
    if s == 0:
        return 0
    return v if v >= (1 << (s - 1)) else v - (1 << s) + 1


def _split_restarts(ecs: bytes) -> list[np.ndarray]:
    """Split an SOS entropy segment at embedded RST markers."""
    arr = np.frombuffer(ecs, np.uint8)
    if arr.size == 0:
        return [arr]
    is_rst = np.zeros(arr.shape[0], bool)
    ff = arr[:-1] == 0xFF
    is_rst[:-1] = ff & (arr[1:] >= RST0) & (arr[1:] <= RST7)
    cuts = np.where(is_rst)[0]
    parts, start = [], 0
    for c in cuts:
        parts.append(arr[start:c])
        start = c + 2  # skip FF Dn
    parts.append(arr[start:])
    return parts


def _decode_block(br: _BitReader, dc: HuffmanTable, ac: HuffmanTable,
                  out: np.ndarray) -> int:
    """Decode one block's coefficients into ``out`` (64,); returns DC diff."""
    s = br.read_code(dc)
    if s > 15:
        raise JpegError(f"bad DC size category {s}")
    diff = _extend(br.receive(s), s)
    k = 1
    while k < dctlib.NFREQ:
        rs = br.read_code(ac)
        r, s = rs >> 4, rs & 0x0F
        if s == 0:
            if r == 15:       # ZRL: sixteen zeros
                k += 16
                continue
            break             # EOB
        k += r
        if k >= dctlib.NFREQ:
            raise JpegError("AC run past end of block")
        out[k] = _extend(br.receive(s), s)
        k += 1
    return diff


def decode_jpeg(data: bytes) -> DecodedJpeg:
    """Entropy-decode baseline JFIF bytes to quantized zigzag coefficients.

    Bit-exact: the returned integers are the file's step-5 values with the
    DC prediction undone — re-encoding them (``codec.encode``) reproduces
    an equivalent bitstream, and ``codec.normalize`` turns them into the
    network's real-valued convention.
    """
    qtables: dict[int, np.ndarray] = {}
    huffman: dict[tuple[int, int], HuffmanTable] = {}
    frame = None
    restart_interval = 0
    scan = None

    for marker, payload, ecs in parse_segments(data):
        if marker == DQT:
            _parse_dqt(payload, qtables)
        elif marker == DHT:
            _parse_dht(payload, huffman)
        elif marker == DAC:
            raise UnsupportedJpegError(
                "arithmetic-coded JPEG (DAC conditioning marker); "
                + _UNSUPPORTED_HINT)
        elif marker == DRI:
            restart_interval = _u16(payload, 0)
        elif marker in _SOF_ALL:
            if frame is not None:
                raise UnsupportedJpegError("multi-frame (hierarchical) JPEG")
            frame = _parse_sof(marker, payload)
        elif marker == SOS:
            if frame is None:
                raise JpegError("SOS before SOF")
            if scan is not None:
                raise UnsupportedJpegError("multi-scan JPEG (progressive?)")
            scan = (payload, ecs)
        # APPn / COM / others: skipped

    if frame is None or scan is None:
        raise JpegError("no image data (missing SOF/SOS)")
    width, height, comps = frame
    payload, ecs = scan
    ns = payload[0]
    if ns != len(comps):
        raise UnsupportedJpegError("partial-component scan")
    by_id = {c.ident: i for i, c in enumerate(comps)}
    order, tables = [], []
    for j in range(ns):
        cs, tdta = payload[1 + 2 * j: 3 + 2 * j]
        if cs not in by_id:
            raise JpegError(f"scan references unknown component {cs}")
        order.append(by_id[cs])
        td, ta = tdta >> 4, tdta & 0x0F
        try:
            tables.append((huffman[(0, td)], huffman[(1, ta)]))
        except KeyError as e:
            raise JpegError(f"scan references missing Huffman table {e}")
    for c in comps:
        if c.tq not in qtables:
            raise JpegError(f"component quantization table {c.tq} missing")

    hmax = max(c.h for c in comps)
    vmax = max(c.v for c in comps)
    mcux = -(-width // (dctlib.BLOCK * hmax))
    mcuy = -(-height // (dctlib.BLOCK * vmax))
    interleaved = ns > 1
    if not interleaved:
        c = comps[order[0]]
        # non-interleaved: the MCU is one block on the component's own grid
        bx = -(-(-(-width * c.h // hmax)) // dctlib.BLOCK)
        by = -(-(-(-height * c.v // vmax)) // dctlib.BLOCK)
        grid = {order[0]: (by, bx)}
        n_mcus = by * bx
    else:
        grid = {i: (mcuy * c.v, mcux * c.h) for i, c in enumerate(comps)}
        n_mcus = mcuy * mcux
    coef = [np.zeros((*grid[i], dctlib.NFREQ), np.int32)
            for i in range(len(comps))]

    segments = _split_restarts(ecs)
    expected = (-(-n_mcus // restart_interval)
                if restart_interval else 1)
    if len(segments) != expected:
        raise JpegError(
            f"restart markers disagree with DRI: {len(segments)} segments "
            f"for {n_mcus} MCUs at interval {restart_interval}")

    block = np.zeros(dctlib.NFREQ, np.int32)
    mcu = 0
    for seg in segments:
        br = _BitReader(seg)
        preds = [0] * len(comps)
        seg_end = (min(mcu + restart_interval, n_mcus)
                   if restart_interval else n_mcus)
        while mcu < seg_end:
            if interleaved:
                my, mx = divmod(mcu, mcux)
                for j, ci in enumerate(order):
                    c = comps[ci]
                    dc_t, ac_t = tables[j]
                    for vy in range(c.v):
                        for vx in range(c.h):
                            block[:] = 0
                            preds[ci] += _decode_block(br, dc_t, ac_t, block)
                            block[0] = preds[ci]
                            coef[ci][my * c.v + vy, mx * c.h + vx] = block
            else:
                ci = order[0]
                dc_t, ac_t = tables[0]
                by_, bx_ = grid[ci]
                yy, xx = divmod(mcu, bx_)
                block[:] = 0
                preds[ci] += _decode_block(br, dc_t, ac_t, block)
                block[0] = preds[ci]
                coef[ci][yy, xx] = block
            mcu += 1
    if mcu != n_mcus:
        raise JpegError(f"decoded {mcu} MCUs, expected {n_mcus}")

    return DecodedJpeg(width, height, comps,
                       {k: v.copy() for k, v in qtables.items()},
                       coef, restart_interval)
