"""Baseline JFIF bitstream parser + numpy Huffman entropy decoder.

This is the ingest half of the compressed-domain serving story: raw JPEG
bytes go to **quantized zigzag coefficients** — the file's own step-5
integers — without ever materialising pixels.  ``codec.normalize`` then
rescales them into the network's canonical quantization-table convention
and ``codec.ingest`` packs batches for the compiled plan.

Scope: baseline sequential DCT (SOF0), 8-bit precision, Huffman entropy
coding, optional restart intervals — i.e. the JFIF files libjpeg emits by
default.  Progressive (SOF2) and arithmetic coding raise
:class:`UnsupportedJpegError` loudly rather than mis-decoding.

Decoder shape
-------------
The entropy decode is structured for numpy rather than per-bit python:

* each entropy-coded segment is byte-unstuffed **vectorially** (drop the
  ``0x00`` after every ``0xFF``);
* a 24-bit window array over the unstuffed bytes is precomputed in one
  vector pass (8 bytes per input byte — never a per-bit expansion), so
  peeking the next 16 bits at any bit position is one index + shift;
* per Huffman table a flat 2¹⁶ lookup table maps the next 16 bits to
  ``(symbol, code length)`` — the canonical-code walk of spec §F.16
  collapses to ``lut[peek]``, and RECEIVE of ``s`` value bits is the
  same peek shifted.

Only the MCU walk itself (a few symbols per block) remains a python loop.

Coefficients come out in **zigzag order** (the file's native order, which
is also the repo-wide convention — ``core.dct.zigzag_permutation``), with
the DC prediction already undone, one ``(blocks_y, blocks_x, 64)`` int32
array per component on that component's own (MCU-padded) sampling grid.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np

from repro.core import dct as dctlib

__all__ = [
    "CodecError",
    "JpegError",
    "TruncatedJpegError",
    "MarkerError",
    "HuffmanError",
    "EntropyError",
    "UnsupportedJpegError",
    "HuffmanTable",
    "FrameComponent",
    "DecodedJpeg",
    "Scan",
    "WalkArrays",
    "build_huffman_lut",
    "parse_segments",
    "prepare_scan",
    "decode_segment",
    "assemble_blocks",
    "decode_scan",
    "decode_jpeg",
]

# marker bytes (second byte after 0xFF)
SOI, EOI, SOS, DQT, DHT, DRI, COM = 0xD8, 0xD9, 0xDA, 0xDB, 0xC4, 0xDD, 0xFE
SOF0 = 0xC0
DAC = 0xCC  # arithmetic-coding conditioning — arithmetic streams only
RST0, RST7 = 0xD0, 0xD7
_SOF_ALL = set(range(0xC0, 0xD0)) - {0xC4, 0xC8, 0xCC}  # SOFn family
_SUPPORTED_SOF = {0xC0, 0xC1}  # baseline + extended sequential (Huffman)

#: human names for the SOFn variants this decoder rejects.
_SOF_KIND = {
    0xC2: "progressive (SOF2)",
    0xC3: "lossless (SOF3)",
    0xC5: "differential sequential (SOF5)",
    0xC6: "differential progressive (SOF6)",
    0xC7: "differential lossless (SOF7)",
    0xC9: "arithmetic-coded sequential (SOF9)",
    0xCA: "arithmetic-coded progressive (SOF10)",
    0xCB: "arithmetic-coded lossless (SOF11)",
    0xCD: "differential arithmetic-coded sequential (SOF13)",
    0xCE: "differential arithmetic-coded progressive (SOF14)",
    0xCF: "differential arithmetic-coded lossless (SOF15)",
}

_UNSUPPORTED_HINT = (
    "supported markers are SOF0 (baseline) and SOF1 (extended sequential "
    "Huffman) — re-encode the file as baseline (libjpeg/PIL defaults), or "
    'see the ROADMAP item "progressive (SOF2) decode" for the planned '
    "extension")


def _rebuild_codec_error(cls, message, offset, marker):
    """Unpickle helper (module-level so spawn pool workers can ship
    :class:`CodecError` instances back to the parent with context intact)."""
    return cls(message, offset=offset, marker=marker)


class CodecError(ValueError):
    """Base of the codec error hierarchy: malformed, truncated, or
    unsupported compressed input.

    Carries structured context for fault isolation and debugging:
    ``offset`` — the byte offset of the failure (relative to the buffer
    being parsed: file-relative during marker parsing, segment-relative
    during entropy decode) — and ``marker`` — the JPEG marker byte being
    handled, when one is implicated.  Both land in ``str(err)``.

    A ``CodecError`` means *this input* is bad, never that the decoder is
    unhealthy: the serving stack fails the offending request individually
    and keeps serving (``serving.scheduler``), and these errors do not
    feed the circuit breaker.  Subclasses ``ValueError`` so pre-existing
    ``except ValueError`` call sites keep working.
    """

    def __init__(self, message: str, *, offset: int | None = None,
                 marker: int | None = None):
        self.raw_message = message
        self.offset = offset
        self.marker = marker
        ctx = []
        if marker is not None:
            ctx.append(f"marker 0x{marker:02X}")
        if offset is not None:
            ctx.append(f"byte {offset}")
        super().__init__(message + (f" [{', '.join(ctx)}]" if ctx else ""))

    def __reduce__(self):  # keep offset/marker across process boundaries
        return (_rebuild_codec_error,
                (type(self), self.raw_message, self.offset, self.marker))


class JpegError(CodecError):
    """Malformed or truncated JPEG bitstream."""


class TruncatedJpegError(JpegError):
    """The stream ended before the structure it promised (cut file,
    missing EOI, segment shorter than its length field)."""


class MarkerError(JpegError):
    """Structurally invalid marker sequence or segment body."""


class HuffmanError(JpegError):
    """Inconsistent or overfull Huffman table definition (DHT)."""


class EntropyError(JpegError):
    """The entropy-coded data itself is invalid: unknown Huffman prefix,
    bit reads past the segment, coefficient runs past the block.
    ``offset`` is the bit cursor's byte position *within the segment*."""


class UnsupportedJpegError(JpegError):
    """Valid JPEG, but outside the baseline-sequential scope."""


class HuffmanTable(NamedTuple):
    """A decoded DHT table plus its flat 16-bit decode LUT.

    ``lut[peek]`` packs ``(symbol << 8) | code_length`` for the code that
    prefixes the 16-bit window ``peek``; ``-1`` marks invalid prefixes.
    """

    counts: np.ndarray   # (16,) codes per length 1..16
    symbols: np.ndarray  # (sum(counts),) symbol values
    lut: np.ndarray      # (65536,) int32


class FrameComponent(NamedTuple):
    ident: int   # component id from SOF (1=Y, 2=Cb, 3=Cr conventionally)
    h: int       # horizontal sampling factor
    v: int       # vertical sampling factor
    tq: int      # quantization table id


class DecodedJpeg(NamedTuple):
    """Entropy-decoded file: quantized zigzag coefficients, no pixels.

    ``coefficients[i]`` is ``(blocks_y, blocks_x, 64)`` int32 on component
    ``i``'s MCU-padded grid; ``blocks(i)`` gives the true (unpadded) block
    dims.  ``qtables`` are the file's zigzag-ordered DQT vectors.
    """

    width: int
    height: int
    components: tuple[FrameComponent, ...]
    qtables: dict[int, np.ndarray]
    coefficients: list[np.ndarray]
    restart_interval: int = 0

    def blocks(self, i: int) -> tuple[int, int]:
        """True (blocks_y, blocks_x) of component ``i`` before MCU padding."""
        c = self.components[i]
        hmax = max(fc.h for fc in self.components)
        vmax = max(fc.v for fc in self.components)
        w = -(-self.width * c.h // hmax)   # ceil(width * h / hmax)
        h = -(-self.height * c.v // vmax)
        return -(-h // dctlib.BLOCK), -(-w // dctlib.BLOCK)

    def qtable(self, i: int) -> np.ndarray:
        return self.qtables[self.components[i].tq]


# --------------------------------------------------------------------------
# Huffman tables
# --------------------------------------------------------------------------


def build_huffman_lut(counts: np.ndarray, symbols: np.ndarray) -> HuffmanTable:
    """Canonical-code LUT: every 16-bit window starting with code ``c`` of
    length ``l`` maps to that code's symbol (spec §C.2 code assignment)."""
    counts = np.asarray(counts, np.int64)
    symbols = np.asarray(symbols, np.int64)
    if counts.shape != (16,) or symbols.shape[0] != int(counts.sum()):
        raise HuffmanError("inconsistent DHT counts/symbols", marker=DHT)
    lut = np.full(1 << 16, -1, np.int32)
    code = 0
    si = 0
    for length in range(1, 17):
        n = int(counts[length - 1])
        for _ in range(n):
            lo = code << (16 - length)
            hi = (code + 1) << (16 - length)
            if hi > (1 << 16):
                raise HuffmanError("Huffman code overflows 16 bits",
                                   marker=DHT)
            lut[lo:hi] = (int(symbols[si]) << 8) | length
            si += 1
            code += 1
        code <<= 1
    return HuffmanTable(counts, symbols, lut)


@functools.lru_cache(maxsize=256)
def _cached_table(counts: bytes, symbols: bytes) -> HuffmanTable:
    """LUT build keyed on the DHT wire format.  Serving traffic reuses a
    handful of tables (often just Annex K's four), so rebuilding the 2¹⁶
    LUT per file would dominate parse time; the wire-format key also lets
    worker processes rebuild tables from pickled ``(counts, symbols)``
    bytes without ever shipping the LUTs themselves."""
    return build_huffman_lut(np.frombuffer(counts, np.uint8),
                             np.frombuffer(symbols, np.uint8))


# --------------------------------------------------------------------------
# Segment-level parsing
# --------------------------------------------------------------------------


def _u16(data: bytes, at: int) -> int:
    if at + 2 > len(data):
        raise TruncatedJpegError("truncated segment length", offset=at)
    return (data[at] << 8) | data[at + 1]


def parse_segments(data: bytes):
    """Yield ``(marker, payload, ecs, offset)`` tuples in file order.

    ``payload`` is the marker segment body (without the length field);
    ``ecs`` is the entropy-coded byte string following an SOS marker (up to
    but excluding the next non-RST marker), ``b""`` elsewhere.  RST markers
    stay embedded in ``ecs`` — the entropy decoder splits on them.
    ``offset`` is the file offset of the payload's first byte (of the
    position after the marker code for payload-less markers), so structural
    errors inside a segment can name their absolute byte position.
    """
    if data[:2] != b"\xff\xd8":
        raise MarkerError("missing SOI marker — not a JPEG", offset=0)
    yield SOI, b"", b"", 2
    pos = 2
    n = len(data)
    while pos < n:
        if data[pos] != 0xFF:
            raise MarkerError("expected a marker", offset=pos)
        while pos < n and data[pos] == 0xFF:  # fill bytes are legal
            pos += 1
        if pos >= n:
            raise TruncatedJpegError("truncated marker", offset=pos)
        marker = data[pos]
        pos += 1
        if marker == EOI:
            yield EOI, b"", b"", pos
            return
        if RST0 <= marker <= RST7 or marker == 0x01:
            yield marker, b"", b"", pos
            continue
        length = _u16(data, pos)
        if length < 2 or pos + length > n:
            raise MarkerError(f"bad segment length {length}", offset=pos,
                              marker=marker)
        payload = data[pos + 2: pos + length]
        payload_off = pos + 2
        pos += length
        ecs = b""
        if marker == SOS:
            # vector scan for the first 0xFF not followed by a stuffed 0x00
            # or an RST marker — the byte loop here dominated parse time
            start = pos
            arr = np.frombuffer(data, np.uint8)
            nxt = arr[start + 1: n]
            stop = np.nonzero((arr[start: n - 1] == 0xFF) & (nxt != 0x00)
                              & ~((RST0 <= nxt) & (nxt <= RST7)))[0]
            if stop.size == 0:
                raise TruncatedJpegError(
                    "entropy-coded data ran past end of file", offset=start,
                    marker=SOS)
            pos = start + int(stop[0])
            ecs = data[start:pos]
        yield marker, payload, ecs, payload_off
    raise TruncatedJpegError("missing EOI marker", offset=n)


def _parse_dqt(payload: bytes, qtables: dict[int, np.ndarray],
               base: int = 0) -> None:
    at = 0
    while at < len(payload):
        pq, tq = payload[at] >> 4, payload[at] & 0x0F
        at += 1
        n = dctlib.NFREQ
        if pq == 0:
            vals = np.frombuffer(payload[at:at + n], np.uint8)
            at += n
        elif pq == 1:
            vals = np.frombuffer(payload[at:at + 2 * n],
                                 np.uint8).reshape(n, 2)
            vals = vals[:, 0].astype(np.int64) * 256 + vals[:, 1]
            at += 2 * n
        else:
            raise MarkerError(f"bad DQT precision {pq}",
                              offset=base + at - 1, marker=DQT)
        if vals.shape[0] != n:
            raise TruncatedJpegError("truncated DQT", offset=base + at,
                                     marker=DQT)
        qtables[tq] = vals.astype(np.int64)


def _parse_dht(payload: bytes, tables: dict[tuple[int, int], HuffmanTable],
               base: int = 0) -> None:
    at = 0
    while at < len(payload):
        tc, th = payload[at] >> 4, payload[at] & 0x0F
        at += 1
        counts = np.frombuffer(payload[at:at + 16], np.uint8)
        if counts.shape[0] != 16:
            raise TruncatedJpegError("truncated DHT", offset=base + at,
                                     marker=DHT)
        at += 16
        total = int(counts.sum())
        symbols = np.frombuffer(payload[at:at + total], np.uint8)
        if symbols.shape[0] != total:
            raise TruncatedJpegError("truncated DHT symbols",
                                     offset=base + at, marker=DHT)
        at += total
        tables[(tc, th)] = _cached_table(counts.tobytes(), symbols.tobytes())


def _parse_sof(marker: int, payload: bytes, base: int = 0):
    if marker not in _SUPPORTED_SOF:
        kind = _SOF_KIND.get(marker, f"SOF{marker - 0xC0}")
        raise UnsupportedJpegError(f"{kind} JPEG; {_UNSUPPORTED_HINT}",
                                   marker=marker)
    if len(payload) < 6:
        raise TruncatedJpegError("truncated SOF", offset=base, marker=marker)
    precision = payload[0]
    if precision != 8:
        raise UnsupportedJpegError(f"{precision}-bit precision (want 8)",
                                   offset=base, marker=marker)
    height = (payload[1] << 8) | payload[2]
    width = (payload[3] << 8) | payload[4]
    ncomp = payload[5]
    if height == 0 or width == 0:
        raise UnsupportedJpegError("DNL-deferred dimensions not supported",
                                   offset=base, marker=marker)
    comps = []
    for i in range(ncomp):
        cid, hv, tq = payload[6 + 3 * i: 9 + 3 * i]
        comps.append(FrameComponent(cid, hv >> 4, hv & 0x0F, tq))
    return width, height, tuple(comps)


# --------------------------------------------------------------------------
# Entropy decoding
# --------------------------------------------------------------------------


def _unstuff(ecs: np.ndarray) -> np.ndarray:
    """Drop the stuffed 0x00 after every 0xFF (vectorised)."""
    if ecs.size == 0:
        return ecs
    drop = np.zeros(ecs.shape[0], bool)
    ff = ecs[:-1] == 0xFF
    drop[1:] = ff & (ecs[1:] == 0x00)
    bad = ff & (ecs[1:] != 0x00)
    if bad.any():
        raise EntropyError("unescaped marker inside entropy-coded segment",
                           offset=int(np.nonzero(bad)[0][0]))
    return ecs[~drop]


def _windows(raw: np.ndarray) -> tuple[np.ndarray, int]:
    """Unstuff ``raw`` and build the 24-bit peek windows.

    ``w24[i] = bytes[i:i+3]`` big-endian (padded with the spec's 1-bit
    pad value), so the 16 bits starting at bit position ``pos`` are
    ``(w24[pos >> 3] >> (8 - (pos & 7))) & 0xFFFF`` — O(1) per peek,
    8 bytes of table per input byte.  Returns ``(w24, n_bits)``; shared
    by the scalar :class:`_BitReader` and the lockstep decoder
    (``codec.lockstep``), which concatenates many streams' windows.
    """
    data = _unstuff(raw)
    padded = np.concatenate([data,
                             np.full(3, 0xFF, np.uint8)]).astype(np.int64)
    w24 = (padded[:-2] << 16) | (padded[1:-1] << 8) | padded[2:]
    return w24, data.shape[0] * 8


class _BitReader:
    """Bit cursor over unstuffed bytes via precomputed 24-bit windows
    (see :func:`_windows`); reads past ``n`` are caught by the callers."""

    __slots__ = ("w24", "pos", "n")

    def __init__(self, raw: np.ndarray):
        self.w24, self.n = _windows(raw)
        self.pos = 0

    def _peek16(self, pos: int) -> int:
        return (int(self.w24[pos >> 3]) >> (8 - (pos & 7))) & 0xFFFF

    def read_code(self, table: HuffmanTable) -> int:
        if self.pos >= self.n:
            raise EntropyError("bit stream exhausted mid-block",
                               offset=self.pos >> 3)
        packed = int(table.lut[self._peek16(self.pos)])
        if packed < 0:
            raise EntropyError("invalid Huffman code", offset=self.pos >> 3)
        self.pos += packed & 0xFF
        if self.pos > self.n:
            raise EntropyError("Huffman code ran past end of segment",
                               offset=self.pos >> 3)
        return packed >> 8

    def receive(self, s: int) -> int:
        if s == 0:
            return 0
        if self.pos + s > self.n:
            raise EntropyError("value bits ran past end of segment",
                               offset=self.pos >> 3)
        v = self._peek16(self.pos) >> (16 - s)
        self.pos += s
        return v


def _extend(v: int, s: int) -> int:
    """Spec §F.12 EXTEND: map ``s`` received bits to a signed value."""
    if s == 0:
        return 0
    return v if v >= (1 << (s - 1)) else v - (1 << s) + 1


def _split_restarts(ecs: bytes) -> list[np.ndarray]:
    """Split an SOS entropy segment at embedded RST markers."""
    arr = np.frombuffer(ecs, np.uint8)
    if arr.size == 0:
        return [arr]
    is_rst = np.zeros(arr.shape[0], bool)
    ff = arr[:-1] == 0xFF
    is_rst[:-1] = ff & (arr[1:] >= RST0) & (arr[1:] <= RST7)
    cuts = np.where(is_rst)[0]
    parts, start = [], 0
    for c in cuts:
        parts.append(arr[start:c])
        start = c + 2  # skip FF Dn
    parts.append(arr[start:])
    return parts


def _decode_block(br: _BitReader, dc: HuffmanTable, ac: HuffmanTable,
                  out: np.ndarray) -> int:
    """Decode one block's coefficients into ``out`` (64,); returns DC diff."""
    s = br.read_code(dc)
    if s > 15:
        raise EntropyError(f"bad DC size category {s}", offset=br.pos >> 3)
    diff = _extend(br.receive(s), s)
    k = 1
    while k < dctlib.NFREQ:
        rs = br.read_code(ac)
        r, s = rs >> 4, rs & 0x0F
        if s == 0:
            if r == 15:       # ZRL: sixteen zeros
                k += 16
                continue
            break             # EOB
        k += r
        if k >= dctlib.NFREQ:
            raise EntropyError("AC run past end of block",
                               offset=br.pos >> 3)
        out[k] = _extend(br.receive(s), s)
        k += 1
    return diff


class WalkArrays(NamedTuple):
    """Vectorised MCU-walk: for every block of the scan, in entropy-decode
    order, its component index, scan-component slot (→ Huffman table
    pair), and target grid position.  ``per_mcu`` blocks per MCU."""

    ci: np.ndarray  # (n_blocks,) int16 component index
    j: np.ndarray   # (n_blocks,) int16 scan-component slot
    y: np.ndarray   # (n_blocks,) int32 block row on the component grid
    x: np.ndarray   # (n_blocks,) int32 block col on the component grid
    per_mcu: int


class Scan(NamedTuple):
    """A parsed + validated single-scan file, ready for entropy decode.

    ``segments`` are the (still stuffed) entropy-coded byte runs between
    restart markers; ``seg_mcus[i]`` is segment ``i``'s half-open MCU
    range.  Every segment resets the DC predictors and bit alignment, so
    each ``(segment, mcu_range)`` pair is independently decodable — the
    unit of work for both the scalar reference loop and the parallel
    decoders (``codec.lockstep``, the ingest worker pool).
    """

    width: int
    height: int
    comps: tuple[FrameComponent, ...]
    qtables: dict[int, np.ndarray]
    restart_interval: int
    order: tuple[int, ...]
    tables: tuple[tuple[HuffmanTable, HuffmanTable], ...]
    interleaved: bool
    grid: tuple[tuple[int, int], ...]  # per component (blocks_y, blocks_x)
    mcux: int
    n_mcus: int
    segments: list[np.ndarray]
    seg_mcus: tuple[tuple[int, int], ...]

    @property
    def walk(self) -> WalkArrays:
        hv = tuple((c.h, c.v) for c in self.comps)
        return _walk_arrays(self.interleaved, self.order, hv, self.grid,
                            self.mcux, self.n_mcus)

    @property
    def n_blocks(self) -> int:
        return self.n_mcus * self.walk.per_mcu


@functools.lru_cache(maxsize=256)
def _walk_arrays(interleaved, order, hv, grid, mcux, n_mcus) -> WalkArrays:
    if interleaved:
        tpl = []
        for jj, ci in enumerate(order):
            h, v = hv[ci]
            for vy in range(v):
                for vx in range(h):
                    tpl.append((ci, jj, vy, vx, v, h))
        t = np.tile(np.asarray(tpl, np.int64), (n_mcus, 1))
        per_mcu = len(tpl)
        m = np.repeat(np.arange(n_mcus, dtype=np.int64), per_mcu)
        ci, j = t[:, 0], t[:, 1]
        y = (m // mcux) * t[:, 4] + t[:, 2]
        x = (m % mcux) * t[:, 5] + t[:, 3]
    else:
        # single-component scan: the MCU is one block on the component's
        # own grid
        per_mcu = 1
        _, bx = grid[order[0]]
        ar = np.arange(n_mcus, dtype=np.int64)
        ci = np.full(n_mcus, order[0], np.int64)
        j = np.zeros(n_mcus, np.int64)
        y, x = ar // bx, ar % bx
    return WalkArrays(ci.astype(np.int16), j.astype(np.int16),
                      y.astype(np.int32), x.astype(np.int32), per_mcu)


def prepare_scan(data: bytes) -> Scan:
    """Parse + validate baseline JFIF bytes up to (but excluding) the
    entropy decode: markers, tables, scan geometry, restart segmentation.

    All structural errors are raised here; what remains is the pure
    per-segment bit consumption, so callers can fan the returned
    ``(segment, mcu_range)`` pairs out to parallel decoders.
    """
    qtables: dict[int, np.ndarray] = {}
    huffman: dict[tuple[int, int], HuffmanTable] = {}
    frame = None
    restart_interval = 0
    scan = None

    for marker, payload, ecs, off in parse_segments(data):
        if marker == DQT:
            _parse_dqt(payload, qtables, base=off)
        elif marker == DHT:
            _parse_dht(payload, huffman, base=off)
        elif marker == DAC:
            raise UnsupportedJpegError(
                "arithmetic-coded JPEG (DAC conditioning marker); "
                + _UNSUPPORTED_HINT, offset=off, marker=DAC)
        elif marker == DRI:
            restart_interval = _u16(payload, 0)
        elif marker in _SOF_ALL:
            if frame is not None:
                raise UnsupportedJpegError("multi-frame (hierarchical) JPEG",
                                           offset=off, marker=marker)
            frame = _parse_sof(marker, payload, base=off)
        elif marker == SOS:
            if frame is None:
                raise MarkerError("SOS before SOF", offset=off, marker=SOS)
            if scan is not None:
                raise UnsupportedJpegError("multi-scan JPEG (progressive?)",
                                           offset=off, marker=SOS)
            scan = (payload, ecs)
        # APPn / COM / others: skipped

    if frame is None or scan is None:
        raise MarkerError("no image data (missing SOF/SOS)")
    width, height, comps = frame
    payload, ecs = scan
    ns = payload[0]
    if ns != len(comps):
        raise UnsupportedJpegError("partial-component scan", marker=SOS)
    by_id = {c.ident: i for i, c in enumerate(comps)}
    order, tables = [], []
    for j in range(ns):
        cs, tdta = payload[1 + 2 * j: 3 + 2 * j]
        if cs not in by_id:
            raise MarkerError(f"scan references unknown component {cs}",
                              marker=SOS)
        order.append(by_id[cs])
        td, ta = tdta >> 4, tdta & 0x0F
        try:
            tables.append((huffman[(0, td)], huffman[(1, ta)]))
        except KeyError as e:
            raise MarkerError(f"scan references missing Huffman table {e}",
                              marker=SOS)
    for c in comps:
        if c.tq not in qtables:
            raise MarkerError(
                f"component quantization table {c.tq} missing", marker=SOS)

    hmax = max(c.h for c in comps)
    vmax = max(c.v for c in comps)
    mcux = -(-width // (dctlib.BLOCK * hmax))
    mcuy = -(-height // (dctlib.BLOCK * vmax))
    interleaved = ns > 1
    if not interleaved:
        c = comps[order[0]]
        # non-interleaved: the MCU is one block on the component's own grid
        bx = -(-(-(-width * c.h // hmax)) // dctlib.BLOCK)
        by = -(-(-(-height * c.v // vmax)) // dctlib.BLOCK)
        grid = ((by, bx),)
        n_mcus = by * bx
    else:
        grid = tuple((mcuy * c.v, mcux * c.h) for c in comps)
        n_mcus = mcuy * mcux

    segments = _split_restarts(ecs)
    expected = (-(-n_mcus // restart_interval)
                if restart_interval else 1)
    if (restart_interval and len(segments) == expected + 1
            and segments[-1].size == 0):
        # benign encoder shape: a restart marker emitted after the final
        # MCU row, immediately before EOI — an empty trailing segment
        # with no MCUs behind it.  Tolerate (drop) it; any non-empty
        # surplus segment is still a genuine mismatch below.
        segments = segments[:-1]
    if len(segments) != expected:
        raise MarkerError(
            f"restart markers disagree with DRI: {len(segments)} segments "
            f"for {n_mcus} MCUs at interval {restart_interval}")
    r = restart_interval or n_mcus
    seg_mcus = tuple((i * r, min((i + 1) * r, n_mcus))
                     for i in range(len(segments)))

    return Scan(width, height, comps, qtables, restart_interval,
                tuple(order), tuple(tables), interleaved, grid, mcux,
                n_mcus, segments, seg_mcus)


def decode_segment(scan: Scan, seg: np.ndarray, mcu0: int, mcu1: int
                   ) -> np.ndarray:
    """Decode one restart segment's blocks — the pure unit of parallelism.

    Returns ``(n_blocks, 64)`` int32 zigzag coefficients in MCU-walk
    order with the segment-local DC prediction undone.  Depends only on
    ``(scan tables/geometry, seg, mcu range)`` — never on neighbouring
    segments — because a restart resets both predictors and bit
    alignment.
    """
    walk = scan.walk
    b0, b1 = mcu0 * walk.per_mcu, mcu1 * walk.per_mcu
    br = _BitReader(seg)
    out = np.zeros((b1 - b0, dctlib.NFREQ), np.int32)
    preds = [0] * len(scan.comps)
    j_seq = walk.j[b0:b1].tolist()
    c_seq = walk.ci[b0:b1].tolist()
    for t in range(b1 - b0):
        dc_t, ac_t = scan.tables[j_seq[t]]
        ci = c_seq[t]
        preds[ci] += _decode_block(br, dc_t, ac_t, out[t])
        out[t, 0] = preds[ci]
    return out


def assemble_blocks(scan: Scan, blocks: np.ndarray) -> DecodedJpeg:
    """Scatter walk-ordered ``(n_blocks, 64)`` coefficients onto the
    per-component MCU-padded grids."""
    walk = scan.walk
    coef = [np.zeros((*scan.grid[i], dctlib.NFREQ), np.int32)
            for i in range(len(scan.comps))]
    for i in range(len(scan.comps)):
        m = walk.ci == i
        coef[i][walk.y[m], walk.x[m]] = blocks[m]
    return DecodedJpeg(scan.width, scan.height, scan.comps,
                       {k: v.copy() for k, v in scan.qtables.items()},
                       coef, scan.restart_interval)


def decode_scan(scan: Scan) -> DecodedJpeg:
    """Sequential reference decode: every segment through
    :func:`decode_segment`, in file order.  The parallel decoders are
    defined by producing bit-identical output to this loop."""
    parts = [decode_segment(scan, seg, m0, m1)
             for seg, (m0, m1) in zip(scan.segments, scan.seg_mcus)]
    blocks = parts[0] if len(parts) == 1 else np.concatenate(parts)
    return assemble_blocks(scan, blocks)


def decode_jpeg(data: bytes) -> DecodedJpeg:
    """Entropy-decode baseline JFIF bytes to quantized zigzag coefficients.

    Bit-exact: the returned integers are the file's step-5 values with the
    DC prediction undone — re-encoding them (``codec.encode``) reproduces
    an equivalent bitstream, and ``codec.normalize`` turns them into the
    network's real-valued convention.  This is the sequential reference
    path; batched traffic goes through ``codec.ingest``, which fans
    restart segments across the lockstep decoder / worker pool and must
    match this function exactly.
    """
    return decode_scan(prepare_scan(data))
