"""Batched compressed ingest: JPEG bytes → network-ready coefficient batches.

The last stage of the codec subsystem: entropy-decode
(:mod:`codec.bitstream`), normalize into the plan's canonical convention
(:mod:`codec.normalize`), stack into the ``(N, bh, bw, C, 64)`` batch the
plan walk consumes — or pack straight into the tile-packed
``(N, bh, bw, C·w)`` layout the compiled schedule's stem GEMM reads
(``kernels/tiling.py``; per-channel zigzag prefixes of width ``w``), so
band truncation happens at ingest and the 64-wide layout is never
materialised on the serving path.

Ingest also records **empirical per-band statistics** of the traffic it
decodes (:class:`IngestStats`): mean canonical coefficient energy and
nonzero occupancy per zigzag index.  ``core.plan.autotune_bands`` accepts
the energy vector as a drop-in replacement for its ``1/q²`` qtable prior —
band truncation tuned to what the traffic actually contains — and logs
chosen bands against the occupancy so over-truncation is visible.
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Iterable, Iterator, NamedTuple

import numpy as np

from repro.core import dct as dctlib
from repro.codec import bitstream as bslib
from repro.codec import lockstep as lklib
from repro.codec import normalize as nmlib

__all__ = [
    "IngestStats",
    "decode_bytes",
    "ingest_batch",
    "ingest_pipeline",
    "ingest_workers",
    "pack_tiles",
    "merge_stats",
    "pool_restarts",
    "shutdown_pool",
]


class IngestStats(NamedTuple):
    """Per-zigzag-index traffic statistics from one ingest pass.

    ``energy[k]`` — mean squared canonical coefficient at zigzag index
    ``k`` (an *empirical* energy profile; feed to
    ``core.plan.autotune_bands(profile=...)``).  ``occupancy[k]`` — the
    fraction of blocks whose coefficient ``k`` is nonzero (the JPEG
    sparsity the paper's §6 leans on; ``occupancy[b:]`` is what a band
    cutoff at ``b`` throws away).
    """

    images: int
    blocks: int
    bytes_in: int
    energy: np.ndarray     # (64,) float64
    occupancy: np.ndarray  # (64,) float64

    @property
    def mean_nonzero(self) -> float:
        """Average nonzero coefficients per block (format sparsity)."""
        return float(self.occupancy.sum())


def merge_stats(parts: Iterable[IngestStats]) -> IngestStats:
    """Block-weighted merge of stats from several ingest passes."""
    parts = [p for p in parts if p is not None and p.blocks]
    if not parts:
        z = np.zeros(dctlib.NFREQ)
        return IngestStats(0, 0, 0, z, z.copy())
    blocks = sum(p.blocks for p in parts)
    energy = sum(p.energy * p.blocks for p in parts) / blocks
    occ = sum(p.occupancy * p.blocks for p in parts) / blocks
    return IngestStats(sum(p.images for p in parts), blocks,
                       sum(p.bytes_in for p in parts), energy, occ)


def decode_bytes(data: bytes, *, quality: int = 50,
                 grid: tuple[int, int] | None = None,
                 channels: int | None = None) -> np.ndarray:
    """One file → ``(bh, bw, C, 64)`` float32 canonical coefficients
    (entropy decode + per-image quantization normalization, no pixels)."""
    dec = bslib.decode_jpeg(data)
    return nmlib.normalize_image(dec, quality=quality, grid=grid,
                                 channels=channels)


def pack_tiles(coef: np.ndarray, width: int) -> np.ndarray:
    """``(..., C, 64) → (..., C·width)`` — the tile-packed activation
    layout of ``kernels/tiling.py``: each channel keeps its first
    ``width`` zigzag lanes (zero-padded above 64, which cannot happen
    here), concatenated channel-major.  This is exactly the slice+reshape
    the compiled stem would otherwise perform on the 64-wide batch, done
    at ingest so the full-width layout never exists.
    """
    *lead, c, nf = coef.shape
    if width <= nf:
        out = coef[..., :width]
    else:
        out = np.zeros((*lead, c, width), coef.dtype)
        out[..., :nf] = coef
    return np.ascontiguousarray(out).reshape(*lead, c * width)


# ---------------------------------------------------------------------------
# parallel decode: lockstep vectorisation + optional shared worker pool
# ---------------------------------------------------------------------------

#: number of decode workers: ``JPEG_INGEST_WORKERS`` env if set, else the
#: CPU count.  ``1`` means everything stays in-process (the lockstep
#: vector decode still runs; set ``parallel=False`` for the scalar
#: reference path).
def ingest_workers() -> int:
    env = os.environ.get("JPEG_INGEST_WORKERS")
    if env is not None:
        return max(1, int(env))
    return os.cpu_count() or 1


_POOL: ProcessPoolExecutor | None = None
_POOL_SIZE = 0
_POOL_RESTARTS = 0

#: supervisor backoff: first respawn waits this long, doubling per
#: attempt, capped at 1 s.  ``JPEG_POOL_MAX_RESTARTS`` bounds respawns
#: per failed shard batch before the in-process last resort.
POOL_BACKOFF_S = 0.05


def pool_max_restarts() -> int:
    return max(0, int(os.environ.get("JPEG_POOL_MAX_RESTARTS", "2")))


def pool_restarts() -> int:
    """How many times the supervisor has respawned a broken decode pool
    (process-lifetime counter; exported into serving health snapshots)."""
    return _POOL_RESTARTS


def _get_pool(workers: int) -> ProcessPoolExecutor:
    """Shared spawn-context pool, rebuilt if the worker count changes.

    Spawn (not fork) so workers never inherit device handles or thread
    state; the codec is numpy-pure, so a worker's import cost is small
    and paid once per process lifetime.
    """
    global _POOL, _POOL_SIZE
    if _POOL is None or _POOL_SIZE != workers:
        shutdown_pool()
        _POOL = ProcessPoolExecutor(
            max_workers=workers,
            mp_context=multiprocessing.get_context("spawn"))
        _POOL_SIZE = workers
    return _POOL


def shutdown_pool() -> None:
    """Tear down the shared decode pool (tests / clean shutdown)."""
    global _POOL, _POOL_SIZE
    if _POOL is not None:
        _POOL.shutdown(wait=True, cancel_futures=True)
        _POOL = None
        _POOL_SIZE = 0


atexit.register(shutdown_pool)


def _decode_shard(datas: list[bytes], quality: int,
                  grid: tuple[int, int] | None,
                  channels: int | None,
                  isolate: bool = False) -> list[np.ndarray | Exception]:
    """One worker's share: lockstep-decode its images × segments jointly,
    then normalize.  Module-level so spawn workers can import it; raises
    propagate through the future to the caller.

    ``isolate=True`` contains per-image failures instead of failing the
    shard: the joint lockstep decode is attempted first (healthy traffic
    pays nothing), and only if it raises does the shard fall back to
    per-image decode, returning the exception *in place of* the plane at
    each failed index.  ``CodecError.__reduce__`` keeps offset/marker
    context across the spawn-pool pickle boundary.
    """
    try:
        scans = [bslib.prepare_scan(d) for d in datas]
        return [nmlib.normalize_image(dec, quality=quality, grid=grid,
                                      channels=channels)
                for dec in lklib.decode_scans(scans)]
    except Exception:
        if not isolate:
            raise
    out: list[np.ndarray | Exception] = []
    for d in datas:
        try:
            out.append(decode_bytes(d, quality=quality, grid=grid,
                                    channels=channels))
        except Exception as e:
            out.append(e)
    return out


def _pool_shards(shards: list[list[bytes]], quality: int,
                 grid: tuple[int, int] | None, channels: int | None,
                 isolate: bool, workers: int, on_shard=None
                 ) -> list[tuple[int, list[np.ndarray | Exception]]] | None:
    """Run shards on the shared pool under supervision.

    A worker dying mid-decode (OOM-killed, segfault, SIGKILL) surfaces as
    :class:`BrokenProcessPool` on every outstanding future.  The
    supervisor tears the pool down, respawns it with capped exponential
    backoff, and retries the whole shard batch up to
    ``pool_max_restarts()`` times; ``None`` means supervision is
    exhausted and the caller must decode in-process (last resort — slow
    but alive).

    ``on_shard(shard_index, n_images, t0_s, t1_s)`` is an observability
    hook: per-shard submit→done wall on ``time.monotonic`` (done is
    stamped by the future's completion callback, so it measures the
    worker, not the caller's ``.result()`` ordering).  Only the
    successful attempt reports.
    """
    global _POOL_RESTARTS
    attempts = pool_max_restarts() + 1
    for attempt in range(attempts):
        pool = _get_pool(workers)
        try:
            # submit is inside the try: a worker killed *between* batches
            # marks the pool broken and submit itself raises
            futs = []
            done_at: dict[int, float] = {}
            for i, shard in enumerate(shards):
                if not shard:
                    continue
                t_sub = time.monotonic()
                fut = pool.submit(_decode_shard, shard, quality, grid,
                                  channels, isolate)
                if on_shard is not None:
                    fut.add_done_callback(
                        lambda f, i=i: done_at.__setitem__(
                            i, time.monotonic()))
                futs.append((i, t_sub, fut))
            results = [(i, fut.result()) for i, _, fut in futs]
            if on_shard is not None:
                for i, t_sub, fut in futs:
                    on_shard(i, len(shards[i]), t_sub,
                             done_at.get(i, time.monotonic()))
            return results
        except BrokenProcessPool:
            _POOL_RESTARTS += 1
            shutdown_pool()
            if attempt + 1 < attempts:
                time.sleep(min(POOL_BACKOFF_S * (2 ** attempt), 1.0))
    return None


def _decode_planes(datas: list[bytes], *, quality: int,
                   grid: tuple[int, int] | None, channels: int | None,
                   parallel: bool | None, isolate: bool = False,
                   on_shard=None) -> list[np.ndarray | Exception]:
    """Decode a batch to normalized planes, order-preserving.

    ``parallel=False``: strict sequential scalar reference.  ``True``:
    force the lockstep path (and the pool when workers > 1).  ``None``
    (default): lockstep when the batch carries enough independent restart
    streams (``lockstep.LOCKSTEP_MIN_STREAMS``), scalar otherwise —
    always bit-exact either way.

    ``isolate=True`` returns the per-image exception in place of the
    plane at each failed index instead of raising.

    ``on_shard(batch_indices, t0_s, t1_s)`` reports each spawn-pool
    shard's wall with the *original batch indices* it decoded; it only
    fires when the pool path actually ran (in-process decode is covered
    by the caller's whole-batch timing).
    """
    if parallel is False:
        out: list[np.ndarray | Exception] = []
        for d in datas:
            try:
                out.append(decode_bytes(d, quality=quality, grid=grid,
                                        channels=channels))
            except Exception as e:
                if not isolate:
                    raise
                out.append(e)
        return out
    workers = ingest_workers()
    if workers > 1 and len(datas) >= 2:
        shards = [datas[i::workers] for i in range(workers)]
        cb = None
        if on_shard is not None:
            def cb(i, n, ta, tb):
                # shard i holds datas[i::workers] — recover the original
                # batch indices so the caller can label its requests
                on_shard(list(range(i, len(datas), workers))[:n], ta, tb)
        results = _pool_shards(shards, quality, grid, channels, isolate,
                               workers, on_shard=cb)
        if results is not None:
            planes: list[np.ndarray | Exception | None] = [None] * len(datas)
            for i, shard_planes in results:
                for j, plane in enumerate(shard_planes):
                    planes[i + j * workers] = plane
            return planes  # type: ignore[return-value]
        # supervision exhausted: fall through to the in-process path
    if isolate:
        return _decode_shard(datas, quality, grid, channels, True)
    scans = [bslib.prepare_scan(d) for d in datas]
    if parallel or lklib.count_streams(scans) >= lklib.LOCKSTEP_MIN_STREAMS:
        decs = lklib.decode_scans(scans)
    else:
        decs = [bslib.decode_scan(s) for s in scans]
    return [nmlib.normalize_image(dec, quality=quality, grid=grid,
                                  channels=channels) for dec in decs]


def ingest_batch(datas: Iterable[bytes], *, quality: int = 50,
                 grid: tuple[int, int] | None = None, channels: int = 3,
                 pack_width: int | None = None,
                 with_stats: bool = True,
                 parallel: bool | None = None,
                 on_error: str = "raise",
                 on_shard=None):
    """Decode + normalize a batch of JPEG byte strings.

    Returns ``(batch, stats)``: ``batch`` is ``(N, bh, bw, C, 64)``
    float32, or the tile-packed ``(N, bh, bw, C·pack_width)`` layout when
    ``pack_width`` is given (e.g. ``CompiledPlan.stem.w_in``).  All images
    must land on one grid — pass ``grid`` explicitly for mixed-size
    traffic.  ``stats`` aggregates the per-band energy/occupancy of the
    decoded coefficients (pre-packing, so the profile always covers all
    64 indices).

    ``parallel`` picks the decode path (see :func:`_decode_planes`); the
    result — batch, stats, and raised errors — is identical on every
    path, only wall clock differs.  Stats are computed here in the
    parent, so sharded decode cannot perturb them.

    ``on_error="isolate"`` contains per-image decode failures instead of
    failing the batch: the return becomes ``(batch, stats, errors)``
    where ``errors`` maps the *original* index of each failed image to
    its exception (typically a :class:`~repro.codec.CodecError`) and
    ``batch`` stacks only the survivors, original order preserved.  With
    every image failed, ``batch`` is the zero-length
    ``(0, gh, gw, C, 64)`` (``grid`` required for a defined shape, else
    ``(0,)``).  Healthy batches pay no overhead — the joint lockstep
    decode runs exactly as in ``"raise"`` mode and per-image fallback
    only triggers on failure.

    ``on_shard(batch_indices, t0_s, t1_s)`` is the flight-recorder hook:
    per-spawn-pool-shard decode wall (``time.monotonic``), labelled with
    the original batch indices — see :func:`_decode_planes`.
    """
    datas = list(datas)
    if not datas:
        raise ValueError("empty ingest batch")
    if on_error not in ("raise", "isolate"):
        raise ValueError(
            f"on_error must be 'raise' or 'isolate', got {on_error!r}")
    isolate = on_error == "isolate"
    planes = _decode_planes(datas, quality=quality, grid=grid,
                            channels=channels, parallel=parallel,
                            isolate=isolate, on_shard=on_shard)
    errors: dict[int, Exception] = {
        i: p for i, p in enumerate(planes) if isinstance(p, Exception)}
    planes = [p for p in planes if not isinstance(p, Exception)]
    n_bytes = sum(len(d) for i, d in enumerate(datas) if i not in errors)
    if not planes:
        if grid is not None:
            batch = np.zeros((0, grid[0], grid[1], channels or 3,
                              dctlib.NFREQ), np.float32)
            if pack_width is not None:
                batch = pack_tiles(batch, pack_width)
        else:
            batch = np.zeros((0,), np.float32)
        return batch, (merge_stats([]) if with_stats else None), errors
    shapes = {p.shape for p in planes}
    if len(shapes) > 1:
        raise ValueError(
            f"mixed grids in one batch: {sorted(shapes)} — pass grid=")
    batch = np.stack(planes)
    stats = None
    if with_stats:
        flat = batch.reshape(-1, dctlib.NFREQ).astype(np.float64)
        stats = IngestStats(
            images=batch.shape[0],
            blocks=flat.shape[0],
            bytes_in=n_bytes,
            energy=np.mean(flat * flat, axis=0),
            occupancy=np.mean(flat != 0.0, axis=0),
        )
    if pack_width is not None:
        batch = pack_tiles(batch, pack_width)
    if isolate:
        return batch, stats, errors
    return batch, stats


def ingest_pipeline(batches: Iterable[Iterable[bytes]], *, depth: int = 2,
                    **kw) -> Iterator[tuple[np.ndarray, IngestStats | None]]:
    """Double-buffered ingest: decode of batch ``N+1`` overlaps whatever
    the consumer does with batch ``N`` (device compute, typically).

    Yields ``ingest_batch(batch, **kw)`` tuples in order, decoded
    ``depth`` batches ahead on a producer thread.  The lifecycle contract
    is :func:`repro.data.pipeline.prefetch`'s: closing the generator (or
    a consumer exception) joins the producer thread, and a decode error
    re-raises at the consumer's ``next()``.
    """
    from repro.data import pipeline as pipe  # lazy: pipeline imports us

    def produce() -> Iterator[tuple[np.ndarray, IngestStats | None]]:
        for datas in batches:
            yield ingest_batch(datas, **kw)

    return pipe.prefetch(produce(), depth=depth)
