"""Batched compressed ingest: JPEG bytes → network-ready coefficient batches.

The last stage of the codec subsystem: entropy-decode
(:mod:`codec.bitstream`), normalize into the plan's canonical convention
(:mod:`codec.normalize`), stack into the ``(N, bh, bw, C, 64)`` batch the
plan walk consumes — or pack straight into the tile-packed
``(N, bh, bw, C·w)`` layout the compiled schedule's stem GEMM reads
(``kernels/tiling.py``; per-channel zigzag prefixes of width ``w``), so
band truncation happens at ingest and the 64-wide layout is never
materialised on the serving path.

Ingest also records **empirical per-band statistics** of the traffic it
decodes (:class:`IngestStats`): mean canonical coefficient energy and
nonzero occupancy per zigzag index.  ``core.plan.autotune_bands`` accepts
the energy vector as a drop-in replacement for its ``1/q²`` qtable prior —
band truncation tuned to what the traffic actually contains — and logs
chosen bands against the occupancy so over-truncation is visible.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple

import numpy as np

from repro.core import dct as dctlib
from repro.codec import bitstream as bslib
from repro.codec import normalize as nmlib

__all__ = [
    "IngestStats",
    "decode_bytes",
    "ingest_batch",
    "pack_tiles",
    "merge_stats",
]


class IngestStats(NamedTuple):
    """Per-zigzag-index traffic statistics from one ingest pass.

    ``energy[k]`` — mean squared canonical coefficient at zigzag index
    ``k`` (an *empirical* energy profile; feed to
    ``core.plan.autotune_bands(profile=...)``).  ``occupancy[k]`` — the
    fraction of blocks whose coefficient ``k`` is nonzero (the JPEG
    sparsity the paper's §6 leans on; ``occupancy[b:]`` is what a band
    cutoff at ``b`` throws away).
    """

    images: int
    blocks: int
    bytes_in: int
    energy: np.ndarray     # (64,) float64
    occupancy: np.ndarray  # (64,) float64

    @property
    def mean_nonzero(self) -> float:
        """Average nonzero coefficients per block (format sparsity)."""
        return float(self.occupancy.sum())


def merge_stats(parts: Iterable[IngestStats]) -> IngestStats:
    """Block-weighted merge of stats from several ingest passes."""
    parts = [p for p in parts if p is not None and p.blocks]
    if not parts:
        z = np.zeros(dctlib.NFREQ)
        return IngestStats(0, 0, 0, z, z.copy())
    blocks = sum(p.blocks for p in parts)
    energy = sum(p.energy * p.blocks for p in parts) / blocks
    occ = sum(p.occupancy * p.blocks for p in parts) / blocks
    return IngestStats(sum(p.images for p in parts), blocks,
                       sum(p.bytes_in for p in parts), energy, occ)


def decode_bytes(data: bytes, *, quality: int = 50,
                 grid: tuple[int, int] | None = None,
                 channels: int | None = None) -> np.ndarray:
    """One file → ``(bh, bw, C, 64)`` float32 canonical coefficients
    (entropy decode + per-image quantization normalization, no pixels)."""
    dec = bslib.decode_jpeg(data)
    return nmlib.normalize_image(dec, quality=quality, grid=grid,
                                 channels=channels)


def pack_tiles(coef: np.ndarray, width: int) -> np.ndarray:
    """``(..., C, 64) → (..., C·width)`` — the tile-packed activation
    layout of ``kernels/tiling.py``: each channel keeps its first
    ``width`` zigzag lanes (zero-padded above 64, which cannot happen
    here), concatenated channel-major.  This is exactly the slice+reshape
    the compiled stem would otherwise perform on the 64-wide batch, done
    at ingest so the full-width layout never exists.
    """
    *lead, c, nf = coef.shape
    if width <= nf:
        out = coef[..., :width]
    else:
        out = np.zeros((*lead, c, width), coef.dtype)
        out[..., :nf] = coef
    return np.ascontiguousarray(out).reshape(*lead, c * width)


def ingest_batch(datas: Iterable[bytes], *, quality: int = 50,
                 grid: tuple[int, int] | None = None, channels: int = 3,
                 pack_width: int | None = None,
                 with_stats: bool = True
                 ) -> tuple[np.ndarray, IngestStats | None]:
    """Decode + normalize a batch of JPEG byte strings.

    Returns ``(batch, stats)``: ``batch`` is ``(N, bh, bw, C, 64)``
    float32, or the tile-packed ``(N, bh, bw, C·pack_width)`` layout when
    ``pack_width`` is given (e.g. ``CompiledPlan.stem.w_in``).  All images
    must land on one grid — pass ``grid`` explicitly for mixed-size
    traffic.  ``stats`` aggregates the per-band energy/occupancy of the
    decoded coefficients (pre-packing, so the profile always covers all
    64 indices).
    """
    planes, n_bytes = [], 0
    for data in datas:
        planes.append(decode_bytes(data, quality=quality, grid=grid,
                                   channels=channels))
        n_bytes += len(data)
    if not planes:
        raise ValueError("empty ingest batch")
    shapes = {p.shape for p in planes}
    if len(shapes) > 1:
        raise ValueError(
            f"mixed grids in one batch: {sorted(shapes)} — pass grid=")
    batch = np.stack(planes)
    stats = None
    if with_stats:
        flat = batch.reshape(-1, dctlib.NFREQ).astype(np.float64)
        stats = IngestStats(
            images=batch.shape[0],
            blocks=flat.shape[0],
            bytes_in=n_bytes,
            energy=np.mean(flat * flat, axis=0),
            occupancy=np.mean(flat != 0.0, axis=0),
        )
    if pack_width is not None:
        batch = pack_tiles(batch, pack_width)
    return batch, stats
