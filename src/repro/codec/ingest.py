"""Batched compressed ingest: JPEG bytes → network-ready coefficient batches.

The last stage of the codec subsystem: entropy-decode
(:mod:`codec.bitstream`), normalize into the plan's canonical convention
(:mod:`codec.normalize`), stack into the ``(N, bh, bw, C, 64)`` batch the
plan walk consumes — or pack straight into the tile-packed
``(N, bh, bw, C·w)`` layout the compiled schedule's stem GEMM reads
(``kernels/tiling.py``; per-channel zigzag prefixes of width ``w``), so
band truncation happens at ingest and the 64-wide layout is never
materialised on the serving path.

Ingest also records **empirical per-band statistics** of the traffic it
decodes (:class:`IngestStats`): mean canonical coefficient energy and
nonzero occupancy per zigzag index.  ``core.plan.autotune_bands`` accepts
the energy vector as a drop-in replacement for its ``1/q²`` qtable prior —
band truncation tuned to what the traffic actually contains — and logs
chosen bands against the occupancy so over-truncation is visible.
"""
from __future__ import annotations

import atexit
import multiprocessing
import os
from concurrent.futures import ProcessPoolExecutor
from typing import Iterable, Iterator, NamedTuple

import numpy as np

from repro.core import dct as dctlib
from repro.codec import bitstream as bslib
from repro.codec import lockstep as lklib
from repro.codec import normalize as nmlib

__all__ = [
    "IngestStats",
    "decode_bytes",
    "ingest_batch",
    "ingest_pipeline",
    "ingest_workers",
    "pack_tiles",
    "merge_stats",
    "shutdown_pool",
]


class IngestStats(NamedTuple):
    """Per-zigzag-index traffic statistics from one ingest pass.

    ``energy[k]`` — mean squared canonical coefficient at zigzag index
    ``k`` (an *empirical* energy profile; feed to
    ``core.plan.autotune_bands(profile=...)``).  ``occupancy[k]`` — the
    fraction of blocks whose coefficient ``k`` is nonzero (the JPEG
    sparsity the paper's §6 leans on; ``occupancy[b:]`` is what a band
    cutoff at ``b`` throws away).
    """

    images: int
    blocks: int
    bytes_in: int
    energy: np.ndarray     # (64,) float64
    occupancy: np.ndarray  # (64,) float64

    @property
    def mean_nonzero(self) -> float:
        """Average nonzero coefficients per block (format sparsity)."""
        return float(self.occupancy.sum())


def merge_stats(parts: Iterable[IngestStats]) -> IngestStats:
    """Block-weighted merge of stats from several ingest passes."""
    parts = [p for p in parts if p is not None and p.blocks]
    if not parts:
        z = np.zeros(dctlib.NFREQ)
        return IngestStats(0, 0, 0, z, z.copy())
    blocks = sum(p.blocks for p in parts)
    energy = sum(p.energy * p.blocks for p in parts) / blocks
    occ = sum(p.occupancy * p.blocks for p in parts) / blocks
    return IngestStats(sum(p.images for p in parts), blocks,
                       sum(p.bytes_in for p in parts), energy, occ)


def decode_bytes(data: bytes, *, quality: int = 50,
                 grid: tuple[int, int] | None = None,
                 channels: int | None = None) -> np.ndarray:
    """One file → ``(bh, bw, C, 64)`` float32 canonical coefficients
    (entropy decode + per-image quantization normalization, no pixels)."""
    dec = bslib.decode_jpeg(data)
    return nmlib.normalize_image(dec, quality=quality, grid=grid,
                                 channels=channels)


def pack_tiles(coef: np.ndarray, width: int) -> np.ndarray:
    """``(..., C, 64) → (..., C·width)`` — the tile-packed activation
    layout of ``kernels/tiling.py``: each channel keeps its first
    ``width`` zigzag lanes (zero-padded above 64, which cannot happen
    here), concatenated channel-major.  This is exactly the slice+reshape
    the compiled stem would otherwise perform on the 64-wide batch, done
    at ingest so the full-width layout never exists.
    """
    *lead, c, nf = coef.shape
    if width <= nf:
        out = coef[..., :width]
    else:
        out = np.zeros((*lead, c, width), coef.dtype)
        out[..., :nf] = coef
    return np.ascontiguousarray(out).reshape(*lead, c * width)


# ---------------------------------------------------------------------------
# parallel decode: lockstep vectorisation + optional shared worker pool
# ---------------------------------------------------------------------------

#: number of decode workers: ``JPEG_INGEST_WORKERS`` env if set, else the
#: CPU count.  ``1`` means everything stays in-process (the lockstep
#: vector decode still runs; set ``parallel=False`` for the scalar
#: reference path).
def ingest_workers() -> int:
    env = os.environ.get("JPEG_INGEST_WORKERS")
    if env is not None:
        return max(1, int(env))
    return os.cpu_count() or 1


_POOL: ProcessPoolExecutor | None = None
_POOL_SIZE = 0


def _get_pool(workers: int) -> ProcessPoolExecutor:
    """Shared spawn-context pool, rebuilt if the worker count changes.

    Spawn (not fork) so workers never inherit device handles or thread
    state; the codec is numpy-pure, so a worker's import cost is small
    and paid once per process lifetime.
    """
    global _POOL, _POOL_SIZE
    if _POOL is None or _POOL_SIZE != workers:
        shutdown_pool()
        _POOL = ProcessPoolExecutor(
            max_workers=workers,
            mp_context=multiprocessing.get_context("spawn"))
        _POOL_SIZE = workers
    return _POOL


def shutdown_pool() -> None:
    """Tear down the shared decode pool (tests / clean shutdown)."""
    global _POOL, _POOL_SIZE
    if _POOL is not None:
        _POOL.shutdown(wait=True, cancel_futures=True)
        _POOL = None
        _POOL_SIZE = 0


atexit.register(shutdown_pool)


def _decode_shard(datas: list[bytes], quality: int,
                  grid: tuple[int, int] | None,
                  channels: int | None) -> list[np.ndarray]:
    """One worker's share: lockstep-decode its images × segments jointly,
    then normalize.  Module-level so spawn workers can import it; raises
    propagate through the future to the caller."""
    scans = [bslib.prepare_scan(d) for d in datas]
    return [nmlib.normalize_image(dec, quality=quality, grid=grid,
                                  channels=channels)
            for dec in lklib.decode_scans(scans)]


def _decode_planes(datas: list[bytes], *, quality: int,
                   grid: tuple[int, int] | None, channels: int | None,
                   parallel: bool | None) -> list[np.ndarray]:
    """Decode a batch to normalized planes, order-preserving.

    ``parallel=False``: strict sequential scalar reference.  ``True``:
    force the lockstep path (and the pool when workers > 1).  ``None``
    (default): lockstep when the batch carries enough independent restart
    streams (``lockstep.LOCKSTEP_MIN_STREAMS``), scalar otherwise —
    always bit-exact either way.
    """
    if parallel is False:
        return [decode_bytes(d, quality=quality, grid=grid,
                             channels=channels) for d in datas]
    workers = ingest_workers()
    if workers > 1 and len(datas) >= 2:
        pool = _get_pool(workers)
        shards = [datas[i::workers] for i in range(workers)]
        futs = [(i, pool.submit(_decode_shard, shard, quality, grid,
                                channels))
                for i, shard in enumerate(shards) if shard]
        planes: list[np.ndarray | None] = [None] * len(datas)
        for i, fut in futs:
            for j, plane in enumerate(fut.result()):
                planes[i + j * workers] = plane
        return planes  # type: ignore[return-value]
    scans = [bslib.prepare_scan(d) for d in datas]
    if parallel or lklib.count_streams(scans) >= lklib.LOCKSTEP_MIN_STREAMS:
        decs = lklib.decode_scans(scans)
    else:
        decs = [bslib.decode_scan(s) for s in scans]
    return [nmlib.normalize_image(dec, quality=quality, grid=grid,
                                  channels=channels) for dec in decs]


def ingest_batch(datas: Iterable[bytes], *, quality: int = 50,
                 grid: tuple[int, int] | None = None, channels: int = 3,
                 pack_width: int | None = None,
                 with_stats: bool = True,
                 parallel: bool | None = None
                 ) -> tuple[np.ndarray, IngestStats | None]:
    """Decode + normalize a batch of JPEG byte strings.

    Returns ``(batch, stats)``: ``batch`` is ``(N, bh, bw, C, 64)``
    float32, or the tile-packed ``(N, bh, bw, C·pack_width)`` layout when
    ``pack_width`` is given (e.g. ``CompiledPlan.stem.w_in``).  All images
    must land on one grid — pass ``grid`` explicitly for mixed-size
    traffic.  ``stats`` aggregates the per-band energy/occupancy of the
    decoded coefficients (pre-packing, so the profile always covers all
    64 indices).

    ``parallel`` picks the decode path (see :func:`_decode_planes`); the
    result — batch, stats, and raised errors — is identical on every
    path, only wall clock differs.  Stats are computed here in the
    parent, so sharded decode cannot perturb them.
    """
    datas = list(datas)
    if not datas:
        raise ValueError("empty ingest batch")
    n_bytes = sum(len(d) for d in datas)
    planes = _decode_planes(datas, quality=quality, grid=grid,
                            channels=channels, parallel=parallel)
    shapes = {p.shape for p in planes}
    if len(shapes) > 1:
        raise ValueError(
            f"mixed grids in one batch: {sorted(shapes)} — pass grid=")
    batch = np.stack(planes)
    stats = None
    if with_stats:
        flat = batch.reshape(-1, dctlib.NFREQ).astype(np.float64)
        stats = IngestStats(
            images=batch.shape[0],
            blocks=flat.shape[0],
            bytes_in=n_bytes,
            energy=np.mean(flat * flat, axis=0),
            occupancy=np.mean(flat != 0.0, axis=0),
        )
    if pack_width is not None:
        batch = pack_tiles(batch, pack_width)
    return batch, stats


def ingest_pipeline(batches: Iterable[Iterable[bytes]], *, depth: int = 2,
                    **kw) -> Iterator[tuple[np.ndarray, IngestStats | None]]:
    """Double-buffered ingest: decode of batch ``N+1`` overlaps whatever
    the consumer does with batch ``N`` (device compute, typically).

    Yields ``ingest_batch(batch, **kw)`` tuples in order, decoded
    ``depth`` batches ahead on a producer thread.  The lifecycle contract
    is :func:`repro.data.pipeline.prefetch`'s: closing the generator (or
    a consumer exception) joins the producer thread, and a decode error
    re-raises at the consumer's ``next()``.
    """
    from repro.data import pipeline as pipe  # lazy: pipeline imports us

    def produce() -> Iterator[tuple[np.ndarray, IngestStats | None]]:
        for datas in batches:
            yield ingest_batch(datas, **kw)

    return pipe.prefetch(produce(), depth=depth)
