"""Per-image quantization normalization: file coefficients → plan convention.

One compiled plan serves mixed-quality traffic because every decoded image
is **exactly linearly rescaled** into the plan's canonical coefficient
convention before it touches the network (no spatial decode, no rounding):

* the file's quantized integers are multiplied by the file's own DQT
  vector (de-quantization — still zigzag, still per component);
* pixels are mapped from JPEG's level-shifted ``[-128, 128)`` to the
  network's ``[-1, 1)`` (a ``1/128`` scale, which commutes with the DCT);
* the result is divided by the plan's canonical quantization table
  (``core.dct.quantization_table(spec.quality)``, the ``scaled=True``
  convention of ``core.jpeg.jpeg_encode`` — see the convention table in
  ``core/jpeg.py``).

Net effect per zigzag index ``k`` (non-subsampled components):
``coef[k] · q_file[k] / (128 · q_canon[k])`` — one multiply per
coefficient, exact in float64 and then cast.  Subsampled components
de-quantize first, upsample in the plain DCT basis, and apply the
canonical divide last — the upsample map mixes zigzag indices, so the
per-index rescales must bracket it, not precede it.

Chroma subsampling is undone **in the coefficient domain**: replicating a
pixel ``f×`` is linear, so the DCT coefficients of each upsampled output
block are an exact 64×64 linear map of the source block's coefficients
(:func:`upsample_matrices`; one matrix per output quadrant, precomputed).
The result equals spatial nearest-neighbour upsampling exactly — again no
pixels are materialised.

Finally :func:`fit_grid` pads (zero blocks — mid-gray after the level
shift) or center-crops the block grid to the plan's expected input.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import dct as dctlib
from repro.codec.bitstream import DecodedJpeg

__all__ = [
    "PIXEL_SCALE",
    "canonical_qtable",
    "rescale_component",
    "upsample_matrices",
    "upsample_coefficients",
    "fit_grid",
    "normalize_image",
]

#: pixel-range scale between JPEG's level-shifted samples and the
#: network's ~[-1, 1) convention: x = (p - 128) / 128.
PIXEL_SCALE = 128.0


def canonical_qtable(quality: int) -> np.ndarray:
    """The plan's zigzag quantization vector (``dc_is_mean`` convention)."""
    return dctlib.quantization_table(quality)


def rescale_component(coef: np.ndarray, q_file: np.ndarray, *,
                      quality: int) -> np.ndarray:
    """Exact linear rescale of one component's quantized integers into the
    canonical ``scaled=True`` convention: ``coef · q_file / (128 · q_canon)``.
    """
    q_file = np.asarray(q_file, np.float64).reshape(dctlib.NFREQ)
    gain = q_file / (PIXEL_SCALE * canonical_qtable(quality))
    return (np.asarray(coef, np.float64) * gain).astype(np.float32)


@functools.lru_cache(maxsize=None)
def upsample_matrices(fy: int, fx: int) -> np.ndarray:
    """Coefficient-domain replication upsampling operators.

    ``out[qy, qx]`` is the 64×64 (zigzag→zigzag) map taking one source
    block's coefficients to the coefficients of output quadrant
    ``(qy, qx)`` of its ``fy × fx`` pixel-replicated expansion:
    ``M = R @ S @ Rᵀ`` with ``R`` the orthonormal zigzag reconstruction
    matrix and ``S`` the pixel-selection matrix of the quadrant.  Exact —
    replication is linear, and ``R`` is orthonormal.
    """
    b = dctlib.BLOCK
    rec = dctlib.reconstruction_matrix()  # (64 coef, 64 flat pixel)
    mats = np.zeros((fy, fx, dctlib.NFREQ, dctlib.NFREQ))
    for qy in range(fy):
        for qx in range(fx):
            sel = np.zeros((dctlib.NFREQ, dctlib.NFREQ))
            for m in range(b):
                for n in range(b):
                    sm = (qy * b + m) // fy
                    sn = (qx * b + n) // fx
                    sel[m * b + n, sm * b + sn] = 1.0
            mats[qy, qx] = rec @ sel @ rec.T
    return mats


def upsample_coefficients(coef: np.ndarray, fy: int, fx: int) -> np.ndarray:
    """``(by, bx, 64) → (by·fy, bx·fx, 64)`` coefficient-domain replication
    upsample (chroma to the luma block grid) — no pixels materialised."""
    if fy == 1 and fx == 1:
        return coef
    mats = upsample_matrices(fy, fx)  # (fy, fx, 64out, 64in)
    by, bx, _ = coef.shape
    # out[y, qy, x, qx, j] = sum_k coef[y, x, k] mats[qy, qx, j, k]
    out = np.einsum("yxk,abjk->yaxbj", coef, mats, optimize=True)
    return out.reshape(by * fy, bx * fx, dctlib.NFREQ).astype(coef.dtype)


def fit_grid(coef: np.ndarray, bh: int, bw: int) -> np.ndarray:
    """Zero-pad (bottom/right) or center-crop a ``(by, bx, 64)`` block grid
    to ``(bh, bw, 64)`` — the plan's expected input grid."""
    by, bx, nf = coef.shape
    if by > bh:
        off = (by - bh) // 2
        coef = coef[off: off + bh]
    if bx > bw:
        off = (bx - bw) // 2
        coef = coef[:, off: off + bw]
    by, bx = coef.shape[:2]
    if by < bh or bx < bw:
        out = np.zeros((bh, bw, nf), coef.dtype)
        out[:by, :bx] = coef
        coef = out
    return coef


def normalize_image(dec: DecodedJpeg, *, quality: int,
                    grid: tuple[int, int] | None = None,
                    channels: int | None = None) -> np.ndarray:
    """One decoded file → ``(bh, bw, C, 64)`` float32 network coefficients.

    Per component: de-quantize with the file's own table, rescale into the
    canonical convention, undo chroma subsampling in the coefficient
    domain, crop the MCU padding, then fit the plan's ``grid``.  A
    grayscale file feeding a ``channels=3`` network replicates luma; a
    color file feeding ``channels=1`` keeps only luma.
    """
    hmax = max(c.h for c in dec.components)
    vmax = max(c.v for c in dec.components)
    gain_out = 1.0 / (PIXEL_SCALE * canonical_qtable(quality))
    planes = []
    for i, c in enumerate(dec.components):
        # order matters: de-quantize in the file basis (where quantization
        # happened), upsample in the plain DCT basis, and only then apply
        # the per-index canonical rescale — the upsample map mixes zigzag
        # indices, so a per-index divide must not precede it
        plane = (np.asarray(dec.coefficients[i], np.float64)
                 * np.asarray(dec.qtable(i), np.float64))
        fy, fx = vmax // c.v, hmax // c.h
        if vmax % c.v or hmax % c.h:
            raise ValueError(
                f"non-integer sampling ratio {(vmax, c.v, hmax, c.h)}")
        plane = upsample_coefficients(plane, fy, fx)
        plane = (plane * gain_out).astype(np.float32)
        # crop the MCU padding down to the true luma-grid block dims
        bh_true = -(-dec.height // dctlib.BLOCK)
        bw_true = -(-dec.width // dctlib.BLOCK)
        plane = plane[:bh_true, :bw_true]
        planes.append(plane)
    if channels is not None and len(planes) != channels:
        if len(planes) == 1:
            planes = planes * channels
        elif channels == 1:
            planes = planes[:1]
        else:
            raise ValueError(
                f"file has {len(planes)} components, network wants "
                f"{channels} channels")
    out = np.stack(planes, axis=2)  # (bh, bw, C, 64)
    if grid is not None:
        bh, bw = grid
        out = np.stack([fit_grid(out[:, :, c], bh, bw)
                        for c in range(out.shape[2])], axis=2)
    return np.ascontiguousarray(out, np.float32)
