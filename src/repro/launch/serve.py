"""Batched serving driver: prefill + decode with slot-based batching.

A fixed pool of ``--batch`` decode slots; finished sequences (random length
budget per request — synthetic workload) are replaced by newly prefilling
requests, i.e. continuous batching at slot granularity.  Reports prefill
and decode throughput.  Also serves the paper's jpeg-resnet as a batched
image-classification service (``--arch jpeg-resnet``): batches of JPEG
coefficients in, labels out — the paper's "skip the decompression step"
deployment story.

Two request formats (``--ingest``): ``coefficients`` (pre-materialized
coefficient tensors from the synthetic pipeline — the parity/benchmark
workload) and ``bytes`` — **real baseline JPEG files**, entropy-decoded
and quantization-normalized by ``repro.codec`` on the host (no spatial
decode anywhere) and packed straight into the compiled plan's tile-packed
stem layout.  Byte requests come from ``--jpeg-dir`` when given, else
from a deterministic synthetic stream of *mixed-quality* encodes
(qualities 35/50/75/90 through ``codec.encode_pixels``), exercising the
per-image quantization normalization that lets one plan serve them all.

With ``--qos`` the process serves through the **band-elastic runtime**
(``repro.serving``): the plan is compiled into a ladder of band tiers
(``--tiers``, default autotuned/48/32/24) and an async scheduler with
admission control and per-request deadlines (``--deadline-ms``) picks the
tier per batch from queue depth + deadline slack — degrading bands under
overload, recovering as the queue drains.  Execution runs on the **plan
grid** (``repro.serving.grid``): every (batch bucket × band tier) cell is
precompiled at warmup with pinned, donated buffers (``--batch-buckets``
picks the capture schedule), so steady-state serving performs zero JIT
compiles and pads partial batches only to the covering bucket.  The
report then carries per-request latency percentiles, per-tier throughput
and padding fractions, tier-switch events, compile accounting
(``compiles_total`` / ``compiles_post_warmup`` / ``grid_cell_hits``),
and ingest occupancy (``--report-out`` writes it to a file).  Without
``--qos`` the original fixed-band slot loop serves, but still reports
p50/p95/p99 per-request latency through ``serving.metrics``.

jpeg-resnet serving is **plan-backed** (convert-once): the process restores
an :class:`repro.core.plan.InferencePlan` from ``--plan-dir`` — fused
batch norm, per-layer autotuned bands, apply paths resolved at build time
— and never calls ``precompute_operators`` (let alone re-explodes Ξ) at
serve time.  When the directory holds no usable plan, one is built once,
saved through the checkpoint manager, and *re-loaded from disk* so every
serve run exercises the restore path.  By default the forward runs the
**compiled schedule** (``core.plan.compile_plan``: fused residual-block
steps over tile-packed banded operators, restored from the plan dir's
``compiled/`` subdirectory and compiled+saved whenever the plan itself is
built); ``--no-compiled`` falls back to the per-layer plan walk.  Requests
then run through the same slot pool as the LM driver: each request
classifies a random number of images, finished slots are refilled from the
pending queue.

CPU example:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --requests 12 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch jpeg-resnet \
        --reduced --batch 8 --requests 12 --autotune-bands
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import subprocess
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core import dispatch as dispatchlib
from repro.models.registry import build_model

__all__ = ["main", "serve_lm", "serve_jpeg_resnet", "prepare_plan",
           "prepare_ladder", "parse_tiers", "jpeg_byte_requests",
           "run_metadata"]


def _git_sha() -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return None


def run_metadata(args, *, plan=None, ladder=None, buckets=None) -> dict:
    """Run-identity block embedded in every serve report (``meta``): git
    sha, backend, device count, dispatch config, band tiers, and bucket
    schedule — the same provenance the fig5 benchmark rows carry, so
    reports from different runs/machines are comparable artifacts."""
    meta: dict[str, Any] = {
        "git_sha": _git_sha(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "seed": args.seed,
        "batch": args.batch,
        "requests": args.requests,
        "reduced": bool(getattr(args, "reduced", False)),
        "ingest": getattr(args, "ingest", "coefficients"),
    }
    if plan is not None:
        meta["dispatch"] = plan.cfg.path
        meta["bands_min"] = min(plan.bands.values())
        meta["bands_max"] = max(plan.bands.values())
    if ladder is not None:
        meta["band_tiers"] = [
            {"name": t.name, "cap": t.cap,
             "bands": sorted(set(t.bands.values()))} for t in ladder.tiers]
    if buckets is not None:
        meta["batch_buckets"] = list(buckets)
    return meta

#: quality mix of the synthetic byte stream — one compiled plan serves all
#: of them through codec.normalize's per-image qtable rescale.
BYTE_QUALITIES = (35, 50, 75, 90)


def jpeg_byte_requests(args, cfg, seed: int):
    """Request source for ``--ingest bytes``: ``fn(step) -> list[bytes]``.

    With ``--jpeg-dir``: deterministic (seed, step) sampling from the
    sorted file list (same semantics as ``data.jpeg_file_iterator``).
    Otherwise: the synthetic image corpus entropy-encoded to *real*
    baseline JFIF bytes at a rotating quality mix — genuine compressed
    traffic with per-image quantization tables.
    """
    from repro.data.synthetic import _rng, image_batch

    jpeg_dir = getattr(args, "jpeg_dir", None)
    if jpeg_dir:
        from repro.data.pipeline import list_jpeg_files

        paths = list_jpeg_files(jpeg_dir)
        if not paths:
            raise FileNotFoundError(f"no JPEG files under {jpeg_dir}")

        def from_files(step: int) -> list[bytes]:
            idx = _rng(seed, step).integers(0, len(paths), size=args.batch)
            out = []
            for j in idx:
                with open(paths[j], "rb") as f:
                    out.append(f.read())
            return out

        return from_files

    from repro.codec import encode_pixels
    from repro.core import dct as dctlib

    def from_synthetic(step: int) -> list[bytes]:
        b = image_batch(seed, step, args.batch, cfg.image_size,
                        cfg.in_channels, cfg.num_classes)
        out = []
        for i, img in enumerate(b["images"]):
            q = BYTE_QUALITIES[(step * args.batch + i) % len(BYTE_QUALITIES)]
            # the *true* IJG table (no dc_is_mean) — foreign files don't
            # share the plan's DC convention; normalize rescales exactly
            qt = np.rint(dctlib.quantization_table(
                q, dc_is_mean=False)).astype(np.int64)
            out.append(encode_pixels(np.clip(img, -1.0, 127.0 / 128.0),
                                     qtable=qt))
        return out

    return from_synthetic


def serve_lm(args) -> dict:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    b = args.batch
    cache = model.init_cache(b, args.ctx)

    decode = jax.jit(model.decode_step)

    # synthetic request stream; never start more than args.requests
    started = min(b, args.requests)
    pending = args.requests - started
    budgets = rng.integers(4, args.max_new + 1, size=(b,))
    active = np.arange(b) < started
    produced = np.zeros((b,), np.int64)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 1)), jnp.int32)

    n_tokens = 0
    completed = 0
    t0 = time.time()
    while completed < args.requests:
        logits, cache = decode(params, cache, {"tokens": tokens})
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        tokens = next_tok[:, None]
        n_tokens += int(active.sum())
        produced += active
        done = active & (produced >= budgets)
        for i in np.where(done)[0]:
            completed += 1
            produced[i] = 0
            if pending > 0:
                pending -= 1
                budgets[i] = rng.integers(4, args.max_new + 1)
            else:
                active[i] = False
        if not active.any():
            break
    wall = time.time() - t0
    out = {"arch": cfg.name, "decode_tokens": n_tokens, "wall_s": wall,
           "tokens_per_s": n_tokens / max(wall, 1e-9),
           "completed": completed}
    print(json.dumps(out))
    return out


def prepare_plan(args, cfg, dcfg):
    """Restore the serving plan from ``--plan-dir``, building it first only
    when the directory holds no compatible plan.

    Returns ``(plan, compiled, info)`` where the plan always comes from a
    *disk restore* — a fresh build is saved and re-loaded, so the
    save → CheckpointManager → load round trip is on the serve path by
    construction.  ``compiled`` is the fused static schedule
    (``core.plan.CompiledPlan``) restored from the plan dir's ``compiled/``
    subdirectory: built+saved alongside a fresh plan (and on explicit
    ``--compiled``); ``--no-compiled`` serves the per-layer plan walk, and
    the default uses a compiled schedule whenever the directory holds one.
    """
    from repro.core import plan as planlib
    from repro.core import resnet as R
    from repro.models.registry import jpeg_resnet_spec

    spec = jpeg_resnet_spec(cfg)
    autotune = getattr(args, "autotune_bands", False)
    want_compiled = getattr(args, "compiled", None)
    from_bytes = getattr(args, "ingest", "coefficients") == "bytes"
    plan_dir = args.plan_dir or os.path.join("plans", cfg.name)
    plan, built = None, False
    try:
        plan = planlib.load_plan(plan_dir)
    except (FileNotFoundError, ValueError, KeyError):
        plan = None
    if plan is not None and (
            plan.spec != spec
            or (args.dispatch is not None and plan.cfg.path != args.dispatch)
            or (args.bands is not None
                and set(plan.bands.values()) != {args.bands})
            or (autotune
                and (plan.provenance or {}).get("bands_mode") != "auto")):
        plan = None  # stale artifact for a different config — rebuild
    if plan is None:
        built = True
        params, state = R.init_resnet(jax.random.PRNGKey(args.seed), spec)
        probe, profile, occupancy = None, None, None
        if autotune:
            if from_bytes:
                # probe the *byte* traffic itself: the empirical energy /
                # occupancy stats replace the 1/q² qtable prior, so band
                # truncation is tuned to what the stream actually carries
                from repro.codec import ingest as ingestlib

                n_blocks = cfg.image_size // 8
                probe_np, stats = ingestlib.ingest_batch(
                    jpeg_byte_requests(args, cfg, args.seed + 1)(0),
                    quality=spec.quality, grid=(n_blocks, n_blocks),
                    channels=cfg.in_channels)
                probe = jnp.asarray(probe_np)
                profile, occupancy = stats.energy, stats.occupancy
            else:
                from repro.data import jpeg_iterator

                probe_it = jpeg_iterator(args.seed + 1, 4, cfg.image_size,
                                         cfg.in_channels, cfg.num_classes)
                probe = jnp.asarray(next(probe_it)["coefficients"])
        bands = "auto" if autotune else args.bands
        plan = planlib.build_plan(params, state, spec, dispatch=dcfg,
                                  bands=bands, probe_coef=probe,
                                  profile=profile, occupancy=occupancy)
        planlib.save_plan(plan, plan_dir)
        plan = planlib.load_plan(plan_dir)  # serve from the restored artifact

    compiled = None
    compiled_dir = os.path.join(plan_dir, "compiled")
    if want_compiled is not False:
        had_artifact = False
        if not built:
            try:
                compiled = planlib.load_compiled_plan(compiled_dir)
                had_artifact = True
                if compiled.spec != plan.spec or compiled.bands != plan.bands:
                    compiled = None  # stale schedule for a different plan
            except FileNotFoundError:
                pass
            except (ValueError, KeyError):
                had_artifact = True  # unreadable/foreign — recompile below
        if compiled is None and (built or want_compiled or had_artifact):
            # convert-once: a fresh plan gets its schedule compiled, saved,
            # and re-restored in the same pass; a stale or corrupt schedule
            # is recompiled rather than silently serving the per-layer walk
            planlib.save_compiled_plan(
                planlib.compile_plan(plan, image_size=cfg.image_size),
                compiled_dir)
            compiled = planlib.load_compiled_plan(compiled_dir)
    info = {"dir": plan_dir, "built": built, "bands": plan.bands,
            "path": plan.cfg.path, "fused_bn": True,
            "compiled": compiled is not None}
    if compiled is not None:
        meta = compiled.meta or {}
        info["fused_blocks"] = list(meta.get("fused", []))
        # "steps", not "blocks": a factored stem lands here too
        info["fallback_steps"] = sorted(meta.get("layers", {}))
    return plan, compiled, info


def parse_buckets(spec, batch: int) -> tuple | None:
    """``--batch-buckets`` string → capture buckets: ``auto``/None → the
    aphrodite schedule up to ``--batch`` (derived at grid build);
    ``fixed`` → the single full-batch bucket (pre-grid pad-to-max
    behaviour); else comma ints, e.g. ``1,2,4,8``."""
    if spec in (None, ""):
        return None
    tok = str(spec).strip().lower()
    if tok == "auto":
        return None
    if tok == "fixed":
        return (batch,)
    return tuple(int(t) for t in tok.split(","))


def parse_tiers(spec) -> tuple:
    """``--tiers`` string → ladder caps: ``"auto,48,32,24"`` →
    ``(None, 48, 32, 24)`` (``auto``/``top``/``none`` = the plan's own
    band assignment, untouched).  None/empty → the default ladder."""
    from repro.serving import DEFAULT_CAPS

    if not spec:
        return DEFAULT_CAPS
    caps = []
    for tok in str(spec).split(","):
        tok = tok.strip().lower()
        caps.append(None if tok in ("auto", "top", "none") else int(tok))
    return tuple(caps)


def prepare_ladder(args, cfg, plan, plan_dir):
    """Restore the tier ladder from ``plan_dir``, rebuilding when absent
    or when its caps disagree with ``--tiers`` / its capture buckets
    with ``--batch-buckets`` (same convert-once contract as
    :func:`prepare_plan` — tiers re-derive bit-exactly from the restored
    plan, and the manifest keeps the grid extent so a restart warms up
    the same cells)."""
    from repro import serving

    caps = parse_tiers(getattr(args, "tiers", None))
    buckets = serving.cover_buckets(
        parse_buckets(getattr(args, "batch_buckets", None), args.batch),
        args.batch)
    ladder = None
    try:
        ladder = serving.load_ladder(plan_dir, plan=plan)
        if ladder.caps != caps:
            ladder = None  # different ladder requested — rebuild
        elif ladder.buckets != buckets:
            # same tiers, different grid extent: the buckets live only
            # in the manifest — update it without recompiling any tier.
            # (Not _replace: PlanLadder.__len__ counts tiers, which
            # breaks namedtuple._make's arity check.)
            ladder = serving.PlanLadder(
                ladder.tiers, ladder.base, ladder.caps, ladder.image_size,
                ladder.vmem_budget, buckets)
            serving.save_ladder(ladder, plan_dir, save_base=False)
    except (FileNotFoundError, ValueError, KeyError):
        ladder = None
    if ladder is None:
        ladder = serving.build_ladder(plan, caps=caps,
                                      image_size=cfg.image_size,
                                      buckets=buckets)
        serving.save_ladder(ladder, plan_dir, save_base=False)
    return ladder


def _qos_request_source(args, cfg, seed: int):
    """Per-request payload stream for the QoS runtime: ``fn(i)`` returns
    one image's payload — a coefficient tensor ``(bh, bw, C, 64)`` or one
    JPEG file's bytes — drawn from the same sources the slot loop uses."""

    def per_item(fetch_batch):
        # requests are submitted strictly in order, so one batch of
        # payloads is materialised at a time and evicted on rollover
        cache: dict[int, Any] = {}

        def fn(i: int):
            step = i // args.batch
            if step not in cache:
                cache.clear()
                cache[step] = fetch_batch(step)
            return cache[step][i % args.batch]

        return fn

    if getattr(args, "ingest", "coefficients") == "bytes":
        return per_item(jpeg_byte_requests(args, cfg, seed)), "bytes"

    from repro.data import jpeg_iterator

    it = jpeg_iterator(seed, args.batch, cfg.image_size, cfg.in_channels,
                       cfg.num_classes)
    return per_item(
        lambda step: np.asarray(next(it)["coefficients"])), "coefficients"


def _chaos_faults(args, serving):
    """Build the chaos run's deterministic fault plan (``--chaos``).

    ``--chaos-rate`` of request indices get guaranteed-fail byte
    corruption; one ingest-pool worker is SIGKILLed before the third
    decode batch (driving the BrokenProcessPool supervisor); dispatches
    2..2+``--chaos-exec-faults`` raise in the executor (driving
    containment, retry exhaustion, and the breaker).
    """
    n_exec = getattr(args, "chaos_exec_faults", 2)
    spec = serving.FaultSpec(
        seed=getattr(args, "chaos_seed", 1234),
        corrupt_rate=getattr(args, "chaos_rate", 0.2),
        kill_worker_before_batch=(
            3 if getattr(args, "chaos_kill_worker", True) else None),
        executor_fail_batches=(2, 2 + n_exec) if n_exec else None,
    )
    # thresholds sized so the injected executor-fault burst visibly trips
    # the breaker and the run closes it again: open after 2 consecutive
    # service failures, half-open after 0.5 s, close on the first probe
    policy = serving.BreakerPolicy(window=16, failure_rate=0.5,
                                   min_samples=8, max_consecutive=2,
                                   open_s=0.5, half_open_successes=1)
    return serving.FaultInjector(spec), policy


def _submit_retry(sched, serving, payload, kind, deadline_s,
                  timeout_s: float = 60.0):
    """Chaos-client submit: retry through open-breaker fast-rejects and
    admission-control rejections (what a real client's backoff does)."""
    t0 = time.time()
    while True:
        try:
            r = sched.submit(payload, kind=kind, deadline_s=deadline_s)
        except serving.ServiceUnavailable:
            if time.time() - t0 > timeout_s:
                raise
            time.sleep(0.05)  # breaker open — wait for the half-open probe
            continue
        if r is not None:
            return r
        if time.time() - t0 > timeout_s:
            return None
        time.sleep(0.01)      # queue full — admission backpressure


def _serve_jpeg_qos(args, cfg, plan, plan_info) -> dict:
    """Serve through the band-elastic runtime: saturating burst of
    single-image requests → admission control, per-batch tier selection,
    degradation under overload, recovery on drain.

    ``--chaos`` turns the burst into a fault drill: a deterministic
    fraction of requests get corrupted bytes, one ingest worker is
    killed mid-stream, and a window of dispatches fails in the executor
    — the run then proves healthy requests still completed (with
    bounded client retries through the breaker) while every fault
    surfaced as a typed per-request error.
    """
    from repro import serving
    from repro.core import plan as planlib

    ladder = prepare_ladder(args, cfg, plan, plan_info["dir"])
    names = [t.name for t in ladder.tiers]
    print(f"[serve] band-elastic ladder: "
          + " > ".join(f"{t.name}(bands {min(t.bands.values())}-"
                       f"{max(t.bands.values())})" for t in ladder.tiers))
    n_blocks = cfg.image_size // 8
    total = args.requests
    deadline_s = (args.deadline_ms / 1e3
                  if getattr(args, "deadline_ms", None) else None)
    max_pending = getattr(args, "max_queue", None) or total
    metrics = serving.ServeMetrics()
    payload_of, kind = _qos_request_source(args, cfg, args.seed)

    # observability sidecars — all torn down on *any* exit (flight
    # recorder semantics: a crashed run still leaves its trace behind)
    obs = contextlib.ExitStack()
    tracer = None
    trace_path = getattr(args, "trace_out", None)
    if trace_path:
        tracer = serving.Tracer(
            capacity=int(getattr(args, "trace_capacity", None) or 65536))
        obs.callback(lambda: tracer.write(trace_path))
    metrics_path = getattr(args, "metrics_out", None)
    if metrics_path:
        obs.callback(serving.MetricsWriter(
            metrics, metrics_path,
            interval_s=float(getattr(args, "metrics_interval", None)
                             or 1.0)).close)
    obs.enter_context(
        serving.jax_profile(getattr(args, "jax_profile", None)))

    chaos = getattr(args, "chaos", False)
    faults, breaker_policy = None, None
    if chaos:
        if kind != "bytes":
            raise ValueError("--chaos corrupts JPEG bytes; needs "
                             "--ingest bytes")
        faults, breaker_policy = _chaos_faults(args, serving)
        print(f"[serve] chaos: corrupt_rate="
              f"{faults.spec.corrupt_rate:g} seed={faults.spec.seed} "
              f"kill_worker_before_batch="
              f"{faults.spec.kill_worker_before_batch} "
              f"executor_fail_batches={faults.spec.executor_fail_batches}")

    sched = serving.BandElasticScheduler(
        ladder, batch=args.batch, metrics=metrics, max_pending=max_pending,
        grid=(n_blocks, n_blocks), channels=cfg.in_channels,
        breaker=breaker_policy, faults=faults, tracer=tracer)
    with obs, sched:
        sched.warmup(kinds=(kind,))
        gs = sched.grid_engine.summary()
        print(f"[serve] plan grid: {gs['distinct_columns']} tier columns x "
              f"buckets {gs['buckets']} = {gs['cells']} captured cells "
              f"({gs['host_staging_bytes'] / 2**20:.1f} MiB pinned host "
              f"staging); post-warmup compiles will be reported")
        profile_grid = None
        if getattr(args, "profile_grid", False):
            # pre-traffic capacity sweep: every warmed cell gets a
            # roofline-predicted and a measured wall (captured
            # executables only — zero post-warmup grid compiles), then
            # per-cell predicted capacity lands on the
            # serve_predicted_capacity gauges and device-dispatch spans
            from repro import introspect

            hw = introspect.resolve_profile(getattr(args, "hw_profile",
                                                    None))
            profile_grid = introspect.profile_plan_grid(
                sched.grid_engine, hw=hw)
            for c in profile_grid["cells"]:
                metrics.record_predicted_capacity(
                    c["cell"], c["predicted_req_s"])
            sched.grid_engine.annotate_costs(
                {c["cell"]: {"flops": c["flops"],
                             "predicted_us": c["predicted_us"]}
                 for c in profile_grid["cells"]})
            print(f"[serve] grid profile ({hw.name}): "
                  + "  ".join(
                      f"{c['cell']}={c['predicted_req_s']:.0f}req/s"
                      for c in profile_grid["cells"][:6])
                  + ("  ..." if len(profile_grid["cells"]) > 6 else ""))
        t0 = time.time()
        requests = []  # (request index, ServeRequest)
        payloads = {}
        for i in range(total):
            p = payload_of(i)
            if faults is not None:
                p = faults.corrupt(i, p)
            payloads[i] = p
            if chaos:
                r = _submit_retry(sched, serving, p, kind, deadline_s)
            else:
                r = sched.submit(p, kind=kind, deadline_s=deadline_s)
            if r is not None:
                requests.append((i, r))
        sched.drain()
        if chaos:
            # a real client retries service-level failures; requests the
            # injected executor/ingest faults killed (healthy bytes, bad
            # luck) are resubmitted until the fleet settles.  Corrupt
            # requests are NOT retried — their typed codec errors are
            # the success criterion, not a transient.
            def _retryable(i, r):
                e = r.error()
                return (isinstance(e, serving.RequestFailed)
                        and e.stage in ("executor", "ingest")
                        and i not in faults.corrupted)

            for _round in range(4):
                retry = [k for k, (i, r) in enumerate(requests)
                         if _retryable(i, r)]
                if not retry:
                    break
                for k in retry:
                    i, _ = requests[k]
                    nr = _submit_retry(sched, serving, payloads[i], kind,
                                       deadline_s)
                    if nr is not None:
                        requests[k] = (i, nr)
                sched.drain()
        wall = time.time() - t0
        health = sched.health()

    # top-tier fidelity probe: requests served at the *top* tier must
    # agree (top-1) with the uncompiled per-layer plan walk — the same
    # parity the fixed-band serve path is held to.
    probe = [r for _, r in requests if r.tier == names[0]][: args.batch]
    agree = None
    if probe:
        if kind == "bytes":
            from repro.codec import ingest as ingestlib

            coefs, _ = ingestlib.ingest_batch(
                [r.payload for r in probe], quality=plan.spec.quality,
                grid=(n_blocks, n_blocks), channels=cfg.in_channels,
                with_stats=False)
        else:
            coefs = np.stack([np.asarray(r.payload) for r in probe])
        ref = np.asarray(planlib.apply_plan(plan, jnp.asarray(coefs)))
        served = np.stack([np.asarray(r.result()) for r in probe])
        agree = float(np.mean(ref.argmax(-1) == served.argmax(-1)))

    qos_report = metrics.report()
    qos_report["grid"] = gs
    qos_report["tiers"] = [
        {"name": t.name, "cap": t.cap,
         "bands": sorted(set(t.bands.values()))} for t in ladder.tiers]
    qos_report["top1_agree_top_tier"] = agree
    served_n = len(requests)
    completed = sum(1 for _, r in requests if r.tier is not None)
    out = {"arch": cfg.name, "images": served_n, "wall_s": wall,
           "images_per_s": served_n / max(wall, 1e-9),
           "completed": completed, "rejected": total - served_n,
           "dispatch": plan.cfg.path, "ingest": kind,
           "latency_ms": qos_report["latency_ms"],
           "qos": qos_report, "plan": plan_info,
           "health": health,
           "meta": run_metadata(args, plan=plan, ladder=ladder,
                                buckets=sched.buckets)}
    if profile_grid is not None:
        out["profile_grid"] = profile_grid
    if tracer is not None:
        s = tracer.summary()
        out["trace"] = {"path": trace_path, "events": s["events"],
                        "dropped": s["dropped"],
                        "capacity": s["capacity"]}
        print(f"[serve] flight recorder: {s['events']} events "
              f"({s['dropped']} dropped) -> {trace_path}")
    if metrics_path:
        out["metrics_out"] = metrics_path
    if chaos:
        stages: dict[str, int] = {}
        for _, r in requests:
            e = r.error()
            if isinstance(e, serving.RequestFailed):
                stages[e.stage] = stages.get(e.stage, 0) + 1
            elif e is not None:
                stages[type(e).__name__] = stages.get(
                    type(e).__name__, 0) + 1
        healthy = [i for i in range(total) if i not in faults.corrupted]
        out["chaos"] = {
            "corrupted": len(faults.corrupted),
            "corrupt_modes": {m: sum(1 for v in faults.corrupted.values()
                                     if v == m)
                              for m in set(faults.corrupted.values())},
            "killed_worker_pid": faults.killed_pid,
            "failed_by_stage": stages,
            "healthy_total": len(healthy),
            "healthy_completed": sum(
                1 for i, r in requests
                if i not in faults.corrupted and r.tier is not None),
        }
    _emit_report(args, out)
    return out


def _emit_report(args, out: dict) -> None:
    print(json.dumps(out))
    path = getattr(args, "report_out", None)
    if path:
        with open(path, "w") as f:
            json.dump(out, f, indent=2)


def serve_jpeg_resnet(args) -> dict:
    from repro.core import plan as planlib
    from repro.data import jpeg_iterator

    # The dispatch flags pick the operator path (reference / pallas /
    # factored) and the §6 band truncation before anything is traced or
    # compiled; omitted flags defer to JPEG_DISPATCH / JPEG_BANDS.  They
    # only matter when a plan has to be *built* — a restored plan carries
    # its own frozen config.
    changes = {}
    if args.dispatch is not None:
        changes["path"] = args.dispatch
    if args.bands is not None:
        changes["bands"] = args.bands
    dcfg = dispatchlib.configure(**changes)
    cfg = reduced_config("jpeg-resnet") if args.reduced else get_config("jpeg-resnet")
    plan, compiled, plan_info = prepare_plan(args, cfg, dcfg)

    if getattr(args, "qos", False):
        # thin-CLI handoff: the band-elastic runtime owns batching, tier
        # selection, deadlines, and metrics from here on
        return _serve_jpeg_qos(args, cfg, plan, plan_info)
    if getattr(args, "trace_out", None) or getattr(args, "metrics_out",
                                                   None) \
            or getattr(args, "profile_grid", False):
        print("[serve] --trace-out/--metrics-out/--profile-grid instrument "
              "the QoS runtime; ignored without --qos")

    if compiled is not None:
        meta = compiled.meta or {}
        fused = meta.get("fused", [])
        fallback = sorted(meta.get("layers", {}))
        print(f"[serve] compiled schedule: {len(fused)} blocks fused "
              f"({','.join(fused) or '-'}), {len(fallback)} steps per-layer "
              f"({','.join(fallback) or '-'})")
        fwd = jax.jit(lambda c: planlib.apply_compiled(compiled, c))
    else:
        print("[serve] per-layer plan execution (no compiled schedule)")
        fwd = jax.jit(lambda c: planlib.apply_plan(plan, c))

    spec = plan.spec
    n_blocks = cfg.image_size // 8
    ingest_mode = getattr(args, "ingest", "coefficients")
    jpeg_dir = getattr(args, "jpeg_dir", None)
    if ingest_mode == "bytes":
        # bytes-in request path: entropy decode + per-image quantization
        # normalization on the host (repro.codec — never a spatial
        # decode), packed straight into the compiled stem's tile-packed
        # layout when a compiled schedule is serving
        from repro.codec import ingest as ingestlib

        requests = jpeg_byte_requests(args, cfg, args.seed)
        pack_w = compiled.stem.w_in if compiled is not None else None
        if compiled is not None:
            fwd = jax.jit(
                lambda c: planlib.apply_compiled_packed(compiled, c))
        collected = []
        ingest_kw = dict(quality=spec.quality, grid=(n_blocks, n_blocks),
                         channels=cfg.in_channels, pack_width=pack_w)
        pipe = {"it": None}

        def byte_stream():
            step = 1  # step 0 feeds the warmup inline
            while True:
                yield requests(step)
                step += 1

        def next_batch(step: int) -> jnp.ndarray:
            if step == 0:
                batch, _ = ingestlib.ingest_batch(requests(0), **ingest_kw)
                return jnp.asarray(batch)
            if pipe["it"] is None:
                # double-buffered: decode of batch N+1 overlaps the
                # device walk of batch N on the prefetch producer thread
                pipe["it"] = ingestlib.ingest_pipeline(
                    byte_stream(), depth=2, **ingest_kw)
            batch, stats = next(pipe["it"])
            collected.append(stats)
            return jnp.asarray(batch)

        layout = f"tile-packed w={pack_w}" if pack_w else "64-wide"
        source = (f"files from {jpeg_dir}" if jpeg_dir
                  else "synthetic mixed-quality stream")
        print(f"[serve] bytes-in ingest: {layout} ({source}), "
              f"overlapped decode ({ingestlib.ingest_workers()} workers)")
    else:
        it = jpeg_iterator(args.seed, args.batch, cfg.image_size,
                           cfg.in_channels, cfg.num_classes)

        def next_batch(step: int) -> jnp.ndarray:
            return jnp.asarray(next(it)["coefficients"])

    # warmup/compile
    fwd(next_batch(0)).block_until_ready()
    if ingest_mode == "bytes":
        collected.clear()  # the timed window starts after warmup

    # slot-based continuous batching (same structure as serve_lm): each
    # request classifies a random number of images; finished slots refill
    # from the pending queue so the batch stays full until the tail.
    from repro.serving import metrics as servemetrics

    rng = np.random.default_rng(args.seed)
    b = args.batch
    max_imgs = max(args.max_new, 1)
    # never start more requests than were asked for (requests < batch
    # leaves the tail slots idle)
    started = min(b, args.requests)
    pending = args.requests - started
    budgets = rng.integers(1, max_imgs + 1, size=(b,))
    active = np.arange(b) < started
    produced = np.zeros((b,), np.int64)
    n_imgs = 0
    completed = 0
    step = 1  # step 0 fed the warmup
    t0 = time.time()
    # per-request latency: a slot's request starts when the slot is
    # (re)filled and completes when its image budget is met
    slot_start = np.full((b,), t0)
    latencies: list[float] = []
    try:
        while completed < args.requests and active.any():
            logits = fwd(next_batch(step))
            step += 1
            logits.block_until_ready()  # labels would ship to clients here
            now = time.time()
            n_imgs += int(active.sum())
            produced += active
            done = active & (produced >= budgets)
            for i in np.where(done)[0]:
                completed += 1
                produced[i] = 0
                latencies.append(now - slot_start[i])
                slot_start[i] = now
                if pending > 0:
                    pending -= 1
                    budgets[i] = rng.integers(1, max_imgs + 1)
                else:
                    active[i] = False
    finally:
        if ingest_mode == "bytes" and pipe["it"] is not None:
            pipe["it"].close()  # joins the decode producer thread
    wall = time.time() - t0
    out = {"arch": cfg.name, "images": n_imgs, "wall_s": wall,
           "images_per_s": n_imgs / max(wall, 1e-9),
           "completed": completed, "dispatch": plan.cfg.path,
           "ingest": ingest_mode,
           "latency_ms": servemetrics.percentiles(latencies),
           "plan": plan_info,
           "meta": run_metadata(args, plan=plan)}
    if ingest_mode == "bytes" and collected:
        from repro.codec import merge_stats

        ingest_stats = merge_stats(collected)
        out["ingest_stats"] = {
            "images": ingest_stats.images,
            "bytes_in": ingest_stats.bytes_in,
            "mb_per_s": ingest_stats.bytes_in / max(wall, 1e-9) / 2**20,
            "mean_nonzero_per_block": round(ingest_stats.mean_nonzero, 2),
            "workers": ingestlib.ingest_workers(),
            "overlap": "pipeline(depth=2)",
        }
    _emit_report(args, out)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dispatch", default=None,
                    choices=("auto",) + dispatchlib.PATHS,
                    help="jpeg-resnet operator path (core.dispatch; "
                         "default: JPEG_DISPATCH env or auto)")
    ap.add_argument("--bands", type=int, default=None,
                    help="zigzag coefficients kept (paper §6 sparsity; "
                         "default: JPEG_BANDS env or 64)")
    ap.add_argument("--plan-dir", default=None,
                    help="jpeg-resnet InferencePlan checkpoint directory "
                         "(default plans/<arch>); restored at startup, "
                         "built+saved once if absent")
    ap.add_argument("--ingest", default="coefficients",
                    choices=("coefficients", "bytes"),
                    help="jpeg-resnet request format: pre-materialized "
                         "coefficient tensors, or real baseline JPEG "
                         "bytes through the repro.codec ingest path "
                         "(entropy decode + quantization normalization, "
                         "no spatial decode)")
    ap.add_argument("--jpeg-dir", default=None,
                    help="directory of .jpg files to serve with "
                         "--ingest bytes (default: synthetic "
                         "mixed-quality encoded stream)")
    ap.add_argument("--autotune-bands", action="store_true",
                    help="when building the plan, pick per-layer bands "
                         "from the quantization table + a parity sweep "
                         "instead of the global knob")
    ap.add_argument("--qos", action="store_true",
                    help="serve jpeg-resnet through the band-elastic "
                         "runtime (repro.serving): compiled-plan ladder "
                         "+ async scheduler + queue-depth/deadline tier "
                         "policy; --requests single-image requests are "
                         "submitted as a saturating burst")
    ap.add_argument("--tiers", default=None,
                    help="ladder band caps for --qos, best first, e.g. "
                         "'auto,48,32,24' (auto = the plan's own "
                         "autotuned assignment; default that ladder)")
    ap.add_argument("--batch-buckets", default=None,
                    help="batch capture buckets of the --qos plan grid: "
                         "'auto' (default; aphrodite schedule 1,2,4 then "
                         "multiples of 8 up to --batch), 'fixed' (single "
                         "full-batch bucket — the pre-grid pad-to-max "
                         "behaviour), or comma ints e.g. '1,2,4,8'")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline for --qos; feeds the "
                         "QoS tier policy and the deadline-miss metric")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-control bound on queued requests "
                         "for --qos (default: accept the whole burst)")
    ap.add_argument("--report-out", default=None,
                    help="also write the serve report JSON to this path")
    ap.add_argument("--trace-out", default=None,
                    help="write the --qos flight-recorder trace (Chrome "
                         "trace-event JSON, Perfetto-loadable: per-request "
                         "admission/queue/decode/dispatch spans, tier and "
                         "breaker instants, batch->request flow links) "
                         "to this path — written on any exit, crash "
                         "included")
    ap.add_argument("--trace-capacity", type=int, default=65536,
                    help="flight-recorder ring size in events; when full "
                         "the oldest events are dropped (and counted)")
    ap.add_argument("--metrics-out", default=None,
                    help="write Prometheus-style text metrics snapshots "
                         "(serving.ServeMetrics.metrics_text) to this "
                         "path periodically during --qos serving")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="seconds between --metrics-out snapshots")
    ap.add_argument("--jax-profile", default=None,
                    help="directory for a jax.profiler device trace "
                         "covering the same window as --trace-out")
    ap.add_argument("--chaos", action="store_true",
                    help="fault-drill the --qos byte stream: corrupt a "
                         "fraction of requests (guaranteed-fail byte "
                         "mutations), SIGKILL an ingest-pool worker "
                         "mid-stream, and fail a window of executor "
                         "dispatches — healthy requests must still "
                         "complete; faults must surface as typed "
                         "per-request errors (serving.faults)")
    ap.add_argument("--chaos-rate", type=float, default=0.2,
                    help="fraction of requests whose bytes are corrupted "
                         "under --chaos (default 0.2)")
    ap.add_argument("--chaos-seed", type=int, default=1234,
                    help="fault-injection seed: corruption placement is "
                         "deterministic in (seed, request index)")
    ap.add_argument("--chaos-kill-worker", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="SIGKILL one ingest-pool worker before the third "
                         "decode batch (exercises the BrokenProcessPool "
                         "supervisor; needs JPEG_INGEST_WORKERS > 1)")
    ap.add_argument("--chaos-exec-faults", type=int, default=2,
                    help="how many worker dispatches raise injected "
                         "executor faults (window starts at dispatch 2; "
                         "sized to trip the chaos breaker policy)")
    ap.add_argument("--profile-grid", action="store_true",
                    help="after --qos warmup, sweep every captured "
                         "(tier x bucket) grid cell: roofline-predicted "
                         "+ measured latency per cell, predicted "
                         "capacity (req/s) on the "
                         "serve_predicted_capacity gauge family and in "
                         "the report's profile_grid section; dispatch "
                         "trace spans gain flops/predicted_us args")
    ap.add_argument("--hw-profile", default=None,
                    help="roofline hardware profile for --profile-grid: "
                         "registry name (introspect.PROFILES), "
                         "'peak_flops,hbm_bw,link_bw' triple, or unset "
                         "for backend detection / $JPEG_HW_PROFILE")
    ap.add_argument("--compiled", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="serve the compiled fused-block schedule "
                         "(plan.compile_plan).  Default: on when the plan "
                         "dir holds a compiled schedule (one is compiled "
                         "and saved whenever the plan itself is built); "
                         "--no-compiled forces the per-layer plan walk")
    args = ap.parse_args()
    if args.arch == "jpeg-resnet":
        serve_jpeg_resnet(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
