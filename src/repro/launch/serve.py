"""Batched serving driver: prefill + decode with slot-based batching.

A fixed pool of ``--batch`` decode slots; finished sequences (random length
budget per request — synthetic workload) are replaced by newly prefilling
requests, i.e. continuous batching at slot granularity.  Reports prefill
and decode throughput.  Also serves the paper's jpeg-resnet as a batched
image-classification service (``--arch jpeg-resnet``): batches of JPEG
coefficients in, labels out — the paper's "skip the decompression step"
deployment story.

CPU example:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core import dispatch as dispatchlib
from repro.models.registry import build_model

__all__ = ["main", "serve_lm", "serve_jpeg_resnet"]


def serve_lm(args) -> dict:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    b = args.batch
    cache = model.init_cache(b, args.ctx)

    decode = jax.jit(model.decode_step)

    # synthetic request stream
    pending = args.requests
    budgets = rng.integers(4, args.max_new + 1, size=(b,))
    pending -= b
    active = np.ones((b,), bool)
    produced = np.zeros((b,), np.int64)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 1)), jnp.int32)

    n_tokens = 0
    completed = 0
    t0 = time.time()
    while completed < args.requests:
        logits, cache = decode(params, cache, {"tokens": tokens})
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        tokens = next_tok[:, None]
        n_tokens += int(active.sum())
        produced += active
        done = active & (produced >= budgets)
        for i in np.where(done)[0]:
            completed += 1
            produced[i] = 0
            if pending > 0:
                pending -= 1
                budgets[i] = rng.integers(4, args.max_new + 1)
            else:
                active[i] = False
        if not active.any():
            break
    wall = time.time() - t0
    out = {"arch": cfg.name, "decode_tokens": n_tokens, "wall_s": wall,
           "tokens_per_s": n_tokens / max(wall, 1e-9),
           "completed": completed}
    print(json.dumps(out))
    return out


def serve_jpeg_resnet(args) -> dict:
    from repro.data import jpeg_iterator

    # The whole forward goes through core.dispatch: the flags pick the
    # operator path (reference / pallas / factored) and the §6 band
    # truncation before anything is traced/compiled.  Omitted flags defer
    # to the JPEG_DISPATCH / JPEG_BANDS environment defaults.
    changes = {}
    if args.dispatch is not None:
        changes["path"] = args.dispatch
    if args.bands is not None:
        changes["bands"] = args.bands
    dcfg = dispatchlib.configure(**changes)
    cfg = reduced_config("jpeg-resnet") if args.reduced else get_config("jpeg-resnet")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    it = jpeg_iterator(args.seed, args.batch, cfg.image_size,
                       cfg.in_channels, cfg.num_classes)
    fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
    # warmup/compile
    batch = next(it)
    fwd(params, {k: jnp.asarray(v) for k, v in batch.items()}).block_until_ready()
    n_imgs = 0
    t0 = time.time()
    for _ in range(args.requests):
        batch = next(it)
        logits = fwd(params, {k: jnp.asarray(v) for k, v in batch.items()})
        logits.block_until_ready()
        n_imgs += args.batch
    wall = time.time() - t0
    out = {"arch": cfg.name, "images": n_imgs, "wall_s": wall,
           "images_per_s": n_imgs / max(wall, 1e-9),
           "dispatch": dcfg.path, "bands": dcfg.bands}
    print(json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dispatch", default=None,
                    choices=("auto",) + dispatchlib.PATHS,
                    help="jpeg-resnet operator path (core.dispatch; "
                         "default: JPEG_DISPATCH env or auto)")
    ap.add_argument("--bands", type=int, default=None,
                    help="zigzag coefficients kept (paper §6 sparsity; "
                         "default: JPEG_BANDS env or 64)")
    args = ap.parse_args()
    if args.arch == "jpeg-resnet":
        serve_jpeg_resnet(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
