"""Batched serving driver: prefill + decode with slot-based batching.

A fixed pool of ``--batch`` decode slots; finished sequences (random length
budget per request — synthetic workload) are replaced by newly prefilling
requests, i.e. continuous batching at slot granularity.  Reports prefill
and decode throughput.  Also serves the paper's jpeg-resnet as a batched
image-classification service (``--arch jpeg-resnet``): batches of JPEG
coefficients in, labels out — the paper's "skip the decompression step"
deployment story.

jpeg-resnet serving is **plan-backed** (convert-once): the process restores
an :class:`repro.core.plan.InferencePlan` from ``--plan-dir`` — fused
batch norm, per-layer autotuned bands, apply paths resolved at build time
— and never calls ``precompute_operators`` (let alone re-explodes Ξ) at
serve time.  When the directory holds no usable plan, one is built once,
saved through the checkpoint manager, and *re-loaded from disk* so every
serve run exercises the restore path.  By default the forward runs the
**compiled schedule** (``core.plan.compile_plan``: fused residual-block
steps over tile-packed banded operators, restored from the plan dir's
``compiled/`` subdirectory and compiled+saved whenever the plan itself is
built); ``--no-compiled`` falls back to the per-layer plan walk.  Requests
then run through the same slot pool as the LM driver: each request
classifies a random number of images, finished slots are refilled from the
pending queue.

CPU example:
    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --reduced --batch 4 --requests 12 --max-new 16
    PYTHONPATH=src python -m repro.launch.serve --arch jpeg-resnet \
        --reduced --batch 8 --requests 12 --autotune-bands
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core import dispatch as dispatchlib
from repro.models.registry import build_model

__all__ = ["main", "serve_lm", "serve_jpeg_resnet", "prepare_plan"]


def serve_lm(args) -> dict:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    b = args.batch
    cache = model.init_cache(b, args.ctx)

    decode = jax.jit(model.decode_step)

    # synthetic request stream; never start more than args.requests
    started = min(b, args.requests)
    pending = args.requests - started
    budgets = rng.integers(4, args.max_new + 1, size=(b,))
    active = np.arange(b) < started
    produced = np.zeros((b,), np.int64)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, 1)), jnp.int32)

    n_tokens = 0
    completed = 0
    t0 = time.time()
    while completed < args.requests:
        logits, cache = decode(params, cache, {"tokens": tokens})
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        tokens = next_tok[:, None]
        n_tokens += int(active.sum())
        produced += active
        done = active & (produced >= budgets)
        for i in np.where(done)[0]:
            completed += 1
            produced[i] = 0
            if pending > 0:
                pending -= 1
                budgets[i] = rng.integers(4, args.max_new + 1)
            else:
                active[i] = False
        if not active.any():
            break
    wall = time.time() - t0
    out = {"arch": cfg.name, "decode_tokens": n_tokens, "wall_s": wall,
           "tokens_per_s": n_tokens / max(wall, 1e-9),
           "completed": completed}
    print(json.dumps(out))
    return out


def prepare_plan(args, cfg, dcfg):
    """Restore the serving plan from ``--plan-dir``, building it first only
    when the directory holds no compatible plan.

    Returns ``(plan, compiled, info)`` where the plan always comes from a
    *disk restore* — a fresh build is saved and re-loaded, so the
    save → CheckpointManager → load round trip is on the serve path by
    construction.  ``compiled`` is the fused static schedule
    (``core.plan.CompiledPlan``) restored from the plan dir's ``compiled/``
    subdirectory: built+saved alongside a fresh plan (and on explicit
    ``--compiled``); ``--no-compiled`` serves the per-layer plan walk, and
    the default uses a compiled schedule whenever the directory holds one.
    """
    from repro.core import plan as planlib
    from repro.core import resnet as R
    from repro.models.registry import jpeg_resnet_spec

    spec = jpeg_resnet_spec(cfg)
    autotune = getattr(args, "autotune_bands", False)
    want_compiled = getattr(args, "compiled", None)
    plan_dir = args.plan_dir or os.path.join("plans", cfg.name)
    plan, built = None, False
    try:
        plan = planlib.load_plan(plan_dir)
    except (FileNotFoundError, ValueError, KeyError):
        plan = None
    if plan is not None and (
            plan.spec != spec
            or (args.dispatch is not None and plan.cfg.path != args.dispatch)
            or (args.bands is not None
                and set(plan.bands.values()) != {args.bands})
            or (autotune
                and (plan.provenance or {}).get("bands_mode") != "auto")):
        plan = None  # stale artifact for a different config — rebuild
    if plan is None:
        built = True
        params, state = R.init_resnet(jax.random.PRNGKey(args.seed), spec)
        probe = None
        if autotune:
            from repro.data import jpeg_iterator

            probe_it = jpeg_iterator(args.seed + 1, 4, cfg.image_size,
                                     cfg.in_channels, cfg.num_classes)
            probe = jnp.asarray(next(probe_it)["coefficients"])
        bands = "auto" if autotune else args.bands
        plan = planlib.build_plan(params, state, spec, dispatch=dcfg,
                                  bands=bands, probe_coef=probe)
        planlib.save_plan(plan, plan_dir)
        plan = planlib.load_plan(plan_dir)  # serve from the restored artifact

    compiled = None
    compiled_dir = os.path.join(plan_dir, "compiled")
    if want_compiled is not False:
        had_artifact = False
        if not built:
            try:
                compiled = planlib.load_compiled_plan(compiled_dir)
                had_artifact = True
                if compiled.spec != plan.spec or compiled.bands != plan.bands:
                    compiled = None  # stale schedule for a different plan
            except FileNotFoundError:
                pass
            except (ValueError, KeyError):
                had_artifact = True  # unreadable/foreign — recompile below
        if compiled is None and (built or want_compiled or had_artifact):
            # convert-once: a fresh plan gets its schedule compiled, saved,
            # and re-restored in the same pass; a stale or corrupt schedule
            # is recompiled rather than silently serving the per-layer walk
            planlib.save_compiled_plan(
                planlib.compile_plan(plan, image_size=cfg.image_size),
                compiled_dir)
            compiled = planlib.load_compiled_plan(compiled_dir)
    info = {"dir": plan_dir, "built": built, "bands": plan.bands,
            "path": plan.cfg.path, "fused_bn": True,
            "compiled": compiled is not None}
    if compiled is not None:
        meta = compiled.meta or {}
        info["fused_blocks"] = list(meta.get("fused", []))
        # "steps", not "blocks": a factored stem lands here too
        info["fallback_steps"] = sorted(meta.get("layers", {}))
    return plan, compiled, info


def serve_jpeg_resnet(args) -> dict:
    from repro.core import plan as planlib
    from repro.data import jpeg_iterator

    # The dispatch flags pick the operator path (reference / pallas /
    # factored) and the §6 band truncation before anything is traced or
    # compiled; omitted flags defer to JPEG_DISPATCH / JPEG_BANDS.  They
    # only matter when a plan has to be *built* — a restored plan carries
    # its own frozen config.
    changes = {}
    if args.dispatch is not None:
        changes["path"] = args.dispatch
    if args.bands is not None:
        changes["bands"] = args.bands
    dcfg = dispatchlib.configure(**changes)
    cfg = reduced_config("jpeg-resnet") if args.reduced else get_config("jpeg-resnet")
    plan, compiled, plan_info = prepare_plan(args, cfg, dcfg)

    if compiled is not None:
        meta = compiled.meta or {}
        fused = meta.get("fused", [])
        fallback = sorted(meta.get("layers", {}))
        print(f"[serve] compiled schedule: {len(fused)} blocks fused "
              f"({','.join(fused) or '-'}), {len(fallback)} steps per-layer "
              f"({','.join(fallback) or '-'})")
        fwd = jax.jit(lambda c: planlib.apply_compiled(compiled, c))
    else:
        print("[serve] per-layer plan execution (no compiled schedule)")
        fwd = jax.jit(lambda c: planlib.apply_plan(plan, c))
    it = jpeg_iterator(args.seed, args.batch, cfg.image_size,
                       cfg.in_channels, cfg.num_classes)
    # warmup/compile
    fwd(jnp.asarray(next(it)["coefficients"])).block_until_ready()

    # slot-based continuous batching (same structure as serve_lm): each
    # request classifies a random number of images; finished slots refill
    # from the pending queue so the batch stays full until the tail.
    rng = np.random.default_rng(args.seed)
    b = args.batch
    max_imgs = max(args.max_new, 1)
    # never start more requests than were asked for (requests < batch
    # leaves the tail slots idle)
    started = min(b, args.requests)
    pending = args.requests - started
    budgets = rng.integers(1, max_imgs + 1, size=(b,))
    active = np.arange(b) < started
    produced = np.zeros((b,), np.int64)
    n_imgs = 0
    completed = 0
    t0 = time.time()
    while completed < args.requests and active.any():
        logits = fwd(jnp.asarray(next(it)["coefficients"]))
        logits.block_until_ready()  # labels would ship to clients here
        n_imgs += int(active.sum())
        produced += active
        done = active & (produced >= budgets)
        for i in np.where(done)[0]:
            completed += 1
            produced[i] = 0
            if pending > 0:
                pending -= 1
                budgets[i] = rng.integers(1, max_imgs + 1)
            else:
                active[i] = False
    wall = time.time() - t0
    out = {"arch": cfg.name, "images": n_imgs, "wall_s": wall,
           "images_per_s": n_imgs / max(wall, 1e-9),
           "completed": completed, "dispatch": plan.cfg.path,
           "plan": plan_info}
    print(json.dumps(out))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=256)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dispatch", default=None,
                    choices=("auto",) + dispatchlib.PATHS,
                    help="jpeg-resnet operator path (core.dispatch; "
                         "default: JPEG_DISPATCH env or auto)")
    ap.add_argument("--bands", type=int, default=None,
                    help="zigzag coefficients kept (paper §6 sparsity; "
                         "default: JPEG_BANDS env or 64)")
    ap.add_argument("--plan-dir", default=None,
                    help="jpeg-resnet InferencePlan checkpoint directory "
                         "(default plans/<arch>); restored at startup, "
                         "built+saved once if absent")
    ap.add_argument("--autotune-bands", action="store_true",
                    help="when building the plan, pick per-layer bands "
                         "from the quantization table + a parity sweep "
                         "instead of the global knob")
    ap.add_argument("--compiled", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="serve the compiled fused-block schedule "
                         "(plan.compile_plan).  Default: on when the plan "
                         "dir holds a compiled schedule (one is compiled "
                         "and saved whenever the plan itself is built); "
                         "--no-compiled forces the per-layer plan walk")
    args = ap.parse_args()
    if args.arch == "jpeg-resnet":
        serve_jpeg_resnet(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
