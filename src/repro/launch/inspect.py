"""Plan introspection CLI: per-block predicted-vs-measured attribution.

Restores (or builds, convert-once — the same :func:`serve.prepare_plan`
path the serving driver uses) the compiled plan from ``--plan-dir``,
runs :func:`repro.introspect.predicted_vs_measured` on a deterministic
coefficient batch, prints the per-block table, and writes the validated
JSON report to ``--report-out``.  The report is the versioned schema
``introspect.validate_report`` checks — the CI ``introspect-smoke`` job
runs exactly this command and re-validates the artifact.

CPU example:
    PYTHONPATH=src python -m repro.launch.inspect --arch jpeg-resnet \
        --reduced --plan-dir plans/inspect --batch 16 \
        --report-out introspect_report.json
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced_config
from repro.core import dispatch as dispatchlib
from repro.data import jpeg_iterator
from repro import introspect
from repro.launch import serve as servelib

__all__ = ["main", "run_inspect"]


def resolve_executor(spec: str | None) -> str | None:
    """``--executor`` → the ``apply_compiled`` executor argument.

    ``auto`` mirrors the serving scheduler: the compiled schedule's own
    dispatch path on TPU, the band-elastic GEMM reference off-TPU."""
    tok = (spec or "auto").strip().lower()
    if tok == "auto":
        return None if jax.default_backend() == "tpu" else "gemm"
    if tok in ("plan", "dispatch", "none"):
        return None
    if tok == "gemm":
        return "gemm"
    raise SystemExit(f"unknown --executor {spec!r} "
                     "(expected auto | gemm | plan)")


def run_inspect(args) -> dict:
    changes = {}
    if args.dispatch is not None:
        changes["path"] = args.dispatch
    if args.bands is not None:
        changes["bands"] = args.bands
    dcfg = dispatchlib.configure(**changes)
    cfg = (reduced_config(args.arch) if args.reduced
           else get_config(args.arch))
    plan, compiled, plan_info = servelib.prepare_plan(args, cfg, dcfg)
    if compiled is None:
        raise SystemExit("[inspect] no compiled schedule for this plan "
                         "(per-layer walk has no step table to attribute)")

    it = jpeg_iterator(args.seed, args.batch, cfg.image_size,
                       cfg.in_channels, cfg.num_classes)
    coef = jnp.asarray(next(it)["coefficients"])

    executor = resolve_executor(args.executor)
    hw = introspect.resolve_profile(args.hw_profile)
    print(f"[inspect] plan {plan_info['dir']} "
          f"({'built' if plan_info['built'] else 'restored'}), "
          f"{len(plan_info.get('fused_blocks', []))} fused blocks, "
          f"executor={executor or 'plan'}, hw={hw.name}")
    report = introspect.predicted_vs_measured(
        compiled, coef, executor=executor, hw=hw, iters=args.iters,
        warmup=args.warmup)
    report["meta"]["plan"] = plan_info

    print(introspect.render_text(report))
    summary = introspect.validate_report(report)  # raises on violations
    if args.report_out:
        with open(args.report_out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"[inspect] report written to {args.report_out} "
              f"({summary['blocks']} blocks, reconciliation "
              f"{summary['reconciliation']:.3f})")
    return report


def main() -> None:
    ap = argparse.ArgumentParser(
        description="per-block cost attribution for a compiled plan")
    ap.add_argument("--arch", default="jpeg-resnet")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--iters", type=int, default=5,
                    help="profiled/unprofiled timing iterations (medians)")
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-dir", default=None,
                    help="plan checkpoint directory (restored when "
                         "present, built+saved once otherwise)")
    ap.add_argument("--dispatch", default=None,
                    help="operator path when the plan must be built "
                         "(reference | pallas | factored)")
    ap.add_argument("--bands", type=int, default=None,
                    help="band truncation when the plan must be built")
    ap.add_argument("--autotune-bands", action="store_true")
    ap.add_argument("--executor", default="auto",
                    help="schedule executor: auto (backend-resolved) | "
                         "gemm | plan")
    ap.add_argument("--hw-profile", default=None,
                    help="roofline hardware profile: registry name "
                         f"({', '.join(sorted(introspect.PROFILES))}), "
                         "'peak_flops,hbm_bw,link_bw' triple, or unset "
                         "for backend detection / $JPEG_HW_PROFILE")
    ap.add_argument("--report-out", default=None,
                    help="write the validated JSON report here")
    args = ap.parse_args()
    # prepare_plan reads these off the serve namespace; pin them to the
    # introspection defaults (compiled schedule forced on — attribution
    # needs the step table — and coefficient ingest)
    args.compiled = True
    args.ingest = "coefficients"
    try:
        run_inspect(args)
    except ValueError as e:
        print(f"[inspect] INVALID: {e}", file=sys.stderr)
        raise SystemExit(2)


if __name__ == "__main__":
    main()
