"""Step builders + sharding trees: where models meet the mesh.

``build_train_step`` / ``build_prefill_step`` / ``build_decode_step`` return
(step_fn, in_shardings, out_shardings-ish, example args builder) bundles the
launcher and the dry-run share, so a compile success in the dry-run is a
compile success in the trainer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models.registry import Model, input_specs
from repro.optim import (
    accumulate_microbatches, clip_by_global_norm, compress_grads,
    make_optimizer, make_schedule,
)
from repro.parallel.sharding import (
    AxisRules, batch_pspec, cache_pspec, param_pspec, zero1_pspec,
)

__all__ = [
    "path_str", "params_shardings", "opt_shardings", "batch_shardings",
    "cache_shardings", "build_train_step", "build_prefill_step",
    "build_decode_step", "TrainStepBundle",
]


def path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _tree_shardings(mesh, tree, spec_fn: Callable[[str, tuple], P]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        shape = tuple(getattr(leaf, "shape", ()))
        out.append(NamedSharding(mesh, spec_fn(path_str(path), shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def params_shardings(mesh, params_tree, cfg: ModelConfig):
    return _tree_shardings(
        mesh, params_tree, lambda p, s: param_pspec(p, s, cfg))


def opt_shardings(mesh, opt_tree, cfg: ModelConfig, rules: AxisRules,
                  zero1: bool = True):
    def spec(path, shape):
        if not shape:
            return P()
        ps = param_pspec(path, shape, cfg)
        return zero1_pspec(ps, shape, rules) if zero1 else ps
    return _tree_shardings(mesh, opt_tree, spec)


def batch_shardings(mesh, batch_tree, rules: AxisRules, global_batch: int):
    baxes = batch_pspec(rules, global_batch)
    bspec = baxes if baxes else None

    def spec(path, shape):
        if not shape:
            return P()
        return P(bspec, *([None] * (len(shape) - 1)))
    return _tree_shardings(mesh, batch_tree, spec)


def cache_shardings(mesh, cache_tree, cfg: ModelConfig, rules: AxisRules,
                    global_batch: int):
    """Decode-cache shardings: batch over (pod, data) when divisible, cache
    sequence over the leftover axes (sequence-parallel KV — the flash-decode
    layout; XLA inserts the partial-softmax combines)."""
    baxes, seq_axes = cache_pspec(rules, global_batch)
    bspec = baxes if baxes else None
    sspec = tuple(a for a in seq_axes if a not in (baxes or ()))
    sspec = sspec if sspec else None
    model_ax = "model"

    def spec(path, shape):
        if not shape:
            return P()
        p = path.lower()
        if "cross" in p and shape and len(shape) == 5:
            return P(None, bspec, None, None, None)
        if p.endswith("/k") or p.endswith("/v"):
            # (n_periods, B, T, KVH, hd)
            return P(None, bspec, sspec, None, None)
        if "wkv" in p:  # (n_periods, B, H, hs, hs)
            ok = len(shape) == 5 and shape[2] % max(rules.size("model"), 1) == 0
            return P(None, bspec, model_ax if ok else None, None, None)
        if "ssm" in p:  # (n_periods, B, di, ds)
            return P(None, bspec, model_ax, None)
        if "conv" in p:  # (n_periods, B, dc-1, di)
            return P(None, bspec, None, model_ax)
        if "shift" in p:  # (n_periods, B, 1, D)
            return P(None, bspec, None, None)
        return P()  # index and other scalars

    return _tree_shardings(mesh, cache_tree, spec)


class TrainStepBundle(NamedTuple):
    step_fn: Callable
    params_shape: Any
    opt_shape: Any
    in_shardings: tuple
    out_shardings: tuple
    init_fns: tuple  # (init_params(key), opt_init(params))


def build_train_step(model: Model, run: RunConfig, mesh, rules: AxisRules
                     ) -> TrainStepBundle:
    """Fused loss+grad+update step with DP/TP/EP shardings and ZeRO-1."""
    cfg, tc = model.cfg, run.train
    optimizer = make_optimizer(
        tc.optimizer, b1=tc.beta1, b2=tc.beta2, eps=tc.eps,
        weight_decay=tc.weight_decay)
    schedule = make_schedule(tc.schedule, tc.learning_rate, tc.warmup_steps,
                             tc.total_steps)

    def pure_loss(params, batch):
        return model.loss_fn(params, batch)[0]

    def grad_constraint(tree):
        """ZeRO-2: shard the fp32 grad accumulator over the data axis."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for path, leaf in flat:
            ps = param_pspec(path_str(path), leaf.shape, cfg)
            ps = zero1_pspec(ps, leaf.shape, rules)
            out.append(jax.lax.with_sharding_constraint(leaf, ps))
        return jax.tree_util.tree_unflatten(treedef, out)

    def train_step(params, opt_state, batch):
        loss, grads = accumulate_microbatches(
            pure_loss, params, batch, tc.grad_accum,
            grad_constraint=grad_constraint if tc.zero1 else None)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        grads = compress_grads(grads, tc.grad_compression)
        lr = schedule(opt_state.step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_params, new_opt, metrics

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    p_sh = params_shardings(mesh, params_shape, cfg)
    o_sh = opt_shardings(mesh, opt_shape, cfg, rules, tc.zero1)
    batch_tree = input_specs(cfg, run.shape, dryrun=True)
    b_sh = batch_shardings(mesh, batch_tree, rules, run.shape.global_batch)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P()),
                  "lr": NamedSharding(mesh, P())}
    return TrainStepBundle(
        step_fn=train_step,
        params_shape=params_shape,
        opt_shape=opt_shape,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, metrics_sh),
        init_fns=(model.init_params, optimizer.init),
    )


def build_prefill_step(model: Model, run: RunConfig, mesh, rules: AxisRules):
    cfg = model.cfg

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    p_sh = params_shardings(mesh, params_shape, cfg)
    batch_tree = input_specs(cfg, run.shape, dryrun=True)
    b_sh = batch_shardings(mesh, batch_tree, rules, run.shape.global_batch)
    return prefill_step, (p_sh, b_sh), params_shape, batch_tree


def build_decode_step(model: Model, run: RunConfig, mesh, rules: AxisRules):
    cfg = model.cfg

    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    p_sh = params_shardings(mesh, params_shape, cfg)
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(run.shape.global_batch, run.shape.seq_len))
    c_sh = cache_shardings(mesh, cache_shape, cfg, rules,
                           run.shape.global_batch)
    batch_tree = input_specs(cfg, run.shape, dryrun=True)
    b_sh = batch_shardings(mesh, batch_tree, rules, run.shape.global_batch)
    return decode_step, (p_sh, c_sh, b_sh), (params_shape, cache_shape, batch_tree)
