import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any other import (jax locks the device count on first init).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this lowers the *real* step function (train_step for train
shapes, prefill/serve steps for inference shapes) against the production
mesh with full shardings, compiles it, and records:

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM;
* ``compiled.cost_analysis()``    — XLA's aggregate (counts scan bodies once);
* trip-count-aware FLOPs / bytes / collective bytes from
  ``repro.launch.hlo_analysis`` — the §Roofline source of truth.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, MeshConfig, RunConfig, TrainConfig, get_config
from repro.launch import hlo_analysis
from repro.launch.mesh import make_axis_rules, make_production_mesh
from repro.launch.steps import (
    build_decode_step, build_prefill_step, build_train_step,
)
from repro.models.registry import build_model, cell_is_skipped, input_specs
from repro.parallel.sharding import sharding_rules

DEFAULT_OUT = "artifacts/dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             extra_tags: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    n_dev = 512 if multi else 256
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": n_dev, "family": cfg.family,
    }
    skip = cell_is_skipped(cfg, shape)
    if skip:
        record["status"] = skip
        _write(record, out_dir, extra_tags)
        return record

    mesh_cfg = MeshConfig(multi_pod=multi)
    mesh = make_production_mesh(multi_pod=multi)
    rules = make_axis_rules(mesh_cfg).with_mesh(mesh)
    if os.environ.get("DRYRUN_NO_TP"):
        # Hillclimb lever: pure-DP on the same mesh (replicated weights, no
        # model-axis collectives) — right for sub-1B models where TP=16
        # costs more in activation all-reduces than it saves in memory.
        import dataclasses as _dc
        rules = _dc.replace(rules, rules=dict(rules.rules, model=()))
    # Microbatching keeps per-device activation memory inside v5e HBM at the
    # 1M-token global batch (measured: 18.2GB -> 4.6GB on smollm train_4k at
    # accum=4); the DP gradient reduction still happens once.  Wider models
    # carry proportionally larger per-layer activations -> deeper accum;
    # jamba-52B additionally carries (B, c, d_inner, d_state) SSM chunks.
    if cfg.ssm_kind == "mamba" and cfg.d_model >= 4096:
        default_accum = "16"
    elif cfg.d_model >= 4096:
        default_accum = "8"
    else:
        default_accum = "4"
    grad_accum = int(os.environ.get("DRYRUN_GRAD_ACCUM", default_accum))
    # Hillclimb knobs (EXPERIMENTS.md §Perf A/B runs):
    remat = os.environ.get("DRYRUN_REMAT", "full")
    compression = os.environ.get("DRYRUN_GRAD_COMPRESSION", "none")
    train_cfg = TrainConfig(remat=remat, scan_layers=True,
                            grad_accum=grad_accum,
                            grad_compression=compression)
    run = RunConfig(model=cfg, shape=shape, train=train_cfg, mesh=mesh_cfg)
    model = build_model(cfg, remat=train_cfg.remat)

    t0 = time.time()
    try:
        with mesh, sharding_rules(rules):
            if shape.kind == "train":
                bundle = build_train_step(model, run, mesh, rules)
                batch = input_specs(cfg, shape, dryrun=True)
                jitted = jax.jit(
                    bundle.step_fn,
                    in_shardings=bundle.in_shardings,
                    out_shardings=bundle.out_shardings,
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(bundle.params_shape, bundle.opt_shape,
                                       batch)
            elif shape.kind == "prefill":
                step, shardings, params_shape, batch = build_prefill_step(
                    model, run, mesh, rules)
                jitted = jax.jit(step, in_shardings=shardings)
                lowered = jitted.lower(params_shape, batch)
            else:  # decode
                step, shardings, (params_shape, cache_shape, batch) = \
                    build_decode_step(model, run, mesh, rules)
                jitted = jax.jit(step, in_shardings=shardings,
                                 out_shardings=(None, shardings[1]),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_shape, cache_shape, batch)
            record["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            record["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        print(mem)  # proves it fits
        ca = compiled.cost_analysis() or {}
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        record["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        }
        record["xla_cost"] = {
            "flops": float(ca.get("flops", -1.0)),
            "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
        }
        hlo = compiled.as_text()
        cost = hlo_analysis.analyze_hlo(hlo, n_dev)
        record["hlo_cost"] = cost.to_json()
        record["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 2)
    _write(record, out_dir, extra_tags)
    return record


def _write(record: dict, out_dir: str, extra_tags: str = "") -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{extra_tags}" if extra_tags else ""
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1)
    status = record["status"]
    print(f"[dryrun] {record['arch']} × {record['shape']} × {record['mesh']}"
          f" -> {status} ({record.get('total_s', 0)}s)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs.base import list_archs

    if args.all:
        cells = [(a, s, m) for a in list_archs() for s in SHAPES
                 for m in ("single", "multi")]
    else:
        cells = [(args.arch, args.shape, args.mesh)]
    for arch, shape, mesh_kind in cells:
        path = os.path.join(args.out, f"{arch}__{shape}__{mesh_kind}.json")
        if args.skip_existing and os.path.exists(path):
            continue
        run_cell(arch, shape, mesh_kind, args.out)


if __name__ == "__main__":
    main()
