"""Optimized-HLO text analysis: FLOPs / bytes / collective bytes per device.

Why not ``compiled.cost_analysis()``?  It counts every ``while`` body ONCE
(verified empirically — a 40-layer scanned transformer reports 1 layer of
FLOPs), which silently under-counts scanned models by 40×.  This module
parses ``compiled.as_text()`` directly:

* walks computations recursively through ``while`` (× known_trip_count),
  ``call``, ``conditional`` (max branch), and fusion calls;
* FLOPs: dots (2·prod(out)·prod(contracting)), convolutions, elementwise,
  reductions;
* bytes: a TPU-fusion byte model — operand+output sizes of *anchor* ops
  only (dot/conv/reduce/sort/custom-call, collectives, and data movers
  such as copy/gather/scatter/dynamic-update-slice/concatenate).  Pure
  elementwise/layout ops and CPU-backend fusion boundaries are assumed
  fused away on TPU (charging them measured 10-20× over napkin-math HBM
  traffic: the tensors that must cross HBM are exactly the MXU operands,
  reduction inputs, moved data and collective payloads);
* collective bytes by op kind (all-gather counts the gathered output,
  all-reduce 2× input — ring reduce-scatter + all-gather phases — etc.)
  with replica-group sizes recorded so pod-crossing (DCI) traffic can be
  split from intra-pod (ICI).

Validated against ``cost_analysis`` on unrolled loops (tests).
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

__all__ = ["analyze_hlo", "HloCost", "Collective"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "and", "or", "xor", "not", "compare",
    "select", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "sign", "convert", "cosine", "sine", "atan2", "clamp", "erf", "logistic",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "cbrt", "tan", "is-finite", "popcnt", "clz",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy", "copy-start", "copy-done", "reshape",
    "broadcast", "transpose", "slice", "concatenate", "pad", "iota",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter", "reverse",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "rng-bit-generator", "rng-get-and-update-state", "optimization-barrier",
    "send", "send-done", "recv", "recv-done", "infeed", "outfeed",
    "all-gather-start", "all-gather-done", "all-reduce-start",
    "all-reduce-done", "collective-permute-start", "collective-permute-done",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all"}


@dataclass
class Collective:
    kind: str
    bytes: float
    group_size: int
    count: float  # trip-multiplied occurrence count


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: list = field(default_factory=list)
    warnings: list = field(default_factory=list)
    # single-count twin (while bodies counted once) — used to scale XLA's
    # fusion-aware `bytes accessed` by the trip-count inflation ratio.
    flops_single: float = 0.0
    bytes_single: float = 0.0
    # named sub-computation -> HloCost, filled by analyze_hlo(...,
    # per_computation=True).  Every charge lands in exactly one bucket
    # (trip-multiplied: a while body's bucket carries trip× its ops;
    # fusion interiors land in the fused computation's own bucket), so
    # the buckets sum exactly to the whole-module totals.
    per_computation: dict = field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(c.bytes for c in self.collectives)

    def collective_bytes_by_group_size(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for c in self.collectives:
            out[c.group_size] = out.get(c.group_size, 0.0) + c.bytes
        return out

    def to_json(self) -> dict:
        out = {
            "flops": self.flops,
            "bytes": self.bytes,
            "flops_single": self.flops_single,
            "bytes_single": self.bytes_single,
            "transcendentals": self.transcendentals,
            "collective_bytes": self.collective_bytes,
            "collectives_by_group": {
                str(k): v for k, v in
                self.collective_bytes_by_group_size().items()},
            "collective_ops": [
                {"kind": c.kind, "bytes": c.bytes,
                 "group_size": c.group_size, "count": c.count}
                for c in self.collectives],
            "warnings": self.warnings,
        }
        if self.per_computation:
            out["per_computation"] = {
                name: c.to_json() for name, c in
                self.per_computation.items()}
        return out


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attrs raw text


def _split_computations(hlo: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    current: list[_Op] | None = None
    for line in hlo.splitlines():
        # HLO embeds /*index=N*/ comments inside large tuple types; the '='
        # inside them breaks op parsing — strip all block comments first.
        line = re.sub(r"/\*.*?\*/", "", line)
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$", stripped)
        if header and "=" not in stripped.split("(")[0]:
            current = comps.setdefault(header.group(1), [])
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if m:
            current.append(_Op(m.group(1), m.group(2).strip(), m.group(3),
                               m.group(4)))
    return comps


_OPERAND_RE = re.compile(
    r"(?:([a-z0-9]+\[[\d,]*\])(?:\{[^}]*\})?\s+)?%([\w.\-]+)")


def _operands(op_rest: str, shapes: dict[str, str]) -> list[tuple[str, str]]:
    """(name, type_str) per operand of an op line.

    Older XLA text prints operand types inline (``dot(f32[32,128]{1,0}
    %param, ...)``) — those win; otherwise the type comes from the
    name -> type table built while walking the computation.
    """
    head = op_rest.split(")")[0]
    return [(name, typ or shapes.get(name, ""))
            for typ, name in _OPERAND_RE.findall(head)]


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(op.type_str)
    operands = _operands(op.rest, shapes)
    contracting = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not operands or not contracting:
        lhs_name = re.match(r"\s*%?([\w.\-]+)", op.rest)
        if not lhs_name or not contracting:
            return 2.0 * out_elems  # degenerate
        lhs_dims = _first_shape_dims(shapes.get(lhs_name.group(1), ""))
    else:
        lhs_dims = _first_shape_dims(operands[0][1])
    k = 1
    for i in contracting.group(1).split(","):
        if i and int(i) < len(lhs_dims):
            k *= lhs_dims[int(i)]
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, shapes: dict[str, str]) -> float:
    out_elems = _shape_elems(op.type_str)
    operands = _operands(op.rest, shapes)
    dl = re.search(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)", op.rest)
    if len(operands) < 2 or not dl:
        return 2.0 * out_elems
    kshape = _first_shape_dims(operands[1][1])
    klabels = dl.group(2)
    o_pos = klabels.find("o")
    if o_pos < 0 or o_pos >= len(kshape):
        return 2.0 * out_elems
    k_prod = 1
    for i, d in enumerate(kshape):
        if i != o_pos:
            k_prod *= d
    feature_group = re.search(r"feature_group_count=(\d+)", op.rest)
    fg = int(feature_group.group(1)) if feature_group else 1
    return 2.0 * out_elems * k_prod / max(fg, 1)


def _collective_payload(op: _Op, shapes: dict[str, str]) -> float:
    """Bytes moved per device (payload convention, DESIGN.md §Roofline)."""
    out_b = _shape_bytes(op.type_str)
    if op.opcode == "all-gather":
        return out_b  # each device materialises the gathered output
    if op.opcode == "all-reduce":
        return 2.0 * out_b  # ring: reduce-scatter + all-gather phases
    # reduce-scatter / all-to-all / collective-permute: input size
    names = re.findall(r"^\s*%?([\w.\-]+)", op.rest)
    in_b = sum(_shape_bytes(shapes.get(n, "")) for n in
               re.findall(r"%([\w.\-]+)", "%" + op.rest.split(")")[0])
               ) or out_b
    if op.opcode == "reduce-scatter":
        return in_b
    return max(in_b, out_b)


def _group_size(op: _Op, total_devices: int) -> int:
    m = _GROUPS_RE.search(op.rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(op.rest)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len(first.split(","))
    return total_devices


def analyze_hlo(hlo: str, total_devices: int = 1, *,
                per_computation: bool = False) -> HloCost:
    """Analyze optimized HLO text.  With ``per_computation=True`` the
    result's ``per_computation`` maps every named sub-computation walked
    (entry, while bodies, called computations, fusion interiors) to its
    own ``HloCost`` — each charge lands in exactly one bucket, so the
    buckets sum exactly to the module totals (tests assert this)."""
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation with the most ops
        entry = max(comps, key=lambda k: len(comps[k])) if comps else None
    cost = HloCost()
    if entry is None:
        cost.warnings.append("no computations parsed")
        return cost
    per = {} if per_computation else None
    _walk(entry, comps, 1.0, cost, total_devices, top=True, seen=set(),
          per_comp=per)
    single = HloCost()
    _walk(entry, comps, 1.0, single, total_devices, top=True, seen=set(),
          honor_trips=False)
    cost.flops_single = single.flops
    cost.bytes_single = single.bytes
    if per is not None:
        cost.per_computation = per
    return cost


def _walk(comp_name: str, comps: dict, mult: float, cost: HloCost,
          total_devices: int, *, top: bool, seen: set,
          honor_trips: bool = True, per_comp: dict | None = None):
    ops = comps.get(comp_name)
    if ops is None:
        cost.warnings.append(f"missing computation {comp_name}")
        return
    targets = (cost,)
    if per_comp is not None:
        targets = (cost, per_comp.setdefault(comp_name, HloCost()))

    def add(attr, v):
        for t in targets:
            setattr(t, attr, getattr(t, attr) + v)

    shapes = {op.name: op.type_str for op in ops}
    for op in ops:
        oc = op.opcode
        if oc == "while":
            trip = 1.0
            if honor_trips:
                m = _TRIP_RE.search(op.rest)
                if m:
                    trip = float(m.group(1))
                else:
                    cost.warnings.append(
                        f"while without trip count in {comp_name}")
            body = _BODY_RE.search(op.rest)
            if body:
                _walk(body.group(1), comps, mult * trip, cost, total_devices,
                      top=top, seen=seen, honor_trips=honor_trips,
                      per_comp=per_comp)
            continue
        if oc in ("call", "async-start"):
            callee = _CALLS_RE.search(op.rest)
            if callee:
                _walk(callee.group(1), comps, mult, cost, total_devices,
                      top=top, seen=seen, honor_trips=honor_trips,
                      per_comp=per_comp)
            continue
        if oc == "conditional":
            branches = _COND_BRANCH_RE.search(op.rest)
            if branches:
                names = re.findall(r"%?([\w.\-]+)", branches.group(1))
                for n in names[:1]:  # approximate: first branch
                    _walk(n, comps, mult, cost, total_devices, top=top,
                          seen=seen, honor_trips=honor_trips,
                          per_comp=per_comp)
            continue
        if oc == "fusion":
            callee = _CALLS_RE.search(op.rest)
            if callee:
                _walk_fused(callee.group(1), comps, mult, cost,
                            per_comp=per_comp)
            # No byte charge: CPU-backend fusions are tiny elementwise
            # islands whose boundaries would not exist under TPU fusion
            # (charging them measured 87.8% of all bytes on a 12B train
            # step — 10× over napkin-math HBM traffic).
            continue
        if oc in _COLLECTIVES:
            payload = _collective_payload(op, shapes)
            gs = _group_size(op, total_devices)
            for t in targets:
                t.collectives.append(Collective(oc, mult * payload, gs, mult))
            add("bytes", mult * _op_io_bytes(op, shapes))
            continue
        if oc in _FREE:
            # Only data-moving ops count as HBM traffic; layout ops
            # (broadcast/transpose/reshape/pad/slice) fuse away on TPU.
            if oc in ("copy", "dynamic-update-slice", "gather", "scatter",
                      "dynamic-slice", "concatenate"):
                add("bytes", mult * _op_io_bytes(op, shapes))
            continue
        if oc == "dot":
            add("flops", mult * _dot_flops(op, shapes))
            add("bytes", mult * _op_io_bytes(op, shapes))
            continue
        if oc == "convolution":
            add("flops", mult * _conv_flops(op, shapes))
            add("bytes", mult * _op_io_bytes(op, shapes))
            continue
        if oc in ("reduce", "reduce-window", "sort", "reduce-precision"):
            in_elems = _op_in_elems(op, shapes)
            add("flops", mult * in_elems)
            add("bytes", mult * _op_io_bytes(op, shapes))
            continue
        if oc == "custom-call":
            add("bytes", mult * _op_io_bytes(op, shapes))
            add("flops", mult * _shape_elems(op.type_str))
            continue
        if oc in _ELEMENTWISE or oc == "map":
            elems = _shape_elems(op.type_str)
            add("flops", mult * elems)
            if oc in ("exponential", "tanh", "log", "logistic", "power",
                      "cosine", "sine", "erf", "tan"):
                add("transcendentals", mult * elems)
            # no bytes: elementwise fuses into producers/consumers on TPU
            continue
        # unknown op: count bytes conservatively
        add("bytes", mult * _op_io_bytes(op, shapes))


def _walk_fused(comp_name: str, comps: dict, mult: float, cost: HloCost,
                per_comp: dict | None = None):
    """Inside a fusion: count FLOPs only (no HBM traffic).  Charges land
    in the fused computation's own per-computation bucket."""
    ops = comps.get(comp_name)
    if ops is None:
        return
    targets = (cost,)
    if per_comp is not None:
        targets = (cost, per_comp.setdefault(comp_name, HloCost()))

    def add(attr, v):
        for t in targets:
            setattr(t, attr, getattr(t, attr) + v)

    shapes = {op.name: op.type_str for op in ops}
    for op in ops:
        oc = op.opcode
        if oc == "fusion":
            callee = _CALLS_RE.search(op.rest)
            if callee:
                _walk_fused(callee.group(1), comps, mult, cost,
                            per_comp=per_comp)
        elif oc == "dot":
            add("flops", mult * _dot_flops(op, shapes))
        elif oc == "convolution":
            add("flops", mult * _conv_flops(op, shapes))
        elif oc in ("reduce", "reduce-window"):
            add("flops", mult * _op_in_elems(op, shapes))
        elif oc in _ELEMENTWISE:
            elems = _shape_elems(op.type_str)
            add("flops", mult * elems)
            if oc in ("exponential", "tanh", "log", "logistic", "power",
                      "cosine", "sine", "erf", "tan"):
                add("transcendentals", mult * elems)
        elif oc in ("call",):
            callee = _CALLS_RE.search(op.rest)
            if callee:
                _walk_fused(callee.group(1), comps, mult, cost,
                            per_comp=per_comp)


def _op_io_bytes(op: _Op, shapes: dict[str, str]) -> float:
    """Output + operand bytes (operands resolved from same computation)."""
    total = _shape_bytes(op.type_str)
    operand_part = op.rest.split("),")[0] if ")," in op.rest else op.rest
    for name in re.findall(r"%([\w.\-]+)", operand_part):
        if name in shapes:
            total += _shape_bytes(shapes[name])
    return total


def _op_in_elems(op: _Op, shapes: dict[str, str]) -> float:
    operand_part = op.rest.split("),")[0] if ")," in op.rest else op.rest
    total = 0.0
    for name in re.findall(r"%([\w.\-]+)", operand_part):
        if name in shapes:
            total += _shape_elems(shapes[name])
    return total or _shape_elems(op.type_str)
