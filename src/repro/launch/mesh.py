"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets ``XLA_FLAGS`` before any jax init).
"""
from __future__ import annotations

from repro.configs.base import MeshConfig
from repro.parallel.compat import make_mesh
from repro.parallel.sharding import AxisRules

__all__ = ["make_production_mesh", "make_mesh_from_config", "make_axis_rules",
           "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: 16×16 per pod, 2 pods multi-pod.

    ``pod`` is a second data-parallel level whose collectives cross the
    inter-pod DCI; ``data``/``model`` live on intra-pod ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_from_config(cfg: MeshConfig):
    if cfg.multi_pod:
        shape, axes = (cfg.pods, cfg.data, cfg.model), ("pod", "data", "model")
    else:
        shape, axes = (cfg.data, cfg.model), ("data", "model")
    return make_mesh(shape, axes)


def make_axis_rules(cfg: MeshConfig) -> AxisRules:
    return AxisRules.default(
        cfg.multi_pod, pods=cfg.pods, data=cfg.data, model=cfg.model
    )


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for CPU sharding tests (requires forced host devices)."""
    if pods:
        return make_mesh((pods, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
