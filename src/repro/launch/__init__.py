"""Launchers: mesh construction, dry-run, trainer, server.

Note: ``repro.launch.dryrun`` must be imported/executed FIRST in its
process (it sets XLA_FLAGS before jax initialises); do not import it here.
"""
from repro.launch.mesh import (  # noqa: F401
    make_axis_rules,
    make_mesh_from_config,
    make_production_mesh,
    make_test_mesh,
)
