"""Fault-tolerant training driver.

Features exercised end-to-end on CPU (reduced configs) and designed for the
production mesh (full configs through the same code path as the dry-run):

* auto-resume from the newest valid checkpoint (corrupted ones skipped);
* SIGTERM/SIGINT preemption hook: save synchronously, exit 0 (the cluster
  scheduler restarts the job, which resumes — classic preemption handling);
* async checkpoint writes every ``--ckpt-every`` steps, keep-last-k;
* data-iterator state inside the checkpoint (exactly-once batches);
* straggler watchdog: per-step wall-clock EWMA; steps slower than
  ``--straggler-factor``× the EWMA are logged with their step index (on a
  real cluster this feeds the controller that re-shards around the slow
  host; here it is recorded in the metrics file);
* works for LM archs and the paper's jpeg-resnet (``--arch jpeg-resnet``).

Example (CPU):
    PYTHONPATH=src python -m repro.launch.train --arch jpeg-resnet \
        --reduced --steps 300 --batch 32 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import (
    MeshConfig, RunConfig, ShapeConfig, TrainConfig, get_config,
    reduced_config,
)
from repro.data import jpeg_iterator, token_iterator
from repro.models.registry import build_model, count_params
from repro.optim import make_optimizer, make_schedule
from repro.optim.grad import clip_by_global_norm

__all__ = ["main", "train_loop", "export_plan"]


def export_plan(cfg, bundle, ckpt_dir: str, *, step: int = 0) -> str:
    """Plan-aware training handoff: fuse the current jpeg-resnet weights
    into an ``InferencePlan`` (+ compiled schedule) under
    ``<ckpt_dir>/plan``, the directory ``launch.serve --plan-dir`` restores
    from — serving picks up fresh weights without a manual convert step.
    """
    from repro.core import plan as planlib
    from repro.models.registry import jpeg_resnet_spec

    spec = jpeg_resnet_spec(cfg)
    plan_dir = os.path.join(ckpt_dir, "plan")
    plan = planlib.build_plan(bundle["params"], bundle["bn_state"], spec)
    planlib.save_plan(plan, plan_dir, step=step)
    planlib.save_compiled_plan(
        planlib.compile_plan(plan, image_size=cfg.image_size),
        os.path.join(plan_dir, "compiled"), step=step)
    print(f"[train] exported inference plan -> {plan_dir} (step {step})",
          flush=True)
    return plan_dir


def build_iterator(cfg, batch: int, seq: int, seed: int):
    if cfg.family == "jpeg_resnet":
        return jpeg_iterator(seed, batch, cfg.image_size, cfg.in_channels,
                             cfg.num_classes)
    return token_iterator(seed, batch, seq, cfg.vocab_size)


def to_model_batch(cfg, host_batch, d_model=None):
    batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
    if cfg.family == "vlm":
        b = batch["tokens"].shape[0]
        batch["vision_embeds"] = jnp.zeros(
            (b, cfg.vision_prefix_len, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        b = batch["tokens"].shape[0]
        batch["frames"] = jnp.zeros(
            (b, cfg.encoder_context_len, cfg.d_model), jnp.float32)
    return batch


def train_loop(args) -> dict:
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 1),
                     optimizer=args.optimizer, grad_clip=1.0)
    model = build_model(cfg)
    optimizer = make_optimizer(tc.optimizer, weight_decay=tc.weight_decay)
    schedule = make_schedule(tc.schedule, tc.learning_rate, tc.warmup_steps,
                             tc.total_steps)

    it = build_iterator(cfg, args.batch, args.seq, seed=args.seed)
    manager = CheckpointManager(args.ckpt_dir, keep=args.keep)

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt_state = optimizer.init(params)
    start_step = 0
    restored = manager.restore_latest({"params": params, "opt": opt_state})
    if restored is not None and args.resume:
        step0, tree, extra = restored
        params, opt_state = tree["params"], tree["opt"]
        it.load_state_dict(extra["data_state"])
        start_step = step0
        print(f"[train] resumed from step {step0}", flush=True)

    @jax.jit
    def step_fn(params, opt_state, batch):
        def pure_loss(p, b):
            return model.loss_fn(p, b)[0]
        loss, grads = jax.value_and_grad(pure_loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        lr = schedule(opt_state.step)
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        return params, opt_state, loss, gnorm

    # --- preemption hook --------------------------------------------------
    state = {"params": params, "opt": opt_state, "step": start_step}
    interrupted = {"flag": False}

    def _preempt(signum, frame):
        print(f"[train] signal {signum}: checkpoint-and-exit", flush=True)
        interrupted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        old_handlers[sig] = signal.signal(sig, _preempt)

    # --- loop ---------------------------------------------------------
    losses, straggler_log = [], []
    ewma = None
    n_params = count_params(params)
    print(f"[train] {cfg.name}: {n_params:,} params", flush=True)
    t_loop = time.time()
    step = start_step
    try:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = to_model_batch(cfg, next(it))
            params, opt_state, loss, gnorm = step_fn(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                lv = float(loss)
                losses.append((step, lv))
                print(f"[train] step {step} loss {lv:.4f} "
                      f"gnorm {float(gnorm):.3f}", flush=True)
            dt = time.time() - t0
            if ewma is None:
                ewma = dt
            else:
                if dt > args.straggler_factor * ewma:
                    straggler_log.append({"step": step, "dt": dt,
                                          "ewma": ewma})
                    print(f"[train] straggler: step {step} took {dt:.2f}s "
                          f"(ewma {ewma:.2f}s)", flush=True)
                ewma = 0.9 * ewma + 0.1 * dt
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                manager.save(step + 1, {"params": params, "opt": opt_state},
                             extra={"data_state": it.state_dict()},
                             blocking=False)
                every = getattr(args, "export_plan_every", 0)
                n_saves = (step + 1) // args.ckpt_every
                if (every and cfg.family == "jpeg_resnet"
                        and n_saves % every == 0):
                    export_plan(cfg, params, args.ckpt_dir, step=step + 1)
            if interrupted["flag"]:
                break
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    manager.wait()
    final_step = step + 1 if not interrupted["flag"] else step
    manager.save(final_step, {"params": params, "opt": opt_state},
                 extra={"data_state": it.state_dict()})
    plan_dir = None
    if cfg.family == "jpeg_resnet" and getattr(args, "export_plan", True):
        # export point: the final checkpoint doubles as a serving handoff
        plan_dir = export_plan(cfg, params, args.ckpt_dir, step=final_step)
    wall = time.time() - t_loop
    result = {
        "arch": cfg.name, "steps_run": final_step - start_step,
        "final_step": final_step, "losses": losses,
        "stragglers": straggler_log, "wall_s": wall,
        "interrupted": interrupted["flag"], "params": n_params,
        "plan_dir": plan_dir,
    }
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(result, f, indent=1)
    if interrupted["flag"]:
        sys.exit(0)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--no-resume", dest="resume", action="store_false")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--export-plan", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="jpeg-resnet: fuse the final weights into an "
                         "InferencePlan (+ compiled schedule) under "
                         "<ckpt-dir>/plan so serve.py --plan-dir picks "
                         "them up without a manual convert step")
    ap.add_argument("--export-plan-every", type=int, default=0,
                    help="additionally export the plan at every Nth "
                         "periodic checkpoint save (counted in saves, "
                         "not steps; 0 = final save only)")
    args = ap.parse_args()
    train_loop(args)


if __name__ == "__main__":
    main()
