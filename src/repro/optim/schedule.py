"""Learning-rate schedules as pure ``step -> lr`` functions."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_linear", "constant", "make_schedule"]


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        lin = peak_lr * (1 - (1 - final_frac) * t)
        return jnp.where(step < warmup_steps, warm, lin)
    return fn


def constant(peak_lr: float):
    return lambda step: jnp.full((), peak_lr, jnp.float32)


def make_schedule(name: str, peak_lr: float, warmup_steps: int, total_steps: int):
    if name == "cosine":
        return warmup_cosine(peak_lr, warmup_steps, total_steps)
    if name == "linear":
        return warmup_linear(peak_lr, warmup_steps, total_steps)
    if name == "constant":
        return constant(peak_lr)
    raise ValueError(f"unknown schedule {name!r}")
