"""Hand-built optimizers (AdamW, SGD-momentum, Lion) as pure pytree maps.

All state lives in a pytree mirroring the params, which lets the sharding
layer ZeRO-shard it (``repro.parallel.sharding.zero1_spec``) without the
optimizer knowing.  Master weights: when params are bf16, AdamW keeps an
fp32 copy in state (mixed-precision training) and emits bf16 updates.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "OptState", "adamw", "sgd", "lion", "make_optimizer"]


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any  # optimizer-specific pytree(s)


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any, jnp.ndarray], tuple[Any, OptState]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    """AdamW with decoupled weight decay and fp32 master weights."""

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = _f32(params)
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
                         "master": master})

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / c1
            vh = v / c2
            new_master = master - lr * (mh / (jnp.sqrt(vh) + eps)
                                        + weight_decay * master)
            return m, v, new_master

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.inner["m"])
        flat_v = treedef.flatten_up_to(state.inner["v"])
        flat_w = treedef.flatten_up_to(state.inner["master"])
        out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
        new_m = treedef.unflatten([o[0] for o in out])
        new_v = treedef.unflatten([o[1] for o in out])
        new_master = treedef.unflatten([o[2] for o in out])
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_master, params
        )
        return new_params, OptState(step, {"m": new_m, "v": new_v,
                                           "master": new_master})

    return Optimizer(init, update)


def sgd(momentum: float = 0.9, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        vel = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"vel": vel})

    def update(grads, state, params, lr):
        def upd(g, v, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            v = momentum * v + g
            d = g + momentum * v if nesterov else v
            return v, (p.astype(jnp.float32) - lr * d).astype(p.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state.inner["vel"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_vel = treedef.unflatten([o[0] for o in out])
        new_params = treedef.unflatten([o[1] for o in out])
        return new_params, OptState(state.step + 1, {"vel": new_vel})

    return Optimizer(init, update)


def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1) -> Optimizer:
    """Lion (EvoLved Sign Momentum) — sign updates, one state tensor."""

    def init(params):
        m = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), {"m": m})

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            d = jnp.sign(b1 * m + (1 - b1) * g)
            new_p = pf - lr * (d + weight_decay * pf)
            new_m = b2 * m + (1 - b2) * g
            return new_m, new_p.astype(p.dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.inner["m"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (treedef.unflatten([o[1] for o in out]),
                OptState(state.step + 1,
                         {"m": treedef.unflatten([o[0] for o in out])}))

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "sgd":
        return sgd(**{k: v for k, v in kw.items()
                      if k in ("momentum", "nesterov", "weight_decay")})
    if name == "lion":
        return lion(**{k: v for k, v in kw.items()
                       if k in ("b1", "b2", "weight_decay")})
    raise ValueError(f"unknown optimizer {name!r}")
