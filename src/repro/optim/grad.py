"""Gradient transforms: clipping, compression, accumulation.

Compression casts gradients to a narrower dtype *before* the data-parallel
all-reduce (the psum is inserted by SPMD where the cast tensor crosses the
data axis), halving DP collective bytes — recorded as a distributed-
optimization trick in DESIGN.md §5.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["global_norm", "clip_by_global_norm", "compress_grads",
           "accumulate_microbatches"]


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def compress_grads(grads: Any, mode: str) -> Any:
    """'none' | 'bf16': compress before the cross-replica reduction."""
    if mode == "none":
        return grads
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    raise ValueError(f"unknown gradient compression {mode!r}")


def accumulate_microbatches(
    loss_fn: Callable[[Any, Any], jnp.ndarray],
    params: Any,
    batch: Any,
    n_micro: int,
    grad_constraint: Callable[[Any], Any] | None = None,
) -> tuple[jnp.ndarray, Any]:
    """Gradient accumulation with one deferred reduction.

    Splits the leading batch axis into ``n_micro`` chunks and accumulates
    fp32 gradients in a ``lax.scan``.

    ``grad_constraint`` shards the fp32 accumulator (ZeRO-2 style: the
    launcher passes a data-axis constraint, so each microbatch's gradients
    reduce-scatter into a sharded accumulator instead of a replicated one —
    an unsharded fp32 accumulator measured 11.7 GB/device on mixtral-8x7b).
    """
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn)(params, batch)

    def reshape(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    grad_init = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if grad_constraint is not None:
        grad_init = grad_constraint(grad_init)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        if grad_constraint is not None:
            # Constrain the microbatch gradient itself: SPMD then lowers the
            # DP gradient reduction as a reduce-scatter into the sharded
            # accumulator (ZeRO-2) instead of an all-reduce into a
            # replicated one — the full-size fp32 tensor never exists.
            g = grad_constraint(g)
        grad_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), grad_acc, g)
        if grad_constraint is not None:
            grad_acc = grad_constraint(grad_acc)
        return (loss_acc + loss, grad_acc), None

    (loss_sum, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), grad_init), micro
    )
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads)
