"""Optimizers, schedules and gradient transforms."""
from repro.optim.grad import (  # noqa: F401
    accumulate_microbatches,
    clip_by_global_norm,
    compress_grads,
    global_norm,
)
from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    OptState,
    adamw,
    lion,
    make_optimizer,
    sgd,
)
from repro.optim.schedule import make_schedule  # noqa: F401
