"""Deterministic fault injection for the serving stack (test-only hooks).

The chaos suite and the CI ``chaos-smoke`` job need *reproducible*
disasters: the same seed must corrupt the same requests the same way on
every run, or a green build proves nothing.  Everything here is pure with
respect to ``(seed, request index)`` — each decision draws from
``np.random.default_rng((seed, index))``, so fault placement is
insensitive to arrival order, thread timing, and batch composition.

Three injection points, wired through :class:`repro.serving.scheduler.
BandElasticScheduler`'s ``faults=`` hook (``None`` in production — the
hot path pays one attribute check):

- **corrupt(i, data)** — client-side byte mutation before ``submit()``.
  The default modes are *guaranteed-fail*: truncation (the EOI marker is
  gone, so ``parse_segments`` must raise) and unescaped-marker injection
  into the entropy-coded segment (``_unstuff`` must raise).  Random
  bit-flips are also available but JPEG carries no checksum, so a flip
  may decode silently — fuzz tests use them, parity-asserting chaos
  tests don't.
- **on_ingest(reqs)** — runs on the scheduler's ingest thread before a
  batch decodes: optional decode delay (deadline/backpressure chaos) and
  a one-shot SIGKILL of a live ingest-pool worker (drives the
  ``BrokenProcessPool`` supervision path).
- **on_execute(seq, reqs)** — runs in the worker loop before dispatch
  ``seq``: raises :class:`InjectedFault` inside a configured dispatch
  window, driving executor-failure containment, retry, and the breaker.
"""
from __future__ import annotations

import os
import signal
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.codec import ingest as ingest_mod

__all__ = ["FaultSpec", "FaultInjector", "InjectedFault",
           "kill_one_ingest_worker"]


class InjectedFault(RuntimeError):
    """Raised by the executor-fault hook; distinguishable from real bugs."""


def kill_one_ingest_worker() -> int | None:
    """SIGKILL one live worker of the shared ingest pool, if any.

    Returns the pid killed, or ``None`` when no pool (or no live worker)
    exists.  The next shard batch submitted to the pool then surfaces
    ``BrokenProcessPool`` and exercises the supervisor's
    respawn-with-backoff path.
    """
    pool = ingest_mod._POOL
    if pool is None:
        return None
    procs = list(getattr(pool, "_processes", {}).values())
    for p in procs:
        if p.is_alive():
            os.kill(p.pid, signal.SIGKILL)
            return p.pid
    return None


def _truncate(data: bytes, rng: np.random.Generator) -> bytes:
    """Cut the file at 10–80% of its length: EOI is gone, parse fails."""
    cut = max(2, int(len(data) * rng.uniform(0.1, 0.8)))
    return data[:cut]


def _inject_marker(data: bytes, rng: np.random.Generator) -> bytes:
    """Write an unescaped marker into the entropy-coded data.

    ``0xFF 0xC7`` inside an ECS is structurally illegal (not a stuffed
    zero, not an RST), so either the SOS byte-scan mis-segments or
    ``_unstuff`` raises — always a :class:`~repro.codec.CodecError`,
    never a silent wrong decode.
    """
    arr = bytearray(data)
    # land inside the entropy-coded data: right after the SOS header
    # (overwrites inside DQT/DHT payloads can decode silently — they just
    # shift table values — so aiming by file fraction is not enough)
    sos = data.find(b"\xff\xda")
    if sos < 0 or sos + 4 > len(data):
        return _truncate(data, rng)
    lo = sos + 2 + int.from_bytes(data[sos + 2:sos + 4], "big")
    hi = len(arr) - 4
    if hi <= lo:
        return _truncate(data, rng)
    at = int(rng.integers(lo, hi))
    arr[at:at + 2] = b"\xff\xc7"
    return bytes(arr)


def _bitflip(data: bytes, rng: np.random.Generator) -> bytes:
    """Flip one random bit.  May decode silently (JPEG has no checksum)."""
    arr = bytearray(data)
    at = int(rng.integers(2, len(arr) - 2))
    arr[at] ^= 1 << int(rng.integers(0, 8))
    return bytes(arr)


_MUTATORS = {"truncate": _truncate, "marker": _inject_marker,
             "bitflip": _bitflip}


@dataclass(frozen=True)
class FaultSpec:
    """What to break, and when — all deterministic in ``seed``.

    ``corrupt_rate`` — fraction of request indices whose bytes are
    mutated by :meth:`FaultInjector.corrupt`; the mode is drawn uniformly
    from ``corrupt_modes``.  ``decode_delay_s`` stalls the ingest thread
    before every batch decode.  ``kill_worker_before_batch`` SIGKILLs one
    ingest-pool worker right before that many ingest batches have been
    seen (one-shot).  ``executor_fail_batches`` is a half-open
    ``[lo, hi)`` window of worker dispatch sequence numbers in which
    ``on_execute`` raises :class:`InjectedFault`.
    """

    seed: int = 0
    corrupt_rate: float = 0.0
    corrupt_modes: Sequence[str] = ("truncate", "marker")
    decode_delay_s: float = 0.0
    kill_worker_before_batch: int | None = None
    executor_fail_batches: tuple[int, int] | None = None


@dataclass
class FaultInjector:
    """Stateful driver of a :class:`FaultSpec` (one per chaos run)."""

    spec: FaultSpec
    killed_pid: int | None = None
    corrupted: dict[int, str] = field(default_factory=dict)
    _ingest_batches: int = 0

    def corrupt(self, index: int, data: bytes) -> bytes:
        """Maybe mutate request ``index``'s bytes (pure in (seed, index)).

        Records the chosen mode in ``corrupted[index]`` so the harness
        knows exactly which requests must fail.
        """
        spec = self.spec
        if spec.corrupt_rate <= 0.0:
            return data
        rng = np.random.default_rng((spec.seed, index))
        if rng.random() >= spec.corrupt_rate:
            return data
        mode = str(rng.choice(list(spec.corrupt_modes)))
        self.corrupted[index] = mode
        return _MUTATORS[mode](data, rng)

    def on_ingest(self, reqs) -> None:
        """Scheduler ingest-thread hook, called before each batch decode."""
        spec = self.spec
        self._ingest_batches += 1
        if (spec.kill_worker_before_batch is not None
                and self.killed_pid is None
                and self._ingest_batches >= spec.kill_worker_before_batch):
            self.killed_pid = kill_one_ingest_worker()
        if spec.decode_delay_s > 0.0:
            import time
            time.sleep(spec.decode_delay_s)

    def on_execute(self, seq: int, reqs) -> None:
        """Worker-loop hook, called with the dispatch sequence number
        before each batch executes.  Raises inside the configured window
        (every retry too — an injected fault is not transient, so it
        deterministically exhausts the retry budget and surfaces)."""
        win = self.spec.executor_fail_batches
        if win is not None and win[0] <= seq < win[1]:
            raise InjectedFault(
                f"injected executor fault at dispatch {seq}")
