"""Flight-recorder tracing for the serving runtime.

The serve report aggregates (``device_wall_s``, ``ingest_wall_s``,
latency histograms) — this module records *where the time went*: a
bounded, thread-safe ring of typed spans and instant events, one chain
per request, exportable as Chrome trace-event JSON that loads directly
in Perfetto (https://ui.perfetto.dev).

Span taxonomy (one chain per request id, see TESTING.md):

=============== ========== =====================================================
track (pid)     name        interval
=============== ========== =====================================================
``scheduler``   admission*  ``submit()`` entry → accepted into a queue
``scheduler``   batch-form  batch taken from the queue → executor dispatch
                            (tier selection + tile packing)
``ingest``      ingest-decode  one bytes batch through ``codec.ingest_batch``
``ingest``      decode-shard   one spawn-pool shard of that batch (tid = shard)
``device``      device-dispatch  staged batch through the grid cell executable
                            (the interval ``device_wall_s`` accumulates)
``device``      pad/stage   host staging copy into the pinned bucket buffer
``request``     admission / queue   per-request rows (tid = request id)
``request``     complete / fail / shed   terminal instants closing the chain
=============== ========== =====================================================

Instant events mark tier switches, breaker transitions, ingest-pool
restarts, and post-warmup compiles.  Batches link to their member
requests through flow events (``id`` = request id), so clicking a
``device-dispatch`` slice in Perfetto highlights every request it
served.

The recorder is a true flight recorder: a ring of the newest
``capacity`` events, O(1) per record, with a ``dropped`` counter for
evicted history — it can stay on under sustained load without growing.
The clock is injectable (tests drive it deterministically); timestamps
are exported relative to tracer construction in microseconds.

:data:`NULL_TRACER` is the disabled no-op twin — the scheduler threads
it unconditionally so tracing costs one attribute check when off.
:func:`validate_trace` is the schema/chain checker CI and the tests
share.  :func:`jax_profile` optionally brackets the same window with
``jax.profiler`` so a device-level profile can be captured alongside.
"""
from __future__ import annotations

import collections
import contextlib
import json
import math
import threading
import time
from typing import Any, Callable

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "TRACKS",
    "validate_trace",
    "jax_profile",
]

#: canonical component tracks, in display order (one Perfetto "process"
#: per component; unknown tracks are appended after these)
TRACKS = ("scheduler", "ingest", "device", "request")


class NullTracer:
    """Disabled tracer: every hook is a no-op.

    The scheduler and grid call the tracer unconditionally; this twin
    keeps the disabled-path cost to an attribute check (``enabled``)
    plus an empty method call on the few sites that don't guard.
    """

    enabled = False
    dropped = 0
    capacity = 0

    def now(self) -> float:
        return 0.0

    def span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass

    def flow(self, *a, **kw) -> None:
        pass

    def events(self) -> list:
        return []

    def summary(self) -> dict:
        return {"enabled": False, "events": 0, "dropped": 0}


NULL_TRACER = NullTracer()


class Tracer:
    """Thread-safe bounded ring of trace events.

    ``capacity`` bounds memory: the ring keeps the newest ``capacity``
    events and counts evictions in :attr:`dropped` (a flight recorder
    keeps the end of the story, not the beginning).  ``clock`` is any
    monotonic ``() -> float`` (seconds); every recorded timestamp is
    a reading of this clock, stored relative to construction time.

    Recording is a tuple append under a lock — cheap enough to leave on
    in production serving (the fig5 serving mode measures the overhead).
    """

    enabled = True

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._clock = clock
        self._t0 = clock()
        self._lock = threading.Lock()
        # record: (ph, track, tid, name, t_rel_s, dur_s_or_flow_id, args)
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self._dropped = 0

    # ------------------------------------------------------------- recording
    def now(self) -> float:
        """Current clock reading (absolute; pass to :meth:`span`)."""
        return self._clock()

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def _push(self, rec: tuple) -> None:
        with self._lock:
            if len(self._ring) == self.capacity:
                self._dropped += 1
            self._ring.append(rec)

    def span(self, track: str, name: str, t0: float, t1: float, *,
             tid: int = 0, args: dict | None = None) -> None:
        """One completed interval ``[t0, t1]`` (absolute clock readings)."""
        self._push(("X", track, tid, name, t0 - self._t0,
                    max(t1 - t0, 0.0), args))

    def instant(self, track: str, name: str, *, t: float | None = None,
                tid: int = 0, args: dict | None = None) -> None:
        """One point event (``t`` defaults to the clock's now)."""
        t = self._clock() if t is None else t
        self._push(("i", track, tid, name, t - self._t0, 0.0, args))

    def flow(self, fid: int, src: tuple[str, int, float],
             dst: tuple[str, int, float]) -> None:
        """Link two slices with a flow arrow (``fid`` = request id).

        ``src``/``dst`` are ``(track, tid, t)`` — the timestamps must
        fall inside the slices the arrow should bind to.
        """
        track, tid, t = src
        self._push(("s", track, tid, "req", t - self._t0, int(fid), None))
        track, tid, t = dst
        self._push(("f", track, tid, "req", t - self._t0, int(fid), None))

    # --------------------------------------------------------------- export
    def events(self) -> list[tuple]:
        """Snapshot of the ring (oldest surviving event first)."""
        with self._lock:
            return list(self._ring)

    def summary(self) -> dict:
        """Cheap run summary for reports/narration (no event payloads)."""
        with self._lock:
            evs = list(self._ring)
            dropped = self._dropped
        by_name: dict[str, int] = {}
        for ph, track, _tid, name, *_ in evs:
            if ph in ("X", "i"):
                by_name[f"{track}/{name}"] = by_name.get(
                    f"{track}/{name}", 0) + 1
        return {"enabled": True, "events": len(evs), "dropped": dropped,
                "capacity": self.capacity, "by_name": by_name}

    def export(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        One pid per component track (process metadata named), ``X``
        complete events for spans, ``i`` instants, ``s``/``f`` flow
        pairs.  Timestamps/durations are microseconds relative to
        tracer construction.
        """
        evs = self.events()
        with self._lock:
            dropped = self._dropped
        pids: dict[str, int] = {}
        out: list[dict] = []
        order = list(TRACKS) + sorted(
            {e[1] for e in evs} - set(TRACKS))
        present = {e[1] for e in evs}
        for track in order:
            if track not in present:
                continue
            pid = pids[track] = len(pids) + 1
            out.append({"name": "process_name", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"name": track}})
            out.append({"name": "process_sort_index", "ph": "M", "pid": pid,
                        "tid": 0, "args": {"sort_index": pid}})
        for ph, track, tid, name, ts, dur, args in evs:
            ev: dict[str, Any] = {"name": name, "ph": ph, "cat": track,
                                  "ts": round(ts * 1e6, 3),
                                  "pid": pids[track], "tid": tid}
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            elif ph == "i":
                ev["s"] = "t"  # thread-scoped instant
            elif ph in ("s", "f"):
                ev["cat"] = "flow"
                ev["id"] = int(dur)
                if ph == "f":
                    ev["bp"] = "e"  # bind to the enclosing slice
            if args:
                ev["args"] = args
            out.append(ev)
        return {
            "traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped": dropped, "capacity": self.capacity,
                          "events": len(evs)},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.export(), f)


# ---------------------------------------------------------------------------
# validation (shared by CI and the tests)
# ---------------------------------------------------------------------------

_PHASES = ("X", "i", "s", "f", "M")


def validate_trace(obj: dict, *, require_closed: bool = True) -> dict:
    """Validate an exported trace: event schema + span-chain closure.

    Schema: every event carries ``name``/``ph``/``ts``/``pid``/``tid``
    with sane types; ``X`` events need a non-negative ``dur``; the
    top-level object needs ``traceEvents`` and an ``otherData.dropped``
    counter.

    Chains: on the ``request`` track (tid = request id), every id that
    appears must have an ``admission`` span, and every id whose chain
    ended in ``complete`` must also have a ``queue`` span and belong to
    exactly one ``device-dispatch`` span's ``args.rids``.  With
    ``require_closed`` (the default), any id without a terminal instant
    (``complete``/``fail``/``shed``) is an orphan and fails validation.

    Returns a summary dict: event counts, per-terminal request counts,
    ``device_span_s``/``ingest_span_s`` (span sums that must reconcile
    with the report's ``device_wall_s``/``ingest_wall_s``), and
    ``open_chains``.  Raises :class:`ValueError` on any violation.
    """
    problems: list[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace-event object (no traceEvents)")
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("traceEvents is not a list")
    other = obj.get("otherData")
    if not isinstance(other, dict) or not isinstance(
            other.get("dropped"), int):
        problems.append("otherData.dropped missing or not an int")

    pid_names: dict[int, str] = {}
    for ev in evs:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            pid_names[ev["pid"]] = ev["args"]["name"]

    spans_by_name: dict[str, int] = {}
    span_sum_s: dict[str, float] = {}
    admission: set[int] = set()
    queued: set[int] = set()
    terminal: dict[int, str] = {}
    dispatch_members: dict[int, int] = {}  # rid -> device-dispatch count
    n_spans = n_instants = n_flows = 0
    for k, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {k}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            problems.append(f"event {k}: bad ph {ph!r}")
            continue
        if ph == "M":  # process metadata: no timestamp
            if not isinstance(ev.get("name"), str) \
                    or not isinstance(ev.get("pid"), int):
                problems.append(f"event {k}: bad metadata event")
            continue
        for key, typ in (("name", str), ("ts", (int, float)),
                         ("pid", int), ("tid", int)):
            if not isinstance(ev.get(key), typ):
                problems.append(f"event {k}: bad {key} {ev.get(key)!r}")
        track = pid_names.get(ev.get("pid"))
        name = ev.get("name")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0 \
                    or not math.isfinite(dur):
                problems.append(f"event {k}: X without sane dur ({dur!r})")
                continue
            n_spans += 1
            key = f"{track}/{name}"
            spans_by_name[key] = spans_by_name.get(key, 0) + 1
            span_sum_s[key] = span_sum_s.get(key, 0.0) + dur / 1e6
            if track == "request":
                rid = ev["tid"]
                if name == "admission":
                    admission.add(rid)
                elif name == "queue":
                    queued.add(rid)
            elif track == "device" and name == "device-dispatch":
                for rid in (ev.get("args") or {}).get("rids", ()):
                    dispatch_members[rid] = dispatch_members.get(rid, 0) + 1
        elif ph == "i":
            n_instants += 1
            if track == "request" and name in ("complete", "fail", "shed"):
                terminal[ev["tid"]] = name
        elif ph in ("s", "f"):
            n_flows += 1
            if not isinstance(ev.get("id"), int):
                problems.append(f"event {k}: flow without id")

    seen = admission | queued | set(terminal)
    for rid in sorted(seen - admission):
        problems.append(f"request {rid}: span chain without admission")
    complete = {r for r, t in terminal.items() if t == "complete"}
    for rid in sorted(complete):
        if rid not in queued:
            problems.append(f"request {rid}: completed without a queue span")
        if dispatch_members.get(rid, 0) != 1:
            problems.append(
                f"request {rid}: completed in "
                f"{dispatch_members.get(rid, 0)} device-dispatch spans "
                f"(want exactly 1)")
    open_chains = sorted(seen - set(terminal))
    if require_closed:
        for rid in open_chains:
            problems.append(f"request {rid}: orphan span chain "
                            f"(no terminal complete/fail/shed)")
    if problems:
        raise ValueError("invalid trace:\n  " + "\n  ".join(problems[:20]))
    return {
        "events": sum(1 for e in evs if e.get("ph") != "M"),
        "spans": n_spans,
        "instants": n_instants,
        "flows": n_flows,
        "dropped": other.get("dropped") if isinstance(other, dict) else None,
        "requests": len(seen),
        "complete": len(complete),
        "failed": sum(1 for t in terminal.values() if t == "fail"),
        "shed": sum(1 for t in terminal.values() if t == "shed"),
        "open_chains": open_chains,
        "spans_by_name": spans_by_name,
        "device_span_s": span_sum_s.get("device/device-dispatch", 0.0),
        "ingest_span_s": span_sum_s.get("ingest/ingest-decode", 0.0),
    }


# ---------------------------------------------------------------------------
# optional device-profiler bracket
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def jax_profile(trace_dir: str | None):
    """Bracket a window with ``jax.profiler`` when ``trace_dir`` is set.

    The device profile covers the same wall-clock window as the flight
    recorder, so host-side spans and device-side HLO timings can be
    correlated.  ``None`` is a no-op (the common case); an unavailable
    profiler backend degrades to a no-op with a warning rather than
    failing the serve run.
    """
    if not trace_dir:
        yield
        return
    import jax

    try:
        jax.profiler.start_trace(trace_dir)
    except Exception as e:  # profiler backend unavailable — don't kill serving
        print(f"[trace] jax.profiler unavailable ({e}); continuing without")
        yield
        return
    try:
        yield
    finally:
        jax.profiler.stop_trace()
