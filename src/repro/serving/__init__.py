"""Band-elastic serving runtime (ROADMAP "serving runtime").

The paper's §6 sparsity result makes ``bands`` a *runtime* quality/latency
knob: one trained network, compiled at several band budgets, can walk the
accuracy/compute frontier under load.  This package turns that into a
serving subsystem on top of the convert-once engine (``core.plan``):

* :mod:`repro.serving.ladder` — one ``InferencePlan`` compiled into a
  **plan ladder** of band tiers whose operators are prefix-slices of the
  same exploded Ξ buffers, with bit-exact save/restore;
* :mod:`repro.serving.grid` — the ladder made 2-D: a **plan grid** of
  precompiled (batch bucket × band tier) executors (aphrodite-style
  capture buckets 1, 2, 4, multiples of 8) with pinned host staging and
  input donation, so steady-state serving does zero compiles, zero
  reshapes, and pads only to the covering bucket;
* :mod:`repro.serving.scheduler` — an async request scheduler with
  admission control, per-request deadlines, and mixed
  ``coefficients``/``bytes`` ingest queues feeding ``repro.codec``;
* :mod:`repro.serving.qos` — the band-elastic policy: queue-depth and
  deadline-slack signals pick the tier per batch, degrading bands under
  overload and recovering (with hysteresis) as the queue drains;
* :mod:`repro.serving.metrics` — per-request latency histograms (O(1)
  memory log₂ buckets), per-tier throughput, tier-switch events, ingest
  occupancy, failure counters per reason, breaker state timeline, and a
  Prometheus-style text exposition with periodic snapshot writes;
* :mod:`repro.serving.trace` — the flight recorder: a bounded ring of
  per-request spans (admission → queue → ingest-decode → batch-form →
  pad/stage → device-dispatch → complete/fail/shed) exported as
  Perfetto-loadable Chrome trace-event JSON;
* :mod:`repro.serving.breaker` — a circuit breaker over service-level
  failures: fast-rejects (``ServiceUnavailable``) while the backend is
  evidently unhealthy, half-opens on a timer;
* :mod:`repro.serving.faults` — deterministic, seedable fault injection
  (corrupt bytes, worker kills, executor faults) driving the chaos
  suite; production runs never construct it.

``launch/serve.py`` is a thin CLI over this runtime (``--qos``,
``--tiers``, ``--deadline-ms``); ``benchmarks/fig5_throughput.py``'s
``serving`` mode measures fixed-band vs elastic under overload.
"""
from repro.serving.grid import (
    GridCell,
    GridColumn,
    PinnedPool,
    PlanGrid,
    batch_buckets,
    bucket_for,
    cover_buckets,
    validate_buckets,
)
from repro.serving.ladder import (
    DEFAULT_CAPS,
    PlanLadder,
    PlanTier,
    build_ladder,
    cap_plan,
    load_ladder,
    save_ladder,
)
from repro.serving.breaker import BreakerPolicy, CircuitBreaker
from repro.serving.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serving.metrics import (
    Log2Histogram,
    MetricsWriter,
    ServeMetrics,
    percentiles,
)
from repro.serving.qos import QosPolicy, TierSelector
from repro.serving.trace import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    jax_profile,
    validate_trace,
)
from repro.serving.scheduler import (
    BandElasticScheduler,
    DeadlineExceeded,
    RequestFailed,
    SchedulerClosed,
    ServeRequest,
    ServiceUnavailable,
)

__all__ = [
    "DEFAULT_CAPS",
    "GridCell",
    "GridColumn",
    "PinnedPool",
    "PlanGrid",
    "batch_buckets",
    "bucket_for",
    "cover_buckets",
    "validate_buckets",
    "PlanLadder",
    "PlanTier",
    "build_ladder",
    "cap_plan",
    "save_ladder",
    "load_ladder",
    "Log2Histogram",
    "MetricsWriter",
    "NULL_TRACER",
    "NullTracer",
    "ServeMetrics",
    "Tracer",
    "jax_profile",
    "percentiles",
    "validate_trace",
    "QosPolicy",
    "TierSelector",
    "BandElasticScheduler",
    "BreakerPolicy",
    "CircuitBreaker",
    "DeadlineExceeded",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "RequestFailed",
    "SchedulerClosed",
    "ServeRequest",
    "ServiceUnavailable",
]
