"""Async band-elastic request scheduler over a compiled-plan ladder.

Generalizes the slot loop ``launch/serve.py`` used to hard-code into a
runtime object:

* **admission control** — at most ``max_pending`` queued requests; over
  that, :meth:`BandElasticScheduler.submit` rejects (recorded in
  metrics) instead of letting the queue grow without bound;
* **two ingest queues, decode off the worker** — ``coefficients``
  requests carry pre-decoded ``(bh, bw, C, 64)`` tensors; ``bytes``
  requests carry real JPEG files.  A dedicated ingest thread drains the
  bytes queue through ``repro.codec`` (parallel restart-segment entropy
  decode + per-image quantization normalization) into a bounded
  decoded-coefficients queue, so host Huffman work overlaps device
  compute and the worker never decodes inline; decoded-but-unserved
  requests still count against ``max_pending`` (decode backpressure
  reaches admission control).  Batches are kind-homogeneous; the queue
  whose head request is oldest goes first (FIFO across kinds);
* **per-request deadlines** — a request may carry a deadline; the QoS
  selector sees the head-of-queue slack; requests already expired at
  dequeue are shed (failed with :class:`DeadlineExceeded`, counted as
  ``deadline_shed``) instead of burning a batch slot, and completions
  past their deadline are recorded as misses;
* **band-elastic execution over the plan grid** — before each batch the
  :class:`repro.serving.qos.TierSelector` picks the ladder tier from
  queue depth + deadline slack; the batch then runs in the smallest
  **capture bucket** covering its size (``repro.serving.grid`` — the
  aphrodite schedule 1, 2, 4, multiples of 8), through that
  (tier × bucket) cell's precompiled, input-donated executable.
  :meth:`warmup` sweeps the whole grid so steady-state serving performs
  zero JIT compiles and pads only to the covering bucket, never to
  ``max_batch``; every trace is counted (``ServeMetrics.record_compile``)
  and any compile after warmup is reported as ``compiles_post_warmup``.

Lifecycle mirrors the ``data.pipeline.prefetch`` contract: the worker
thread is owned by the scheduler — :meth:`close` (or leaving the
``with`` block) joins it, draining queued requests by default.

**Fault isolation** (the robustness layer): failures are contained at
the smallest scope that owns them.  A malformed JPEG fails *that*
request with :class:`RequestFailed` (stage ``"codec"``, the
``codec.CodecError`` on ``__cause__``) — batch-mates decode and serve
normally via ``ingest_batch(..., on_error="isolate")``.  An executor
exception gets one bounded retry, then fails only its batch (stage
``"executor"``) — the scheduler keeps serving.  Ingest-infrastructure
failures fail only the batch being decoded (stage ``"ingest"``); the
codec's pool supervisor respawns dead workers underneath.  Service-level
failures feed a :class:`~repro.serving.breaker.CircuitBreaker` that
fast-rejects new submissions with :class:`ServiceUnavailable` while the
service is evidently unhealthy (per-request codec errors never trip it —
corrupt *input* is not an unhealthy *service*).  ``_fail_all`` — the old
fail-deadly path — is reserved for genuinely unrecoverable states
(``BaseException`` escaping a loop); :meth:`close` re-raises it.
:meth:`health` snapshots breaker state, failure counters, pool restarts,
and queue depths at any time.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from typing import Any

import numpy as np
import jax

from repro.serving.breaker import BreakerPolicy, CircuitBreaker
from repro.serving.grid import PlanGrid
from repro.serving.ladder import PlanLadder
from repro.serving.metrics import ServeMetrics
from repro.serving.qos import QosPolicy, TierSelector
from repro.serving.trace import NULL_TRACER, NullTracer, Tracer

__all__ = ["DeadlineExceeded", "RequestFailed", "SchedulerClosed",
           "ServeRequest", "ServiceUnavailable", "BandElasticScheduler"]

KINDS = ("coefficients", "bytes")


class SchedulerClosed(RuntimeError):
    """The scheduler was closed (or died) before the request completed."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed before it was dispatched; it was
    shed at dequeue instead of wasting a batch slot."""


class RequestFailed(RuntimeError):
    """One request failed; the scheduler is still serving.

    ``stage`` names where it died — ``"codec"`` (this request's bytes
    are malformed; the underlying :class:`~repro.codec.CodecError` is on
    ``__cause__``), ``"executor"`` (the batch's compiled executable
    raised after the retry budget), ``"ingest"`` (decode infrastructure
    failed under the batch).
    """

    def __init__(self, stage: str, rid: int, cause: BaseException):
        super().__init__(f"request {rid} failed at {stage}: {cause}")
        self.stage = stage
        self.rid = rid
        self.__cause__ = cause


class ServiceUnavailable(RuntimeError):
    """Fast-reject: the circuit breaker is open.  Retry after backoff —
    the breaker half-opens on its own timer."""


class ServeRequest:
    """One in-flight classification request (a single image).

    ``result()`` blocks until the scheduler completes the request and
    returns the logits row; it raises the scheduler's failure if the
    worker died (or :class:`SchedulerClosed` on a non-draining close).
    """

    __slots__ = ("rid", "kind", "payload", "deadline", "submitted",
                 "t_enq", "tier", "latency_s", "_event", "_result",
                 "_error")

    def __init__(self, rid: int, kind: str, payload: Any,
                 deadline: float | None):
        self.rid = rid
        self.kind = kind
        self.payload = payload
        self.deadline = deadline          # absolute monotonic seconds
        self.submitted = time.monotonic()
        self.t_enq = self.submitted       # tracer-clock enqueue time
        self.tier: str | None = None      # tier name that served it
        self.latency_s: float | None = None
        self._event = threading.Event()
        self._result: np.ndarray | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def error(self) -> BaseException | None:
        """The failure outcome, if the request is done and failed —
        without raising (chaos harnesses inspect fleets of requests)."""
        return self._error if self._event.is_set() else None

    def result(self, timeout: float | None = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} still pending")
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, logits: np.ndarray, tier: str) -> None:
        if self._event.is_set():
            return  # first outcome wins (containment paths may race)
        self.tier = tier
        self.latency_s = time.monotonic() - self.submitted
        self._result = logits
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        if self._event.is_set():
            return  # already resolved; keep the first outcome
        self._error = err
        self._event.set()


class BandElasticScheduler:
    """Continuous-batching scheduler with a band-elastic tier policy.

    ``grid``/``channels`` describe the serving resolution (block grid of
    the coefficient layout); they are required for ``bytes`` ingest and
    for :meth:`warmup`.  ``policy=None`` with ``len(ladder) > 1`` uses
    the default :class:`QosPolicy`; a single-tier ladder pins tier 0
    (the fixed-band configuration the benchmarks compare against).

    ``buckets`` pins the batch capture buckets of the plan grid (default:
    the ladder's own recorded buckets, else the aphrodite schedule up to
    ``batch`` — see ``serving.grid.cover_buckets``); ``buckets=(batch,)``
    reproduces the pre-grid pad-to-``max_batch`` behaviour.

    ``executor`` selects the compiled-plan lowering (see
    ``core.plan.apply_compiled``): the band-elastic runtime defaults to
    the transform-domain tile-packed GEMM executor off-TPU — the only
    off-TPU lowering whose latency the band budget actually moves (the
    spatial lowering's conv cost is band-independent, which would make
    every tier equally expensive and the ladder pointless).  On TPU the
    compile-time path resolution (the Mosaic megakernel over the same
    packed operands) is already band-elastic and is kept.
    """

    def __init__(self, ladder: PlanLadder, *, batch: int = 8,
                 policy: QosPolicy | None = None,
                 metrics: ServeMetrics | None = None,
                 max_pending: int = 64,
                 grid: tuple[int, int] | None = None,
                 channels: int = 3,
                 executor: str | None = "auto",
                 buckets=None,
                 donate: bool = True,
                 breaker: CircuitBreaker | BreakerPolicy | None = None,
                 faults=None,
                 executor_retries: int = 1,
                 tracer: Tracer | NullTracer | None = None):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        if executor_retries < 0:
            raise ValueError("executor_retries must be >= 0")
        if executor == "auto":
            # off-TPU, only the packed-GEMM lowering is band-elastic; on
            # TPU the per-block megakernel path already is
            executor = None if jax.default_backend() == "tpu" else "gemm"
        self.executor = executor
        self.ladder = ladder
        self.batch = batch
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.max_pending = max_pending
        self.grid = grid
        self.channels = channels
        self.quality = ladder.base.spec.quality
        self._warmed = False
        # the flight recorder: NULL_TRACER keeps every call site
        # unconditional, and hot paths guard on `tracer.enabled`
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # service-level failure breaker (codec errors never feed it); a
        # prebuilt CircuitBreaker is taken as-is, a BreakerPolicy (or
        # None = defaults) builds one wired into the metrics timeline
        # and the trace instant stream
        if isinstance(breaker, CircuitBreaker):
            self.breaker = breaker
        else:
            self.breaker = CircuitBreaker(
                breaker, on_transition=self._on_breaker)
        self.faults = faults          # FaultInjector | None (tests only)
        self.executor_retries = executor_retries
        from repro.codec import ingest as _ingestlib

        self._pool_seen = _ingestlib.pool_restarts()
        self._dispatch_seq = 0

        # the (batch bucket × band tier) executor grid: one column per
        # *distinct* compiled schedule (shared tiers reuse cells and
        # their compile cache), one captured, input-donated executable
        # per (kind, bucket) cell
        self.grid_engine = PlanGrid(
            ladder, batch=batch, buckets=buckets, grid=grid,
            channels=channels, executor=executor, donate=donate,
            on_compile=self._note_compile, tracer=self.tracer)
        self.buckets = self.grid_engine.buckets
        self._execs = self.grid_engine.columns
        self.tier_names = [t.name for t in ladder.tiers]

        self.selector = TierSelector(
            len(ladder.tiers), policy, tier_names=self.tier_names,
            on_switch=self._on_switch)

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._queues = {k: collections.deque() for k in KINDS}
        # bytes batches the ingest thread has already decoded, waiting for
        # the worker: (reqs, (N, bh, bw, C, 64) float32, decode wall).
        # Bounded: the ingest thread stalls past _decoded_cap batches so
        # decode cannot run unboundedly ahead of the device.
        self._decoded: collections.deque = collections.deque()
        self._decoded_cap = 2
        self._ingesting = 0          # bytes requests currently decoding
        self._ingest_alive = True
        self._rid = itertools.count()
        self._in_flight = 0
        self._stop = False
        self._drain = True
        self._error: BaseException | None = None
        self._batches = 0
        self._images = 0
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="scheduler-worker")
        self._ingest_thread = threading.Thread(
            target=self._ingest_run, daemon=True, name="scheduler-ingest")
        self._worker.start()
        self._ingest_thread.start()

    # ----------------------------------------------------------- submission
    def submit(self, payload: Any, *, kind: str = "coefficients",
               deadline_s: float | None = None) -> ServeRequest | None:
        """Enqueue one request; returns None when admission control
        rejects it (queue at ``max_pending``), raises
        :class:`ServiceUnavailable` while the circuit breaker is open,
        and re-raises the worker's failure when the scheduler has died."""
        if kind not in KINDS:
            raise ValueError(f"unknown request kind {kind!r} "
                             f"(expected one of {KINDS})")
        if kind == "bytes" and self.grid is None:
            raise ValueError("bytes ingest needs grid= at construction")
        tr = self.tracer
        t_sub = tr.now() if tr.enabled else 0.0
        with self._lock:
            if self._error is not None:
                raise self._error
            if self._stop:
                raise SchedulerClosed("scheduler is closed")
            if not self.breaker.allow():
                self.metrics.record_failure("rejected-open-breaker")
                if tr.enabled:
                    tr.instant("scheduler", "reject",
                               args={"reason": "breaker-open"})
                raise ServiceUnavailable(
                    "circuit breaker open — service unhealthy, retry later")
            if self._pending_locked() >= self.max_pending:
                self.metrics.record_rejected()
                if tr.enabled:
                    tr.instant("scheduler", "reject",
                               args={"reason": "queue-full"})
                return None
            req = ServeRequest(next(self._rid), kind, payload,
                               None if deadline_s is None
                               else time.monotonic() + deadline_s)
            if tr.enabled:
                req.t_enq = tr.now()
                tr.span("request", "admission", t_sub, req.t_enq,
                        tid=req.rid, args={"kind": kind})
            self._queues[kind].append(req)
            self._work.notify_all()  # worker and ingest thread both wait
            return req

    def _pending_locked(self) -> int:
        # everything submitted but not yet dispatched: raw queues, bytes
        # mid-decode, and decoded batches awaiting the worker — so
        # admission control sees decode backpressure too
        return (sum(len(q) for q in self._queues.values())
                + self._ingesting
                + sum(len(e[0]) for e in self._decoded))

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending_locked()

    @property
    def images_served(self) -> int:
        with self._lock:
            return self._images

    def health(self) -> dict:
        """Point-in-time service health: breaker state, failure counters
        per reason, ingest-pool restarts, queue depths, thread liveness.
        Exported through the serve report (``--report-out``)."""
        with self._lock:
            queues = {k: len(q) for k, q in self._queues.items()}
            queues["decoded_batches"] = len(self._decoded)
            queues["decoding"] = self._ingesting
            in_flight = self._in_flight
            dead = self._error is not None
        return {
            "breaker": self.breaker.snapshot(),
            "failures_total": self.metrics.failures_total(),
            "pool_restarts": self.metrics.pool_restarts(),
            "qos_estimates": self.selector.estimates(),
            "queues": queues,
            "in_flight": in_flight,
            "worker_alive": self._worker.is_alive(),
            "ingest_alive": self._ingest_thread.is_alive(),
            "dead": dead,
        }

    # ------------------------------------------------------------ lifecycle
    def _note_compile(self, cell: str) -> None:
        """Fires from inside every cell's traced body — exactly once per
        compile.  After :meth:`warmup` the shape set is closed, so any
        further firing is a mid-traffic compile the report must show."""
        self.metrics.record_compile(cell, post_warmup=self._warmed)
        if self._warmed and self.tracer.enabled:
            # only post-warmup compiles are anomalies worth a timeline
            # mark; the warmup sweep would just flood the ring
            self.tracer.instant("device", "compile",
                                args={"cell": cell, "post_warmup": True})

    def _on_switch(self, batch_seq: int, from_tier: str, to_tier: str,
                   reason: str) -> None:
        """QoS tier switch: metrics timeline + trace instant."""
        self.metrics.record_switch(batch_seq, from_tier, to_tier, reason)
        if self.tracer.enabled:
            self.tracer.instant(
                "scheduler", "tier-switch",
                args={"from": from_tier, "to": to_tier, "reason": reason})

    def _on_breaker(self, frm: str, to: str, reason: str) -> None:
        """Circuit-breaker transition: metrics timeline + trace instant."""
        self.metrics.record_breaker(frm, to, reason)
        if self.tracer.enabled:
            self.tracer.instant("scheduler", "breaker",
                                args={"from": frm, "to": to,
                                      "reason": reason})

    def warmup(self, kinds=KINDS) -> None:
        """Sweep the whole plan grid: compile every (kind × bucket × tier)
        cell so steady-state serving — including tier switches and every
        partial-batch bucket — never pays an inline trace.  ``kinds``
        limits the sweep to the ingest kinds the caller will actually
        submit — a coefficients-only serve has no reason to pay the
        packed-stem compiles (and vice versa).  After the sweep, any
        compile is counted as ``compiles_post_warmup``."""
        if self.grid is None:
            raise ValueError("warmup needs grid= at construction")
        self.grid_engine.warmup(kinds)
        self._warmed = True

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every submitted request has completed (or the
        scheduler died — the error re-raises here).  Returns False on
        timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._idle:
            while self._pending_locked() or self._in_flight:
                if self._error is not None:
                    raise self._error
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    return False
                self._idle.wait(timeout=0.05 if left is None
                                else min(left, 0.05))
            if self._error is not None:
                raise self._error
        return True

    def close(self, drain: bool = True) -> None:
        """Stop the worker and join it.

        ``drain=True`` (default) serves everything already queued first;
        ``drain=False`` fails queued requests with
        :class:`SchedulerClosed`.  A worker failure re-raises here (once)
        so errors cannot vanish with the thread.
        """
        with self._lock:
            self._stop = True
            self._drain = drain
            self._work.notify_all()
        self._ingest_thread.join()
        self._worker.join()
        if self._error is not None and not isinstance(self._error,
                                                      SchedulerClosed):
            err, self._error = self._error, SchedulerClosed(
                "scheduler died; error already re-raised")
            raise err

    def __enter__(self) -> "BandElasticScheduler":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # consumer exception → don't sit around serving a dead consumer
        self.close(drain=exc_type is None)

    # -------------------------------------------------------- ingest thread
    def _ingest_run(self) -> None:
        """Drain the bytes queue into the decoded-coefficients queue.

        Decodes full-width (64-lane) batches so tier selection stays with
        the worker — packing to the chosen tier's stem width is a cheap
        slice at execute time.  Runs the codec's parallel path; decode
        wall is measured here and reported separately from device wall.

        Failure containment: decode runs with ``on_error="isolate"`` —
        each malformed image fails its own request (stage ``"codec"``)
        and the survivors serve normally.  An infrastructure exception
        under the batch (the pool supervisor already retried underneath)
        fails only that batch (stage ``"ingest"``), feeds the breaker,
        and the thread keeps draining.  Only ``BaseException`` poisons
        the scheduler.
        """
        from repro.codec import ingest as ingestlib

        reqs: list[ServeRequest] = []
        try:
            while True:
                with self._lock:
                    while True:
                        if self._error is not None or (
                                self._stop
                                and (not self._drain
                                     or not self._queues["bytes"])):
                            return
                        if (self._queues["bytes"] and
                                len(self._decoded) < self._decoded_cap):
                            break  # work available, decoded queue has room
                        self._work.wait(timeout=0.05)
                    now = time.monotonic()
                    reqs, shed = [], []
                    q = self._queues["bytes"]
                    while q and len(reqs) < self.batch:
                        r = q.popleft()
                        if r.deadline is not None and now > r.deadline:
                            shed.append(r)  # shed before paying the decode
                        else:
                            reqs.append(r)
                    self._ingesting = len(reqs)
                self._shed(shed)
                if not reqs:
                    with self._idle:
                        self._idle.notify_all()
                    continue
                tr = self.tracer
                on_shard = None
                if tr.enabled:
                    rids = [r.rid for r in reqs]

                    def on_shard(indices, ta, tb, _rids=rids):
                        # one spawn-pool shard of this batch (tid = the
                        # shard's first batch index, which is its shard
                        # number under the i::workers striping)
                        tr.span("ingest", "decode-shard", ta, tb,
                                tid=1 + (indices[0] if indices else 0),
                                args={"rids": [_rids[j] for j in indices]})

                t0 = time.monotonic()
                t0s = tr.now() if tr.enabled else 0.0
                try:
                    if self.faults is not None:
                        self.faults.on_ingest(reqs)
                    coef, stats, errors = ingestlib.ingest_batch(
                        [r.payload for r in reqs], quality=self.quality,
                        grid=self.grid, channels=self.channels,
                        on_error="isolate", on_shard=on_shard)
                except Exception as e:
                    # decode infrastructure died under the whole batch —
                    # fail these requests, keep the thread serving
                    self._note_pool_restarts(ingestlib)
                    if tr.enabled:
                        t = tr.now()
                        for r in reqs:
                            tr.span("request", "queue", r.t_enq, t,
                                    tid=r.rid)
                            tr.instant("request", "fail", t=t, tid=r.rid,
                                       args={"stage": "ingest"})
                    for r in reqs:
                        r._fail(RequestFailed("ingest", r.rid, e))
                    self.metrics.record_failure("ingest", len(reqs))
                    self.breaker.record_failure("ingest")
                    with self._lock:
                        self._ingesting = 0
                        reqs = []
                    with self._idle:
                        self._idle.notify_all()
                    continue
                wall = time.monotonic() - t0
                if tr.enabled:
                    tr.span("ingest", "ingest-decode", t0s, tr.now(),
                            args={"n": len(reqs),
                                  "rids": [r.rid for r in reqs]})
                self._note_pool_restarts(ingestlib)
                self.metrics.record_ingest(stats)
                if errors:
                    if tr.enabled:
                        t = tr.now()
                        for i in errors:
                            tr.span("request", "queue", reqs[i].t_enq, t,
                                    tid=reqs[i].rid)
                            tr.instant("request", "fail", t=t,
                                       tid=reqs[i].rid,
                                       args={"stage": "codec"})
                    for i, err in errors.items():
                        r = reqs[i]
                        r._fail(RequestFailed("codec", r.rid, err))
                    self.metrics.record_failure("codec", len(errors))
                    reqs = [r for i, r in enumerate(reqs)
                            if i not in errors]
                with self._lock:
                    if self._stop and not self._drain:
                        for r in reqs:
                            r._fail(SchedulerClosed(
                                "scheduler closed before completion"))
                        self._ingesting = 0
                        return
                    if self._error is not None:
                        # the worker died while we were decoding: these
                        # requests are invisible to _fail_all — fail them
                        # here so close() never strands a waiter
                        for r in reqs:
                            r._fail(self._error)
                        self._ingesting = 0
                        return
                    if reqs:
                        self._decoded.append(
                            (reqs, np.asarray(coef, np.float32), wall))
                    self._ingesting = 0
                    reqs = []
                    self._work.notify_all()
                with self._idle:
                    self._idle.notify_all()
        except BaseException as e:  # noqa: BLE001 — re-raised at waiters
            for r in reqs:
                r._fail(e)
            with self._lock:
                self._ingesting = 0
            self._fail_all(e)
        finally:
            leftover: list[ServeRequest] = []
            with self._lock:
                self._ingest_alive = False
                if self._error is not None:
                    # decoded batches appended after (or never seen by)
                    # _fail_all would strand their waiters — drain them
                    leftover = [r for e in self._decoded for r in e[0]]
                    self._decoded.clear()
                err = self._error
                self._work.notify_all()
            for r in leftover:
                r._fail(err)

    def _note_pool_restarts(self, ingestlib) -> None:
        """Fold the codec pool supervisor's respawn count into metrics
        (delta since construction / last observation)."""
        now = ingestlib.pool_restarts()
        delta = now - self._pool_seen
        if delta > 0:
            self._pool_seen = now
            self.metrics.record_pool_restarts(delta)
            if self.tracer.enabled:
                self.tracer.instant("ingest", "pool-restart",
                                    args={"restarts": delta})

    def _shed(self, shed: list[ServeRequest]) -> None:
        if not shed:
            return
        self.metrics.record_deadline_shed(len(shed))
        self.metrics.record_failure("deadline", len(shed))
        tr = self.tracer
        t = tr.now() if tr.enabled else 0.0
        for r in shed:
            if tr.enabled:
                # close the chain: time-in-queue span, then the terminal
                tr.span("request", "queue", r.t_enq, t, tid=r.rid)
                tr.instant("request", "shed", t=t, tid=r.rid)
            r._fail(DeadlineExceeded(
                f"request {r.rid} expired before dispatch"))

    # --------------------------------------------------------------- worker
    def _ready_locked(self) -> bool:
        return bool(self._decoded) or bool(self._queues["coefficients"])

    def _take_batch_locked(self, now: float):
        """Pop the next kind-homogeneous batch, shedding expired requests.

        Returns ``(reqs, decoded, shed)``: ``decoded`` is the ingest
        thread's ``(coef, ingest_wall)`` for a bytes batch, None for a
        coefficients batch; ``shed`` are expired requests to fail.
        """
        heads = []
        if self._decoded:
            heads.append((self._decoded[0][0][0].rid, "bytes"))
        if self._queues["coefficients"]:
            heads.append((self._queues["coefficients"][0].rid,
                          "coefficients"))
        if not heads:
            return [], None, []
        _, kind = min(heads)  # oldest head request wins (FIFO across kinds)
        if kind == "bytes":
            reqs, coef, wall = self._decoded.popleft()
            live = [i for i, r in enumerate(reqs)
                    if r.deadline is None or now <= r.deadline]
            shed = [r for i, r in enumerate(reqs) if i not in set(live)]
            if len(live) != len(reqs):
                reqs = [reqs[i] for i in live]
                coef = coef[live]
            return reqs, (coef, wall), shed
        q = self._queues["coefficients"]
        reqs, shed = [], []
        while q and len(reqs) < self.batch:
            r = q.popleft()
            if r.deadline is not None and now > r.deadline:
                shed.append(r)
            else:
                reqs.append(r)
        return reqs, None, shed

    def _head_slack_locked(self, now: float) -> float | None:
        slacks = [q[0].deadline - now for q in self._queues.values()
                  if q and q[0].deadline is not None]
        slacks += [r.deadline - now for e in self._decoded
                   for r in e[0][:1] if r.deadline is not None]
        return min(slacks) if slacks else None

    def _run(self) -> None:
        try:
            while True:
                with self._lock:
                    while (not self._ready_locked() and not self._stop
                           and self._error is None):
                        self._work.wait(timeout=0.05)
                    if self._error is not None:
                        raise self._error
                    if self._stop and (not self._drain
                                       or (not self._pending_locked()
                                           and not self._ingesting)):
                        break
                    now = time.monotonic()
                    slack = self._head_slack_locked(now)
                    depth = self._pending_locked()
                    reqs, decoded, shed = self._take_batch_locked(now)
                    tier_ix = None
                    if reqs:
                        # tier selection happens *after* the take so the
                        # capture bucket is known and the QoS estimates
                        # key to the right grid cell (a bucket-1 trickle
                        # must not be judged by bucket-8 latency)
                        tier_ix = self.selector.select(
                            pending=depth, batch=self.batch,
                            head_slack_s=slack,
                            bucket=self.grid_engine.bucket_for(len(reqs)))
                    self._in_flight = len(reqs)
                self._shed(shed)
                if not reqs:
                    with self._idle:
                        self._in_flight = 0
                        self._idle.notify_all()
                    continue
                tr = self.tracer
                t_take = tr.now() if tr.enabled else 0.0
                if tr.enabled:
                    # queue span closes here for the whole batch — once,
                    # before the retry loop, so retries don't duplicate it
                    for r in reqs:
                        tr.span("request", "queue", r.t_enq, t_take,
                                tid=r.rid)
                seq = self._dispatch_seq
                self._dispatch_seq += 1
                err: Exception | None = None
                for _attempt in range(self.executor_retries + 1):
                    try:
                        if self.faults is not None:
                            self.faults.on_execute(seq, reqs)
                        self._execute(reqs, tier_ix, depth, decoded,
                                      t_take=t_take)
                        err = None
                        break
                    except Exception as e:  # transient? bounded retry
                        err = e
                    except BaseException as e:
                        for r in reqs:  # the in-flight batch left the
                            r._fail(e)  # queue — _fail_all can't see it
                        raise
                if err is None:
                    self.breaker.record_success()
                else:
                    # retry budget exhausted: fail only this batch — the
                    # scheduler survives, the breaker accumulates
                    if tr.enabled:
                        t = tr.now()
                        for r in reqs:
                            tr.instant("request", "fail", t=t, tid=r.rid,
                                       args={"stage": "executor"})
                    for r in reqs:
                        r._fail(RequestFailed("executor", r.rid, err))
                    self.metrics.record_failure("executor", len(reqs))
                    self.breaker.record_failure("executor")
                    self.selector.note_failure()
                    with self._idle:
                        self._in_flight = 0
                        self._idle.notify_all()
        except BaseException as e:  # noqa: BLE001 — re-raised at waiters
            self._fail_all(e)
            return
        self._fail_all(SchedulerClosed("scheduler closed before completion"),
                       record=False)

    def _execute(self, reqs: list[ServeRequest], tier_ix: int,
                 depth: int, decoded=None, t_take: float = 0.0) -> None:
        ex = self._execs[tier_ix]
        name = self.tier_names[tier_ix]
        n = len(reqs)
        bucket = self.grid_engine.bucket_for(n)
        tr = self.tracer
        rids = [r.rid for r in reqs] if tr.enabled else None
        # rids ride along only when tracing: untraced dispatch keeps the
        # bare executor signature (tests monkeypatch coef_fn/packed_fn)
        kw = {"rids": rids} if tr.enabled else {}
        ingest_wall = None
        t0 = time.monotonic()
        t0s = tr.now() if tr.enabled else 0.0
        if reqs[0].kind == "bytes":
            from repro.codec import ingest as ingestlib

            # decode already happened on the ingest thread; only the
            # pack-to-tier-width slice and the device walk run here.
            # Rows go in *unpadded*: the grid cell stages them into its
            # pinned bucket-shaped buffer and zero-fills the pad tail.
            coef, ingest_wall = decoded
            kind = "bytes"
            logits = np.asarray(ex.packed_fn(
                ingestlib.pack_tiles(coef, ex.w_in), **kw))
        else:
            kind = "coefficients"
            logits = np.asarray(ex.coef_fn(np.stack(
                [np.asarray(r.payload, np.float32) for r in reqs]), **kw))
        wall = time.monotonic() - t0
        if tr.enabled:
            t1s = tr.now()
            # batch-form covers take -> dispatch start (tier selection +
            # tile packing); device-dispatch is exactly the interval the
            # report's device_wall_s accumulates, so span sums reconcile
            tr.span("scheduler", "batch-form", t_take, t0s,
                    args={"tier": name, "n": n, "bucket": bucket,
                          "kind": kind})
            dargs = {"tier": name, "n": n, "bucket": bucket,
                     "kind": kind, "rids": rids}
            # --profile-grid cost annotations: the span carries the
            # cell's static FLOPs and roofline-predicted wall, so a
            # Perfetto query can put predicted-vs-measured on one track
            cost = self.grid_engine.cost_for(f"{name}/{kind}/b{bucket}")
            if cost:
                dargs.update({k: cost[k] for k in ("flops", "predicted_us")
                              if k in cost})
            tr.span("device", "device-dispatch", t0s, t1s, args=dargs)
            for r in reqs:
                # flow arrow: this request's queue row -> its batch slice
                tr.flow(r.rid, ("request", r.rid, t_take),
                        ("device", 0, t0s))
        # only device wall reaches the QoS EMA: host decode cost is
        # band-independent, so folding it in would poison tier selection
        self.selector.observe(tier_ix, wall, bucket=bucket)
        self.metrics.record_batch(name, n, wall, queue_depth=depth,
                                  ingest_s=ingest_wall, slots=bucket,
                                  cell=f"{name}/{kind}/b{bucket}")
        now = time.monotonic()
        t_now = tr.now() if tr.enabled else 0.0
        for i, r in enumerate(reqs):
            r._complete(logits[i], name)
            if tr.enabled:
                tr.instant("request", "complete", t=t_now, tid=r.rid,
                           args={"tier": name})
            self.metrics.record_request(
                r.latency_s, tier=name,
                deadline_missed=(r.deadline is not None
                                 and now > r.deadline))
        with self._idle:
            self._in_flight = 0
            self._batches += 1
            self._images += n
            self._idle.notify_all()

    def _fail_all(self, err: BaseException, record: bool = True) -> None:
        with self._idle:
            if record and self._error is None:
                self._error = err
            pending = [r for q in self._queues.values() for r in q]
            pending += [r for e in self._decoded for r in e[0]]
            for q in self._queues.values():
                q.clear()
            self._decoded.clear()
            self._in_flight = 0
            self._work.notify_all()
            self._idle.notify_all()
        for r in pending:
            r._fail(err)
