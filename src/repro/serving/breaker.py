"""Circuit breaker for the serving runtime.

The breaker watches *service-level* failures (executor crashes, ingest
infrastructure faults) and trips to fast-reject when the service is
evidently unhealthy, so a dying backend sheds load in O(1) per request
instead of queueing doomed work against deadlines.  Per-request input
errors (``codec.CodecError`` — *that request's* bytes are bad) never
feed it: corrupt traffic is contained request-by-request and must not
starve healthy requests (``serving.scheduler``).

States follow the classic pattern:

- **closed** — normal service.  Failures land in a rolling window; the
  breaker opens when the window failure rate or the consecutive-failure
  streak crosses :class:`BreakerPolicy` thresholds.
- **open** — every ``allow()`` is refused (the scheduler maps this to
  ``ServiceUnavailable``) until ``open_s`` has elapsed.
- **half_open** — probe mode: requests flow again, but one failure
  re-opens immediately and ``half_open_successes`` consecutive successes
  close.

The clock is injectable so tests drive the open→half_open timer
deterministically; ``on_transition`` lets the scheduler export the state
timeline through ``ServeMetrics``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable

__all__ = ["BreakerPolicy", "CircuitBreaker"]

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclass(frozen=True)
class BreakerPolicy:
    """Thresholds for :class:`CircuitBreaker`.

    ``window`` outcomes back the rolling failure rate; the rate only
    trips after ``min_samples`` outcomes so a cold start can't open on
    one failure.  ``max_consecutive`` is the fast path for hard-down
    backends (opens regardless of the window).  ``open_s`` is the
    open→half_open timer; ``half_open_successes`` consecutive probe
    successes close the breaker again.
    """

    window: int = 32
    failure_rate: float = 0.5
    min_samples: int = 8
    max_consecutive: int = 4
    open_s: float = 1.0
    half_open_successes: int = 2


class CircuitBreaker:
    """Thread-safe three-state breaker (see module docstring)."""

    def __init__(self, policy: BreakerPolicy | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str, str, str], None]
                 | None = None):
        self.policy = policy or BreakerPolicy()
        self._clock = clock
        self._on_transition = on_transition
        self._lock = threading.Lock()
        self._state = CLOSED
        self._outcomes: deque[bool] = deque(maxlen=self.policy.window)
        self._consecutive = 0
        self._opened_at = 0.0
        self._probe_successes = 0
        self._last_failure_reason: str | None = None
        self._since = self._clock()   # clock reading at last transition
        self._transitions = 0

    # -- internal ----------------------------------------------------------

    def _transition(self, to: str, reason: str) -> None:
        """Move to ``to`` (lock held) and notify outside state mutation."""
        frm, self._state = self._state, to
        if to == OPEN:
            self._opened_at = self._clock()
        if to == HALF_OPEN:
            self._probe_successes = 0
        if to == CLOSED:
            self._outcomes.clear()
            self._consecutive = 0
        if frm != to:
            self._since = self._clock()
            self._transitions += 1
            if self._on_transition is not None:
                self._on_transition(frm, to, reason)

    def _should_open(self) -> bool:
        p = self.policy
        if self._consecutive >= p.max_consecutive:
            return True
        if len(self._outcomes) >= p.min_samples:
            rate = sum(self._outcomes) / len(self._outcomes)
            if rate >= p.failure_rate:
                return True
        return False

    # -- public ------------------------------------------------------------

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow(self) -> bool:
        """May a request be admitted right now?

        In ``open``, flips to ``half_open`` once the timer expires and
        admits the probe; otherwise refuses.
        """
        with self._lock:
            if self._state == OPEN:
                if self._clock() - self._opened_at >= self.policy.open_s:
                    self._transition(HALF_OPEN, "open timer expired")
                    return True
                return False
            return True

    def record_success(self) -> None:
        with self._lock:
            self._consecutive = 0
            if self._state == HALF_OPEN:
                self._probe_successes += 1
                if self._probe_successes >= self.policy.half_open_successes:
                    self._transition(CLOSED, "probe successes")
            elif self._state == CLOSED:
                self._outcomes.append(False)

    def record_failure(self, reason: str = "failure") -> None:
        with self._lock:
            self._last_failure_reason = reason
            self._consecutive += 1
            if self._state == HALF_OPEN:
                self._transition(OPEN, f"probe failed: {reason}")
                return
            if self._state == CLOSED:
                self._outcomes.append(True)
                if self._should_open():
                    self._transition(OPEN, reason)

    def snapshot(self) -> dict:
        """Point-in-time view for ``health()`` / report export."""
        with self._lock:
            n = len(self._outcomes)
            return {
                "state": self._state,
                "state_age_s": round(self._clock() - self._since, 6),
                "transitions": self._transitions,
                "window_failure_rate": (sum(self._outcomes) / n) if n else 0.0,
                "window_samples": n,
                "consecutive_failures": self._consecutive,
                "last_failure_reason": self._last_failure_reason,
            }
