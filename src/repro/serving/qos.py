"""Band-elastic QoS policy: pick the ladder tier per batch, with hysteresis.

The signals are deliberately cheap and local — things the scheduler
already knows at batch-formation time:

* **queue depth** — pending requests relative to the batch size.  Above
  ``QosPolicy.high_depth`` batches of backlog the system is considered
  overloaded; below ``low_depth`` it is draining.
* **deadline slack** — the head-of-queue request's remaining time vs the
  current tier's observed batch latency (an EMA per tier).  A head that
  cannot make its deadline at the current tier is an overload signal even
  when the queue is short.

Degradation walks one rung down per decision, recovery one rung up — and
both require ``hysteresis`` *consecutive* batches of agreeing signal, so
a single bursty arrival or one fast batch does not thrash the ladder.
Recovery additionally requires the better tier's expected latency to fit
the current drain budget (``recover_margin``) so the system does not
climb straight back into the overload that demoted it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["QosPolicy", "TierSelector"]


@dataclasses.dataclass(frozen=True)
class QosPolicy:
    """Knobs of the band-elastic tier policy.

    ``high_depth``/``low_depth`` are queue depths in units of *batches*
    (pending / batch_size).  ``hysteresis`` is the number of consecutive
    agreeing decisions required before a switch.  ``latency_ema`` is the
    smoothing factor for per-tier batch-latency estimates.
    ``recover_margin`` scales the better tier's latency estimate when
    deciding whether recovery is safe (>1 = conservative).
    """

    high_depth: float = 2.0
    low_depth: float = 0.5
    hysteresis: int = 2
    latency_ema: float = 0.5
    recover_margin: float = 1.5

    def __post_init__(self):
        if self.high_depth <= self.low_depth:
            raise ValueError("high_depth must exceed low_depth "
                             f"({self.high_depth} <= {self.low_depth})")
        if self.hysteresis < 1:
            raise ValueError("hysteresis must be >= 1")


class TierSelector:
    """Stateful tier chooser over an ``n_tiers``-rung ladder.

    Tier 0 is best quality; higher indices are narrower bands.  The
    scheduler calls :meth:`select` before forming each batch and
    :meth:`observe` after it completes; ``on_switch`` (e.g.
    ``ServeMetrics.record_switch``) fires on every tier change.
    """

    def __init__(self, n_tiers: int, policy: QosPolicy | None = None, *,
                 on_switch: Callable[[int, str, str, str], None] | None = None,
                 tier_names: list[str] | None = None):
        if n_tiers < 1:
            raise ValueError("need at least one tier")
        self.n_tiers = n_tiers
        self.policy = policy or QosPolicy()
        self.tier = 0
        self._names = tier_names or [str(i) for i in range(n_tiers)]
        self._on_switch = on_switch
        self._over = 0
        self._under = 0
        self._seq = 0
        # keyed (tier, bucket): the plan grid runs one executable per
        # batch bucket, and a bucket-1 batch says nothing about bucket-8
        # latency — bucket=None is the pre-grid wildcard (fixed-shape
        # schedulers and the unit tests), matching any bucket on read
        self._latency: dict[tuple[int, int | None], float] = {}

    # ------------------------------------------------------------ estimates
    def observe(self, tier: int, batch_wall_s: float, *,
                bucket: int | None = None) -> None:
        """Fold one completed batch's wall clock into the (tier, bucket)
        cell's EMA (``bucket=None`` = the tier-wide wildcard cell)."""
        a = self.policy.latency_ema
        key = (tier, bucket)
        prev = self._latency.get(key)
        self._latency[key] = (batch_wall_s if prev is None
                              else a * batch_wall_s + (1 - a) * prev)

    def _tier_latency(self, tier: int, bucket: int | None) -> float | None:
        """Best estimate within one tier: the exact (tier, bucket) cell,
        else the tier's nearest observed bucket (wildcard entries match
        at distance 0; with no target bucket the *largest* observed
        bucket wins — the conservative, worst-case-latency choice)."""
        exact = self._latency.get((tier, bucket))
        if exact is not None:
            return exact
        best, best_d = None, None
        for (t, b), v in self._latency.items():
            if t != tier:
                continue
            if bucket is None:
                d = -(b if b is not None else 1 << 30)
            else:
                d = 0 if b is None else abs(b - bucket)
            if best_d is None or d < best_d:
                best, best_d = v, d
        return best

    def est_latency(self, tier: int, bucket: int | None = None
                    ) -> float | None:
        """Best latency estimate for ``tier`` (at ``bucket``, when the
        grid knows it): the tier's own cells, else the nearest observed
        tier's (better a stale neighbour than nothing)."""
        own = self._tier_latency(tier, bucket)
        if own is not None:
            return own
        for d in range(1, self.n_tiers):
            for t in (tier - d, tier + d):
                est = self._tier_latency(t, bucket)
                if est is not None:
                    return est
        return None

    # ------------------------------------------------------------ selection
    def select(self, *, pending: int, batch: int,
               head_slack_s: float | None = None,
               bucket: int | None = None) -> int:
        """Tier for the next batch.

        ``pending`` — total queued requests; ``batch`` — slot count;
        ``head_slack_s`` — remaining time until the oldest queued
        request's deadline (None = no deadline traffic); ``bucket`` —
        the capture bucket the batch will run in, keying the latency
        estimates to the right grid cell.
        """
        self._seq += 1
        p = self.policy
        depth = pending / max(batch, 1)
        est = self.est_latency(self.tier, bucket)

        overload = depth >= p.high_depth
        reason = f"queue depth {pending} >= {p.high_depth:g}x batch {batch}"
        if not overload and head_slack_s is not None and est is not None \
                and est > head_slack_s:
            overload = True
            reason = (f"head deadline slack {head_slack_s * 1e3:.0f}ms < "
                      f"tier latency {est * 1e3:.0f}ms")

        drained = depth <= p.low_depth
        if drained and self.tier > 0:
            better = self.est_latency(self.tier - 1, bucket)
            if head_slack_s is not None and better is not None \
                    and better * p.recover_margin > head_slack_s:
                drained = False  # recovery would blow the head deadline

        if overload:
            self._over += 1
            self._under = 0
            if self._over >= p.hysteresis and self.tier < self.n_tiers - 1:
                self._switch(self.tier + 1, reason)
                self._over = 0
        elif drained:
            self._under += 1
            self._over = 0
            if self._under >= p.hysteresis and self.tier > 0:
                self._switch(self.tier - 1,
                             f"queue drained to {pending} "
                             f"<= {p.low_depth:g}x batch {batch}")
                self._under = 0
        else:
            self._over = 0
            self._under = 0
        return self.tier

    def estimates(self) -> dict[str, float]:
        """Point-in-time (tier, bucket) EMA snapshot, keyed
        ``"{tier}/b{bucket}"`` (``b*`` = the wildcard cell) — the live
        view ``health()`` exports so an operator can see what the
        selector currently believes about each grid cell."""
        return {
            f"{self._names[t]}/b{'*' if b is None else b}": round(v, 6)
            for (t, b), v in sorted(self._latency.items(),
                                    key=lambda kv: (kv[0][0],
                                                    kv[0][1] or 0))
        }

    def note_failure(self) -> None:
        """A batch at the current tier failed (executor fault, not load).

        Resets both hysteresis streaks: a failed batch produced neither a
        latency observation nor evidence about queue pressure, so letting
        its ``select`` vote stand would let a fault burst walk the ladder
        on garbage signal.
        """
        self._over = 0
        self._under = 0

    def _switch(self, to: int, reason: str) -> None:
        frm = self.tier
        self.tier = to
        if self._on_switch is not None:
            self._on_switch(self._seq, self._names[frm], self._names[to],
                            reason)
