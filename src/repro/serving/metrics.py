"""Serving metrics: request latencies, per-tier throughput, QoS events.

``ServeMetrics`` is the one sink every serving component writes into —
the scheduler records per-request latency and per-batch tier/throughput,
the QoS selector records tier-switch events, the ingest path records the
codec's per-band occupancy stats — and :meth:`ServeMetrics.report` folds
everything into the JSON-serializable block the serve report embeds.

:func:`percentiles` is also used standalone by the non-QoS slot loop in
``launch/serve.py`` so plain serving reports p50/p95/p99 per-request
latency too, not just aggregate wall clock.
"""
from __future__ import annotations

import threading
from typing import Any, Iterable, Sequence

import numpy as np

__all__ = ["percentiles", "ServeMetrics"]


def percentiles(latencies_s: Sequence[float],
                pcts: Iterable[int] = (50, 95, 99)) -> dict[str, float]:
    """Latency summary in milliseconds: ``{"p50_ms": ..., "p95_ms": ...,
    "p99_ms": ..., "mean_ms": ..., "max_ms": ..., "n": ...}``.

    Empty input yields ``{"n": 0}`` (serving nothing is not an error).
    """
    xs = np.asarray(list(latencies_s), np.float64)
    if xs.size == 0:
        return {"n": 0}
    out: dict[str, float] = {
        f"p{p}_ms": round(float(np.percentile(xs, p)) * 1e3, 3)
        for p in pcts
    }
    out["mean_ms"] = round(float(xs.mean()) * 1e3, 3)
    out["max_ms"] = round(float(xs.max()) * 1e3, 3)
    out["n"] = int(xs.size)
    return out


class ServeMetrics:
    """Thread-safe recorder for one serving run.

    Every ``record_*`` hook may be called from the scheduler worker and
    from submitting threads concurrently; :meth:`report` may be called at
    any time (it snapshots under the lock).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._latencies: list[float] = []
        self._per_tier_latencies: dict[str, list[float]] = {}
        self._tiers: dict[str, dict[str, float]] = {}
        self._switches: list[dict[str, Any]] = []
        self._rejected = 0
        self._deadline_misses = 0
        self._deadline_shed = 0
        self._requests = 0
        self._ingest: list[Any] = []
        self._ingest_wall_s = 0.0
        self._device_wall_s = 0.0
        self._images = 0
        self._slots = 0
        self._cell_hits: dict[str, int] = {}
        self._compiles_total = 0
        self._compiles_post_warmup = 0
        self._compiled_cells: list[dict[str, Any]] = []
        self._failures: dict[str, int] = {}
        self._pool_restarts = 0
        self._breaker_events: list[dict[str, Any]] = []

    # ------------------------------------------------------------- requests
    def record_request(self, latency_s: float, *, tier: str | None = None,
                       deadline_missed: bool = False) -> None:
        with self._lock:
            self._requests += 1
            self._latencies.append(float(latency_s))
            if tier is not None:
                self._per_tier_latencies.setdefault(tier, []).append(
                    float(latency_s))
            if deadline_missed:
                self._deadline_misses += 1

    def record_rejected(self, n: int = 1) -> None:
        """Admission control turned a request away (queue full)."""
        with self._lock:
            self._rejected += n

    def record_deadline_shed(self, n: int = 1) -> None:
        """Requests already expired at dequeue, failed without dispatch."""
        with self._lock:
            self._deadline_shed += n

    # -------------------------------------------------------------- batches
    def record_batch(self, tier: str, images: int, wall_s: float,
                     queue_depth: int | None = None,
                     ingest_s: float | None = None,
                     slots: int | None = None,
                     cell: str | None = None) -> None:
        """One executed batch.  ``wall_s`` is *device* wall (what the QoS
        selector is fed); ``ingest_s``, when given, is the host entropy
        decode wall the ingest thread spent on this batch — kept separate
        so bytes-heavy traffic cannot poison per-tier latency.

        ``slots`` is the padded batch width the executor actually ran
        (the capture bucket) — ``slots - images`` slots were padding, and
        the report's ``padding_fraction`` aggregates that waste.
        ``cell`` names the grid cell that served the batch (per-cell hit
        counts land in ``grid_cell_hits``)."""
        with self._lock:
            t = self._tiers.setdefault(
                tier, {"batches": 0, "images": 0, "wall_s": 0.0,
                       "max_queue_depth": 0, "slots": 0})
            t["batches"] += 1
            t["images"] += int(images)
            t["wall_s"] += float(wall_s)
            self._device_wall_s += float(wall_s)
            self._images += int(images)
            if slots is not None:
                t["slots"] += int(slots)
                self._slots += int(slots)
            if cell is not None:
                self._cell_hits[cell] = self._cell_hits.get(cell, 0) + 1
            if ingest_s is not None:
                self._ingest_wall_s += float(ingest_s)
            if queue_depth is not None:
                t["max_queue_depth"] = max(t["max_queue_depth"],
                                           int(queue_depth))

    def record_compile(self, cell: str, *, post_warmup: bool = False
                       ) -> None:
        """One executable trace/compile (fired from inside the traced
        body, so exactly once per compile).  ``post_warmup`` marks a
        compile after :meth:`BandElasticScheduler.warmup` declared the
        shape set closed — steady-state serving must report zero."""
        with self._lock:
            self._compiles_total += 1
            if post_warmup:
                self._compiles_post_warmup += 1
            self._compiled_cells.append({"cell": cell,
                                         "post_warmup": bool(post_warmup)})

    def record_switch(self, batch_seq: int, from_tier: str, to_tier: str,
                      reason: str) -> None:
        with self._lock:
            self._switches.append({"batch": int(batch_seq),
                                   "from": from_tier, "to": to_tier,
                                   "reason": reason})

    # ------------------------------------------------------------- failures
    def record_failure(self, reason: str, n: int = 1) -> None:
        """One failed request, keyed by reason — ``codec`` (bad input
        bytes), ``deadline``, ``executor``, ``ingest`` (decode
        infrastructure), ``rejected-open-breaker`` (fast-reject)."""
        with self._lock:
            self._failures[reason] = self._failures.get(reason, 0) + n

    def record_pool_restarts(self, n: int = 1) -> None:
        """The ingest-pool supervisor respawned a broken worker pool."""
        with self._lock:
            self._pool_restarts += n

    def record_breaker(self, frm: str, to: str, reason: str) -> None:
        """One circuit-breaker state transition (the state timeline)."""
        with self._lock:
            self._breaker_events.append(
                {"seq": len(self._breaker_events), "from": frm, "to": to,
                 "reason": reason})

    def failures_total(self) -> dict[str, int]:
        with self._lock:
            return dict(self._failures)

    def pool_restarts(self) -> int:
        with self._lock:
            return self._pool_restarts

    def breaker_timeline(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._breaker_events)

    def record_ingest(self, stats: Any) -> None:
        """Accumulate a ``codec.ingest.IngestStats`` from one byte batch."""
        if stats is not None:
            with self._lock:
                self._ingest.append(stats)

    # --------------------------------------------------------------- report
    @property
    def tier_switches(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._switches)

    def latency_report(self) -> dict[str, float]:
        with self._lock:
            return percentiles(self._latencies)

    def report(self) -> dict[str, Any]:
        with self._lock:
            per_tier = {}
            for name, t in self._tiers.items():
                wall = max(t["wall_s"], 1e-9)
                per_tier[name] = {
                    **{k: (round(v, 6) if isinstance(v, float) else v)
                       for k, v in t.items()},
                    "images_per_s": round(t["images"] / wall, 2),
                    "latency_ms": percentiles(
                        self._per_tier_latencies.get(name, ())),
                }
                if t["slots"]:
                    per_tier[name]["padding_fraction"] = round(
                        1.0 - t["images"] / t["slots"], 4)
            out: dict[str, Any] = {
                "requests": self._requests,
                "rejected": self._rejected,
                "padding_fraction": (
                    round(1.0 - self._images / self._slots, 4)
                    if self._slots else None),
                "compiles_total": self._compiles_total,
                "compiles_post_warmup": self._compiles_post_warmup,
                "grid_cell_hits": dict(self._cell_hits),
                "deadline_misses": self._deadline_misses,
                "deadline_miss_rate": round(
                    self._deadline_misses / max(self._requests, 1), 4),
                "deadline_shed": self._deadline_shed,
                "device_wall_s": round(self._device_wall_s, 6),
                "ingest_wall_s": round(self._ingest_wall_s, 6),
                "latency_ms": percentiles(self._latencies),
                "per_tier": per_tier,
                "tier_switches": list(self._switches),
                "failures_total": dict(self._failures),
                "pool_restarts": self._pool_restarts,
                "breaker_timeline": list(self._breaker_events),
            }
            if self._compiles_post_warmup:
                # name the offending cells so a CI zero-compile assertion
                # failure points straight at the missing warmup shape
                out["post_warmup_compiles"] = [
                    c["cell"] for c in self._compiled_cells
                    if c["post_warmup"]]
            if self._ingest:
                from repro.codec import merge_stats

                stats = merge_stats(self._ingest)
                occ = np.asarray(stats.occupancy, np.float64)
                total = float(occ.sum())
                out["ingest"] = {
                    "images": stats.images,
                    "bytes_in": stats.bytes_in,
                    "wall_s": round(self._ingest_wall_s, 6),
                    "mean_nonzero_per_block": round(stats.mean_nonzero, 2),
                    # occupancy mass beyond common band cutoffs: what each
                    # ladder rung throws away, measured on the traffic
                    "occupancy_dropped": {
                        str(b): round(float(occ[b:].sum())
                                      / max(total, 1e-12), 4)
                        for b in (24, 32, 48)
                    },
                }
            return out
