"""Serving metrics: request latencies, per-tier throughput, QoS events.

``ServeMetrics`` is the one sink every serving component writes into —
the scheduler records per-request latency and per-batch tier/throughput,
the QoS selector records tier-switch events, the ingest path records the
codec's per-band occupancy stats — and :meth:`ServeMetrics.report` folds
everything into the JSON-serializable block the serve report embeds.

Latency storage is O(1) in request count: samples land in fixed-bucket
log₂ histograms (:class:`Log2Histogram`) rather than unbounded Python
lists, so the recorder can run under sustained traffic without growing.
Histograms keep exact ``n``/``sum``/``min``/``max``; percentiles are
interpolated within a bucket, so the error is bounded by one bucket
width (sub-buckets per octave keep that under ~12.5% relative by
default).  :meth:`ServeMetrics.metrics_text` renders the same state as
Prometheus text exposition, and :class:`MetricsWriter` snapshots it to a
file on a timer for live scraping (``serve.py --metrics-out``).

Event timelines (``tier_switches``, ``breaker_timeline``) are stamped
with ``t_s`` — seconds since recorder construction on an injectable
monotonic clock — so they correlate with flight-recorder spans
(``serving/trace.py``) and, later, across shards.

:func:`percentiles` is also used standalone by the non-QoS slot loop in
``launch/serve.py`` so plain serving reports p50/p95/p99 per-request
latency too, not just aggregate wall clock.
"""
from __future__ import annotations

import math
import os
import threading
import time
from typing import Any, Callable, Iterable, Sequence

import numpy as np

__all__ = ["percentiles", "Log2Histogram", "ServeMetrics", "MetricsWriter"]


def percentiles(latencies_s: Sequence[float],
                pcts: Iterable[int] = (50, 95, 99)) -> dict[str, float]:
    """Latency summary in milliseconds: ``{"p50_ms": ..., "p95_ms": ...,
    "p99_ms": ..., "mean_ms": ..., "max_ms": ..., "n": ...}``.

    Empty input yields ``{"n": 0}`` (serving nothing is not an error).
    """
    xs = np.asarray(list(latencies_s), np.float64)
    if xs.size == 0:
        return {"n": 0}
    out: dict[str, float] = {
        f"p{p}_ms": round(float(np.percentile(xs, p)) * 1e3, 3)
        for p in pcts
    }
    out["mean_ms"] = round(float(xs.mean()) * 1e3, 3)
    out["max_ms"] = round(float(xs.max()) * 1e3, 3)
    out["n"] = int(xs.size)
    return out


class Log2Histogram:
    """Fixed-size log₂ latency histogram (HdrHistogram-style).

    The value axis is split into ``octaves`` powers of two starting at
    ``base`` seconds, each octave into ``sub`` linear sub-buckets —
    ``octaves * sub`` counters total, O(1) memory however many samples
    land.  Defaults cover 10 µs … ~670 s with 12.5% relative bucket
    width.  ``n``/``sum``/``min``/``max`` are tracked exactly; only
    percentiles are approximate (linear interpolation inside the bucket
    holding the target rank, so the error is at most one bucket width).

    Not thread-safe on its own — :class:`ServeMetrics` records under its
    lock.
    """

    __slots__ = ("base", "octaves", "sub", "counts", "n", "total",
                 "vmin", "vmax")

    def __init__(self, base: float = 1e-5, octaves: int = 26,
                 sub: int = 8) -> None:
        if base <= 0 or octaves < 1 or sub < 1:
            raise ValueError(f"bad histogram shape: base={base} "
                             f"octaves={octaves} sub={sub}")
        self.base = float(base)
        self.octaves = int(octaves)
        self.sub = int(sub)
        self.counts = [0] * (self.octaves * self.sub)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _index(self, v: float) -> int:
        # bucket 0 absorbs everything below base (including <= 0); the
        # last bucket absorbs overflow — min/max stay exact regardless
        if v < self.base:
            return 0
        m, e = math.frexp(v / self.base)  # v/base = m * 2**e, m in [0.5, 1)
        k = e - 1
        if k >= self.octaves:
            return len(self.counts) - 1
        minor = int((2.0 * m - 1.0) * self.sub)
        if minor >= self.sub:  # float edge at the octave boundary
            minor = self.sub - 1
        return k * self.sub + minor

    def bucket_bounds(self, idx: int) -> tuple[float, float]:
        """``[lo, hi)`` value bounds of bucket ``idx`` in seconds."""
        k, minor = divmod(idx, self.sub)
        scale = self.base * (2.0 ** k)
        lo = scale * (1.0 + minor / self.sub)
        hi = scale * (1.0 + (minor + 1) / self.sub)
        if idx == 0:
            lo = 0.0
        return lo, hi

    def record(self, v: float) -> None:
        v = float(v)
        self.counts[self._index(v)] += 1
        self.n += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def percentile(self, q: float) -> float | None:
        """Approximate q-th percentile in seconds (``None`` when empty)."""
        if self.n == 0:
            return None
        target = q / 100.0 * self.n
        cum = 0
        for idx, c in enumerate(self.counts):
            if not c:
                continue
            if cum + c >= target:
                lo, hi = self.bucket_bounds(idx)
                hi = min(hi, self.vmax)
                lo = max(lo, min(self.vmin, hi))
                frac = max(target - cum, 0.0) / c
                return max(self.vmin, min(lo + frac * (hi - lo), self.vmax))
            cum += c
        return self.vmax

    def summary(self) -> dict[str, float]:
        """Same shape as :func:`percentiles` (histogram-derived)."""
        if self.n == 0:
            return {"n": 0}
        out = {f"p{p}_ms": round(self.percentile(p) * 1e3, 3)
               for p in (50, 95, 99)}
        out["mean_ms"] = round(self.total / self.n * 1e3, 3)
        out["max_ms"] = round(self.vmax * 1e3, 3)
        out["n"] = self.n
        return out

    def cumulative_octaves(self) -> list[tuple[float, int]]:
        """Cumulative counts at octave upper bounds (Prometheus ``le``
        edges — one per octave keeps the exposition small and the edge
        set identical across scrapes)."""
        out = []
        cum = 0
        for k in range(self.octaves):
            cum += sum(self.counts[k * self.sub:(k + 1) * self.sub])
            out.append((self.base * (2.0 ** (k + 1)), cum))
        return out


class ServeMetrics:
    """Thread-safe recorder for one serving run.

    Every ``record_*`` hook may be called from the scheduler worker and
    from submitting threads concurrently; :meth:`report` may be called at
    any time (it snapshots under the lock).  ``clock`` is the injectable
    monotonic source for event ``t_s`` stamps (seconds relative to
    recorder construction).
    """

    def __init__(self, clock: Callable[[], float] = time.monotonic) -> None:
        self._lock = threading.Lock()
        self._clock = clock
        self._t0 = clock()
        self._lat = Log2Histogram()
        self._per_tier_lat: dict[str, Log2Histogram] = {}
        self._tiers: dict[str, dict[str, float]] = {}
        self._switches: list[dict[str, Any]] = []
        self._rejected = 0
        self._deadline_misses = 0
        self._deadline_shed = 0
        self._requests = 0
        self._ingest: list[Any] = []
        self._ingest_wall_s = 0.0
        self._device_wall_s = 0.0
        self._images = 0
        self._slots = 0
        self._cell_hits: dict[str, int] = {}
        self._compiles_total = 0
        self._compiles_post_warmup = 0
        self._compiled_cells: list[dict[str, Any]] = []
        self._failures: dict[str, int] = {}
        self._pool_restarts = 0
        self._breaker_events: list[dict[str, Any]] = []
        self._predicted_capacity: dict[str, float] = {}

    def _t_s(self) -> float:
        return round(self._clock() - self._t0, 6)

    # ------------------------------------------------------------- requests
    def record_request(self, latency_s: float, *, tier: str | None = None,
                       deadline_missed: bool = False) -> None:
        with self._lock:
            self._requests += 1
            self._lat.record(latency_s)
            if tier is not None:
                h = self._per_tier_lat.get(tier)
                if h is None:
                    h = self._per_tier_lat[tier] = Log2Histogram()
                h.record(latency_s)
            if deadline_missed:
                self._deadline_misses += 1

    def record_rejected(self, n: int = 1) -> None:
        """Admission control turned a request away (queue full)."""
        with self._lock:
            self._rejected += n

    def record_deadline_shed(self, n: int = 1) -> None:
        """Requests already expired at dequeue, failed without dispatch."""
        with self._lock:
            self._deadline_shed += n

    # -------------------------------------------------------------- batches
    def record_batch(self, tier: str, images: int, wall_s: float,
                     queue_depth: int | None = None,
                     ingest_s: float | None = None,
                     slots: int | None = None,
                     cell: str | None = None) -> None:
        """One executed batch.  ``wall_s`` is *device* wall (what the QoS
        selector is fed); ``ingest_s``, when given, is the host entropy
        decode wall the ingest thread spent on this batch — kept separate
        so bytes-heavy traffic cannot poison per-tier latency.

        ``slots`` is the padded batch width the executor actually ran
        (the capture bucket) — ``slots - images`` slots were padding, and
        the report's ``padding_fraction`` aggregates that waste.
        ``cell`` names the grid cell that served the batch (per-cell hit
        counts land in ``grid_cell_hits``)."""
        with self._lock:
            t = self._tiers.setdefault(
                tier, {"batches": 0, "images": 0, "wall_s": 0.0,
                       "max_queue_depth": 0, "slots": 0})
            t["batches"] += 1
            t["images"] += int(images)
            t["wall_s"] += float(wall_s)
            self._device_wall_s += float(wall_s)
            self._images += int(images)
            if slots is not None:
                t["slots"] += int(slots)
                self._slots += int(slots)
            if cell is not None:
                self._cell_hits[cell] = self._cell_hits.get(cell, 0) + 1
            if ingest_s is not None:
                self._ingest_wall_s += float(ingest_s)
            if queue_depth is not None:
                t["max_queue_depth"] = max(t["max_queue_depth"],
                                           int(queue_depth))

    def record_compile(self, cell: str, *, post_warmup: bool = False
                       ) -> None:
        """One executable trace/compile (fired from inside the traced
        body, so exactly once per compile).  ``post_warmup`` marks a
        compile after :meth:`BandElasticScheduler.warmup` declared the
        shape set closed — steady-state serving must report zero."""
        with self._lock:
            self._compiles_total += 1
            if post_warmup:
                self._compiles_post_warmup += 1
            self._compiled_cells.append({"cell": cell,
                                         "post_warmup": bool(post_warmup)})

    def record_switch(self, batch_seq: int, from_tier: str, to_tier: str,
                      reason: str) -> None:
        with self._lock:
            self._switches.append({"batch": int(batch_seq),
                                   "t_s": self._t_s(),
                                   "from": from_tier, "to": to_tier,
                                   "reason": reason})

    # ------------------------------------------------------------- failures
    def record_failure(self, reason: str, n: int = 1) -> None:
        """One failed request, keyed by reason — ``codec`` (bad input
        bytes), ``deadline``, ``executor``, ``ingest`` (decode
        infrastructure), ``rejected-open-breaker`` (fast-reject)."""
        with self._lock:
            self._failures[reason] = self._failures.get(reason, 0) + n

    def record_pool_restarts(self, n: int = 1) -> None:
        """The ingest-pool supervisor respawned a broken worker pool."""
        with self._lock:
            self._pool_restarts += n

    def record_predicted_capacity(self, cell: str, req_s: float) -> None:
        """Roofline-predicted capacity of one grid cell, in requests per
        second (``--profile-grid`` sweep) — exposed as the
        ``serve_predicted_capacity`` gauge family for capacity planning
        against the measured ``serve_images_total`` rates."""
        with self._lock:
            self._predicted_capacity[cell] = float(req_s)

    def record_breaker(self, frm: str, to: str, reason: str) -> None:
        """One circuit-breaker state transition (the state timeline)."""
        with self._lock:
            self._breaker_events.append(
                {"seq": len(self._breaker_events), "t_s": self._t_s(),
                 "from": frm, "to": to, "reason": reason})

    def failures_total(self) -> dict[str, int]:
        with self._lock:
            return dict(self._failures)

    def pool_restarts(self) -> int:
        with self._lock:
            return self._pool_restarts

    def breaker_timeline(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._breaker_events)

    def record_ingest(self, stats: Any) -> None:
        """Accumulate a ``codec.ingest.IngestStats`` from one byte batch."""
        if stats is not None:
            with self._lock:
                self._ingest.append(stats)

    # --------------------------------------------------------------- report
    @property
    def tier_switches(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._switches)

    def latency_report(self) -> dict[str, float]:
        with self._lock:
            return self._lat.summary()

    def report(self) -> dict[str, Any]:
        with self._lock:
            per_tier = {}
            for name, t in self._tiers.items():
                wall = max(t["wall_s"], 1e-9)
                h = self._per_tier_lat.get(name)
                per_tier[name] = {
                    **{k: (round(v, 6) if isinstance(v, float) else v)
                       for k, v in t.items()},
                    "images_per_s": round(t["images"] / wall, 2),
                    "latency_ms": h.summary() if h is not None else {"n": 0},
                }
                if t["slots"]:
                    per_tier[name]["padding_fraction"] = round(
                        1.0 - t["images"] / t["slots"], 4)
            # shed requests never reach record_request, so the miss rate
            # counts them explicitly on both sides of the fraction: a shed
            # request is a missed deadline the scheduler saw coming
            missed = self._deadline_misses + self._deadline_shed
            served = self._requests + self._deadline_shed
            out: dict[str, Any] = {
                "requests": self._requests,
                "rejected": self._rejected,
                "padding_fraction": (
                    round(1.0 - self._images / self._slots, 4)
                    if self._slots else None),
                "compiles_total": self._compiles_total,
                "compiles_post_warmup": self._compiles_post_warmup,
                "grid_cell_hits": dict(self._cell_hits),
                "deadline_misses": self._deadline_misses,
                "deadline_miss_rate": round(missed / max(served, 1), 4),
                "deadline_shed": self._deadline_shed,
                "device_wall_s": round(self._device_wall_s, 6),
                "ingest_wall_s": round(self._ingest_wall_s, 6),
                "latency_ms": self._lat.summary(),
                "per_tier": per_tier,
                "tier_switches": list(self._switches),
                "failures_total": dict(self._failures),
                "pool_restarts": self._pool_restarts,
                "breaker_timeline": list(self._breaker_events),
            }
            if self._predicted_capacity:
                out["predicted_capacity_req_s"] = {
                    c: round(v, 2)
                    for c, v in sorted(self._predicted_capacity.items())}
            if self._compiles_post_warmup:
                # name the offending cells so a CI zero-compile assertion
                # failure points straight at the missing warmup shape
                out["post_warmup_compiles"] = [
                    c["cell"] for c in self._compiled_cells
                    if c["post_warmup"]]
            if self._ingest:
                from repro.codec import merge_stats

                stats = merge_stats(self._ingest)
                occ = np.asarray(stats.occupancy, np.float64)
                total = float(occ.sum())
                out["ingest"] = {
                    "images": stats.images,
                    "bytes_in": stats.bytes_in,
                    "wall_s": round(self._ingest_wall_s, 6),
                    "mean_nonzero_per_block": round(stats.mean_nonzero, 2),
                    # occupancy mass beyond common band cutoffs: what each
                    # ladder rung throws away, measured on the traffic
                    "occupancy_dropped": {
                        str(b): round(float(occ[b:].sum())
                                      / max(total, 1e-12), 4)
                        for b in (24, 32, 48)
                    },
                }
            return out

    # ----------------------------------------------------------- exposition
    def metrics_text(self) -> str:
        """Prometheus text exposition of the live counters/histograms.

        Counter families use ``serve_`` prefixes; latency histograms
        expose cumulative octave-boundary ``le`` edges (stable across
        scrapes) with exact ``_sum``/``_count``.
        """
        with self._lock:
            lines: list[str] = []

            def counter(name: str, help_: str,
                        samples: list[tuple[str, float]]) -> None:
                lines.append(f"# HELP {name} {help_}")
                lines.append(f"# TYPE {name} counter")
                for labels, v in samples:
                    g = float(v)
                    lines.append(f"{name}{labels} "
                                 f"{int(g) if g == int(g) else g}")

            counter("serve_requests_total", "Completed requests.",
                    [("", self._requests)])
            counter("serve_rejected_total",
                    "Requests refused by admission control.",
                    [("", self._rejected)])
            counter("serve_deadline_missed_total",
                    "Completed requests that missed their deadline.",
                    [("", self._deadline_misses)])
            counter("serve_deadline_shed_total",
                    "Requests shed at dequeue (expired unserved).",
                    [("", self._deadline_shed)])
            counter("serve_failures_total", "Failed requests by reason.",
                    [(f'{{reason="{r}"}}', n)
                     for r, n in sorted(self._failures.items())] or
                    [("", 0)])
            counter("serve_compiles_total", "Executable compiles.",
                    [('{phase="warmup"}',
                      self._compiles_total - self._compiles_post_warmup),
                     ('{phase="post_warmup"}', self._compiles_post_warmup)])
            counter("serve_pool_restarts_total",
                    "Ingest worker-pool respawns.",
                    [("", self._pool_restarts)])
            counter("serve_tier_switches_total", "QoS tier switches.",
                    [("", len(self._switches))])
            counter("serve_breaker_transitions_total",
                    "Circuit-breaker state transitions.",
                    [("", len(self._breaker_events))])
            counter("serve_images_total", "Images served in batches.",
                    [(f'{{tier="{n}"}}', t["images"])
                     for n, t in sorted(self._tiers.items())] or [("", 0)])
            counter("serve_batches_total", "Batches executed.",
                    [(f'{{tier="{n}"}}', t["batches"])
                     for n, t in sorted(self._tiers.items())] or [("", 0)])
            counter("serve_device_wall_seconds_total",
                    "Device dispatch wall.", [("", self._device_wall_s)])
            counter("serve_ingest_wall_seconds_total",
                    "Host entropy-decode wall.", [("", self._ingest_wall_s)])

            if self._predicted_capacity:
                name = "serve_predicted_capacity"
                lines.append(f"# HELP {name} Roofline-predicted grid-cell "
                             "capacity (requests/second).")
                lines.append(f"# TYPE {name} gauge")
                for cell, v in sorted(self._predicted_capacity.items()):
                    lines.append(f'{name}{{cell="{cell}"}} {v:.6g}')

            def hist(name: str, labels: str, h: Log2Histogram) -> None:
                sep = "," if labels else ""
                base = labels[:-1] + sep if labels else "{"
                for le, cum in h.cumulative_octaves():
                    lines.append(f'{name}_bucket{base}le="{le:.6g}"}} {cum}')
                lines.append(f'{name}_bucket{base}le="+Inf"}} {h.n}')
                lines.append(f"{name}_sum{labels} {h.total:.9g}")
                lines.append(f"{name}_count{labels} {h.n}")

            name = "serve_request_latency_seconds"
            lines.append(f"# HELP {name} End-to-end request latency.")
            lines.append(f"# TYPE {name} histogram")
            hist(name, "", self._lat)
            for tier, h in sorted(self._per_tier_lat.items()):
                hist(name, f'{{tier="{tier}"}}', h)
            return "\n".join(lines) + "\n"


class MetricsWriter:
    """Periodic snapshot writer: ``metrics_text()`` to a file on a timer.

    Writes are atomic (tmp file + ``os.replace``) so a scraper never
    reads a torn exposition; one final snapshot lands on :meth:`close`.
    """

    def __init__(self, metrics: ServeMetrics, path: str,
                 interval_s: float = 1.0) -> None:
        self.metrics = metrics
        self.path = path
        self.interval_s = max(float(interval_s), 0.05)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="metrics-writer", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._write()

    def _write(self) -> None:
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            f.write(self.metrics.metrics_text())
        os.replace(tmp, self.path)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._write()

    def __enter__(self) -> "MetricsWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
