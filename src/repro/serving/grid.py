"""Plan grid: precompiled (batch bucket × band tier) serving executors.

The band ladder (``serving.ladder``) made quality a runtime knob; this
module makes **batch shape** one too.  aphrodite/vLLM precapture a ladder
of padded batch sizes so serving never recompiles; the plan grid is the
2-D version of that idea — one executor per (batch bucket × band tier)
cell, all captured at warmup:

* **buckets** follow the aphrodite capture schedule: 1, 2, 4, then
  multiples of 8 up to ``max_batch`` (:func:`batch_buckets`); a batch of
  ``n`` requests runs in the smallest covering bucket
  (:func:`bucket_for` — 1→1, 3→4, 9→16, 17→24 …), so low-occupancy
  traffic stops paying ``max_batch``-wide GEMMs;
* **tiers** are the ladder's band tiers; every cell in a tier column
  closes over the *same* prefix-sliced Ξ buffers (closed-over jax arrays
  lower to jaxpr consts shared across executables), so device memory
  stays O(one ladder) no matter how many buckets are captured;
* each cell is a **static-shape, donated** entry point
  (``core.plan.capture_compiled``): the input device buffer is donated
  to the executable and the host side stages rows into a reusable
  pinned buffer (:class:`PinnedPool`) — steady-state serving does zero
  reshapes, zero retraces, and no per-batch host allocations beyond the
  one staged copy;
* **compile accounting** rides on the capture: every trace fires the
  grid's ``on_compile(cell_name)`` hook exactly once, so the scheduler
  can report ``compiles_total`` / ``compiles_post_warmup`` and CI can
  assert the post-warmup count is zero.

:class:`GridColumn` keeps the attribute surface of the scheduler's old
per-tier executor (``coef_fn`` / ``packed_fn`` / ``compiled`` / ``w_in``)
so the column is a drop-in replacement that additionally routes each
call to the covering bucket's cell.
"""
from __future__ import annotations

import bisect
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.serving.trace import NULL_TRACER

__all__ = [
    "batch_buckets",
    "validate_buckets",
    "bucket_for",
    "cover_buckets",
    "PinnedPool",
    "GridCell",
    "GridColumn",
    "PlanGrid",
]

KINDS = ("coefficients", "bytes")


# --------------------------------------------------------------------------
# Bucket math (aphrodite _BATCH_SIZES_TO_CAPTURE / _get_graph_batch_size)
# --------------------------------------------------------------------------


def batch_buckets(max_batch: int) -> tuple[int, ...]:
    """The aphrodite-style capture schedule up to ``max_batch``:
    ``1, 2, 4`` then multiples of 8, with ``max_batch`` itself always the
    last bucket (so every admissible batch has a cover)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets = [b for b in (1, 2, 4) if b <= max_batch]
    buckets += list(range(8, max_batch + 1, 8))
    if buckets[-1] != max_batch:
        buckets.append(max_batch)
    return tuple(buckets)


def validate_buckets(buckets) -> tuple[int, ...]:
    """Normalize an explicit bucket list: ints, strictly increasing,
    all positive."""
    out = tuple(int(b) for b in buckets)
    if not out:
        raise ValueError("need at least one bucket")
    if any(b < 1 for b in out):
        raise ValueError(f"buckets must be positive: {out}")
    if any(a >= b for a, b in zip(out, out[1:])):
        raise ValueError(f"buckets must be strictly increasing: {out}")
    return out


def bucket_for(n: int, buckets) -> int:
    """Smallest bucket covering ``n`` requests (aphrodite's
    ``_get_graph_batch_size``): 1→1, 3→4, 9→16, 17→24 under the default
    schedule.  A batch no bucket covers is a caller bug — the scheduler
    never forms batches past the largest bucket."""
    if n < 1:
        raise ValueError(f"batch must be >= 1, got {n}")
    i = bisect.bisect_left(buckets, n)
    if i == len(buckets):
        raise ValueError(
            f"batch {n} exceeds the largest capture bucket {buckets[-1]}")
    return buckets[i]


def cover_buckets(buckets, batch: int) -> tuple[int, ...]:
    """The bucket set a scheduler with ``batch`` slots actually captures:
    the default schedule when ``buckets`` is None, else the explicit list
    clipped to ``batch`` — and ``batch`` itself is always present, so the
    full batch has a cell."""
    if buckets is None:
        return batch_buckets(batch)
    out = tuple(b for b in validate_buckets(buckets) if b <= batch)
    if not out or out[-1] != batch:
        out = out + (batch,)
    return out


# --------------------------------------------------------------------------
# Pinned host staging + captured cells
# --------------------------------------------------------------------------


class PinnedPool:
    """Reusable host staging buffers, keyed by (shape, dtype).

    One buffer per distinct full-batch shape, shared by every cell that
    stages through it — the grid has one dispatching thread (the
    scheduler worker), so sharing is safe and keeps host memory at
    O(distinct shapes), not O(cells).
    """

    def __init__(self) -> None:
        self._bufs: dict[tuple, np.ndarray] = {}

    def get(self, shape, dtype=np.float32) -> np.ndarray:
        key = (tuple(int(s) for s in shape), np.dtype(dtype))
        buf = self._bufs.get(key)
        if buf is None:
            buf = self._bufs[key] = np.zeros(key[0], key[1])
        return buf

    @property
    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bufs.values())

    def __len__(self) -> int:
        return len(self._bufs)


class GridCell:
    """One (kind, bucket) executor of a grid column.

    ``__call__`` stages up to ``bucket`` rows into the pooled pinned
    buffer (zero-filling the pad tail), copies it to device
    (``jnp.array`` always copies — the staging buffer stays reusable
    while the fresh device buffer is donated into the executable), and
    returns the logits for all ``bucket`` slots; callers slice off the
    first ``n``.  ``hits`` counts dispatches for the metrics report.
    """

    __slots__ = ("name", "bucket", "item_shape", "hits", "_fn", "_pool",
                 "_shape", "_tracer", "_compiled", "_executor", "_packed")

    def __init__(self, name: str, bucket: int, item_shape,
                 fn: Callable, pool: PinnedPool, tracer=None, *,
                 compiled=None, executor=None, packed=False):
        self.name = name
        self.bucket = int(bucket)
        self.item_shape = tuple(int(s) for s in item_shape)
        self._shape = (self.bucket, *self.item_shape)
        self._fn = fn
        self._pool = pool
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._compiled = compiled
        self._executor = executor
        self._packed = bool(packed)
        self.hits = 0

    def __call__(self, rows: np.ndarray, rids=None) -> jnp.ndarray:
        rows = np.asarray(rows, np.float32)
        n = rows.shape[0]
        if n > self.bucket or tuple(rows.shape[1:]) != self.item_shape:
            raise ValueError(
                f"cell {self.name} serves shape {self._shape}, "
                f"got {tuple(rows.shape)}")
        tr = self._tracer
        ta = tr.now() if tr.enabled else 0.0
        host = self._pool.get(self._shape)
        host[:n] = rows
        if n < self.bucket:
            host[n:] = 0.0
        dev = jnp.array(host)
        if tr.enabled:
            # nested under the scheduler's device-dispatch span: the
            # host-staging + host->device copy share of the dispatch
            tr.span("device", "pad/stage", ta, tr.now(),
                    args={"cell": self.name, "n": n,
                          "pad": self.bucket - n, "rids": rids})
        self.hits += 1
        return self._fn(dev)

    def warmup(self) -> None:
        host = self._pool.get(self._shape)
        host[:] = 0.0
        self._fn(jnp.array(host)).block_until_ready()

    def time_wall(self, *, iters: int = 3) -> float:
        """Median wall (seconds) of the captured executable on a zero
        bucket batch — the staged host→device copy stays outside the
        wall, exactly as :meth:`__call__` dispatches.  Uses only the
        already-captured entry: zero new compiles on a warmed cell."""
        import statistics
        import time

        host = self._pool.get(self._shape)
        host[:] = 0.0
        out = self._fn(jnp.array(host))  # untimed: ensures compiled
        jax.block_until_ready(out)
        walls = []
        for _ in range(max(1, iters)):
            dev = jnp.array(host)
            t0 = time.perf_counter()
            out = self._fn(dev)
            jax.block_until_ready(out)
            walls.append(time.perf_counter() - t0)
        return statistics.median(walls)

    def profile(self, rows: np.ndarray | None = None, *,
                iters: int = 3, warmup: int = 1) -> dict:
        """Per-block measured walls for this cell's schedule, plus the
        whole-cell wall through its own captured (donated) executable.

        Runs the cell's compiled plan in the profiling execution mode
        (``core.plan.StepProfile`` — per-step jit with device fences;
        logits bit-identical to the captured executable's) on ``rows``
        staged exactly as :meth:`__call__` stages them (zero-pad to the
        bucket; an all-zero batch when ``rows`` is None), then times the
        unprofiled captured entry on the same staged input.  Returns
        ``{"cell", "bucket", "steps": [{"name", "measured_us"}...],
        "profiled_total_us", "cell_wall_us", "logits"}`` with medians
        over ``iters`` timed calls after ``warmup`` discarded ones.
        """
        import statistics
        import time

        if self._compiled is None:
            raise RuntimeError(
                f"cell {self.name} was built without a compiled-plan "
                "reference; profiling needs the schedule, not just the "
                "captured entry")
        host = self._pool.get(self._shape)
        host[:] = 0.0
        if rows is not None:
            rows = np.asarray(rows, np.float32)
            n = rows.shape[0]
            if n > self.bucket or tuple(rows.shape[1:]) != self.item_shape:
                raise ValueError(
                    f"cell {self.name} serves shape {self._shape}, "
                    f"got {tuple(rows.shape)}")
            host[:n] = rows
        apply_fn = (planlib.apply_compiled_packed if self._packed
                    else planlib.apply_compiled)
        prof = planlib.StepProfile()
        for _ in range(max(1, warmup)):
            apply_fn(self._compiled, jnp.array(host),
                     executor=self._executor, profile=prof)
        prof.reset()
        logits = None
        for _ in range(max(1, iters)):
            logits = apply_fn(self._compiled, jnp.array(host),
                              executor=self._executor, profile=prof)
        # the captured executable donates its input: fresh device buffer
        # per call, staged copy outside the timed wall (as __call__ does)
        walls = []
        out = self._fn(jnp.array(host))  # untimed: ensures it is compiled
        jax.block_until_ready(out)
        for _ in range(max(1, iters)):
            dev = jnp.array(host)
            t0 = time.perf_counter()
            out = self._fn(dev)
            jax.block_until_ready(out)
            walls.append(time.perf_counter() - t0)
        steps = prof.summary()
        return {
            "cell": self.name,
            "bucket": self.bucket,
            "steps": [{"name": k, "measured_us": v * 1e6}
                      for k, v in steps.items()],
            "profiled_total_us": sum(steps.values()) * 1e6,
            "cell_wall_us": statistics.median(walls) * 1e6,
            "logits": np.asarray(logits),
        }


class GridColumn:
    """All bucket cells of one *distinct* compiled schedule (band tier).

    Drop-in for the scheduler's former per-tier executor:
    :meth:`coef_fn` / :meth:`packed_fn` take an **unpadded** row batch,
    route it to the smallest covering bucket's cell, and return the full
    bucket's logits.  Cells materialize lazily on first use (so a column
    serving only ``coefficients`` traffic never compiles packed cells)
    and eagerly under :meth:`PlanGrid.warmup`.
    """

    def __init__(self, compiled: planlib.CompiledPlan,
                 executor: str | None = None, *,
                 buckets=None, pool: PinnedPool | None = None,
                 donate: bool = True,
                 on_compile: Callable[[str], None] | None = None,
                 tier_name: str = "tier", tracer=None):
        self.compiled = compiled
        self.executor = executor
        self.w_in = compiled.stem.w_in
        self.buckets = None if buckets is None else validate_buckets(buckets)
        self.donate = donate
        self.tier_name = tier_name
        self.pool = pool if pool is not None else PinnedPool()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._on_compile = on_compile
        self.cells: dict[tuple[str, int], GridCell] = {}

    def cell(self, kind: str, bucket: int, item_shape) -> GridCell:
        key = (kind, int(bucket))
        c = self.cells.get(key)
        if c is None:
            name = f"{self.tier_name}/{kind}/b{int(bucket)}"
            on_compile = self._on_compile
            fn = planlib.capture_compiled(
                self.compiled, (int(bucket), *item_shape),
                packed=(kind == "bytes"), executor=self.executor,
                donate=self.donate,
                on_trace=(None if on_compile is None
                          else (lambda: on_compile(name))))
            c = self.cells[key] = GridCell(name, bucket, item_shape, fn,
                                           self.pool, tracer=self.tracer,
                                           compiled=self.compiled,
                                           executor=self.executor,
                                           packed=(kind == "bytes"))
        return c

    def _route(self, kind: str, rows: np.ndarray,
               rids=None) -> jnp.ndarray:
        rows = np.asarray(rows, np.float32)
        n = rows.shape[0]
        bucket = n if self.buckets is None else bucket_for(n, self.buckets)
        return self.cell(kind, bucket, rows.shape[1:])(rows, rids=rids)

    def coef_fn(self, rows: np.ndarray, rids=None) -> jnp.ndarray:
        """Serve a ``(n, bh, bw, C, 64)`` coefficient batch (n need not
        match any bucket — the covering cell pads).  ``rids`` labels the
        rows' request ids on the flight-recorder span, nothing more."""
        return self._route("coefficients", rows, rids=rids)

    def packed_fn(self, rows: np.ndarray, rids=None) -> jnp.ndarray:
        """Serve a ``(n, bh, bw, C·w_in)`` tile-packed batch."""
        return self._route("bytes", rows, rids=rids)


class PlanGrid:
    """The full (batch bucket × band tier) executor grid over a ladder.

    ``columns[i]`` serves ``ladder.tiers[i]``; tiers sharing a
    ``CompiledPlan`` share a column (and its cells, pinned buffers, and
    compile cache).  ``grid``/``channels`` fix the serving resolution so
    :meth:`warmup` can sweep every cell eagerly; without them cells
    still materialize lazily from the first batch's shape.
    """

    def __init__(self, ladder, *, batch: int, buckets=None,
                 grid: tuple[int, int] | None = None, channels: int = 3,
                 executor: str | None = None, donate: bool = True,
                 on_compile: Callable[[str], None] | None = None,
                 tracer=None):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.ladder = ladder
        self.batch = int(batch)
        if buckets is None:
            buckets = getattr(ladder, "buckets", None)
        self.buckets = cover_buckets(buckets, self.batch)
        self.grid = grid
        self.channels = channels
        self.pool = PinnedPool()
        by_id: dict[int, GridColumn] = {}
        self.columns: list[GridColumn] = []
        for tier in ladder.tiers:
            key = id(tier.compiled)
            if key not in by_id:
                by_id[key] = GridColumn(
                    tier.compiled, executor, buckets=self.buckets,
                    pool=self.pool, donate=donate, on_compile=on_compile,
                    tier_name=tier.name, tracer=tracer)
            self.columns.append(by_id[key])
        self.distinct = list(by_id.values())
        # optional per-cell cost annotations (introspect.profile_plan_grid
        # fills these in under serve --profile-grid): cell name ->
        # {"flops", "predicted_us", ...}; the scheduler stamps them onto
        # its device-dispatch trace spans
        self.cell_costs: dict[str, dict] = {}

    def annotate_costs(self, costs: dict[str, dict]) -> None:
        """Attach per-cell cost annotations (merged by cell name)."""
        self.cell_costs.update(costs)

    def cost_for(self, cell_name: str) -> dict | None:
        return self.cell_costs.get(cell_name)

    def bucket_for(self, n: int) -> int:
        return bucket_for(n, self.buckets)

    def warmup(self, kinds=KINDS) -> None:
        """Compile every (kind, bucket) cell of every distinct column.
        After this sweep the set of compiled shapes is closed: any
        further trace is a bug the compile accounting will surface."""
        if self.grid is None:
            raise ValueError("warmup needs grid= at construction")
        bh, bw = self.grid
        for col in self.distinct:
            for bucket in self.buckets:
                if "coefficients" in kinds:
                    col.cell("coefficients", bucket,
                             (bh, bw, self.channels, 64)).warmup()
                if "bytes" in kinds:
                    col.cell("bytes", bucket,
                             (bh, bw, self.channels * col.w_in)).warmup()

    def cell_hits(self) -> dict[str, int]:
        return {c.name: c.hits
                for col in self.distinct for c in col.cells.values()}

    def summary(self) -> dict[str, Any]:
        """Startup-log / report block: grid extent and staging cost."""
        return {
            "buckets": list(self.buckets),
            "tiers": [t.name for t in self.ladder.tiers],
            "distinct_columns": len(self.distinct),
            "cells": sum(len(col.cells) for col in self.distinct),
            "host_staging_bytes": self.pool.nbytes,
        }
