"""Multi-tier compiled-plan ladder: one plan, several band budgets.

A *tier* is the compiled schedule of the serving plan with every layer's
band assignment capped at a budget (``min(layer_bands, cap)``).  The key
property making a ladder cheap to hold and exact to reason about: band
truncation of an exploded operator **is a prefix slice** —
``explosion_basis`` builds the truncated basis as ``full[..., :b, :b]``,
so

    explode(kernel, bands=b) == explode(kernel, bands=64)[..., :b, :, :b]

bit-exactly (same contractions, elementwise prefix), and the folded
batch-norm scale/shift commute with the slice.  Tiers therefore *derive*
from the top tier's operators by slicing — no re-explosion, no second
copy of the weights at build time — and compile through the ordinary
``core.plan.compile_plan`` into tile-packed schedules.  Tiers whose
capped band assignment collapses onto an earlier tier's share that tier's
``CompiledPlan`` object outright.

Serialization rides on the plan artifact: :func:`save_ladder` stores only
the base plan (``core.plan.save_plan``) plus a small ladder manifest
through ``CheckpointManager``; :func:`load_ladder` restores the plan and
re-derives the tiers, which is bit-exact because the derivation is a
deterministic slice + pack.  The manifest records each tier's band
assignment so a stale ladder (saved against a different plan) is rejected
loudly instead of silently serving different math.
"""
from __future__ import annotations

import os
from typing import Any, NamedTuple

from repro.core import dct as dctlib
from repro.core import plan as planlib

__all__ = [
    "DEFAULT_CAPS",
    "PlanTier",
    "PlanLadder",
    "cap_operator",
    "cap_plan",
    "build_ladder",
    "save_ladder",
    "load_ladder",
]

#: default band budgets, best quality first.  ``None`` = the plan's own
#: (autotuned) assignment, untouched; ints cap every layer at that budget.
DEFAULT_CAPS = (None, 48, 32, 24)

_LADDER_SUBDIR = "ladder"
_LADDER_FORMAT = 1


class PlanTier(NamedTuple):
    """One rung: the capped plan and its compiled schedule.

    ``shared_with`` is the index of the earlier tier whose ``CompiledPlan``
    this tier reuses (its cap changed nothing), else ``None``.
    """

    name: str
    cap: int | None
    bands: dict[str, int]
    plan: planlib.InferencePlan
    compiled: planlib.CompiledPlan
    shared_with: int | None = None


class PlanLadder(NamedTuple):
    """An ordered tier stack, index 0 = best quality (widest bands).

    ``buckets`` (optional, trailing for positional-construction compat)
    records the batch capture buckets the ladder was prepared to serve —
    ``serving.grid.PlanGrid`` defaults to them, and the manifest
    persists them so a serve process restores the same grid extent it
    warmed up last time.  ``None`` = derive the aphrodite schedule from
    the scheduler's batch size at grid-build time.
    """

    tiers: tuple[PlanTier, ...]
    base: planlib.InferencePlan
    caps: tuple[int | None, ...]
    image_size: int | None
    vmem_budget: int
    buckets: tuple[int, ...] | None = None

    @property
    def top(self) -> PlanTier:
        return self.tiers[0]

    def __len__(self) -> int:
        return len(self.tiers)


def _tier_name(cap: int | None) -> str:
    return "top" if cap is None else f"b{cap}"


def cap_operator(op, cap: int):
    """Cap one ``ConvOperator`` at ``cap`` bands by prefix-slicing its Ξ.

    Bit-exact vs re-exploding at the capped band count (the basis
    truncation *is* this slice); factored operators (no materialised Ξ)
    just lower their ``bands`` field — their apply path truncates by
    zeroing at run time.
    """
    b = min(op.bands, cap)
    if b == op.bands:
        return op
    xi = op.xi
    if xi is not None:
        xi = xi[:, :, :, :b, :, :b]
    return op._replace(xi=xi, bands=b)


def cap_plan(plan: planlib.InferencePlan, cap: int | None
             ) -> planlib.InferencePlan:
    """Derive the plan at band budget ``cap`` (``None`` → the plan itself).

    Shares every operator the cap does not touch; touched operators are
    prefix-slices of the originals (see :func:`cap_operator`).
    """
    if cap is None or cap >= max(plan.bands.values()):
        return plan
    if not 8 <= cap <= dctlib.NFREQ or cap % 8:
        raise ValueError(
            f"tier cap must be a multiple of 8 in [8, {dctlib.NFREQ}], "
            f"got {cap}")
    operators: dict[str, Any] = {}
    for name, entry in plan.operators.items():
        if isinstance(entry, dict):
            operators[name] = {slot: cap_operator(op, cap)
                               for slot, op in entry.items()}
        else:
            operators[name] = cap_operator(entry, cap)
    bands = {k: min(v, cap) for k, v in plan.bands.items()}
    provenance = dict(plan.provenance or {}, tier_cap=cap)
    return plan._replace(operators=operators, bands=bands,
                         provenance=provenance)


def _validate_caps(caps) -> tuple[int | None, ...]:
    caps = tuple(caps)
    if not caps:
        raise ValueError("ladder needs at least one tier")
    if caps[0] is not None and any(c is None for c in caps):
        raise ValueError("the uncapped (None) tier must come first")
    numeric = [c for c in caps if c is not None]
    if numeric != sorted(numeric, reverse=True) or len(set(caps)) != len(caps):
        raise ValueError(
            f"tier caps must be strictly decreasing (best first): {caps}")
    return caps


def build_ladder(plan: planlib.InferencePlan, *,
                 caps=DEFAULT_CAPS,
                 image_size: int | None = None,
                 vmem_budget: int = planlib.VMEM_BUDGET,
                 buckets=None) -> PlanLadder:
    """Compile ``plan`` into a tier ladder at the given band budgets.

    Tiers are ordered best-quality first; caps wider than the plan's own
    assignment collapse onto the previous tier (sharing its compiled
    schedule rather than compiling a duplicate).  ``buckets`` pins the
    batch capture buckets the serving grid should precompile (see
    :class:`PlanLadder`).
    """
    caps = _validate_caps(caps)
    if buckets is not None:
        from repro.serving.grid import validate_buckets

        buckets = validate_buckets(buckets)
    tiers: list[PlanTier] = []
    by_bands: dict[tuple, int] = {}
    for cap in caps:
        capped = cap_plan(plan, cap)
        key = tuple(sorted(capped.bands.items()))
        shared = by_bands.get(key)
        if shared is not None:
            prev = tiers[shared]
            tiers.append(PlanTier(_tier_name(cap), cap, dict(capped.bands),
                                  prev.plan, prev.compiled, shared))
            continue
        compiled = planlib.compile_plan(capped, vmem_budget=vmem_budget,
                                        image_size=image_size)
        by_bands[key] = len(tiers)
        tiers.append(PlanTier(_tier_name(cap), cap, dict(capped.bands),
                              capped, compiled))
    return PlanLadder(tuple(tiers), plan, caps, image_size, vmem_budget,
                      buckets)


# --------------------------------------------------------------------------
# Serialization: base plan + manifest; tiers re-derive bit-exactly
# --------------------------------------------------------------------------


def save_ladder(ladder: PlanLadder, directory: str, *,
                save_base: bool = True) -> None:
    """Persist a ladder under ``directory``.

    ``directory`` is a plan directory (``core.plan.save_plan`` layout);
    the ladder manifest goes into ``directory/ladder`` through the
    checksummed ``CheckpointManager`` store.  ``save_base=False`` skips
    re-saving the base plan when the caller already did (the serve path:
    ``prepare_plan`` saved it before the ladder was built).
    """
    from repro.checkpoint import CheckpointManager

    if save_base:
        planlib.save_plan(ladder.base, directory)
    extra = {
        "kind": "jpeg_plan_ladder",
        "format": _LADDER_FORMAT,
        "caps": [c for c in ladder.caps],
        "image_size": ladder.image_size,
        "vmem_budget": int(ladder.vmem_budget),
        # absent in pre-grid manifests; .get(None) on load keeps format 1
        "buckets": (None if ladder.buckets is None
                    else [int(b) for b in ladder.buckets]),
        "tiers": [{"name": t.name, "cap": t.cap, "bands": t.bands,
                   "shared_with": t.shared_with} for t in ladder.tiers],
    }
    CheckpointManager(os.path.join(directory, _LADDER_SUBDIR)).save(
        0, {}, extra=extra)


def load_ladder(directory: str, *,
                plan: planlib.InferencePlan | None = None) -> PlanLadder:
    """Restore a ladder saved by :func:`save_ladder`.

    The base plan restores bit-exactly through the checkpoint store and
    the tiers re-derive from it (deterministic slice + pack ⇒ bit-exact
    tier schedules).  A manifest whose recorded per-tier band assignments
    disagree with the restored plan — a ladder saved against a *different*
    plan — is rejected with ``ValueError``.
    """
    from repro.checkpoint import CheckpointManager

    _, _, extra = CheckpointManager(
        os.path.join(directory, _LADDER_SUBDIR)).restore_tree()
    if extra.get("kind") != "jpeg_plan_ladder":
        raise ValueError(f"{directory} does not hold a plan ladder")
    if extra.get("format") != _LADDER_FORMAT:
        raise ValueError(
            f"unsupported ladder format {extra.get('format')!r}")
    if plan is None:
        plan = planlib.load_plan(directory)
    caps = tuple(None if c is None else int(c) for c in extra["caps"])
    buckets = extra.get("buckets")
    ladder = build_ladder(
        plan, caps=caps,
        image_size=(None if extra.get("image_size") is None
                    else int(extra["image_size"])),
        vmem_budget=int(extra["vmem_budget"]),
        buckets=None if buckets is None else tuple(int(b) for b in buckets))
    for tier, meta in zip(ladder.tiers, extra["tiers"]):
        saved = {k: int(v) for k, v in meta["bands"].items()}
        if saved != tier.bands:
            raise ValueError(
                f"ladder manifest is stale: tier {tier.name} was saved "
                f"with bands {saved}, the restored plan derives "
                f"{tier.bands} — rebuild the ladder for this plan")
    return ladder
