"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

40 experts, top-8, per-expert d_ff=512 — every layer is MoE.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab_size=49155, rope_theta=10_000.0, tie_embeddings=True,
        n_experts=40, experts_per_token=8,
        source="[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 40e top-8",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=512, n_experts=4, experts_per_token=2,
        tie_embeddings=True, dtype="float32",
    )


register("granite-moe-3b-a800m", full, reduced)
