"""StarCoder2-3B [arXiv:2402.19173; hf].

Assignment feature set is "GQA, RoPE" — implemented with full attention
(no sliding window), hence the mandated long_500k skip applies.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
        d_ff=12288, vocab_size=49152, rope_theta=100_000.0,
        source="[arXiv:2402.19173; hf] GQA, RoPE",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=192, vocab_size=512, dtype="float32",
    )


register("starcoder2-3b", full, reduced)
