"""Mixtral-8x7B [arXiv:2401.04088; hf]. 8 experts top-2, sliding-window attn."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=32000, rope_theta=1_000_000.0,
        sliding_window=4096, n_experts=8, experts_per_token=2,
        source="[arXiv:2401.04088; hf] 8e top-2, SWA",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=64,
        n_experts=4, experts_per_token=2, dtype="float32",
    )


register("mixtral-8x7b", full, reduced)
