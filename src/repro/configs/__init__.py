"""Architecture configs: one module per assigned architecture + the paper's."""
from repro.configs.base import (  # noqa: F401
    SHAPES,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    list_archs,
    reduced_config,
    register,
)
