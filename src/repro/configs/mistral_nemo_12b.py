"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=131072, rope_theta=1_000_000.0,
        source="[hf:mistralai/Mistral-Nemo-Base-2407; hf] 128k ctx",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, rope_theta=1_000_000.0, dtype="float32",
    )


register("mistral-nemo-12b", full, reduced)
