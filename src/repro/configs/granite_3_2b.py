"""Granite-3.0-2B [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
        d_ff=8192, vocab_size=49155, rope_theta=10_000.0, tie_embeddings=True,
        source="[hf:ibm-granite/granite-3.0-2b-base; hf] GQA",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, tie_embeddings=True, dtype="float32",
    )


register("granite-3-2b", full, reduced)
