"""Whisper-small [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB.

``frontend_stub=True``: the conv1d/mel frontend is replaced by precomputed
frame embeddings from ``input_specs()`` per the assignment.  Shape mapping
(DESIGN.md §4): ``train`` shapes use encoder length = seq_len and decoder
length = seq_len // 8; ``prefill`` = encoder forward; ``decode`` = decoder
step with a self-attn KV cache of seq_len plus a fixed 1500-frame encoder
context.  long_500k is skipped (full attention).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=3072, vocab_size=51865, use_rope=False,
        encoder_decoder=True, n_encoder_layers=12, cross_attention=True,
        frontend_stub=True, encoder_context_len=1500,
        source="[arXiv:2212.04356; unverified] enc-dec, conv frontend stub",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, use_rope=False,
        encoder_decoder=True, n_encoder_layers=2, cross_attention=True,
        frontend_stub=True, encoder_context_len=32, dtype="float32",
    )


register("whisper-small", full, reduced)
