"""SmolLM-360M (llama-arch small) [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab_size=49152, rope_theta=10_000.0, tie_embeddings=True,
        source="[hf:HuggingFaceTB/SmolLM-135M; hf] llama-arch small",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-reduced", family="dense",
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
        d_ff=128, vocab_size=512, tie_embeddings=True, dtype="float32",
    )


register("smollm-360m", full, reduced)
