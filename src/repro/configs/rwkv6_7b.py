"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf] — attention-free, data-dependent decay."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, head_dim=0,
        d_ff=14336, vocab_size=65536, use_rope=False,
        ssm_kind="rwkv6", rwkv_head_size=64,
        source="[arXiv:2404.05892; hf] Finch, data-dependent decay",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-reduced", family="ssm",
        n_layers=2, d_model=64, d_ff=128, vocab_size=512, use_rope=False,
        ssm_kind="rwkv6", rwkv_head_size=16, dtype="float32",
    )


register("rwkv6-7b", full, reduced)
