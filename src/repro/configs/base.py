"""Config dataclasses + registry for every selectable architecture.

``get_config(arch_id)`` returns the full published configuration;
``reduced_config(arch_id)`` returns a tiny same-family config for CPU smoke
tests.  Input shapes (the assigned shape set) live in ``SHAPES``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Optional

__all__ = [
    "ModelConfig", "ShapeConfig", "TrainConfig", "MeshConfig", "RunConfig",
    "SHAPES", "register", "get_config", "reduced_config", "list_archs",
]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | hybrid | ssm | audio | jpeg_resnet
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # MoE FFN on layers where (i % moe_every) == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # --- attention ---
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    attn_every: int = 1         # hybrid: attention on layers where (i % attn_every) == attn_offset
    attn_offset: int = 0
    use_rope: bool = True
    # --- SSM ---
    ssm_kind: Optional[str] = None  # 'mamba' | 'rwkv6'
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    rwkv_head_size: int = 64
    # --- encoder-decoder / multimodal ---
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    cross_attention: bool = False
    vision_prefix_len: int = 0   # stub patch embeddings prepended to tokens
    frontend_stub: bool = False  # inputs are precomputed frame embeddings
    encoder_context_len: int = 1500  # fixed encoder output length for decode
    # --- jpeg-resnet ---
    image_size: int = 32
    in_channels: int = 3
    widths: tuple[int, ...] = ()
    blocks_per_stage: int = 1
    num_classes: int = 10
    asm_phi: int = 14
    # --- numerics ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""  # provenance note: [source; verified-tier]

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_attn_layer(self, i: int) -> bool:
        return (i % self.attn_every) == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every) == self.moe_offset

    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context (bounded attention state)?"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True  # attention minority; cache still bounded? full attn layers
        return self.sliding_window is not None


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1_000
    schedule: str = "cosine"          # 'cosine' | 'linear' | 'constant'
    optimizer: str = "adamw"          # 'adamw' | 'sgd' | 'lion'
    grad_clip: float = 1.0
    grad_accum: int = 1
    grad_compression: str = "none"    # 'none' | 'bf16'
    zero1: bool = True                # shard optimizer state over data axis
    remat: str = "full"               # 'none' | 'full' | 'dots'
    scan_layers: bool = True
    seed: int = 0


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # (pod, data, model) — single-pod drops the pod axis.
    pods: int = 2
    data: int = 16
    model: int = 16


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = full
    _REDUCED[arch_id] = reduced


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def reduced_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    return _REDUCED[arch_id]()


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    # Import arch modules lazily to avoid import cycles.
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        granite_3_2b, granite_moe_3b_a800m, internvl2_1b, jamba_v01_52b,
        jpeg_resnet, mistral_nemo_12b, mixtral_8x7b, rwkv6_7b, smollm_360m,
        starcoder2_3b, whisper_small,
    )
