"""Jamba-v0.1-52B [arXiv:2403.19887; hf].

Mamba + attention at 1:7 interleave (attention on layer i where
i % 8 == 4, per the paper's block layout), MoE every other layer
(16 experts, top-2).
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b", family="hybrid",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab_size=65536, rope_theta=10_000.0, use_rope=False,
        n_experts=16, experts_per_token=2, moe_every=2, moe_offset=1,
        attn_every=8, attn_offset=4, ssm_kind="mamba",
        d_state=16, d_conv=4, expand=2,
        source="[arXiv:2403.19887; hf] Mamba+attn 1:7, MoE 16e top-2",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-reduced", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, use_rope=False,
        n_experts=4, experts_per_token=2, moe_every=2, moe_offset=1,
        attn_every=2, attn_offset=1, ssm_kind="mamba",
        d_state=8, d_conv=4, expand=2, dtype="float32",
    )


register("jamba-v0.1-52b", full, reduced)
