"""InternVL2-1B [arXiv:2404.16821; hf] — Qwen2-0.5B LM tower + InternViT stub.

The vision frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (``vision_prefix_len`` of them) prepended to
the token sequence.  The beyond-paper JPEG-domain patch embedding
(``core.transform_linear.fold_patch_embed``) is available behind
``vision_jpeg_domain`` in tests.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151655, rope_theta=1_000_000.0,
        tie_embeddings=True, vision_prefix_len=256, frontend_stub=True,
        source="[arXiv:2404.16821; hf] InternViT + InternLM2/Qwen2 tower",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, vision_prefix_len=16, frontend_stub=True,
        tie_embeddings=True, dtype="float32",
    )


register("internvl2-1b", full, reduced)
