"""The paper's own architecture: JPEG transform-domain ResNet (Fig. 3).

``full()`` is an ImageNet-scale variant used for the extra (beyond the 40
mandated LM cells) dry-run/roofline story of the paper's technique itself;
``reduced()`` is the paper's CIFAR-scale network.
"""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jpeg-resnet", family="jpeg_resnet",
        image_size=256, in_channels=3, widths=(64, 128, 256, 512),
        blocks_per_stage=2, num_classes=1000, asm_phi=14,
        dtype="float32",
        source="[arXiv:1812.11690] scaled-up paper Fig. 3",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jpeg-resnet-reduced", family="jpeg_resnet",
        image_size=32, in_channels=3, widths=(16, 32, 64),
        blocks_per_stage=1, num_classes=10, asm_phi=14, dtype="float32",
        source="[arXiv:1812.11690] paper Fig. 3",
    )


register("jpeg-resnet", full, reduced)
