"""Jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret`` mode — the
kernel body runs through the Pallas interpreter for correctness validation;
on TPU (``jax.default_backend() == 'tpu'``) they compile to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.conv import pad_bands
from repro.kernels.asm_relu import asm_relu_pallas
from repro.kernels.block_dct import block_dct_pallas, block_idct_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_block import fused_block_pallas
from repro.kernels.jpeg_conv import jpeg_conv_pallas

__all__ = ["interpret_default", "asm_relu", "block_dct", "block_idct",
           "jpeg_conv_apply", "fused_block", "flash_attention"]


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def asm_relu(coef: jnp.ndarray, phi: int = 14,
             bands: int | None = None) -> jnp.ndarray:
    """ASM ReLU over (..., 64) coefficient tensors (orthonormal units).

    ``bands`` slices the input to the kept zigzag coefficients before the
    kernel's matmuls and zero-pads the result back to the caller's width.
    """
    lead, nf = coef.shape[:-1], coef.shape[-1]
    flat = coef.reshape(-1, nf)
    if bands is not None and bands < nf:
        flat = flat[:, :bands]
    out = asm_relu_pallas(flat, phi, interpret=interpret_default())
    return pad_bands(out, nf).reshape(*lead, nf)


def block_dct(blocks: jnp.ndarray, quality: int | None = None) -> jnp.ndarray:
    lead = blocks.shape[:-2]
    flat = blocks.reshape(-1, 8, 8)
    out = block_dct_pallas(flat, quality=quality,
                           interpret=interpret_default())
    return out.reshape(*lead, 64)


def block_idct(coef: jnp.ndarray, quality: int | None = None) -> jnp.ndarray:
    lead = coef.shape[:-1]
    flat = coef.reshape(-1, 64)
    out = block_idct_pallas(flat, quality=quality,
                            interpret=interpret_default())
    return out.reshape(*lead, 8, 8)


def jpeg_conv_apply(coef: jnp.ndarray, xi: jnp.ndarray,
                    stride: int = 1) -> jnp.ndarray:
    """Pallas twin of ``core.conv.apply_exploded``."""
    return jpeg_conv_pallas(coef, xi, stride, interpret=interpret_default())


def fused_block(x: jnp.ndarray, conv1, asm_mid, conv2, asm_out,
                proj=None) -> jnp.ndarray:
    """One fused residual block over tile-packed operators
    (``kernels.fused_block``); ``x`` is ``(N, bh, bw, Cin·w_in)``."""
    return fused_block_pallas(x, conv1, asm_mid, conv2, asm_out, proj,
                              interpret=interpret_default())


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    window: int | None = None) -> jnp.ndarray:
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret_default())
