"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = ["asm_relu_ref", "jpeg_conv_ref", "block_dct_ref", "block_idct_ref",
           "flash_attention_ref"]


def asm_relu_ref(coef: jnp.ndarray, phi: int) -> jnp.ndarray:
    """ASM ReLU over (N, 64) zigzag coefficient rows (orthonormal units)."""
    recon = jnp.asarray(dctlib.reconstruction_matrix(), coef.dtype)
    recon_phi = jnp.asarray(dctlib.truncated_reconstruction_matrix(phi),
                            coef.dtype)
    mask = (coef @ recon_phi) > 0
    spatial = coef @ recon
    return jnp.where(mask, spatial, 0.0) @ recon.T


def jpeg_conv_ref(coef: jnp.ndarray, xi: jnp.ndarray, stride: int = 1
                  ) -> jnp.ndarray:
    """Exploded-operator apply over (N, bh, bw, Cin, 64) — mirrors core.conv."""
    from repro.core.conv import apply_exploded

    return apply_exploded(coef, xi, stride)


def block_dct_ref(blocks: jnp.ndarray) -> jnp.ndarray:
    """(N, 8, 8) pixel blocks -> (N, 64) zigzag orthonormal coefficients."""
    d = jnp.asarray(dctlib.dct_matrix(), blocks.dtype)
    zz = dctlib.zigzag_permutation()
    f = jnp.einsum("am,nmk,bk->nab", d, blocks, d)
    return f.reshape(blocks.shape[0], 64)[:, zz]


def block_idct_ref(coef: jnp.ndarray) -> jnp.ndarray:
    """(N, 64) zigzag coefficients -> (N, 8, 8) pixel blocks."""
    d = jnp.asarray(dctlib.dct_matrix(), coef.dtype)
    inv = np.argsort(dctlib.zigzag_permutation())
    f = coef[:, inv].reshape(coef.shape[0], 8, 8)
    return jnp.einsum("am,nab,bk->nmk", d, f, d)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True,
                        window: int | None = None) -> jnp.ndarray:
    """Dense masked attention, (B, S, H, hd) x (B, T, KVH, hd) GQA."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd).astype(jnp.float32) * (hd ** -0.5)
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k.astype(jnp.float32))
    qpos = jnp.arange(s)
    kpos = jnp.arange(t)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)
