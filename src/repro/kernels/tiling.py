"""Shared tiling + operator-packing helpers for the Pallas kernels.

Extracted from ``jpeg_conv.py`` / ``asm_relu.py`` so the fused residual-block
kernel (``fused_block.py``) and the per-layer kernels agree on one set of
layout rules:

* ``round_up`` / ``pick_tile`` — sublane-aligned tile selection.  The row
  tile is picked *from the input size* (balanced over ``ceil(n / max_tile)``
  tiles) instead of always padding up to the maximum tile, so a serve-time
  single-image request does not burn VPU cycles on >90% padding.
* ``PackedConv`` / ``PackedAsm`` — build-time **tile-packed** banded
  operators.  A band-truncated Ξ ``(ndy, ndx, Cin, b, Cout, b')`` is padded
  once to sublane-aligned per-channel widths and concatenated over block
  offsets into one contiguous ``(ndy·ndx, Cin·w_in, Cout·w_out)`` buffer;
  the ASM ReLU matrices are packed to the same widths with the mask and
  reconstruction operands concatenated into a single ``(w, 128)`` lane-wide
  operand.  The runtime path then does *zero* reshaping or band fix-ups:
  every step is a dense 2-D GEMM over the packed layout (coefficients
  beyond a layer's band cutoff are zero rows/columns baked in here).
* ``conv_slices`` / ``packed_conv_apply`` / ``packed_asm_apply`` — the
  XLA reference executors over the packed layout (one im2col-style GEMM
  per convolution instead of ``ndy·ndx`` separate einsums; also the
  off-TPU perf path the Pallas kernels delegate to).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.core import asm as asmlib
from repro.core import dct as dctlib
from repro.core.conv import _offsets_from

__all__ = [
    "LANE", "SUBLANE", "round_up", "pick_tile",
    "PackedConv", "PackedAsm", "pack_conv", "pack_asm",
    "conv_slices", "packed_conv_apply", "packed_asm_apply", "fit_width",
]

#: TPU vector lane count — the last axis of a VMEM tile.
LANE = 128
#: float32 sublane count — the second-to-last axis of a VMEM tile.
SUBLANE = 8


def round_up(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is ≥ ``n``."""
    return -(-n // m) * m


def pick_tile(n: int, max_tile: int, align: int = SUBLANE) -> int:
    """Sublane-aligned row tile for ``n`` rows, balanced across tiles.

    ``ceil(n / max_tile)`` tiles are used and the tile size is the aligned
    per-tile share, so small inputs get a tile sized to *them* (a single
    64-row image request runs one 64-row tile, not a padded ``max_tile``
    one) and sizes just past a tile boundary split evenly instead of
    paying a nearly-empty trailing tile.
    """
    if n <= 0:
        raise ValueError(f"need at least one row, got {n}")
    num = -(-n // max_tile)
    return min(round_up(-(-n // num), align), round_up(max_tile, align))


# --------------------------------------------------------------------------
# Build-time packed operators
# --------------------------------------------------------------------------


class PackedConv(NamedTuple):
    """A band-truncated conv operator packed for tile-aligned execution.

    ``xi`` is ``(ndy·ndx, Cin·w_in, Cout·w_out)`` — per-block-offset Ξ
    slices flattened to 2-D GEMM operands and concatenated into one
    contiguous buffer; ``shift`` is a ``(1, Cout·w_out)`` row carrying the
    folded batch-norm DC shift (zeros off the per-channel DC slots) so the
    epilogue is a plain broadcast add.  ``w_in``/``w_out`` are the
    *padded* per-channel coefficient widths; rows/columns beyond the true
    band counts are zero, baked in at pack time.
    """

    xi: jnp.ndarray
    shift: jnp.ndarray
    stride: int
    ndy: int
    ndx: int
    cin: int
    w_in: int
    cout: int
    w_out: int

    @property
    def nbytes(self) -> int:
        return int(self.xi.size + self.shift.size) * self.xi.dtype.itemsize


class PackedAsm(NamedTuple):
    """ASM ReLU operands packed to a per-channel width ``w``.

    ``cat`` is ``(w, 2·64)``: the φ-truncated mask reconstruction in lanes
    ``[:64]`` and the exact reconstruction in lanes ``[64:]`` — one
    lane-wide GEMM produces both the mask pre-activation and the spatial
    values.  ``recon_t`` is ``(64, w)`` back to (padded) coefficients.
    Rows/columns beyond the true band count are zero.
    """

    cat: jnp.ndarray
    recon_t: jnp.ndarray
    w: int
    bands: int
    phi: int

    @property
    def nbytes(self) -> int:
        return int(self.cat.size + self.recon_t.size) * self.cat.dtype.itemsize


def pack_conv(xi, shift, stride: int, *, w_in: int, w_out: int,
              dtype=jnp.float32) -> PackedConv:
    """Pack an exploded operator ``(ndy, ndx, Cin, b, Cout, b')`` plus an
    optional DC ``shift`` (per output channel) into a :class:`PackedConv`.

    ``w_in``/``w_out`` are the target padded per-channel widths; the true
    band axes are cropped to ``min(b, w)`` (coefficients the consumer would
    slice away anyway are dropped here, at build time).
    """
    xi = np.asarray(xi)
    ndy, ndx, cin, b_in, cout, b_out = xi.shape
    k_in, k_out = min(b_in, w_in), min(b_out, w_out)
    packed = np.zeros((ndy * ndx, cin, w_in, cout, w_out), np.float32)
    packed[:, :, :k_in, :, :k_out] = xi.reshape(
        ndy * ndx, cin, b_in, cout, b_out)[:, :, :k_in, :, :k_out]
    packed = packed.reshape(ndy * ndx, cin * w_in, cout * w_out)
    row = np.zeros((1, cout * w_out), np.float32)
    if shift is not None:
        row[0, np.arange(cout) * w_out] = np.asarray(shift)
    return PackedConv(jnp.asarray(packed, dtype), jnp.asarray(row, dtype),
                      stride, ndy, ndx, cin, w_in, cout, w_out)


def pack_asm(phi: int, bands: int, w: int, dtype=jnp.float32) -> PackedAsm:
    """Pack the ASM ReLU matrices at band count ``bands``, padded to ``w``."""
    c = asmlib.asm_constants(phi, bands=bands)
    cat = np.zeros((w, 2 * dctlib.NFREQ), np.float32)
    cat[:bands, : dctlib.NFREQ] = c.recon_phi
    cat[:bands, dctlib.NFREQ:] = c.recon
    rt = np.zeros((dctlib.NFREQ, w), np.float32)
    rt[:, :bands] = c.recon_t
    return PackedAsm(jnp.asarray(cat, dtype), jnp.asarray(rt, dtype),
                     w, bands, phi)


# --------------------------------------------------------------------------
# Reference executors over the packed layout (XLA; also the off-TPU path)
# --------------------------------------------------------------------------


def conv_slices(x: jnp.ndarray, stride: int, ndy: int, ndx: int) -> jnp.ndarray:
    """im2col over block offsets: ``(N, bh, bw, K)`` → ``(N, bh/s, bw/s,
    ndy·ndx·K)`` with the offset-major layout :func:`pack_conv` uses."""
    n, bh, bw, k = x.shape
    d_min_y, _ = _offsets_from(ndy, stride)
    d_min_x, _ = _offsets_from(ndx, stride)
    bh_o, bw_o = bh // stride, bw // stride
    pad_y = (-d_min_y, ndy - 1 + d_min_y)
    pad_x = (-d_min_x, ndx - 1 + d_min_x)
    padded = jnp.pad(x, ((0, 0), pad_y, pad_x, (0, 0)))
    parts = []
    for iy in range(ndy):
        for ix in range(ndx):
            parts.append(padded[:, iy: iy + stride * bh_o: stride,
                                ix: ix + stride * bw_o: stride])
    return jnp.concatenate(parts, axis=-1)


def packed_conv_apply(h: jnp.ndarray, pc: PackedConv) -> jnp.ndarray:
    """One GEMM per layer: gather offset slices, multiply the packed Ξ."""
    n, bh, bw, _ = h.shape
    cat = conv_slices(h, pc.stride, pc.ndy, pc.ndx)
    noff, k, m = pc.xi.shape
    out = cat.reshape(-1, noff * k) @ pc.xi.reshape(noff * k, m)
    return out.reshape(n, bh // pc.stride, bw // pc.stride, m) + pc.shift


def fit_width(h: jnp.ndarray, c: int, w_to: int) -> jnp.ndarray:
    """Adapt a packed ``(..., c·w)`` activation to per-channel width
    ``w_to`` (slice or zero-pad each channel's coefficient lanes).

    No-op when the widths already match — the plan compiler packs each
    operator at its true band width, so this is the only runtime band
    bookkeeping left, and it is elementwise (never inflates a GEMM).
    Narrowing drops lanes that are zero or about to be truncated by the
    consumer's band cutoff; widening inserts zero lanes.
    """
    w_from = h.shape[-1] // c
    if w_from == w_to:
        return h
    lead = h.shape[:-1]
    t = h.reshape(*lead, c, w_from)
    if w_to < w_from:
        t = t[..., :w_to]
    else:
        t = jnp.pad(t, [(0, 0)] * (t.ndim - 1) + [(0, w_to - w_from)])
    return t.reshape(*lead, c * w_to)


def packed_asm_apply(h: jnp.ndarray, pa: PackedAsm) -> jnp.ndarray:
    """ASM ReLU over a packed ``(..., C·w)`` activation.

    The trailing reshape to ``(rows·C, w)`` is a row-major view (channels
    are blocks of ``w`` lanes) — no data movement.
    """
    shape = h.shape
    t = h.reshape(-1, pa.w)
    both = t @ pa.cat
    nf = dctlib.NFREQ
    masked = jnp.where(both[:, :nf] > 0, both[:, nf:], 0.0)
    return (masked @ pa.recon_t).reshape(shape)
