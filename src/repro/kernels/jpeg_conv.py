"""Pallas TPU kernel: exploded JPEG-domain convolution (paper §4.1 / Alg. 1).

Applies the block-banded operator Ξ (built by ``core.conv.explode``) as
dense MXU matmuls.  Grid: ``(image, out_block_row, cout_tile, cin_tile)``;
one instance computes one output block-row tile:

    out[n, i, :, co] += Σ_{dy, dx} in[n, s·i+dy, dx::s, ci] @ Ξ[dy, dx, ci, co]

The input row is passed once per ``dy`` offset (same buffer, shifted
index map — overlapping windows are not expressible with one BlockSpec);
``ci`` is the accumulation grid dim (output block constant across it, so
revisiting is legal).  Channel tiles keep the Ξ slices inside VMEM:
(ndx, 256, 256) fp32 per dy ≈ 0.8 MB.

This kernel is why the paper's "sparse einsum" complaint (§6) does not
apply on TPU: every matmul is a dense (bw, 256)x(256, 256) MXU op.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.conv import _offsets_from
from repro.kernels.tiling import round_up

__all__ = ["jpeg_conv_pallas", "CH_TILE"]

CH_TILE = 256


def _make_kernel(ndy: int, ndx: int, stride: int, bw_out: int):
    def kernel(*refs):
        in_refs = refs[:ndy]
        xi_refs = refs[ndy: 2 * ndy]
        out_ref = refs[2 * ndy]
        ci = pl.program_id(3)

        @pl.when(ci == 0)
        def _init():
            out_ref[...] = jnp.zeros(out_ref.shape, out_ref.dtype)

        acc = jnp.zeros(out_ref.shape[2:], jnp.float32)
        for dy in range(ndy):
            row = in_refs[dy][0, 0]  # (bw_pad, ci_tile)
            xi_dy = xi_refs[dy]      # (1, ndx, ci_tile, co_tile)
            for dx in range(ndx):
                sl = row[dx: dx + stride * bw_out: stride]  # (bw_out, ci_tile)
                acc = acc + jnp.dot(sl, xi_dy[0, dx],
                                    preferred_element_type=jnp.float32)
        out_ref[0, 0] = (out_ref[0, 0] + acc.astype(out_ref.dtype))

    return kernel


@functools.partial(jax.jit, static_argnames=("stride", "interpret"))
def jpeg_conv_pallas(coef: jnp.ndarray, xi: jnp.ndarray, stride: int = 1, *,
                     interpret: bool = True) -> jnp.ndarray:
    """Apply an exploded operator.

    ``coef``: (N, bh, bw, Cin, nf); ``xi``: (ndy, ndx, Cin, nf, Cout, nf').
    Returns (N, bh/stride, bw/stride, Cout, nf').  Matches
    ``core.conv.apply_exploded`` exactly (tests sweep shapes); band-truncated
    operators (``nf = nf' = bands < 64``) shrink the matmuls accordingly.
    """
    ndy, ndx = xi.shape[0], xi.shape[1]
    nf_in, cout, nf_out = xi.shape[3], xi.shape[4], xi.shape[5]
    if coef.shape[-1] > nf_in:
        coef = coef[..., :nf_in]
    n, bh, bw, cin, _ = coef.shape
    d_min_y, _ = _offsets_from(ndy, stride)
    d_min_x, _ = _offsets_from(ndx, stride)
    bh_out, bw_out = bh // stride, bw // stride

    x = coef.reshape(n, bh, bw, cin * nf_in)
    pad_lo_y, pad_hi_y = -d_min_y, ndy - 1 + d_min_y
    pad_lo_x, pad_hi_x = -d_min_x, ndx - 1 + d_min_x
    x = jnp.pad(x, ((0, 0), (pad_lo_y, pad_hi_y), (pad_lo_x, pad_hi_x),
                    (0, 0)))
    w = xi.reshape(ndy, ndx, cin * nf_in, cout * nf_out)

    ci_full, co_full = cin * nf_in, cout * nf_out
    tci = min(CH_TILE, ci_full)
    tco = min(CH_TILE, co_full)
    if ci_full % tci:
        p = round_up(ci_full, tci) - ci_full
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, p)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, p), (0, 0)))
        ci_full += p
    if co_full % tco:
        p = round_up(co_full, tco) - co_full
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, p)))
    co_pad = w.shape[-1]
    bw_pad = x.shape[2]

    grid = (n, bh_out, co_pad // tco, ci_full // tci)
    in_specs = []
    for dy in range(ndy):
        in_specs.append(pl.BlockSpec(
            (1, 1, bw_pad, tci),
            functools.partial(
                lambda b, i, co, ci, dy=dy: (b, stride * i + dy, 0, ci))))
    for dy in range(ndy):
        in_specs.append(pl.BlockSpec(
            (1, ndx, tci, tco),
            functools.partial(
                lambda b, i, co, ci, dy=dy: (dy, 0, ci, co))))
    out_spec = pl.BlockSpec((1, 1, bw_out, tco),
                            lambda b, i, co, ci: (b, i, 0, co))
    out = pl.pallas_call(
        _make_kernel(ndy, ndx, stride, bw_out),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((n, bh_out, bw_out, co_pad),
                                       coef.dtype),
        interpret=interpret,
    )(*([x] * ndy + [w] * ndy))
    out = out[..., : cout * nf_out]
    return out.reshape(n, bh_out, bw_out, cout, nf_out)
