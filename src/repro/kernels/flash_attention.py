"""Pallas TPU kernel: flash attention with GQA + causal/sliding-window masks.

Grid ``(batch, kv_head, q_group, q_tile, kv_tile)`` with the kv-tile as the
innermost (accumulation) dimension; running max / denominator / weighted
accumulator live in VMEM scratch across kv tiles (the online-softmax
recurrence).  Working set per instance: q tile (Tq, hd) + kv tiles
(Tk, hd)×2 + (Tq, Tk) scores — all VMEM.  The pure-JAX twin used by the
models is ``repro.models.layers.attention``; tests assert they agree with
``ref.flash_attention_ref`` across shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas", "Q_TILE", "KV_TILE"]

Q_TILE = 256
KV_TILE = 256
NEG_INF = -1e30


def _make_kernel(causal: bool, window: int | None, qt: int, kt: int,
                 scale: float, n_kv: int, t_valid: int):
    def kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr):
        i = pl.program_id(3)
        j = pl.program_id(4)

        @pl.when(j == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG_INF)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        q = q_ref[0, 0, 0].astype(jnp.float32) * scale  # (qt, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (kt, hd)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        qpos = i * qt + jax.lax.broadcasted_iota(jnp.int32, (qt, kt), 0)
        kpos = j * kt + jax.lax.broadcasted_iota(jnp.int32, (qt, kt), 1)
        mask = kpos < t_valid  # key padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

        @pl.when(j == n_kv - 1)
        def _finish():
            denom = jnp.maximum(l_scr[...], 1e-30)
            o_ref[0, 0, 0] = (acc_scr[...] / denom[:, None]
                              ).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int | None = None,
                           interpret: bool = True) -> jnp.ndarray:
    """``q``: (B, S, H, hd); ``k``/``v``: (B, T, KVH, hd) -> (B, S, H, hd)."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qt = min(Q_TILE, s)
    kt = min(KV_TILE, t)
    s_pad = -(-s // qt) * qt
    t_pad = -(-t // kt) * kt
    qx = q.reshape(b, s, kvh, g, hd).transpose(0, 2, 3, 1, 4)  # (B,KVH,G,S,hd)
    if s_pad != s:
        qx = jnp.pad(qx, ((0, 0), (0, 0), (0, 0), (0, s_pad - s), (0, 0)))
    kx = k.transpose(0, 2, 1, 3)  # (B, KVH, T, hd)
    vx = v.transpose(0, 2, 1, 3)
    if t_pad != t:
        # padding keys sit at positions >= t; mask them out via window/causal
        # or explicit validity below
        kx = jnp.pad(kx, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
        vx = jnp.pad(vx, ((0, 0), (0, 0), (0, t_pad - t), (0, 0)))
    n_q, n_kv = s_pad // qt, t_pad // kt
    grid = (b, kvh, g, n_q, n_kv)
    kernel = _make_kernel(causal, window, qt, kt, hd ** -0.5, n_kv, t)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, qt, hd), lambda b_, n, g_, i, j: (b_, n, g_, i, 0)),
            pl.BlockSpec((1, 1, kt, hd), lambda b_, n, g_, i, j: (b_, n, j, 0)),
            pl.BlockSpec((1, 1, kt, hd), lambda b_, n, g_, i, j: (b_, n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, qt, hd),
                               lambda b_, n, g_, i, j: (b_, n, g_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, s_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qt,), jnp.float32),
            pltpu.VMEM((qt,), jnp.float32),
            pltpu.VMEM((qt, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qx, kx, vx)
    out = out[:, :, :, :s]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)
