"""Pallas TPU kernel: batched 8×8 forward/inverse DCT (+ zigzag, + quant).

The JPEG encode hot loop (data pipeline / first-layer folding).  A tile of
``TILE`` blocks is laid out as ``(TILE, 64)`` flat pixels in VMEM; the 2-D
DCT is one ``(64, 64)`` matmul with the precomputed separable operator
``K[pq, ab] = D[a,p]·D[b,q]`` (zigzag and quantization folded in), keeping
everything in a single MXU pass — no 8-wide matmuls, no transposes.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dct as dctlib

__all__ = ["block_dct_pallas", "block_idct_pallas"]

TILE = 1024


def _matmul_kernel(x_ref, op_ref, out_ref):
    out_ref[...] = jnp.dot(x_ref[...], op_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def _run(x: jnp.ndarray, op: np.ndarray, interpret: bool) -> jnp.ndarray:
    n = x.shape[0]
    tile = min(TILE, n)
    if n % tile:
        x = jnp.pad(x, ((0, tile - n % tile), (0, 0)))
    grid = (x.shape[0] // tile,)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 64), lambda i: (i, 0)),
            pl.BlockSpec((64, 64), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, jnp.asarray(op, x.dtype))
    return out[:n]


@functools.lru_cache(maxsize=None)
def _fwd_operator(quality: int | None) -> np.ndarray:
    """(64 flat-pixel, 64 zigzag-coef) forward DCT operator."""
    r = dctlib.reconstruction_matrix()  # (coef, pixel); forward = transpose
    op = r.T.copy()
    if quality is not None:
        op = op / dctlib.quantization_table(quality)[None, :]
    return op


@functools.lru_cache(maxsize=None)
def _inv_operator(quality: int | None) -> np.ndarray:
    r = dctlib.reconstruction_matrix().copy()
    if quality is not None:
        r = dctlib.quantization_table(quality)[:, None] * r
    return r


@functools.partial(jax.jit, static_argnames=("quality", "interpret"))
def block_dct_pallas(blocks: jnp.ndarray, *, quality: int | None = None,
                     interpret: bool = True) -> jnp.ndarray:
    """(N, 8, 8) pixel blocks -> (N, 64) zigzag coefficients."""
    n = blocks.shape[0]
    return _run(blocks.reshape(n, 64), _fwd_operator(quality), interpret)


@functools.partial(jax.jit, static_argnames=("quality", "interpret"))
def block_idct_pallas(coef: jnp.ndarray, *, quality: int | None = None,
                      interpret: bool = True) -> jnp.ndarray:
    """(N, 64) zigzag coefficients -> (N, 8, 8) pixel blocks."""
    out = _run(coef, _inv_operator(quality), interpret)
    return out.reshape(coef.shape[0], 8, 8)
