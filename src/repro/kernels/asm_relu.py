"""Pallas TPU kernel: fused ASM ReLU (paper §4.2) over tiles of JPEG blocks.

One grid step processes ``TILE_BLOCKS`` 8×8 blocks resident in VMEM:

    approx  = tile @ R_phi      # (T, 64)·(64, 64) MXU
    mask    = approx > 0        # VPU
    spatial = tile @ R          # MXU
    out     = (mask ? spatial : 0) @ Rᵀ   # VPU select + MXU

Three small matmuls per tile, no HBM round-trip for the spatial
intermediate — this is the TPU-native replacement for the paper's sparse
harmonic-mixing einsum (DESIGN.md §3).  The 64-wide contraction is padded
to 128 lanes by Mosaic; tiles are 8·128 rows to keep the MXU busy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import dct as dctlib
from repro.kernels.tiling import pick_tile

__all__ = ["asm_relu_pallas", "TILE_BLOCKS"]

TILE_BLOCKS = 1024


def _asm_relu_kernel(coef_ref, recon_phi_ref, recon_ref, recon_t_ref, out_ref):
    tile = coef_ref[...]
    approx = jnp.dot(tile, recon_phi_ref[...],
                     preferred_element_type=jnp.float32)
    spatial = jnp.dot(tile, recon_ref[...],
                      preferred_element_type=jnp.float32)
    masked = jnp.where(approx > 0, spatial, 0.0)
    out_ref[...] = jnp.dot(masked, recon_t_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("phi", "interpret"))
def asm_relu_pallas(coef: jnp.ndarray, phi: int = 14, *,
                    interpret: bool = True) -> jnp.ndarray:
    """ASM ReLU over ``(N, nf)`` zigzag coefficients (orthonormal units).

    ``nf`` may be < 64 for band-truncated activations (paper §6 sparsity):
    the reconstruction operands shrink to ``(nf, 64)`` / ``(64, nf)`` so the
    dropped coefficients never enter the MXU.  ``interpret=True`` runs the
    kernel body on CPU for validation; on TPU pass ``interpret=False``.
    """
    n, nf = coef.shape
    # Tile picked *from n* (balanced, sublane-aligned — kernels.tiling):
    # a serve-time single-image request runs one right-sized tile instead
    # of padding up to TILE_BLOCKS and wasting the VPU on zeros.
    tile = pick_tile(n, TILE_BLOCKS)
    if n % tile:
        pad = tile - n % tile
        coef = jnp.pad(coef, ((0, pad), (0, 0)))
    grid = (coef.shape[0] // tile,)
    recon = jnp.asarray(dctlib.reconstruction_matrix()[:nf], coef.dtype)
    recon_phi = jnp.asarray(dctlib.truncated_reconstruction_matrix(phi)[:nf],
                            coef.dtype)
    out = pl.pallas_call(
        _asm_relu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, nf), lambda i: (i, 0)),
            pl.BlockSpec((nf, 64), lambda i: (0, 0)),
            pl.BlockSpec((nf, 64), lambda i: (0, 0)),
            pl.BlockSpec((64, nf), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, nf), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(coef.shape, coef.dtype),
        interpret=interpret,
    )(coef, recon_phi, recon, recon.T)
    return out[:n]
