"""Pallas megakernel: one fused JPEG-domain residual block per grid step.

``InferencePlan`` execution (PR 2) still paid one dispatch call per layer:
every intermediate — conv1 output, ASM mask input, conv2 output, residual
sum — made an HBM round trip.  This kernel executes an **entire residual
block** on a VMEM-resident activation tile:

    h   = conv1(x)·Ξ₁ + shift₁          # banded GEMMs, BN scale already in Ξ
    h   = ASM(h)                         # mask from the same VMEM tile
    y   = conv2(h)·Ξ₂ + shift₂
    y  += shortcut                       # identity, or proj conv of x
    out = ASM(y)                         # epilogue at the residual join bands

Grid: ``(image,)`` — one instance owns one image's full block grid, which
is what the paper's scale makes natural: after the stem a 32×32 input is a
4×4 block grid, so whole feature maps are a few hundred KB.  All operands
are the **tile-packed** banded operators from ``kernels.tiling``
(``PackedConv`` / ``PackedAsm``): band padding and batch-norm folds were
baked at plan-compile time, so the kernel body is nothing but dense 2-D
MXU dots, a compare, and adds — zero reshapes of HBM-resident data.

VMEM budget per block tile (float32 bytes, per grid instance):

    x tile        bh·bw·Cin·w_in·4           (+ halo-padded copy, same order)
    h tile        (bh/s)·(bw/s)·C·w_mid·4    (+ halo-padded copy for conv2)
    y/out tiles   (bh/s)·(bw/s)·C·w_out·4
    Ξ₁, Ξ₂, proj  ndy·ndx·(Cin·w_in)·(Cout·w_out)·4 each
    ASM operands  w·(2·64)·4 + 64·w·4 per stage

``core.plan.compile_plan`` evaluates this sum against its ``vmem_budget``
(default 12 MB of the ~16 MB/core budget) and falls back to per-layer
execution for blocks that do not fit.  Like the other kernels in this
package the body is interpreter-validated on CPU (tests force
``interpret=True``); Mosaic compilation on TPU is tracked by the ROADMAP
"TPU non-interpret CI" item.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import conv as convlib
from repro.core import dct as dctlib
from repro.core.conv import _offsets_from
from repro.kernels.tiling import PackedAsm, PackedConv, fit_width, \
    packed_asm_apply, packed_conv_apply

__all__ = ["fused_block_pallas", "fused_block_reference",
           "fused_block_spatial", "fused_stem_spatial", "fused_vmem_bytes"]


def _conv_tile(x, xi_ref, shift_ref, stride: int, ndy: int, ndx: int):
    """Banded conv over one image's VMEM tile: Σ_offsets slice·Ξ + shift."""
    bh, bw, k = x.shape
    m = xi_ref.shape[2]
    d_min_y, _ = _offsets_from(ndy, stride)
    d_min_x, _ = _offsets_from(ndx, stride)
    bh_o, bw_o = bh // stride, bw // stride
    xp = jnp.pad(x, ((-d_min_y, ndy - 1 + d_min_y),
                     (-d_min_x, ndx - 1 + d_min_x), (0, 0)))
    acc = jnp.zeros((bh_o * bw_o, m), jnp.float32)
    for o in range(ndy * ndx):
        iy, ix = o // ndx, o % ndx
        sl = xp[iy: iy + stride * bh_o: stride,
                ix: ix + stride * bw_o: stride]
        acc = acc + jnp.dot(sl.reshape(bh_o * bw_o, k), xi_ref[o],
                            preferred_element_type=jnp.float32)
    return acc.reshape(bh_o, bw_o, m) + shift_ref[0]


def _asm_tile(h, cat_ref, rt_ref, w: int):
    """ASM ReLU on a resident tile: mask and value from one lane-wide dot."""
    nf = dctlib.NFREQ
    shape = h.shape
    t = h.reshape(-1, w)
    both = jnp.dot(t, cat_ref[...], preferred_element_type=jnp.float32)
    masked = jnp.where(both[:, :nf] > 0, both[:, nf:], 0.0)
    out = jnp.dot(masked, rt_ref[...], preferred_element_type=jnp.float32)
    return out.reshape(shape)


def _make_kernel(conv1: PackedConv, asm_mid: PackedAsm, conv2: PackedConv,
                 asm_out: PackedAsm, proj: PackedConv | None, out_dtype):
    def kernel(*refs):
        (x_ref, xi1, sh1, cat1, rt1, xi2, sh2, cat2, rt2, *rest) = refs
        out_ref = rest[-1]
        x = x_ref[0]
        h = fit_width(x, conv1.cin, conv1.w_in)
        h = _conv_tile(h, xi1, sh1, conv1.stride, conv1.ndy, conv1.ndx)
        h = _asm_tile(h, cat1, rt1, asm_mid.w)
        h = fit_width(h, conv2.cin, conv2.w_in)
        y = _conv_tile(h, xi2, sh2, conv2.stride, conv2.ndy, conv2.ndx)
        y = fit_width(y, conv2.cout, asm_out.w)
        if proj is not None:
            pxi, psh = rest[0], rest[1]
            short = fit_width(x, proj.cin, proj.w_in)
            short = _conv_tile(short, pxi, psh, proj.stride, proj.ndy,
                               proj.ndx)
            short = fit_width(short, proj.cout, asm_out.w)
        else:
            short = fit_width(x, conv1.cin, asm_out.w)
        y = y + short
        out_ref[0] = _asm_tile(y, cat2, rt2, asm_out.w).astype(out_dtype)

    return kernel


def fused_block_pallas(x: jnp.ndarray, conv1: PackedConv, asm_mid: PackedAsm,
                       conv2: PackedConv, asm_out: PackedAsm,
                       proj: PackedConv | None = None, *,
                       interpret: bool = True) -> jnp.ndarray:
    """Run one residual block fused; ``x`` is ``(N, bh, bw, Cin·w)``.

    Each operand is applied at its own packed band width; the activation
    is width-fitted on the VMEM tile between stages (slice / zero lanes —
    never a GEMM-dimension inflation).  Matches
    :func:`fused_block_reference` on every shape the compiler emits
    (tests sweep strides, shortcuts, bands, and φ).
    """
    n, bh, bw, k_in = x.shape
    if k_in % conv1.cin:
        raise ValueError(f"input width {k_in} not a multiple of "
                         f"Cin={conv1.cin}")
    s = conv1.stride
    bh_o, bw_o = bh // s, bw // s
    m_out = conv2.cout * conv2.w_out

    def whole(shape):
        nd = len(shape)
        return pl.BlockSpec(shape, lambda b, nd=nd: (0,) * nd)

    in_specs = [pl.BlockSpec((1, bh, bw, k_in), lambda b: (b, 0, 0, 0)),
                whole(conv1.xi.shape), whole(conv1.shift.shape),
                whole(asm_mid.cat.shape), whole(asm_mid.recon_t.shape),
                whole(conv2.xi.shape), whole(conv2.shift.shape),
                whole(asm_out.cat.shape), whole(asm_out.recon_t.shape)]
    operands = [x, conv1.xi, conv1.shift, asm_mid.cat, asm_mid.recon_t,
                conv2.xi, conv2.shift, asm_out.cat, asm_out.recon_t]
    if proj is not None:
        in_specs += [whole(proj.xi.shape), whole(proj.shift.shape)]
        operands += [proj.xi, proj.shift]
    out = pl.pallas_call(
        _make_kernel(conv1, asm_mid, conv2, asm_out, proj, x.dtype),
        grid=(n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh_o, bw_o, m_out),
                               lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, bh_o, bw_o, m_out), x.dtype),
        interpret=interpret,
    )(*operands)
    return out


def fused_block_reference(x: jnp.ndarray, conv1: PackedConv,
                          asm_mid: PackedAsm, conv2: PackedConv,
                          asm_out: PackedAsm,
                          proj: PackedConv | None = None) -> jnp.ndarray:
    """XLA twin of the megakernel over the same packed operators — the
    parity oracle the interpreted kernel is tested against.  (Off-TPU
    *serving* uses :func:`fused_block_spatial` instead, which is the
    FLOP-optimal lowering of the same block.)
    """
    h = packed_conv_apply(fit_width(x, conv1.cin, conv1.w_in), conv1)
    h = packed_asm_apply(h, asm_mid)
    y = packed_conv_apply(fit_width(h, conv2.cin, conv2.w_in), conv2)
    y = fit_width(y, conv2.cout, asm_out.w)
    if proj is None:
        short = fit_width(x, conv1.cin, asm_out.w)
    else:
        short = packed_conv_apply(fit_width(x, proj.cin, proj.w_in), proj)
        short = fit_width(short, proj.cout, asm_out.w)
    return packed_asm_apply(y + short, asm_out)


# --------------------------------------------------------------------------
# Spatial-resident fused block: the XLA (off-TPU) serving path
# --------------------------------------------------------------------------
#
# On the MXU the banded Ξ matmuls above are the right shape.  On XLA
# backends the FLOP count rules instead, and Ξ application costs
# ``ndy·ndx·Cin·Cout·b²`` per block versus ``64·r²·Cin·Cout`` for the
# spatial convolution it factors through — ~(b/8)² more work.  Per-layer
# execution cannot exploit this (each op must return to the coefficient
# domain to stay composable), but a *fused block* can: decode once at
# block entry, run both convolutions on the spatial tile, take the ASM
# masks directly from it (ASM ≡ project-to-bands → threshold), and encode
# once at the join.  Mathematically identical to the Ξ walk — every band
# truncation of the plan is reproduced as a subspace projection — and
# parity-tested against it.


def _blocks_to_image(px: jnp.ndarray) -> jnp.ndarray:
    """``(N, bh, bw, C, 64)`` raster-ordered block pixels → ``(N, C, H, W)``."""
    n, bh, bw, c, _ = px.shape
    b = dctlib.BLOCK
    t = px.reshape(n, bh, bw, c, b, b).transpose(0, 3, 1, 4, 2, 5)
    return t.reshape(n, c, bh * b, bw * b)


def _image_to_blocks(img: jnp.ndarray) -> jnp.ndarray:
    """``(N, C, H, W)`` → ``(N, bh, bw, C, 64)`` raster-ordered pixels."""
    n, c, h, w = img.shape
    b = dctlib.BLOCK
    t = img.reshape(n, c, h // b, b, w // b, b).transpose(0, 2, 4, 1, 3, 5)
    return t.reshape(n, h // b, w // b, c, b * b)


def _recon(dtype) -> jnp.ndarray:
    return jnp.asarray(dctlib.reconstruction_matrix(), dtype)


def _recon_phi(phi: int, dtype) -> jnp.ndarray:
    return jnp.asarray(dctlib.truncated_reconstruction_matrix(phi), dtype)


def _spatial_op(img: jnp.ndarray, op) -> jnp.ndarray:
    """One conv layer in pixel space: BN-scaled kernel, stride, DC shift
    (a coefficient-DC shift ``s`` is a per-pixel bias ``s/8`` — the
    orthonormal DC basis value)."""
    k = op.kernel
    if op.bn_scale is not None:
        k = k * op.bn_scale[:, None, None, None]
    img = convlib.spatial_conv(img, k, op.stride)
    if op.shift is not None:
        img = img + (op.shift / dctlib.BLOCK)[None, :, None, None]
    return img


def _pad_last(t: jnp.ndarray, w: int) -> jnp.ndarray:
    if t.shape[-1] == w:
        return t
    return jnp.pad(t, [(0, 0)] * (t.ndim - 1) + [(0, w - t.shape[-1])])


def fused_block_spatial(x: jnp.ndarray, blk, phi: int) -> jnp.ndarray:
    """Whole-block execution on a spatial-resident activation.

    ``blk`` is a ``core.plan.CompiledBlock`` (its ``ops`` carry the raw
    kernels plus the retained BN folds); ``x`` is the packed
    ``(N, bh, bw, Cin·w_in)`` coefficient activation with true content in
    the first ``blk.bands_in`` lanes per channel.
    """
    ops = blk.ops
    c1, c2 = ops["conv1"], ops["conv2"]
    pr = ops.get("proj")
    n, bh, bw, k_in = x.shape
    w_in = k_in // blk.cin
    r = _recon(x.dtype)
    rphi = _recon_phi(phi, x.dtype)
    coef = x.reshape(n, bh, bw, blk.cin, w_in)
    b1, b2 = c1.bands, c2.bands

    # conv1 (input truncated to its band cutoff, decoded once)
    bin1 = min(b1, blk.bands_in, w_in)
    img = _blocks_to_image(coef[..., :bin1] @ r[:bin1])
    px = _image_to_blocks(_spatial_op(img, c1))
    # mid ASM at b1: project onto the kept bands, threshold, keep pixels
    t = px @ r[:b1].T
    px = jnp.where(t @ rphi[:b1] > 0, t @ r[:b1], 0.0)
    # conv2 input truncation (nested projections collapse: P_a∘P_b = P_min)
    bin2 = min(b2, b1)
    px = (px @ r[:bin2].T) @ r[:bin2]
    img = _spatial_op(_blocks_to_image(px), c2)
    y = _image_to_blocks(img) @ r[:b2].T  # encode + truncate, once per block
    # shortcut: identity stays coefficients (never decoded); projection
    # shortcut runs its own spatial conv
    if pr is not None:
        binp = min(pr.bands, blk.bands_in, w_in)
        simg = _spatial_op(_blocks_to_image(coef[..., :binp] @ r[:binp]), pr)
        s_coef = _image_to_blocks(simg) @ r[:pr.bands].T
    else:
        s_coef = coef[..., : min(blk.bands_in, w_in)]
    j = blk.bands_out
    yj = _pad_last(y, j) + _pad_last(s_coef, j)
    # join ASM at the residual-join bands, back to packed coefficients
    out = jnp.where(yj @ rphi[:j] > 0, yj @ r[:j], 0.0) @ r[:j].T
    s = c1.stride
    return _pad_last(out, blk.w_out).reshape(n, bh // s, bw // s,
                                             blk.cout * blk.w_out)


def fused_stem_spatial(coef: jnp.ndarray, op, phi: int,
                       w_out: int) -> jnp.ndarray:
    """Spatial-resident stem: de-quantize + decode the kept bands, one
    spatial conv, encode, ASM at the stem bands.  ``coef`` is the raw
    ``(N, bh, bw, C, 64)`` quantization-scaled input."""
    n, bh, bw = coef.shape[:3]
    r = _recon(coef.dtype)
    rphi = _recon_phi(phi, coef.dtype)
    b = op.bands
    t = coef[..., :b]
    if op.in_scaled:
        q = jnp.asarray(dctlib.quantization_table(op.quality), coef.dtype)
        t = t * q[:b]
    img = _spatial_op(_blocks_to_image(t @ r[:b]), op)
    y = _image_to_blocks(img) @ r[:b].T
    out = jnp.where(y @ rphi[:b] > 0, y @ r[:b], 0.0) @ r[:b].T
    cout = op.kernel.shape[0]
    s = op.stride
    return _pad_last(out, w_out).reshape(n, bh // s, bw // s, cout * w_out)


def fused_vmem_bytes(bh: int, bw: int, conv1: PackedConv, asm_mid: PackedAsm,
                     conv2: PackedConv, asm_out: PackedAsm,
                     proj: PackedConv | None = None) -> int:
    """Estimated per-instance VMEM footprint (see module docstring)."""
    f = 4  # float32
    s = conv1.stride
    bh_o, bw_o = bh // s, bw // s
    x_t = bh * bw * conv1.cin * conv1.w_in * f
    h_t = bh_o * bw_o * conv1.cout * conv1.w_out * f
    y_t = bh_o * bw_o * conv2.cout * conv2.w_out * f
    ops = conv1.nbytes + conv2.nbytes + asm_mid.nbytes + asm_out.nbytes
    if proj is not None:
        ops += proj.nbytes
    # x and h each exist twice (raw + halo-padded copy); y + out once each.
    return 2 * x_t + 2 * h_t + 2 * y_t + ops
