"""Pallas TPU kernels (pl.pallas_call + explicit BlockSpec VMEM tiling).

The paper's compute hot-spots, TPU-adapted (DESIGN.md §3): ``asm_relu``
(fused harmonic-mixing ReLU), ``jpeg_conv`` (block-banded exploded conv),
``block_dct`` (batched 8×8 codec transform), plus ``flash_attention`` for
the assigned LM architectures.

``ops`` — jit'd wrappers (interpret-mode on CPU, Mosaic on TPU);
``ref`` — pure-jnp oracles the tests assert against.
"""
from repro.kernels import ops, ref  # noqa: F401
