"""Fault-tolerant checkpointing.

Design points (DESIGN.md §5):

* **Atomicity** — writes go to ``step_<n>.tmp/`` and are renamed into place
  only after an integrity manifest (per-leaf checksums) is fsynced; a crash
  mid-write can never shadow the previous good checkpoint.
* **Corruption fallback** — ``restore_latest`` verifies checksums and walks
  backwards to the newest *valid* step.
* **Elastic resharding** — leaves are stored unsharded (gathered) with
  their pytree paths; ``restore`` re-places them under *any* sharding tree,
  so a job restarted on a different mesh (more pods, fewer pods) resumes
  bit-exactly.
* **Async writes** — ``save(..., blocking=False)`` snapshots to host
  memory synchronously (cheap) and writes in a background thread so the
  train loop isn't stalled on I/O; ``wait()`` joins before exit.
* Keep-last-k retention + data-iterator state + arbitrary JSON extras.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree: Any) -> list[tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: dict[str, Any] | None = None,
             blocking: bool = True) -> None:
        leaves = _flatten_with_paths(tree)  # host snapshot (synchronous)
        extra = dict(extra or {})

        def write():
            tmp = os.path.join(self.directory, f"step_{step}.tmp")
            final = os.path.join(self.directory, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "extra": extra, "leaves": {}}
            arrays = {}
            for i, (key, arr) in enumerate(leaves):
                name = f"leaf_{i}"
                store = arr
                if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
                    # numpy's npz cannot round-trip ml_dtypes (bf16 etc.);
                    # widen for storage, restore casts back per the manifest.
                    store = arr.astype(np.float32)
                arrays[name] = store
                manifest["leaves"][name] = {
                    "path": key,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(store.tobytes()).hexdigest(),
                }
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._retain()

        if blocking:
            write()
        else:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_", 1)[1]))
                except ValueError:
                    continue
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(d, "arrays.npz")) as z:
                for name, meta in manifest["leaves"].items():
                    arr = z[name]
                    if hashlib.sha256(arr.tobytes()).hexdigest() != meta["sha256"]:
                        return False
            return True
        except Exception:
            return False

    def restore(self, step: int, target_tree: Any,
                sharding_tree: Any | None = None) -> tuple[Any, dict[str, Any]]:
        """Restore into the structure of ``target_tree``.

        ``sharding_tree`` (same structure, leaves = ``jax.sharding.Sharding``
        or None) re-places each leaf — this is where elastic resharding
        happens: the stored arrays are mesh-agnostic.
        """
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {}
        with np.load(os.path.join(d, "arrays.npz")) as z:
            for name, meta in manifest["leaves"].items():
                by_path[meta["path"]] = z[name]
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shardings = (
            [None] * len(flat) if sharding_tree is None
            else treedef.flatten_up_to(sharding_tree)
        )
        leaves = []
        for (path, leaf), sh in zip(flat, shardings):
            key = "/".join(str(p) for p in path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = by_path[key]
            if hasattr(leaf, "dtype") and str(arr.dtype) != str(leaf.dtype):
                arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))
            leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
        return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]

    def restore_tree(self, step: int | None = None
                     ) -> tuple[int, dict[str, np.ndarray], dict[str, Any]]:
        """Template-free restore: ``(step, {path: array}, extra)``.

        Unlike :meth:`restore` no target pytree is needed — leaves come
        back keyed by their stored path strings (artifact loading, e.g.
        ``core.plan.load_plan``, reconstructs its own structure from the
        manifest ``extra``).  ``step=None`` picks the newest *valid* step;
        an explicit step is checksum-verified before loading.  Raises
        ``FileNotFoundError`` when no valid checkpoint exists.
        """
        if step is None:
            step = next((s for s in reversed(self.steps()) if self._valid(s)),
                        None)
            if step is None:
                raise FileNotFoundError(
                    f"no valid checkpoint under {self.directory}")
        elif not self._valid(step):
            raise FileNotFoundError(
                f"checkpoint step {step} under {self.directory} is missing "
                "or corrupt")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path: dict[str, np.ndarray] = {}
        with np.load(os.path.join(d, "arrays.npz")) as z:
            for name, meta in manifest["leaves"].items():
                arr = z[name]
                if str(arr.dtype) != meta["dtype"]:
                    # bf16 and friends were widened for npz storage
                    arr = np.asarray(jnp.asarray(arr).astype(meta["dtype"]))
                by_path[meta["path"]] = arr
        return step, by_path, manifest["extra"]

    def restore_latest(self, target_tree: Any, sharding_tree: Any | None = None
                       ) -> tuple[int, Any, dict[str, Any]] | None:
        """Newest *valid* checkpoint, or None.  Skips corrupted steps."""
        for step in reversed(self.steps()):
            if self._valid(step):
                tree, extra = self.restore(step, target_tree, sharding_tree)
                return step, tree, extra
        return None
