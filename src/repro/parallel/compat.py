"""Version-compatible jax imports.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the
top-level ``jax.shard_map`` (and its replication check was renamed
``check_rep`` -> ``check_vma``).  Call sites in this repo use the modern
spelling; this shim makes it work back to jax 0.4.x.
"""
from __future__ import annotations

import functools

try:  # jax >= 0.5: top-level export, ``check_vma`` keyword
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    shard_map = _shard_map
except ImportError:  # jax 0.4.x: experimental module, ``check_rep`` keyword
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                  **kwargs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma, **kwargs)

def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    jax >= 0.5 grew ``axis_types`` (and made Explicit sharding opt-in);
    jax 0.4.x meshes are implicitly Auto, so the argument is simply
    omitted there.
    """
    import jax

    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))


def axis_size(axis) -> int:
    """Size of a named mesh axis inside shard_map'd code.

    ``jax.lax.axis_size`` only exists on newer jax; ``psum(1, axis)`` is
    the classic spelling (constant-folds to the axis size).
    """
    import jax

    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis)
    return int(jax.lax.psum(1, axis))


__all__ = ["shard_map", "make_mesh", "axis_size"]
