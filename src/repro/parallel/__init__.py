"""Distribution: sharding rules, collectives, pipeline parallelism."""
from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    batch_pspec,
    cache_pspec,
    current_rules,
    logical_pspec,
    param_pspec,
    shard,
    sharding_rules,
    zero1_pspec,
)
