"""Pipeline parallelism: microbatch schedule over a ``stage`` mesh axis.

GPipe-style fill/steady/drain schedule built from ``shard_map`` +
``lax.ppermute``: every device holds one stage's parameters; activations
hop stage→stage+1 each tick; ``n_micro + n_stages - 1`` ticks total.
Bubble fraction = (S-1)/(M+S-1) — reported by :func:`bubble_fraction`.

At production scale the intended mapping is stages × pods (layer slices
across pods, DCI traffic = one activation tensor per tick per boundary);
CPU tests exercise a 4-stage mesh via forced host devices.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map

__all__ = ["pipelined_apply", "bubble_fraction", "stack_stage_params"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stack_stage_params(per_stage: list[Any]) -> Any:
    """Stack per-stage param pytrees along a leading stage axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_stage)


def pipelined_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,
    microbatches: jnp.ndarray,
    mesh,
    axis: str = "stage",
) -> jnp.ndarray:
    """Run ``y_mb = stage_{S-1}(... stage_0(x_mb))`` for every microbatch.

    ``stage_params``: pytree with leading stage axis (sharded over ``axis``);
    ``microbatches``: (n_micro, mb, ...) — replicated input, every stage sees
    all microbatches but only stage 0 consumes them.  Returns (n_micro, mb,
    ...) outputs (valid on the last stage; replicated back via ppermute ring).
    """
    n_stages = mesh.shape[axis]
    n_micro = microbatches.shape[0]
    total_ticks = n_micro + n_stages - 1
    perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_stage(params, mb):
        params = jax.tree.map(lambda x: x[0], params)  # my stage's slice
        stage_id = jax.lax.axis_index(axis)
        carry = jnp.zeros_like(mb[0])
        outputs = jnp.zeros_like(mb)

        def tick(t, state):
            carry, outputs = state
            # stage 0 ingests microbatch t (while valid), others use carry
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage_id == 0, mb[mb_idx], carry)
            y = stage_fn(params, x_in)
            # last stage records its result for microbatch t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            record = (stage_id == n_stages - 1) & (t >= n_stages - 1)
            outputs = jnp.where(
                record,
                outputs.at[out_idx].set(y),
                outputs)
            carry = jax.lax.ppermute(y, axis, perm_fwd)
            return carry, outputs

        carry, outputs = jax.lax.fori_loop(0, total_ticks, tick,
                                           (carry, outputs))
        # broadcast final outputs from the last stage to all (psum of one-hot)
        is_last = (stage_id == n_stages - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * is_last, axis)

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,  # carries are stage-varying by construction
    )
    return fn(stage_params, microbatches)
