"""Collective helpers: compressed and hierarchical reductions.

Used inside ``shard_map`` regions (manual-collective code paths, e.g. the
pipeline schedule); the pjit paths get their collectives from SPMD, where
compression happens by casting before the reduction (``optim.grad``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import compat

__all__ = ["psum_compressed", "hierarchical_psum", "ring_all_gather"]


def psum_compressed(x: jnp.ndarray, axis: str, dtype=jnp.bfloat16) -> jnp.ndarray:
    """All-reduce in a narrower dtype (halves DP collective bytes)."""
    return jax.lax.psum(x.astype(dtype), axis).astype(x.dtype)


def hierarchical_psum(x: jnp.ndarray, inner_axis: str, outer_axis: str
                      ) -> jnp.ndarray:
    """Reduce over fast links first, then the slow (pod/DCI) axis.

    With SPMD this schedule is implicit; in manual regions the split keeps
    the DCI payload to one already-reduced tensor per pod.
    """
    return jax.lax.psum(jax.lax.psum(x, inner_axis), outer_axis)


def ring_all_gather(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Explicit ring all-gather via ppermute (collective-overlap building
    block for manual pipelines)."""
    n = compat.axis_size(axis)
    perm = [(i, (i + 1) % n) for i in range(n)]
    idx = jax.lax.axis_index(axis)
    pieces = [x] * n
    cur = x
    for step in range(1, n):
        cur = jax.lax.ppermute(cur, axis, perm)
        pieces[step] = cur
    # piece j on device i originated at device (i - j) mod n; roll into order
    stacked = jnp.stack(pieces, axis=0)
    order = (idx - jnp.arange(n)) % n
    inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n))
    return jnp.take(stacked, inv, axis=0)
