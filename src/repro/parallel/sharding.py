"""Logical-axis sharding rules (DP / TP / EP / SP + pod axis).

Models never name mesh axes: they call :func:`shard` with *logical* axis
names; the active :class:`AxisRules` (installed by the launcher via
``with sharding_rules(...)``) maps logical names to mesh axes.  Outside a
rules context every constraint is a no-op, so smoke tests run unsharded.

Parameter shardings are *inferred* from pytree paths + shapes
(:func:`param_pspec`) — one rule table covers all ten architectures:

* vocab-sized dims -> ``model``      (TP vocab/embedding sharding)
* d_ff / q_dim / d_inner dims -> ``model``  (Megatron TP)
* the matching contraction dim of output projections -> ``model``
* optimizer state (via ``zero1_pspec``) additionally shards the *first*
  remaining unsharded dim over ``data`` (ZeRO-1).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = [
    "AxisRules", "sharding_rules", "current_rules", "shard", "logical_pspec",
    "param_pspec", "zero1_pspec", "batch_pspec", "cache_pspec",
]


@dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> tuple of mesh axis names."""

    rules: dict[str, tuple[str, ...]] = field(default_factory=dict)
    mesh_shape: dict[str, int] = field(default_factory=dict)
    mesh: object = None  # the jax Mesh — needed by shard_map code paths

    def with_mesh(self, mesh) -> "AxisRules":
        import dataclasses
        return dataclasses.replace(self, mesh=mesh)

    @staticmethod
    def default(multi_pod: bool, *, pods: int = 2, data: int = 16,
                model: int = 16) -> "AxisRules":
        batch_axes = ("pod", "data") if multi_pod else ("data",)
        shape = {"data": data, "model": model}
        if multi_pod:
            shape["pod"] = pods
        return AxisRules(
            rules={
                "batch": batch_axes,
                "model": ("model",),
                "data": ("data",),
                "replicated": (),
            },
            mesh_shape=shape,
        )

    def axes(self, logical: str) -> tuple[str, ...]:
        return self.rules.get(logical, ())

    def size(self, logical: str) -> int:
        n = 1
        for ax in self.axes(logical):
            n *= self.mesh_shape.get(ax, 1)
        return n


_local = threading.local()


@contextlib.contextmanager
def sharding_rules(rules: Optional[AxisRules]):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def current_rules() -> Optional[AxisRules]:
    return getattr(_local, "rules", None)


def logical_pspec(*logical: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = current_rules()
    if rules is None:
        return P()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
        else:
            axes = rules.axes(name)
            out.append(axes if len(axes) != 1 else axes[0])
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` by logical names; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_pspec(*logical))


# --------------------------------------------------------------------------
# Parameter sharding inference
# --------------------------------------------------------------------------

# Leaf-name hints: substrings of the flattened pytree path.
_SHARD_LAST = ("w_in", "w_gate", "wi", "in_proj", "q_proj", "k_proj",
               "v_proj", "dt_proj", "receptance", "key", "value",
               "gate", "head")
_SHARD_FIRST = ("w_out", "wo", "out_proj", "o_proj", "x_proj", "a_log",
                "output")


def param_pspec(path: str, shape: tuple[int, ...], cfg: ModelConfig) -> P:
    """Infer the TP PartitionSpec of one parameter from its path + shape.

    Exactly one dim is sharded over ``model``:

    * embedding tables: the vocab-sized dim;
    * name-hinted input-side projections (q/k/v, w_in, ...): the last dim;
    * name-hinted output-side projections (o_proj, w_out, ...): dim -2
      (the contraction dim, matching the activations they consume);
    * otherwise: the right-most dim whose size is "wide" (d_ff / vocab /
      q_dim / kv_dim / d_inner) and isn't d_model;
    * 1-D params (norms, biases) and small dims replicate.
    """
    rules = current_rules()
    model_axes = rules.axes("model") if rules else ("model",)
    model_size = rules.size("model") if rules else 1
    spec = [None] * len(shape)
    if len(shape) <= 1:
        return P(*spec)
    lowered = path.lower()

    def mark(dim: int) -> P:
        # in_shardings require exact divisibility (constraints would pad);
        # small or uneven dims replicate instead.  An empty model mapping
        # (pure-DP rules for small models) replicates everything.
        if (not model_axes or shape[dim] < 2 * model_size
                or shape[dim] % model_size):
            return P(*([None] * len(shape)))
        spec[dim] = model_axes if len(model_axes) != 1 else model_axes[0]
        return P(*spec)

    wide_dims = {cfg.d_ff, cfg.vocab_size, cfg.q_dim, cfg.kv_dim,
                 cfg.d_model * cfg.expand, 2 * cfg.d_model * cfg.expand}
    wide_dims.discard(0)
    if "/moe/" in lowered and len(shape) >= 3:
        # ZeRO-3 expert storage: d_ff over `model` (TP) AND d_model over
        # `data` (FSDP).  The layer all-gathers its experts over `data`
        # (cheap — experts are f-sliced) and the autodiff transpose
        # reduce-scatters the weight grads, so no param-shaped tensor is
        # ever replicated (w_gate grads measured 3.8 GB ×L replicated).
        data_axes = rules.axes("data") if rules else ("data",)
        data_size = rules.size("data") if rules else 1
        dspec = data_axes if len(data_axes) != 1 else data_axes[0]
        p = [None] * len(shape)
        f_dim = len(shape) - 1 if shape[-1] == cfg.d_ff else len(shape) - 2
        d_dim = len(shape) - 1 if shape[-1] == cfg.d_model else len(shape) - 2
        if shape[f_dim] == cfg.d_ff and shape[f_dim] % model_size == 0:
            p[f_dim] = model_axes if len(model_axes) != 1 else model_axes[0]
        if (d_dim != f_dim and shape[d_dim] == cfg.d_model
                and shape[d_dim] % max(data_size, 1) == 0 and data_size > 1):
            p[d_dim] = dspec
        return P(*p)
    if "embed" in lowered:
        pv = -(-cfg.vocab_size // 256) * 256  # padded vocab (transformer.py)
        for i, d in enumerate(shape):
            if d in (cfg.vocab_size, pv):
                return mark(i)
        return P(*spec)
    if any(h in lowered for h in _SHARD_FIRST):
        return mark(len(shape) - 2)
    if any(h in lowered for h in _SHARD_LAST):
        return mark(len(shape) - 1)
    for i in range(len(shape) - 1, -1, -1):
        if shape[i] in wide_dims and shape[i] != cfg.d_model:
            return mark(i)
    return P(*spec)


def zero1_pspec(pspec: P, shape: tuple[int, ...], rules: AxisRules) -> P:
    """ZeRO-1: additionally shard the largest un-sharded dim over ``data``.

    Applied to optimizer state (fp32 master/moments) only; falls back to the
    TP spec when no dim is cleanly divisible.
    """
    data_axes = rules.axes("data")
    if not data_axes:
        return pspec
    data_size = rules.size("data")
    if data_size <= 1:
        return pspec
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    used = set()
    for e in entries:
        for ax in (e if isinstance(e, tuple) else (e,)):
            used.add(ax)
    if any(ax in used for ax in data_axes):
        return pspec  # already data-sharded (ZeRO-3 expert storage)
    best, best_dim = None, 0
    for i, (e, d) in enumerate(zip(entries, shape)):
        if e is None and d % data_size == 0 and d > best_dim:
            best, best_dim = i, d
    if best is None:
        return pspec
    entries[best] = data_axes if len(data_axes) != 1 else data_axes[0]
    return P(*entries)


def batch_pspec(rules: AxisRules, global_batch: int) -> tuple[Optional[object], ...]:
    """Mesh axes used for the batch dim — as many of (pod, data) as divide."""
    axes = [ax for ax in rules.axes("batch")]
    n = 1
    used = []
    for ax in axes:
        sz = rules.mesh_shape.get(ax, 1)
        if global_batch % (n * sz) == 0:
            used.append(ax)
            n *= sz
    return tuple(used) if used else ()


def cache_pspec(rules: AxisRules, global_batch: int) -> tuple:
    """(batch_axes, seq_axes) for KV caches — SP over leftover axes.

    Decode with large batch: batch over (pod, data), cache sequence over
    model.  Tiny batch (long-context): sequence over every unused axis.
    """
    batch_axes = batch_pspec(rules, global_batch)
    all_axes = ["pod", "data", "model"] if "pod" in rules.mesh_shape else ["data", "model"]
    seq_axes = tuple(ax for ax in all_axes if ax not in batch_axes)
    return batch_axes, seq_axes
