"""Model conversion: spatial-domain checkpoints → JPEG-domain networks (§4.6).

Because ``repro.core.resnet`` evaluates both domains from one parameter
pytree, conversion is the identity on parameters plus a *verification*
contract: at φ = 14 (exact ReLU) the two networks must agree to float
error (paper Table 1).  ``convert_and_verify`` enforces that contract and
returns the precomputed-operator bundle for fast inference.

For models trained elsewhere, ``from_torch_layout`` maps common layouts
(OIHW conv kernels, BN (γ, β, μ, σ²)) into our pytree.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import asm as asmlib
from repro.core import jpeg as jpeglib
from repro.core import resnet as resnetlib

__all__ = ["ConvertedModel", "convert", "convert_and_verify", "from_torch_layout"]


class ConvertedModel(NamedTuple):
    """``operators`` mirrors ``plan.operators`` when a plan is carried —
    i.e. BN-*fused*; feeding them to ``jpeg_apply_precomputed`` raises
    (it would apply batch norm twice).  Convert with ``fuse_bn=False``
    for unfused per-step-batchnorm operators."""

    params: Any
    state: Any
    operators: Any
    spec: resnetlib.ResNetSpec
    phi: int
    dispatch: Any = None  # DispatchConfig resolved at convert time
    plan: Any = None      # InferencePlan (fused BN) when converted with one

    def __call__(self, coef: jnp.ndarray) -> jnp.ndarray:
        if self.plan is not None:
            from repro.core import plan as planlib

            return planlib.apply_plan(self.plan, coef)
        return resnetlib.jpeg_apply_precomputed(
            self.params, self.state, self.operators, coef,
            spec=self.spec, phi=self.phi, dispatch=self.dispatch,
        )


def convert(params, state, spec: resnetlib.ResNetSpec,
            phi: int = asmlib.EXACT_PHI,
            dispatch=None, *, fuse_bn: bool = True, bands=None,
            probe_coef=None) -> ConvertedModel:
    """Convert a (trained) spatial model for JPEG-domain inference.

    ``dispatch``: a ``core.dispatch.DispatchConfig`` resolving the apply
    path and band truncation of every precomputed operator (None = the
    global config *frozen here*, so later env/config changes cannot skew
    an already-converted model's ASM/batchnorm away from its operators).

    By default the result carries an :class:`repro.core.plan.InferencePlan`
    — inference-mode batch norm fused into the operators at convert time —
    and ``__call__`` serves from it.  ``fuse_bn=False`` keeps the PR-1
    behaviour (unfused operators, per-step batch norm).  ``bands`` is
    forwarded to :func:`repro.core.plan.build_plan` (``"auto"`` autotunes
    per layer from the quantization table; ``probe_coef`` enables the
    parity sweep).
    """
    from repro.core import dispatch as dispatchlib
    from repro.core import plan as planlib

    cfg = dispatchlib.resolve_config(dispatch)
    if not fuse_bn:
        ops = resnetlib.precompute_operators(params, spec, dispatch=cfg)
        return ConvertedModel(params, state, ops, spec, phi, cfg)
    plan = planlib.build_plan(params, state, spec, phi=phi, dispatch=cfg,
                              bands=bands, probe_coef=probe_coef)
    return ConvertedModel(params, state, plan.operators, spec, phi, cfg, plan)


def convert_and_verify(
    params, state, spec: resnetlib.ResNetSpec, sample_images: jnp.ndarray,
    phi: int = asmlib.EXACT_PHI, atol: float = 1e-4,
) -> tuple[ConvertedModel, float]:
    """Convert + assert spatial/JPEG logit agreement on sample images.

    ``sample_images``: (N, C, H, W) pixels.  Returns (model, max_abs_dev).
    At φ = 14 the deviation is float-accumulation only (paper Table 1:
    ~1e-6 in accuracy).
    """
    model = convert(params, state, spec, phi)
    logits_sp, _ = resnetlib.spatial_apply(
        params, state, sample_images, training=False, spec=spec
    )
    coef = jpeglib.jpeg_encode(sample_images, quality=spec.quality, scaled=True)
    coef = jnp.moveaxis(coef, 1, 3)  # (N, bh, bw, C, 64)
    logits_jp = model(coef)
    dev = float(jnp.max(jnp.abs(logits_sp - logits_jp)))
    if phi >= asmlib.EXACT_PHI and dev > atol:
        raise ValueError(
            f"conversion verification failed: max logit deviation {dev} > {atol}"
        )
    return model, dev


def from_torch_layout(tensors: dict[str, Any], spec: resnetlib.ResNetSpec):
    """Map a {name: array} dict in torch ResNet layout onto our pytree.

    Expected names per block: ``<pre>.conv1.weight`` (OIHW), ``<pre>.bn1.
    {weight,bias,running_mean,running_var}``, etc.  Purely a relayout —
    no numerics.
    """
    params: dict[str, Any] = {}
    state: dict[str, Any] = {}

    def grab_bn(src: str, dst: str):
        params[dst] = {
            "gamma": jnp.asarray(tensors[f"{src}.weight"]),
            "beta": jnp.asarray(tensors[f"{src}.bias"]),
        }
        state[dst] = {
            "mean": jnp.asarray(tensors[f"{src}.running_mean"]),
            "var": jnp.asarray(tensors[f"{src}.running_var"]),
        }

    params["stem"] = {"kernel": jnp.asarray(tensors["stem.weight"])}
    grab_bn("stem_bn", "stem_bn")
    for name, s, cin, w in resnetlib._stages(spec):
        entry = {
            "conv1": jnp.asarray(tensors[f"{name}.conv1.weight"]),
            "conv2": jnp.asarray(tensors[f"{name}.conv2.weight"]),
        }
        if f"{name}.proj.weight" in tensors:
            entry["proj"] = jnp.asarray(tensors[f"{name}.proj.weight"])
        params[name] = entry
        grab_bn(f"{name}.bn1", f"{name}_bn1")
        grab_bn(f"{name}.bn2", f"{name}_bn2")
    params["head"] = {
        "w": jnp.asarray(tensors["head.weight"]).T,
        "b": jnp.asarray(tensors["head.bias"]),
    }
    return params, state
