"""Residual networks in the spatial and JPEG transform domains (paper §4).

One parameter pytree drives *two* mathematically-equivalent apply functions:

* :func:`spatial_apply` — ordinary NCHW ResNet (the oracle / source model);
* :func:`jpeg_apply` — the same network evaluated entirely on JPEG
  coefficients: exploded convolutions (§4.1), ASM ReLU (§4.2), coefficient
  batch-norm (§4.3), free residual adds (§4.4), DC-read global pooling
  (§4.5).

Model conversion (§4.6) is therefore *structural*: a spatial checkpoint is a
JPEG checkpoint.  ``precompute_operators`` bakes the exploded Ξ operators
for inference so each step is matmuls only (the paper's "the map can be
precomputed to speed up inference").

Architecture (paper Fig. 3, generalised): a stem conv, then ``len(widths)``
stages of ``blocks_per_stage`` basic residual blocks; every stage after the
first downsamples by 2 so a 32×32 input with 3 stages ends at a single JPEG
block; global average pool; linear classifier.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import asm as asmlib
from repro.core import batchnorm as bnlib
from repro.core import conv as convlib
from repro.core import dispatch as dispatchlib
from repro.core import jpeg as jpeglib
from repro.core import pooling as poollib
from repro.parallel.sharding import shard

__all__ = [
    "ResNetSpec",
    "init_resnet",
    "spatial_apply",
    "jpeg_apply",
    "precompute_operators",
    "jpeg_apply_precomputed",
    "compile_for_inference",
]


class ResNetSpec(NamedTuple):
    in_channels: int = 3
    widths: tuple[int, ...] = (16, 32, 64)
    blocks_per_stage: int = 1
    num_classes: int = 10
    quality: int = 50  # quantization table the input coefficients use
    phi: int = asmlib.EXACT_PHI  # ASM ReLU spatial frequencies


def _conv_init(key, cout, cin, r, dtype):
    fan_in = cin * r * r
    return jax.random.normal(key, (cout, cin, r, r), dtype) * np.sqrt(2.0 / fan_in)


def init_resnet(key: jax.Array, spec: ResNetSpec, dtype=jnp.float32):
    """Returns ``(params, state)`` pytrees shared by both domains."""
    keys = iter(jax.random.split(key, 4 + 4 * len(spec.widths) * spec.blocks_per_stage))
    params: dict[str, Any] = {}
    state: dict[str, Any] = {}

    def bn(name, c):
        p, s = bnlib.init_batchnorm(c, dtype)
        params[name] = {"gamma": p.gamma, "beta": p.beta}
        state[name] = {"mean": s.running_mean, "var": s.running_var}

    params["stem"] = {"kernel": _conv_init(next(keys), spec.widths[0], spec.in_channels, 3, dtype)}
    bn("stem_bn", spec.widths[0])
    cin = spec.widths[0]
    for si, w in enumerate(spec.widths):
        stride = 1 if si == 0 else 2
        for bi in range(spec.blocks_per_stage):
            pre = f"s{si}b{bi}"
            s = stride if bi == 0 else 1
            params[pre] = {
                "conv1": _conv_init(next(keys), w, cin, 3, dtype),
                "conv2": _conv_init(next(keys), w, w, 3, dtype),
            }
            bn(pre + "_bn1", w)
            bn(pre + "_bn2", w)
            if s != 1 or cin != w:
                params[pre]["proj"] = _conv_init(next(keys), w, cin, 1, dtype)
            cin = w
    params["head"] = {
        "w": jax.random.normal(next(keys), (cin, spec.num_classes), dtype)
        * np.sqrt(1.0 / cin),
        "b": jnp.zeros((spec.num_classes,), dtype),
    }
    return params, state


def _stages(spec: ResNetSpec):
    cin = spec.widths[0]
    for si, w in enumerate(spec.widths):
        stride = 1 if si == 0 else 2
        for bi in range(spec.blocks_per_stage):
            s = stride if bi == 0 else 1
            yield f"s{si}b{bi}", s, cin, w
            cin = w


# --------------------------------------------------------------------------
# Spatial-domain apply (oracle)
# --------------------------------------------------------------------------


def spatial_apply(params, state, x, *, training: bool, spec: ResNetSpec):
    """``x``: (N, C, H, W) pixels -> (logits, new_state)."""
    new_state = {}

    def bn(name, h):
        p = bnlib.BatchNormParams(params[name]["gamma"], params[name]["beta"])
        s = bnlib.BatchNormState(state[name]["mean"], state[name]["var"])
        h, s2 = bnlib.batchnorm_spatial(h, p, s, training=training)
        new_state[name] = {"mean": s2.running_mean, "var": s2.running_var}
        return h

    h = convlib.spatial_conv(x, params["stem"]["kernel"], 1)
    h = jax.nn.relu(bn("stem_bn", h))
    for name, s, cin, w in _stages(spec):
        blk = params[name]
        short = h
        if "proj" in blk:
            short = convlib.spatial_conv(h, blk["proj"], s)
        h = convlib.spatial_conv(h, blk["conv1"], s)
        h = jax.nn.relu(bn(name + "_bn1", h))
        h = convlib.spatial_conv(h, blk["conv2"], 1)
        h = bn(name + "_bn2", h)
        h = jax.nn.relu(h + short)
    pooled = poollib.global_avg_pool_spatial(h)
    logits = pooled @ params["head"]["w"] + params["head"]["b"]
    return logits, new_state


# --------------------------------------------------------------------------
# JPEG-domain apply (the paper's network)
# --------------------------------------------------------------------------


def jpeg_apply(params, state, coef, *, training: bool, spec: ResNetSpec,
               phi: int | None = None, remat: bool = False,
               dispatch: dispatchlib.DispatchConfig | None = None):
    """``coef``: (N, bh, bw, C, 64) step-4 JPEG coefficients -> logits.

    Input coefficients are quantization-scaled (true JPEG); the stem conv
    folds de-quantization (Eq. 20 collapsed across the network); all
    internal activations use the orthonormal-DCT convention.

    ``remat``: checkpoint each residual block (recompute the ASM/conv
    intermediates in backward — they are several× the activation size).

    ``dispatch``: per-op backend/band policy (None = the global config,
    see ``core.dispatch``).  Resolved at trace time.
    """
    phi = spec.phi if phi is None else phi
    cfg = dispatchlib.resolve_config(dispatch)
    new_state = {}

    def bn_apply(pdict, sdict, h):
        p = bnlib.BatchNormParams(pdict["gamma"], pdict["beta"])
        s = bnlib.BatchNormState(sdict["mean"], sdict["var"])
        return dispatchlib.batchnorm(h, p, s, training=training, cfg=cfg)

    def bn(name, h):
        h, s2 = bn_apply(params[name], state[name], h)
        new_state[name] = {"mean": s2.running_mean, "var": s2.running_var}
        return h

    def relu(h):
        return dispatchlib.asm_relu(h, phi, cfg=cfg)

    h = dispatchlib.conv(coef, params["stem"]["kernel"], 1,
                         in_scaled=True, quality=spec.quality, cfg=cfg)
    h = relu(bn("stem_bn", h))
    h = shard(h, "batch", None, None, None, None)
    for name, s, cin, w in _stages(spec):

        def block_fn(h, blk, bn1p, bn1s, bn2p, bn2s):
            short = h
            if "proj" in blk:
                short = dispatchlib.conv(h, blk["proj"], s, cfg=cfg)
            h = dispatchlib.conv(h, blk["conv1"], s, cfg=cfg)
            h1, st1 = bn_apply(bn1p, bn1s, h)
            h = relu(h1)
            h = dispatchlib.conv(h, blk["conv2"], 1, cfg=cfg)
            h2, st2 = bn_apply(bn2p, bn2s, h)
            h = relu(poollib.residual_add(h2, short))
            h = shard(h, "batch", None, None, None, None)
            return h, st1, st2

        if remat:
            block_fn = jax.checkpoint(block_fn)
        h, st1, st2 = block_fn(h, params[name], params[name + "_bn1"],
                               state[name + "_bn1"], params[name + "_bn2"],
                               state[name + "_bn2"])
        new_state[name + "_bn1"] = {"mean": st1.running_mean, "var": st1.running_var}
        new_state[name + "_bn2"] = {"mean": st2.running_mean, "var": st2.running_var}
    pooled = poollib.global_avg_pool_jpeg(h)
    logits = pooled @ params["head"]["w"] + params["head"]["b"]
    return logits, new_state


# --------------------------------------------------------------------------
# Precomputed-operator inference (paper §4.1: "can be precomputed")
# --------------------------------------------------------------------------


def precompute_operators(params, spec: ResNetSpec,
                         dispatch: dispatchlib.DispatchConfig | None = None):
    """Explode every convolution once; returns an operator pytree.

    Thin wrapper over :func:`repro.core.plan.build_operators` (the
    convert-once engine) — unfused, so batch norm still runs per step from
    the live ``state``.  Each leaf is a
    :class:`repro.core.dispatch.ConvOperator` whose apply path (reference /
    pallas / factored) and band truncation were resolved at precompute time
    from ``dispatch`` (None = global config).  For fused-BN, per-layer-band
    serving build an :class:`repro.core.plan.InferencePlan` instead.
    """
    from repro.core import plan as planlib

    return planlib.build_operators(params, spec,
                                   dispatchlib.resolve_config(dispatch))


def jpeg_apply_precomputed(params, state, ops, coef, *, spec: ResNetSpec,
                           phi: int | None = None,
                           dispatch: dispatchlib.DispatchConfig | None = None):
    """Inference-only apply using precomputed exploded operators.

    Thin wrapper over :func:`repro.core.plan.apply_operators` — the
    per-step-batchnorm walk, kept as the parity/perf baseline against the
    fused :func:`repro.core.plan.apply_plan`.
    """
    from repro.core import plan as planlib

    return planlib.apply_operators(params, state, ops, coef, spec=spec,
                                   phi=phi, cfg=dispatch)


def compile_for_inference(params, state, spec: ResNetSpec, *,
                          dispatch: dispatchlib.DispatchConfig | None = None,
                          bands=None, probe_coef=None, **compile_kw):
    """One call from trained parameters to the compiled serving schedule:
    ``plan.build_plan`` (fused BN, per-layer bands) followed by
    ``plan.compile_plan`` (fused residual-block megakernels over
    tile-packed operators).  Returns the :class:`repro.core.plan.
    CompiledPlan`; close over it in a jitted lambda to serve."""
    from repro.core import plan as planlib

    plan = planlib.build_plan(params, state, spec, dispatch=dispatch,
                              bands=bands, probe_coef=probe_coef)
    return planlib.compile_plan(plan, **compile_kw)
