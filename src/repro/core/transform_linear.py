"""Generalised transform-domain linear folding (beyond-paper, DESIGN.md §4).

The paper's core precondition is a *fixed invertible linear codec* ``T`` in
front of a *learned linear* layer ``W``: then ``W ∘ T⁻¹`` is one matrix and
the network consumes codec coefficients directly.  This module packages
that insight for non-CNN frontends:

* :func:`fold_patch_embed` — ViT patch embedding over JPEG coefficients:
  a patch-embed projection ``W: (P·P·C) -> d`` becomes a projection from
  the patch's JPEG blocks' coefficients (InternVL2 / any ViT whose patch
  size is a multiple of 8).  Exact — no approximation anywhere.
* :func:`fold_frontend` — generic: fold any fixed linear analysis map
  (mel filterbank, learned PCA, …) into a following linear layer.

Both return plain arrays to be used as drop-in weights.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = ["fold_patch_embed", "unfold_patches_to_blocks", "fold_frontend"]


def fold_frontend(analysis: jnp.ndarray, weight: jnp.ndarray) -> jnp.ndarray:
    """Fold ``y = W @ (A⁻¹ c)`` into one matrix given orthonormal ``A``.

    ``analysis``: (n, n) orthonormal analysis map (rows = basis functions),
    ``weight``: (n, d) layer weight acting on raw samples.  Returns the
    (n, d) weight acting on coefficients: ``Aᵀ⁻¹ = A`` for orthonormal maps,
    so the folded weight is ``A @ weight``.
    """
    return analysis @ weight


def fold_patch_embed(
    weight: jnp.ndarray, patch: int, channels: int, *,
    quality: int = 50, scaled: bool = True,
) -> jnp.ndarray:
    """Fold JPEG decoding into a ViT patch-embed projection.

    ``weight``: (patch*patch*channels, d) acting on row-major (C, P, P)
    pixel patches.  ``patch`` must be a multiple of 8.  Returns a weight of
    the same shape acting on the patch's JPEG coefficients laid out as
    (C, P//8, P//8, 64) — exactly what ``jpeg_encode`` emits per patch.

    The fold is ``W_jpeg[k, :] = Σ_p  J̃[k, p] · W[p, :]`` with the
    block-diagonal J̃; implemented per 8×8 block via the reconstruction
    matrix (plus de-quantization when ``scaled``).
    """
    b = dctlib.BLOCK
    if patch % b:
        raise ValueError("patch size must be a multiple of 8")
    g = patch // b
    d = weight.shape[-1]
    rec = dctlib.reconstruction_matrix()  # (64 coef, 64 pixel)
    if scaled:
        rec = dctlib.quantization_table(quality)[:, None] * rec
    rec = jnp.asarray(rec, weight.dtype)
    # (C, P, P, d) -> blocks (C, g, g, 64pix, d) -> coefficients
    w = weight.reshape(channels, g, b, g, b, d)
    w = jnp.moveaxis(w, 2, 3).reshape(channels, g, g, b * b, d)
    w = jnp.einsum("kp,cxypd->cxykd", rec, w)
    return w.reshape(channels * g * g * b * b, d)


def unfold_patches_to_blocks(images: jnp.ndarray, patch: int) -> jnp.ndarray:
    """``(N, C, H, W) -> (N, n_patches, C*P*P)`` row-major patches (oracle)."""
    n, c, h, w = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(n, c, gh, patch, gw, patch)
    x = jnp.moveaxis(x, 4, 3)  # (n, c, gh, gw, P, P)
    x = jnp.moveaxis(x, 1, 3)  # (n, gh, gw, c, P, P)
    return x.reshape(n, gh * gw, c * patch * patch)
