"""JPEG-domain batch normalization (paper §4.3 / Algorithm 3).

Operates on coefficient activations ``(N, bh, bw, C, 64)`` in the
orthonormal-DCT convention, where for each block:

* ``coef[..., 0] = 8 * block_mean``  (DC gain of the orthonormal 8×8 DCT);
* ``mean_k(coef[..., k]^2) = E[x^2]`` over the block's 64 pixels
  (Parseval / the paper's DCT mean–variance theorem).

So the per-channel spatial statistics are coefficient reductions:

    E[x]   = mean over (N, bh, bw) of coef[..., 0] / 8
    E[x^2] = mean over (N, bh, bw) of mean_k coef[..., k]^2
    Var    = E[x^2] - E[x]^2

Centering subtracts ``8·μ`` from the DC coefficient only; scaling is plain
scalar multiplication (linearity); the shift β adds ``8·β`` to DC.  In the
JPEG-scaled convention with q₀ = 8 the DC gain is 1 (paper's convention) —
pass ``dc_gain=1.0``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = ["BatchNormParams", "BatchNormState", "init_batchnorm", "batchnorm_jpeg",
           "batchnorm_spatial", "fold_batchnorm"]

DC_GAIN = float(dctlib.BLOCK)  # orthonormal DC coefficient = 8 * mean


class BatchNormParams(NamedTuple):
    gamma: jnp.ndarray  # (C,)
    beta: jnp.ndarray  # (C,)


class BatchNormState(NamedTuple):
    running_mean: jnp.ndarray  # (C,)
    running_var: jnp.ndarray  # (C,)


def init_batchnorm(channels: int, dtype=jnp.float32):
    params = BatchNormParams(jnp.ones((channels,), dtype), jnp.zeros((channels,), dtype))
    state = BatchNormState(jnp.zeros((channels,), dtype), jnp.ones((channels,), dtype))
    return params, state


def batchnorm_jpeg(
    coef: jnp.ndarray,
    params: BatchNormParams,
    state: BatchNormState,
    *,
    training: bool,
    momentum: float = 0.1,
    eps: float = 1e-5,
    dc_gain: float = DC_GAIN,
) -> tuple[jnp.ndarray, BatchNormState]:
    """Batch norm over ``(N, bh, bw, C, 64)`` coefficients (Algorithm 3)."""
    if training:
        dc = coef[..., 0] / dc_gain  # per-block means, (N, bh, bw, C)
        mu = jnp.mean(dc, axis=(0, 1, 2))  # E[x] per channel
        # mean_k coef^2 over 64 coefficients == E[x^2] per block (orthonormal
        # basis / the DCT mean-variance theorem, paper Thm. 2).
        second = jnp.mean(jnp.mean(coef * coef, axis=-1), axis=(0, 1, 2))
        var = second - mu * mu
        new_state = BatchNormState(
            (1 - momentum) * state.running_mean + momentum * mu,
            (1 - momentum) * state.running_var + momentum * var,
        )
    else:
        mu, var = state.running_mean, state.running_var
        new_state = state
    inv = params.gamma / jnp.sqrt(var + eps)
    # (x - mu) * inv + beta  ==  x * inv + (beta - mu * inv), and a scalar
    # add is a DC-coefficient add (times the DC gain).
    shift = (params.beta - mu * inv) * dc_gain
    out = coef * inv[None, None, None, :, None]
    out = out.at[..., 0].add(shift[None, None, None, :])
    return out, new_state


def fold_batchnorm(
    params: BatchNormParams,
    state: BatchNormState,
    *,
    eps: float = 1e-5,
    dc_gain: float = DC_GAIN,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inference-mode batch norm as a per-channel affine ``(scale, shift)``.

    Because inference BN is linear — ``y = x·inv + (β − μ·inv)`` with
    ``inv = γ/√(σ²+ε)`` — it commutes with the JPEG-domain layout: the
    scale multiplies every coefficient and the constant shifts only DC (by
    ``dc_gain·(β − μ·inv)``).  Both fold into the *preceding* conv's Ξ at
    precompute time (scale into the output-channel rows, shift as a DC-bias
    term carried on the operator), deleting the per-step batchnorm from the
    precomputed path entirely.  Returns ``(scale (C,), dc_shift (C,))``.
    """
    inv = params.gamma / jnp.sqrt(state.running_var + eps)
    shift = (params.beta - state.running_mean * inv) * dc_gain
    return inv, shift


def batchnorm_spatial(
    x: jnp.ndarray,
    params: BatchNormParams,
    state: BatchNormState,
    *,
    training: bool,
    momentum: float = 0.1,
    eps: float = 1e-5,
) -> tuple[jnp.ndarray, BatchNormState]:
    """Spatial-domain batch norm over ``(N, C, H, W)`` — the oracle twin."""
    if training:
        mu = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.mean(x * x, axis=(0, 2, 3)) - mu * mu
        new_state = BatchNormState(
            (1 - momentum) * state.running_mean + momentum * mu,
            (1 - momentum) * state.running_var + momentum * var,
        )
    else:
        mu, var = state.running_mean, state.running_var
        new_state = state
    inv = params.gamma / jnp.sqrt(var + eps)
    out = (x - mu[None, :, None, None]) * inv[None, :, None, None]
    out = out + params.beta[None, :, None, None]
    return out, new_state
