"""Discrete Cosine Transform primitives for the JPEG transform domain.

Everything in this module is a *constant builder*: functions return numpy
arrays that are closed over by jitted code (they become XLA constants).

Conventions
-----------
* Block size is 8 (JPEG standard); a block of pixels is ``(8, 8)``.
* ``dct_matrix()`` returns the orthonormal DCT-II matrix ``D`` with
  ``D @ D.T == I``.  The 2-D DCT of a block ``X`` is ``D @ X @ D.T``; the
  inverse is ``D.T @ F @ D``.
* Zigzag order follows the JPEG standard (ISO/IEC 10918-1 Figure 5).
* "Spatial frequency" φ of coefficient ``(α, β)`` is the diagonal band
  ``α + β`` — the paper's Theorem 1 ordering.  There are 15 bands
  (0..14) for an 8×8 block; φ = 14 (all bands) is exact.
"""
from __future__ import annotations

import functools

import numpy as np

BLOCK = 8
NFREQ = BLOCK * BLOCK  # 64 coefficients per block
NBANDS = 2 * BLOCK - 1  # 15 diagonal frequency bands

__all__ = [
    "BLOCK",
    "NFREQ",
    "NBANDS",
    "dct_matrix",
    "dct2",
    "idct2",
    "zigzag_order",
    "zigzag_permutation",
    "band_of_zigzag",
    "band_mask",
    "reconstruction_matrix",
    "truncated_reconstruction_matrix",
    "harmonic_mixing_tensor",
    "quantization_table",
    "quality_scale_table",
]


@functools.lru_cache(maxsize=None)
def dct_matrix(n: int = BLOCK) -> np.ndarray:
    """Orthonormal DCT-II matrix of size ``(n, n)``: ``Y = D @ X``.

    ``D[a, m] = V(a) * cos((2m + 1) a pi / (2n))`` with
    ``V(0) = sqrt(1/n)``, ``V(a>0) = sqrt(2/n)`` — matches the paper's
    Eq. (5) normalisation (so that ``D @ D.T = I``).
    """
    a = np.arange(n)[:, None]
    m = np.arange(n)[None, :]
    d = np.cos((2 * m + 1) * a * np.pi / (2 * n))
    d *= np.sqrt(2.0 / n)
    d[0] *= np.sqrt(0.5)
    return d.astype(np.float64)


def dct2(block: np.ndarray) -> np.ndarray:
    """2-D orthonormal DCT of trailing two (8, 8) axes (numpy reference)."""
    d = dct_matrix()
    return np.einsum("am,...mn,bn->...ab", d, block, d)


def idct2(coef: np.ndarray) -> np.ndarray:
    """Inverse 2-D orthonormal DCT of trailing two (8, 8) axes."""
    d = dct_matrix()
    return np.einsum("am,...ab,bn->...mn", d, coef, d)


@functools.lru_cache(maxsize=None)
def zigzag_order(n: int = BLOCK) -> np.ndarray:
    """``(n*n, 2)`` array: zigzag index -> (row α, col β).

    Standard JPEG zigzag: walk anti-diagonals, alternating direction.
    """
    out = []
    for band in range(2 * n - 1):
        coords = [(a, band - a) for a in range(n) if 0 <= band - a < n]
        # Even bands run bottom-left -> top-right (decreasing row);
        # odd bands run top-right -> bottom-left (increasing row).
        coords.sort(key=lambda rc: rc[0], reverse=(band % 2 == 0))
        out.extend(coords)
    return np.array(out, dtype=np.int32)


@functools.lru_cache(maxsize=None)
def zigzag_permutation(n: int = BLOCK) -> np.ndarray:
    """``(n*n,)`` flat permutation: ``flat_coef[zz[k]] == zigzag_coef[k]``."""
    order = zigzag_order(n)
    return (order[:, 0] * n + order[:, 1]).astype(np.int32)


@functools.lru_cache(maxsize=None)
def band_of_zigzag(n: int = BLOCK) -> np.ndarray:
    """``(n*n,)``: diagonal frequency band (α+β) of each zigzag coefficient."""
    order = zigzag_order(n)
    return (order[:, 0] + order[:, 1]).astype(np.int32)


def band_mask(phi: int, n: int = BLOCK) -> np.ndarray:
    """Boolean ``(n*n,)`` mask of zigzag coefficients with band <= phi.

    ``phi`` counts *spatial frequencies* as in the paper: using
    ``phi = k`` keeps bands ``0..k``.  ``phi >= 2n-2`` keeps everything.
    """
    return band_of_zigzag(n) <= phi


@functools.lru_cache(maxsize=None)
def reconstruction_matrix(n: int = BLOCK) -> np.ndarray:
    """``R`` of shape ``(n*n, n*n)``: zigzag coefficients -> flat pixels.

    ``pixels.flat[p] = sum_k coef_zz[k] * R[k, p]``.  Orthonormal:
    ``R @ R.T == I``, and the forward DCT (pixels -> zigzag coefficients)
    is ``R.T``.
    """
    d = dct_matrix(n)
    # full[a, b, m, n] = contribution of coefficient (a, b) to pixel (m, n)
    full = np.einsum("am,bn->abmn", d, d).reshape(n * n, n * n)
    return full[zigzag_permutation(n)].astype(np.float64)


def truncated_reconstruction_matrix(phi: int, n: int = BLOCK) -> np.ndarray:
    """Reconstruction matrix using only bands <= phi (rows zeroed above phi).

    This is the paper's least-squares-optimal approximation operator
    (Theorem 1): ``approx.flat = coef_zz @ R_phi``.
    """
    r = reconstruction_matrix(n).copy()
    r[~band_mask(phi, n)] = 0.0
    return r


@functools.lru_cache(maxsize=None)
def harmonic_mixing_tensor(n: int = BLOCK) -> np.ndarray:
    """The paper's harmonic mixing tensor H (Eq. 17), zigzag indexed.

    Shape ``(n*n [k], n*n [pixel p], n*n [k'])`` with
    ``H[k, p, k'] = R[k, p] * R[k', p]`` so that masking a block is

        ``F'[k'] = sum_{k,p} F[k] * H[k, p, k'] * M[p]``

    which equals ``DCT(IDCT(F) * M)`` exactly.
    """
    r = reconstruction_matrix(n)
    return np.einsum("kp,lp->kpl", r, r)


# --------------------------------------------------------------------------
# Quantization tables
# --------------------------------------------------------------------------

# ISO/IEC 10918-1 Annex K.1 luminance table (quality 50), row-major.
_IJG_LUMA = np.array(
    [
        [16, 11, 10, 16, 24, 40, 51, 61],
        [12, 12, 14, 19, 26, 58, 60, 55],
        [14, 13, 16, 24, 40, 57, 69, 56],
        [14, 17, 22, 29, 51, 87, 80, 62],
        [18, 22, 37, 56, 68, 109, 103, 77],
        [24, 35, 55, 64, 81, 104, 113, 92],
        [49, 64, 78, 87, 103, 121, 120, 101],
        [72, 92, 95, 98, 112, 100, 103, 99],
    ],
    dtype=np.float64,
)


def quality_scale_table(quality: int, table: np.ndarray) -> np.ndarray:
    """IJG quality scaling of a base table (quality in [1, 100])."""
    quality = int(np.clip(quality, 1, 100))
    if quality < 50:
        scale = 5000.0 / quality
    else:
        scale = 200.0 - 2.0 * quality
    q = np.floor((table * scale + 50.0) / 100.0)
    return np.clip(q, 1.0, 255.0)


def quantization_table(
    quality: int = 50, *, dc_is_mean: bool = True, n: int = BLOCK
) -> np.ndarray:
    """Zigzag-ordered quantization vector ``q`` of shape ``(n*n,)``.

    With ``dc_is_mean`` the DC entry is forced to 8 so that the quantized
    DC coefficient stores *exactly* the block mean (paper §4.3: orthonormal
    DC gain is ``1/8 * sum = 8 * mean``; dividing by 8 leaves the mean).
    """
    q = quality_scale_table(quality, _IJG_LUMA)
    if dc_is_mean:
        q = q.copy()
        q[0, 0] = 8.0
    return q.reshape(-1)[zigzag_permutation(n)].astype(np.float64)
