"""Convert-once inference engine: the ``InferencePlan`` artifact.

The paper's deployment story (§4.1 "the map can be precomputed to speed up
inference", §6 sparsity) lands here as a single object.  Building a plan:

* **fuses inference-mode batch norm** into the adjacent conv's Ξ operator
  (``core.batchnorm.fold_batchnorm``): the scale multiplies Ξ's
  output-channel rows at precompute time and the β/μ constant rides on the
  operator as a DC shift — the per-step ``dispatch.batchnorm`` calls
  disappear from the precomputed path entirely;
* **autotunes ``bands`` per layer**: the quantization table already crushed
  high-frequency energy, so an energy budget over ``1/q²`` picks each
  layer's truncation (``bands_for_budget``), optionally refined by a parity
  sweep against the reference full-band path (``autotune_bands``).  The
  global ``DispatchConfig.bands`` knob remains as an override;
* is **serializable** through ``checkpoint.manager.CheckpointManager``
  (``save_plan``/``load_plan``): numeric leaves go into the checksummed
  array store, static structure into the manifest ``extra`` JSON, so a
  serving process restores the plan and never re-explodes at trace time.

``resnet.precompute_operators`` / ``resnet.jpeg_apply_precomputed`` are
thin wrappers over :func:`build_operators` / :func:`apply_operators` (the
unfused, per-step-batchnorm walk kept for training-state parity checks and
as the perf baseline); :func:`build_plan` / :func:`apply_plan` are the
serving path.

A plan can additionally be **compiled** (:func:`compile_plan`): the
per-layer dispatch walk is lowered into a static schedule whose steps are
fused residual-block megakernels (``kernels.fused_block``) over
**tile-packed** banded operators (``kernels.tiling``) — band-truncated Ξ
slices padded to sublane-aligned per-channel widths and concatenated into
one contiguous buffer per layer at compile time, batch-norm DC shifts
baked into broadcast rows, ASM matrices packed to the same widths.  The
compiled runtime path (:func:`apply_compiled`) therefore does zero band
slicing/padding between ops: activations stay at their packed widths from
the stem to the classifier head, and each residual block is one fused step
(conv → ASM → conv → residual add → ASM with no HBM round trips between
them on the Pallas path).  Blocks whose operators are not materialised or
whose VMEM estimate exceeds the budget fall back to the per-layer walk —
recorded per block in ``CompiledPlan.meta``.  Compiled schedules serialize
through the same ``CheckpointManager`` (:func:`save_compiled_plan` /
:func:`load_compiled_plan`) with bit-identical restored logits.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import batchnorm as bnlib
from repro.core import dct as dctlib
from repro.core import dispatch as dispatchlib
from repro.core import pooling as poollib
from repro.core import resnet as resnetlib
from repro.parallel.sharding import shard

__all__ = [
    "InferencePlan",
    "qtable_band_energy",
    "bands_for_budget",
    "bands_for_profile",
    "autotune_bands",
    "operator_keys",
    "build_operators",
    "apply_operators",
    "build_plan",
    "apply_plan",
    "save_plan",
    "load_plan",
    "CompiledStem",
    "CompiledBlock",
    "CompiledPlan",
    "compile_plan",
    "apply_compiled",
    "apply_compiled_packed",
    "capture_compiled",
    "save_compiled_plan",
    "load_compiled_plan",
]

#: candidate band counts the autotuner moves along (multiples of 8 keep the
#: coefficient axis lane-aligned for the Pallas kernels).
BAND_LADDER = (8, 16, 24, 32, 40, 48, 56, 64)


# --------------------------------------------------------------------------
# Per-layer band autotuning (ROADMAP "Band autotuning")
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def qtable_band_energy(quality: int = 50) -> np.ndarray:
    """Cumulative retained-energy fraction per zigzag prefix length.

    The quantization table divides coefficient ``k`` by ``q[k]``; for a
    flat spectral prior the signal energy surviving quantization scales as
    ``1/q[k]²`` — exactly the "high-frequency energy the qtable already
    crushes".  ``out[b-1]`` is the fraction of that retained energy covered
    by keeping the first ``b`` zigzag coefficients; it is non-decreasing.
    """
    q = dctlib.quantization_table(quality)
    w = 1.0 / (q * q)
    return np.cumsum(w) / np.sum(w)


def _bands_from_cum(cum: np.ndarray, budget: float) -> int:
    if not 0.0 < budget <= 1.0:
        raise ValueError(f"budget must be in (0, 1], got {budget}")
    b = int(np.searchsorted(cum, budget - 1e-12) + 1)
    return min(dctlib.NFREQ, ((b + 7) // 8) * 8)


def bands_for_budget(quality: int, budget: float) -> int:
    """Smallest band count whose cumulative qtable energy ≥ ``budget``.

    Rounded up to a multiple of 8 (lane alignment).  Monotone in
    ``budget``: a tighter (smaller) budget never yields *more* bands.
    """
    return _bands_from_cum(qtable_band_energy(quality), budget)


def _profile_cum(profile: np.ndarray) -> np.ndarray:
    p = np.asarray(profile, np.float64).reshape(dctlib.NFREQ)
    if np.any(p < 0):
        raise ValueError("energy profile must be non-negative")
    total = p.sum()
    if total <= 0:
        raise ValueError("energy profile is all zero")
    return np.cumsum(p) / total


def bands_for_profile(profile: np.ndarray, budget: float) -> int:
    """:func:`bands_for_budget` over an *empirical* per-zigzag energy
    profile (e.g. ``codec.ingest.IngestStats.energy`` measured on real
    traffic) instead of the flat-spectrum ``1/q²`` qtable prior.
    Monotone in ``budget`` for a fixed profile.
    """
    return _bands_from_cum(_profile_cum(profile), budget)


def operator_keys(params: Any, spec: resnetlib.ResNetSpec) -> list[str]:
    """Flat conv-operator keys in forward order: ``stem``, ``s0b0/conv1``…"""
    keys = ["stem"]
    for name, s, cin, w in resnetlib._stages(spec):
        if "proj" in params[name]:
            keys.append(f"{name}/proj")
        keys.append(f"{name}/conv1")
        keys.append(f"{name}/conv2")
    return keys


def autotune_bands(
    params: Any,
    state: Any,
    spec: resnetlib.ResNetSpec,
    *,
    budget: float = 0.95,
    probe_coef: jnp.ndarray | None = None,
    tol: float = 5e-2,
    ladder: tuple[int, ...] = BAND_LADDER,
    phi: int | None = None,
    profile: np.ndarray | None = None,
    occupancy: np.ndarray | None = None,
) -> dict[str, int]:
    """Per-layer band assignment from qtable energy + optional parity sweep.

    Every conv operator starts at :func:`bands_for_budget` (the qtable
    energy heuristic — monotone in ``budget``); with ``profile`` (a
    per-zigzag empirical energy vector, e.g. measured by
    ``codec.ingest``) the start point is :func:`bands_for_profile` over
    the *observed* traffic instead of the flat-spectrum prior.  With
    ``probe_coef`` (a small ``(N, bh, bw, C, 64)`` coefficient batch) the
    assignment is refined against the *reference path at full bands*:

    1. escalate all layers one ladder step while the probe logits disagree
       (top-1) or deviate by more than ``tol`` — the heuristic may be too
       aggressive for a particular network;
    2. one greedy tightening pass, last layer to first: lower each layer
       individually while parity still holds — layers differ in
       sensitivity, which is what makes the result genuinely per-layer.

    When a profile is given, the chosen per-layer bands are logged against
    its energy coverage (and ``occupancy`` — the fraction of nonzero
    input coefficients a cutoff drops — when provided), so silent
    over-truncation is visible in the build output.
    """
    base = (bands_for_profile(profile, budget) if profile is not None
            else bands_for_budget(spec.quality, budget))
    keys = operator_keys(params, spec)
    bands = {k: base for k in keys}
    if probe_coef is None:
        _log_band_choice(bands, keys, profile, occupancy)
        return bands

    # The sweep probes many assignments that differ in a single layer, so
    # operators are exploded once per distinct (layer, band) pair and
    # trial plans are assembled from that cache — not rebuilt per probe.
    phi = spec.phi if phi is None else phi
    ref_cfg = dispatchlib.DispatchConfig(path="reference",
                                         bands=dctlib.NFREQ)
    folds = _fold_all(params, state, spec)
    ops_at: dict[int, dict[str, Any]] = {}

    def ops_for(level: int) -> dict[str, Any]:
        if level not in ops_at:
            ops_at[level] = build_operators(params, spec, ref_cfg,
                                            folds=folds, bands=level)
        return ops_at[level]

    def plan_for(assign: dict[str, int]) -> InferencePlan:
        operators: dict[str, Any] = {"stem": ops_for(assign["stem"])["stem"]}
        for name, s, cin, w in resnetlib._stages(spec):
            entry = {}
            for slot in ops_for(assign[f"{name}/conv1"])[name]:
                entry[slot] = ops_for(assign[f"{name}/{slot}"])[name][slot]
            operators[name] = entry
        return InferencePlan(operators, params["head"]["w"],
                             params["head"]["b"], spec, phi, ref_cfg,
                             dict(assign))

    ref = np.asarray(apply_plan(plan_for({k: dctlib.NFREQ for k in keys}),
                                probe_coef))
    ref_top1 = ref.argmax(-1)

    def parity(assign: dict[str, int]) -> bool:
        got = np.asarray(apply_plan(plan_for(assign), probe_coef))
        return (float(np.abs(got - ref).max()) <= tol
                and bool((got.argmax(-1) == ref_top1).all()))

    def bump(b: int) -> int:
        nxt = [l for l in ladder if l > b]
        return nxt[0] if nxt else dctlib.NFREQ

    while not parity(bands) and any(v < dctlib.NFREQ for v in bands.values()):
        bands = {k: bump(v) for k, v in bands.items()}

    for k in reversed(keys):
        while True:
            lower = [l for l in ladder if l < bands[k]]
            if not lower:
                break
            trial = dict(bands)
            trial[k] = lower[-1]
            if not parity(trial):
                break
            bands = trial
    _log_band_choice(bands, keys, profile, occupancy)
    return bands


def _log_band_choice(bands: dict[str, int], keys: list[str],
                     profile: np.ndarray | None,
                     occupancy: np.ndarray | None) -> None:
    """Make over-truncation visible: per layer, the empirical energy the
    cutoff keeps and the nonzero-coefficient mass it drops."""
    if profile is None:
        return
    cum = _profile_cum(profile)
    occ_total = float(np.sum(occupancy)) if occupancy is not None else 0.0
    for k in keys:
        b = bands[k]
        line = f"[autotune] {k}: bands={b} energy_kept={cum[b - 1]:.4f}"
        if occupancy is not None and occ_total > 0:
            dropped = float(np.sum(occupancy[b:])) / occ_total
            line += f" occupancy_dropped={dropped:.2%}"
        print(line, flush=True)


# --------------------------------------------------------------------------
# Operator construction + the two forward walks
# --------------------------------------------------------------------------


def _resolve_bands(bands: Any, key: str,
                   cfg: dispatchlib.DispatchConfig) -> int:
    if bands is None:
        return cfg.bands
    if isinstance(bands, int):
        return bands
    return int(bands.get(key, cfg.bands))


def build_operators(params: Any, spec: resnetlib.ResNetSpec,
                    cfg: dispatchlib.DispatchConfig, *,
                    folds: dict[str, tuple] | None = None,
                    bands: Any = None) -> dict[str, Any]:
    """Explode every convolution once; returns the operator pytree.

    ``folds`` maps operator keys to ``(scale, shift)`` pairs from
    ``batchnorm.fold_batchnorm`` (fused-BN plans); ``bands`` is None
    (global ``cfg.bands``), an int, or a per-key dict.  Each leaf is a
    :class:`repro.core.dispatch.ConvOperator` with its apply path resolved
    here — apply is a pure table lookup per step.
    """
    folds = folds or {}

    def pc(key, kernel, stride, **kw):
        scale, shift = folds.get(key, (None, None))
        return dispatchlib.precompute_conv(
            kernel, stride, bands=_resolve_bands(bands, key, cfg),
            scale=scale, shift=shift, cfg=cfg, **kw)

    ops: dict[str, Any] = {"stem": pc("stem", params["stem"]["kernel"], 1,
                                      in_scaled=True, quality=spec.quality)}
    for name, s, cin, w in resnetlib._stages(spec):
        blk = params[name]
        entry = {
            "conv1": pc(f"{name}/conv1", blk["conv1"], s),
            "conv2": pc(f"{name}/conv2", blk["conv2"], 1),
        }
        if "proj" in blk:
            entry["proj"] = pc(f"{name}/proj", blk["proj"], s)
        ops[name] = entry
    return ops


def apply_operators(params: Any, state: Any, ops: dict[str, Any],
                    coef: jnp.ndarray, *, spec: resnetlib.ResNetSpec,
                    phi: int | None = None,
                    cfg: dispatchlib.DispatchConfig | None = None
                    ) -> jnp.ndarray:
    """Precomputed-operator inference with *per-step* batch norm.

    The unfused walk — kept as the parity baseline against ``jpeg_apply``
    (it consumes the live ``state``) and as the perf baseline the fused
    :func:`apply_plan` is measured against.  Rejects operators that carry
    a fused batch norm: applying ``state`` on top of them would run BN
    twice and silently corrupt the logits — use :func:`apply_plan`.
    """
    phi = spec.phi if phi is None else phi
    cfg = dispatchlib.resolve_config(cfg)
    stem = ops["stem"]
    if stem.shift is not None or stem.scale is not None:
        raise ValueError(
            "operators carry a fused batch norm (built by build_plan); "
            "applying per-step batch norm on top would run BN twice — "
            "serve them through plan.apply_plan, or build unfused "
            "operators with resnet.precompute_operators")

    def bn(name, h):
        p = bnlib.BatchNormParams(params[name]["gamma"], params[name]["beta"])
        s = bnlib.BatchNormState(state[name]["mean"], state[name]["var"])
        h, _ = dispatchlib.batchnorm(h, p, s, training=False, cfg=cfg)
        return h

    def relu(h):
        return dispatchlib.asm_relu(h, phi, cfg=cfg)

    h = dispatchlib.apply_conv(coef, ops["stem"], cfg=cfg)
    h = relu(bn("stem_bn", h))
    for name, s, cin, w in resnetlib._stages(spec):
        blk, op = params[name], ops[name]
        short = h
        if "proj" in blk:
            short = dispatchlib.apply_conv(h, op["proj"], cfg=cfg)
        h = dispatchlib.apply_conv(h, op["conv1"], cfg=cfg)
        h = relu(bn(name + "_bn1", h))
        h = dispatchlib.apply_conv(h, op["conv2"], cfg=cfg)
        h = bn(name + "_bn2", h)
        h = relu(poollib.residual_add(h, short))
    pooled = poollib.global_avg_pool_jpeg(h)
    return pooled @ params["head"]["w"] + params["head"]["b"]


# --------------------------------------------------------------------------
# The plan artifact
# --------------------------------------------------------------------------


class InferencePlan(NamedTuple):
    """Everything JPEG-domain serving needs, precomputed once.

    ``operators`` carry the fused batch norms (scale folded into Ξ, DC
    shift on the operator) at their per-layer band truncations; batch-norm
    parameters and running statistics are *gone* — only the head weights
    remain as raw parameters.  Closure-only (static metadata is not a
    pytree leaf): close over the plan in a jitted lambda rather than
    passing it as a jit argument.
    """

    operators: dict[str, Any]
    head_w: jnp.ndarray
    head_b: jnp.ndarray
    spec: resnetlib.ResNetSpec
    phi: int
    cfg: dispatchlib.DispatchConfig
    bands: dict[str, int]
    #: how the band assignment was produced ({"bands_mode": "auto" |
    #: "global" | "explicit", ...}) — serving uses it to decide whether a
    #: restored plan satisfies an --autotune-bands request.
    provenance: Any = None

    def __call__(self, coef: jnp.ndarray) -> jnp.ndarray:
        return apply_plan(self, coef)


def build_plan(
    params: Any,
    state: Any,
    spec: resnetlib.ResNetSpec,
    *,
    phi: int | None = None,
    dispatch: dispatchlib.DispatchConfig | None = None,
    bands: Any = None,
    budget: float | None = None,
    probe_coef: jnp.ndarray | None = None,
    profile: np.ndarray | None = None,
    occupancy: np.ndarray | None = None,
    eps: float = 1e-5,
) -> InferencePlan:
    """Fuse, autotune, and explode a trained model into an ``InferencePlan``.

    ``bands``: None → the frozen dispatch config's global knob (the
    override path); an int or per-key dict → explicit assignment; the
    string ``"auto"`` (or a ``budget``) → :func:`autotune_bands` from the
    quantization table — or from an empirical coefficient-energy
    ``profile`` (``codec.ingest`` stats) when given — refined by a parity
    sweep when ``probe_coef`` is given.
    """
    phi = spec.phi if phi is None else phi
    cfg = dispatchlib.resolve_config(dispatch)
    autotuned = bands == "auto" or budget is not None
    if autotuned:
        bands = autotune_bands(params, state, spec,
                               budget=0.95 if budget is None else budget,
                               probe_coef=probe_coef, phi=phi,
                               profile=profile, occupancy=occupancy)
    provenance = {
        "bands_mode": ("auto" if autotuned
                       else "global" if bands is None
                       else "explicit"),
        "budget": budget,
        "probe": probe_coef is not None,
        "energy": ("empirical" if profile is not None else "qtable")
        if autotuned else None,
    }
    folds = _fold_all(params, state, spec, eps=eps)
    ops = build_operators(params, spec, cfg, folds=folds, bands=bands)
    resolved = {k: _resolve_bands(bands, k, cfg)
                for k in operator_keys(params, spec)}
    return InferencePlan(ops, params["head"]["w"], params["head"]["b"],
                         spec, phi, cfg, resolved, provenance)


def _fold_all(params: Any, state: Any, spec: resnetlib.ResNetSpec,
              eps: float = 1e-5) -> dict[str, tuple]:
    """(scale, shift) folds for every batch-normed conv, keyed like
    :func:`operator_keys` (proj convs have no BN and get no entry)."""

    def fold(bn_name):
        p = bnlib.BatchNormParams(params[bn_name]["gamma"],
                                  params[bn_name]["beta"])
        s = bnlib.BatchNormState(state[bn_name]["mean"],
                                 state[bn_name]["var"])
        return bnlib.fold_batchnorm(p, s, eps=eps)

    folds = {"stem": fold("stem_bn")}
    for name, s, cin, w in resnetlib._stages(spec):
        folds[f"{name}/conv1"] = fold(name + "_bn1")
        folds[f"{name}/conv2"] = fold(name + "_bn2")
    return folds


def apply_plan(plan: InferencePlan, coef: jnp.ndarray,
               cfg: dispatchlib.DispatchConfig | None = None) -> jnp.ndarray:
    """Serve from a plan: matmuls + ASM only — no batch norm, no explode.

    Each activation runs ASM at its producing layer's band truncation (the
    residual join runs at the wider of its two contributors, since the
    shortcut may carry coefficients the main branch truncated away).
    """
    cfg = plan.cfg if cfg is None else cfg
    ops = plan.operators

    def relu(h, b):
        return dispatchlib.asm_relu(h, plan.phi, cfg=cfg, bands=b)

    h = dispatchlib.apply_conv(coef, ops["stem"], cfg=cfg)
    cur = ops["stem"].bands
    h = relu(h, cur)
    h = shard(h, "batch", None, None, None, None)
    for name, s, cin, w in resnetlib._stages(plan.spec):
        op = ops[name]
        short, short_bands = h, cur
        if "proj" in op:
            short = dispatchlib.apply_conv(h, op["proj"], cfg=cfg)
            short_bands = op["proj"].bands
        h = dispatchlib.apply_conv(h, op["conv1"], cfg=cfg)
        h = relu(h, op["conv1"].bands)
        h = dispatchlib.apply_conv(h, op["conv2"], cfg=cfg)
        cur = max(op["conv2"].bands, short_bands)
        h = relu(poollib.residual_add(h, short), cur)
        h = shard(h, "batch", None, None, None, None)
    pooled = poollib.global_avg_pool_jpeg(h)
    return pooled @ plan.head_w + plan.head_b


# --------------------------------------------------------------------------
# Compiled plan execution: fused megakernels over tile-packed operators
# --------------------------------------------------------------------------

#: default per-instance VMEM allowance for a fused block (of the ~16 MB/core
#: budget; the rest is headroom for Mosaic's own spills and double buffering).
VMEM_BUDGET = 12 << 20


def _r8(bands: int) -> int:
    """Packed per-channel width for a band count (sublane-aligned)."""
    from repro.kernels import tiling

    return min(dctlib.NFREQ, tiling.round_up(bands, tiling.SUBLANE))


class CompiledStem(NamedTuple):
    """The compiled stem step: one packed conv + ASM (no residual)."""

    kind: str                  # "packed" | "layers"
    conv: Any                  # tiling.PackedConv | None
    asm: Any                   # tiling.PackedAsm | None
    op: Any                    # ConvOperator (fallback walk) | None
    cin: int
    cout: int
    w_in: int                  # zigzag prefix sliced from the raw coefficients
    w_out: int
    bands_out: int             # true band count of the stem activation


class CompiledBlock(NamedTuple):
    """One residual block in the compiled schedule.

    ``kind == "fused"`` executes through ``dispatch.fused_block`` (the
    megakernel / its XLA twin) over packed operators; ``kind == "layers"``
    keeps the per-layer dispatch walk (operators not materialised, or the
    VMEM estimate exceeded the budget — ``CompiledPlan.meta`` records why).
    ``w_in``/``w_out`` are packed per-channel widths; ``bands_in`` /
    ``bands_out`` the true band counts (``bands_out`` is the residual-join
    width: ``max(conv2.bands, shortcut bands)``).
    """

    kind: str
    name: str
    cin: int
    cout: int
    w_in: int
    w_out: int
    bands_in: int
    bands_out: int
    path: str                  # resolved execution path for fused steps
    conv1: Any = None
    asm_mid: Any = None
    conv2: Any = None
    proj: Any = None
    asm_out: Any = None
    ops: Any = None            # ConvOperator dict for the fallback walk
    vmem_bytes: int = 0


class CompiledPlan(NamedTuple):
    """A static schedule of fused steps lowered from an ``InferencePlan``.

    Closure-only, like the plan: close over it in a jitted lambda.  The
    activations between steps live in the packed ``(N, bh, bw, C·w)``
    layout — no 64-wide padding anywhere on the runtime path.
    """

    stem: CompiledStem
    blocks: tuple
    head_w: jnp.ndarray
    head_b: jnp.ndarray
    spec: resnetlib.ResNetSpec
    phi: int
    cfg: dispatchlib.DispatchConfig
    bands: dict[str, int]
    meta: Any = None

    def __call__(self, coef: jnp.ndarray) -> jnp.ndarray:
        return apply_compiled(self, coef)


def compile_plan(plan: InferencePlan, *, vmem_budget: int = VMEM_BUDGET,
                 image_size: int | None = None) -> CompiledPlan:
    """Lower a plan into the fused static schedule.

    Per residual block: pack conv1/conv2 (and the projection shortcut) at
    their own sublane-aligned per-channel band widths; the executors fit
    the activation between stages with elementwise lane slices/pads.
    Blocks whose operators are factored (never materialised Ξ) or — on
    the pallas path — whose VMEM estimate exceeds ``vmem_budget`` stay on
    the per-layer walk.

    ``image_size`` sizes the block grid the VMEM estimate assumes (the
    megakernel holds one image's whole feature map per grid instance);
    None falls back to the paper-canonical ``8·2^(stages-1)`` input that
    ends at a single block.  Pass the real serving resolution when it
    differs — an underestimated grid would admit Mosaic kernels that do
    not fit.
    """
    from repro.kernels import fused_block as fblib
    from repro.kernels import tiling

    spec, phi, cfg = plan.spec, plan.phi, plan.cfg
    path = dispatchlib.choose_path("fused_block", cfg)
    if path not in dispatchlib.available_paths("fused_block"):
        path = "reference"
    meta: dict[str, Any] = {"fused": [], "layers": {}, "vmem": {},
                            "budget": int(vmem_budget), "path": path}

    st = plan.operators["stem"]
    cout0 = st.kernel.shape[0]
    cin0 = st.kernel.shape[1]
    w0 = _r8(st.bands)
    if st.xi is not None:
        stem = CompiledStem(
            "packed",
            tiling.pack_conv(st.xi, st.shift, st.stride, w_in=w0, w_out=w0),
            tiling.pack_asm(phi, st.bands, w0),
            st, cin0, cout0, w0, w0, st.bands)
    else:
        stem = CompiledStem("layers", None, None, st, cin0, cout0,
                            dctlib.NFREQ, w0, st.bands)
        meta["layers"]["stem"] = "factored operator"

    # block grid for the VMEM estimate: one block per 8 px at the stem,
    # halving at each stride-2 stage
    if image_size is None:
        image_size = dctlib.BLOCK * 2 ** (len(spec.widths) - 1)
    bh = max(1, image_size // dctlib.BLOCK)
    cur_b, cur_w = stem.bands_out, stem.w_out
    blocks = []
    for name, s, cin, w in resnetlib._stages(spec):
        entry = plan.operators[name]
        c1, c2 = entry["conv1"], entry["conv2"]
        pr = entry.get("proj")
        short_b = pr.bands if pr is not None else cur_b
        j_true = max(c2.bands, short_b)
        convs = [c1, c2] + ([pr] if pr is not None else [])
        materialized = all(op.xi is not None for op in convs)

        blk = None
        if materialized:
            # every operand at its *own* true (sublane-rounded) band width
            # — the fused executor fits the activation between stages with
            # elementwise lane slices/pads, so a wide residual join never
            # inflates a GEMM dimension.
            w_in = cur_w
            w_j = _r8(j_true)
            w_mid = _r8(c1.bands)
            p1 = tiling.pack_conv(c1.xi, c1.shift, c1.stride,
                                  w_in=_r8(min(c1.bands, cur_b)),
                                  w_out=w_mid)
            a1 = tiling.pack_asm(phi, c1.bands, w_mid)
            p2 = tiling.pack_conv(c2.xi, c2.shift, c2.stride,
                                  w_in=_r8(min(c2.bands, c1.bands)),
                                  w_out=_r8(c2.bands))
            pp = None
            if pr is not None:
                pp = tiling.pack_conv(pr.xi, pr.shift, pr.stride,
                                      w_in=_r8(min(pr.bands, cur_b)),
                                      w_out=_r8(pr.bands))
            a2 = tiling.pack_asm(phi, j_true, w_j)
            vmem = fblib.fused_vmem_bytes(bh, bh, p1, a1, p2, a2, pp)
            meta["vmem"][name] = int(vmem)
            # The budget only gates the Mosaic kernel, whose operands must
            # be VMEM-resident per instance; the XLA reference executor
            # (also the off-TPU serving path) has no such limit.
            if path != "pallas" or vmem <= vmem_budget:
                blk = CompiledBlock("fused", name, cin, w, w_in, w_j,
                                    cur_b, j_true, path, p1, a1, p2, pp, a2,
                                    dict(entry), int(vmem))
                meta["fused"].append(name)
            else:
                meta["layers"][name] = f"vmem {vmem} > budget {vmem_budget}"
        else:
            meta["layers"][name] = "factored operator"
        if blk is None:
            blk = CompiledBlock("layers", name, cin, w, cur_w, _r8(j_true),
                                cur_b, j_true, path, ops=dict(entry))
        blocks.append(blk)
        cur_b, cur_w = blk.bands_out, blk.w_out
        bh = max(1, bh // s)
    return CompiledPlan(stem, tuple(blocks), plan.head_w, plan.head_b,
                        spec, phi, cfg, dict(plan.bands), meta)


def _repack_width(h: jnp.ndarray, c: int, w_to: int) -> jnp.ndarray:
    """Move a packed activation between per-channel widths (block
    boundaries only — the compiler chains widths so this is rare)."""
    from repro.kernels.tiling import fit_width

    return fit_width(h, c, w_to)


def _apply_stem(stem: CompiledStem, coef: jnp.ndarray, phi: int, path: str,
                cfg: dispatchlib.DispatchConfig,
                executor: str | None = None) -> jnp.ndarray:
    from repro.kernels import fused_block as fblib
    from repro.kernels import tiling

    n, bh, bw = coef.shape[:3]
    if stem.kind == "packed":
        if executor == "gemm" or (path == "pallas"
                                  and not dispatchlib._pallas_delegates(cfg)):
            h = coef[..., : stem.w_in].reshape(n, bh, bw,
                                               stem.cin * stem.w_in)
            h = tiling.packed_conv_apply(h, stem.conv)
            return tiling.packed_asm_apply(h, stem.asm)
        return fblib.fused_stem_spatial(coef, stem.op, phi, stem.w_out)
    h = dispatchlib.apply_conv(coef, stem.op, cfg=cfg)
    h = dispatchlib.asm_relu(h, phi, cfg=cfg, bands=stem.bands_out)
    return h[..., : stem.w_out].reshape(n, bh, bw, stem.cout * stem.w_out)


def _apply_layers_block(blk: CompiledBlock, h: jnp.ndarray, phi: int,
                        cfg: dispatchlib.DispatchConfig) -> jnp.ndarray:
    """Per-layer fallback: unpack to the 64-wide layout, run the exact
    ``apply_plan`` block body, repack to the scheduled output width."""
    from repro.core.conv import pad_bands

    n, bh, bw, _ = h.shape
    ops = blk.ops
    s = ops["conv1"].stride
    h64 = pad_bands(h.reshape(n, bh, bw, blk.cin, blk.w_in))
    short, short_b = h64, blk.bands_in
    if "proj" in ops:
        short = dispatchlib.apply_conv(h64, ops["proj"], cfg=cfg)
        short_b = ops["proj"].bands
    x = dispatchlib.apply_conv(h64, ops["conv1"], cfg=cfg)
    x = dispatchlib.asm_relu(x, phi, cfg=cfg, bands=ops["conv1"].bands)
    x = dispatchlib.apply_conv(x, ops["conv2"], cfg=cfg)
    x = poollib.residual_add(x, short)
    x = dispatchlib.asm_relu(x, phi, cfg=cfg,
                             bands=max(ops["conv2"].bands, short_b))
    return x[..., : blk.w_out].reshape(n, bh // s, bw // s,
                                       blk.cout * blk.w_out)


def apply_compiled(cp: CompiledPlan, coef: jnp.ndarray,
                   cfg: dispatchlib.DispatchConfig | None = None, *,
                   executor: str | None = None,
                   profile: "StepProfile | None" = None) -> jnp.ndarray:
    """Execute the compiled schedule: packed stem, then one fused (or
    fallback) step per residual block, then the DC-read head.

    Mathematically identical to :func:`apply_plan` on the source plan
    (coefficients beyond each layer's band cutoff are zero in both
    layouts); differs only in float summation order.

    ``executor=None`` honors each step's compile-time path resolution
    (the Mosaic megakernel on TPU, the spatial-resident XLA lowering
    elsewhere).  ``executor="gemm"`` forces the **transform-domain
    tile-packed GEMM lowering** (``kernels.fused_block.
    fused_block_reference`` — the megakernel's operand-identical XLA
    twin) on every fused step: unlike the spatial lowering, whose conv
    cost is independent of the band budget, its FLOPs scale with the
    packed widths — this is the executor whose latency the §6 band knob
    actually moves, hence what the band-elastic serving ladder runs
    off-TPU.

    ``profile`` (a :class:`StepProfile`) switches to the profiling
    execution mode: the identical schedule runs step by step with
    device synchronization around each step, per-step walls accumulate
    on the profile object, and the returned logits are bit-identical to
    the unprofiled walk (same step closures, same order).
    """
    cfg = cp.cfg if cfg is None else cfg
    if profile is not None:
        return _apply_profiled(cp, coef, cfg, executor, profile,
                               packed=False)
    path = (cp.meta or {}).get("path", "reference")
    h = _apply_stem(cp.stem, coef, cp.phi, path, cfg, executor)
    return _run_blocks(cp, h, cfg, executor)


def apply_compiled_packed(cp: CompiledPlan, packed: jnp.ndarray,
                          cfg: dispatchlib.DispatchConfig | None = None, *,
                          executor: str | None = None,
                          profile: "StepProfile | None" = None
                          ) -> jnp.ndarray:
    """Execute the compiled schedule from a **tile-packed** stem input.

    ``packed`` is ``(N, bh, bw, Cin·w_in)`` with ``w_in =
    CompiledPlan.stem.w_in`` — the layout ``codec.ingest.ingest_batch``
    emits with ``pack_width=cp.stem.w_in``, i.e. band truncation already
    happened at ingest and the 64-wide batch was never materialised.
    Identical logits to :func:`apply_compiled` on the corresponding
    full-width batch: every stem executor reads at most ``w_in ≥
    stem.bands`` zigzag lanes per channel, so the packing drops nothing.

    ``profile`` behaves as on :func:`apply_compiled`.
    """
    cfg = cp.cfg if cfg is None else cfg
    if profile is not None:
        return _apply_profiled(cp, packed, cfg, executor, profile,
                               packed=True)
    path = (cp.meta or {}).get("path", "reference")
    st = cp.stem
    n, bh, bw, k = packed.shape
    if k != st.cin * st.w_in:
        raise ValueError(
            f"packed input has per-channel width {k / st.cin:g}, "
            f"stem expects w_in={st.w_in} (cin={st.cin})")
    if st.kind == "packed" and (
            executor == "gemm"
            or (path == "pallas" and not dispatchlib._pallas_delegates(cfg))):
        from repro.kernels import tiling

        h = tiling.packed_conv_apply(packed, st.conv)
        h = tiling.packed_asm_apply(h, st.asm)
    else:
        # the spatial / per-layer stem executors consume the 64-wide
        # layout; unpacking is an elementwise zero-pad (exact — lanes
        # beyond w_in ≥ stem.bands are dropped by the stem conv anyway)
        from repro.core.conv import pad_bands

        coef = pad_bands(packed.reshape(n, bh, bw, st.cin, st.w_in))
        h = _apply_stem(st, coef, cp.phi, path, cfg, executor)
    return _run_blocks(cp, h, cfg, executor)


def capture_compiled(cp: CompiledPlan, shape, *, packed: bool = False,
                     executor: str | None = None, donate: bool = True,
                     dtype=jnp.float32, on_trace=None):
    """Capture a **static-shape** jitted entry point over the compiled
    schedule, with the input buffer donated to the executable.

    ``shape`` is the full batch shape — ``(N, bh, bw, C, 64)`` for the
    coefficient entry, ``(N, bh, bw, C·w_in)`` with ``packed=True`` for
    the tile-packed stem entry.  The returned callable traces (and
    compiles) exactly once: any call at a different shape raises
    ``ValueError`` at trace time instead of silently retracing, which is
    the invariant the serving plan grid is built on — after warmup the
    set of compiled shapes is closed.

    ``donate=True`` passes the input through ``donate_argnums`` so XLA
    may reuse its device buffer for intermediates (steady-state serving
    allocates nothing per batch beyond the staged input itself).  Both
    :func:`apply_compiled` and :func:`apply_compiled_packed` are safe
    under donation: neither aliases the input into the output, so the
    caller only loses the donated array — pass a fresh copy per call
    (``jnp.array`` of a host staging buffer).

    ``on_trace`` (no-arg callable) fires from inside the traced body —
    i.e. exactly once per compile — giving callers honest compile
    accounting without reaching into jax internals.
    """
    shape = tuple(int(s) for s in shape)
    apply_fn = apply_compiled_packed if packed else apply_compiled

    def fwd(x):
        if tuple(x.shape) != shape:
            raise ValueError(
                f"captured executable is pinned to shape {shape}, "
                f"got {tuple(x.shape)} — route through the grid cell "
                f"for this shape instead of retracing")
        if on_trace is not None:
            on_trace()
        return apply_fn(cp, x, executor=executor)

    fn = jax.jit(fwd, donate_argnums=(0,) if donate else ())

    def call(x):
        if not traced:
            # donation is best-effort: when XLA finds no intermediate to
            # fold into the donated buffer it warns at lowering time —
            # harmless (the array is still consumed), and one line per
            # grid cell would drown the serving log
            import warnings

            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore",
                    message="Some donated buffers were not usable")
                out = fn(jnp.asarray(x, dtype))
            traced.append(True)
            return out
        return fn(jnp.asarray(x, dtype))

    traced: list[bool] = []
    call.captured_shape = shape
    return call


def _make_block_fn(blk: CompiledBlock, w_prev: int, phi: int,
                   cfg: dispatchlib.DispatchConfig,
                   executor: str | None):
    """One schedule step: (optional width repack into the block, then)
    the fused/fallback block body, then the batch-axis shard hint."""
    from repro.kernels import fused_block as fblib

    def fn(h):
        if blk.w_in != w_prev:
            h = _repack_width(h, blk.cin, blk.w_in)
        if blk.kind == "fused":
            if executor == "gemm":
                h = fblib.fused_block_reference(h, blk.conv1, blk.asm_mid,
                                                blk.conv2, blk.asm_out,
                                                blk.proj)
            else:
                h = dispatchlib.fused_block(h, blk, phi, path=blk.path,
                                            cfg=cfg)
        else:
            h = _apply_layers_block(blk, h, phi, cfg)
        return shard(h, "batch", None, None, None)

    return fn


def _make_head_fn(cp: CompiledPlan, w: int):
    def fn(h):
        dc = h[..., 0::w]  # per-channel DC lanes of the packed layout
        pooled = jnp.mean(dc, axis=(1, 2)) / bnlib.DC_GAIN
        return pooled @ cp.head_w + cp.head_b

    return fn


def _block_steps(cp: CompiledPlan, cfg: dispatchlib.DispatchConfig,
                 executor: str | None):
    """The post-stem schedule as an explicit ``(name, fn)`` list: one fn
    per residual block plus the DC-read head.  :func:`_run_blocks` folds
    exactly this list, so a per-step walk (profiling, attribution) runs
    the same traced operations as the whole-schedule execution."""
    steps = []
    cur_w = cp.stem.w_out
    for blk in cp.blocks:
        steps.append((blk.name, _make_block_fn(blk, cur_w, cp.phi, cfg,
                                               executor)))
        cur_w = blk.w_out
    steps.append(("head", _make_head_fn(cp, cur_w)))
    return steps


def _run_blocks(cp: CompiledPlan, h: jnp.ndarray,
                cfg: dispatchlib.DispatchConfig,
                executor: str | None = None) -> jnp.ndarray:
    """Shared post-stem walk: fused/fallback steps, DC-read head."""
    h = shard(h, "batch", None, None, None)
    for _name, fn in _block_steps(cp, cfg, executor):
        h = fn(h)
    return h


def compiled_steps(cp: CompiledPlan,
                   cfg: dispatchlib.DispatchConfig | None = None, *,
                   executor: str | None = None, packed: bool = False):
    """The full compiled schedule as an explicit ``(name, fn)`` step
    list: ``stem`` (coefficients — or the tile-packed stem layout with
    ``packed=True`` — to packed activations), one step per residual
    block, and ``head`` (packed activations to logits).

    Folding the list is exactly :func:`apply_compiled` /
    :func:`apply_compiled_packed` — the steps are the *same closures*
    the whole-schedule walk executes, so per-step introspection (HLO
    attribution, profiled timing) observes the production schedule, not
    a re-implementation of it.
    """
    cfg = cp.cfg if cfg is None else cfg
    path = (cp.meta or {}).get("path", "reference")
    st = cp.stem

    def stem_fn(x):
        if packed:
            n, bh, bw, k = x.shape
            if k != st.cin * st.w_in:
                raise ValueError(
                    f"packed input has per-channel width {k / st.cin:g}, "
                    f"stem expects w_in={st.w_in} (cin={st.cin})")
            if st.kind == "packed" and (
                    executor == "gemm"
                    or (path == "pallas"
                        and not dispatchlib._pallas_delegates(cfg))):
                from repro.kernels import tiling

                h = tiling.packed_conv_apply(x, st.conv)
                h = tiling.packed_asm_apply(h, st.asm)
            else:
                from repro.core.conv import pad_bands

                coef = pad_bands(x.reshape(n, bh, bw, st.cin, st.w_in))
                h = _apply_stem(st, coef, cp.phi, path, cfg, executor)
        else:
            h = _apply_stem(st, x, cp.phi, path, cfg, executor)
        return shard(h, "batch", None, None, None)

    return [("stem", stem_fn)] + _block_steps(cp, cfg, executor)


class StepProfile:
    """Collector for per-step device walls of a profiled compiled run.

    Pass an instance as ``apply_compiled(..., profile=prof)`` (or the
    packed twin): the schedule executes step by step — each step jitted
    on its own, with ``jax.block_until_ready`` fencing both sides of the
    wall — and one sample per step is appended per call.  Logits are
    produced by the same step closures the unprofiled walk folds, so
    the profiled output is bit-identical to the unprofiled one.

    The first call through a given ``(plan, executor, packing)`` pays
    per-step compilation inside the recorded walls; call once to warm,
    then :meth:`reset` (keeps the jitted steps, drops the samples)
    before the measuring calls.  :meth:`summary` reduces samples to
    per-step medians.
    """

    def __init__(self) -> None:
        self.order: list[str] = []
        self.samples: dict[str, list[float]] = {}
        self.calls = 0
        self._fns: dict[tuple, list] = {}

    def steps_for(self, cp: CompiledPlan,
                  cfg: dispatchlib.DispatchConfig,
                  executor: str | None, packed: bool):
        key = (id(cp), id(cfg), executor, bool(packed))
        fns = self._fns.get(key)
        if fns is None:
            fns = [(name, jax.jit(fn)) for name, fn in
                   compiled_steps(cp, cfg, executor=executor, packed=packed)]
            self._fns[key] = fns
        return fns

    def record(self, name: str, seconds: float) -> None:
        if name not in self.samples:
            self.order.append(name)
            self.samples[name] = []
        self.samples[name].append(seconds)

    def reset(self) -> None:
        """Drop recorded samples; keep the compiled per-step entries."""
        self.order.clear()
        self.samples.clear()
        self.calls = 0

    def summary(self) -> dict[str, float]:
        """Per-step median wall (seconds), in schedule order."""
        import statistics

        return {name: statistics.median(self.samples[name])
                for name in self.order}

    def total_s(self) -> float:
        return sum(self.summary().values())


def _apply_profiled(cp: CompiledPlan, x: jnp.ndarray,
                    cfg: dispatchlib.DispatchConfig,
                    executor: str | None, profile: StepProfile,
                    packed: bool) -> jnp.ndarray:
    import time

    h = jnp.asarray(x)
    jax.block_until_ready(h)
    for name, fn in profile.steps_for(cp, cfg, executor, packed):
        t0 = time.perf_counter()
        h = fn(h)
        jax.block_until_ready(h)
        profile.record(name, time.perf_counter() - t0)
    profile.calls += 1
    return h


# --------------------------------------------------------------------------
# Serialization through the checkpoint manager
# --------------------------------------------------------------------------

_OP_ARRAYS = ("xi", "kernel", "scale", "shift", "bn_scale")
_OP_STATIC = ("stride", "bands", "quality", "in_scaled", "out_scaled", "path")
# format 2: operators additionally carry ``bn_scale`` (the retained BN fold
# compile_plan re-lowers from) — format-1 artifacts predate compiled plans.
_PLAN_FORMAT = 2


def _flat_ops(plan: InferencePlan) -> dict[str, dispatchlib.ConvOperator]:
    out = {}
    for name, entry in plan.operators.items():
        if isinstance(entry, dict):
            out.update({f"{name}/{slot}": op for slot, op in entry.items()})
        else:
            out[name] = entry
    return out


def _leaf_path(key: str) -> str:
    """The path string CheckpointManager records for flat-dict key ``key``
    (derived through jax itself so renames in DictKey.__str__ can't skew
    the format)."""
    (path, _), = jax.tree_util.tree_flatten_with_path({key: 0})[0]
    return "/".join(str(p) for p in path)


def _op_save(key: str, op: dispatchlib.ConvOperator,
             arrays: dict[str, np.ndarray]) -> dict[str, Any]:
    meta: dict[str, Any] = {f: getattr(op, f) for f in _OP_STATIC}
    for f in _OP_ARRAYS:
        val = getattr(op, f)
        meta[f"has_{f}"] = val is not None
        if val is not None:
            arrays[f"{key}.{f}"] = np.asarray(val)
    return meta


def _op_load(key: str, meta: dict[str, Any],
             arr: Any) -> dispatchlib.ConvOperator:
    fields = {f: meta[f] for f in _OP_STATIC}
    for f in _OP_ARRAYS:
        fields[f] = arr(f"{key}.{f}") if meta[f"has_{f}"] else None
    return dispatchlib.ConvOperator(**fields)


def save_plan(plan: InferencePlan, directory: str, step: int = 0,
              keep: int = 3) -> None:
    """Persist a plan: arrays through the checksummed/atomic checkpoint
    store, static structure in the manifest ``extra`` JSON."""
    from repro.checkpoint import CheckpointManager

    arrays: dict[str, np.ndarray] = {"head.w": np.asarray(plan.head_w),
                                     "head.b": np.asarray(plan.head_b)}
    meta_ops: dict[str, dict[str, Any]] = {}
    for key, op in _flat_ops(plan).items():
        meta_ops[key] = _op_save(key, op, arrays)
    extra = {
        "kind": "jpeg_inference_plan",
        "format": _PLAN_FORMAT,
        "spec": dict(plan.spec._asdict(), widths=list(plan.spec.widths)),
        "phi": plan.phi,
        "cfg": dataclasses.asdict(plan.cfg),
        "bands": plan.bands,
        "provenance": plan.provenance,
        "ops": meta_ops,
    }
    CheckpointManager(directory, keep=keep).save(step, arrays, extra=extra)


def load_plan(directory: str, step: int | None = None) -> InferencePlan:
    """Restore an :class:`InferencePlan` saved by :func:`save_plan`.

    Bit-exact: restored logits equal the pre-save plan's (tests assert
    array equality across all three dispatch paths).
    """
    from repro.checkpoint import CheckpointManager

    _, by_path, extra = CheckpointManager(directory).restore_tree(step)
    if extra.get("kind") != "jpeg_inference_plan":
        raise ValueError(f"{directory} does not hold an inference plan")
    if extra.get("format") != _PLAN_FORMAT:
        raise ValueError(f"unsupported plan format {extra.get('format')!r}")

    def arr(key):
        return jnp.asarray(by_path[_leaf_path(key)])

    spec_d = dict(extra["spec"], widths=tuple(extra["spec"]["widths"]))
    spec = resnetlib.ResNetSpec(**spec_d)
    cfg = dispatchlib.DispatchConfig(**extra["cfg"])
    operators: dict[str, Any] = {}
    for key, meta in extra["ops"].items():
        op = _op_load(key, meta, arr)
        if "/" in key:
            name, slot = key.split("/", 1)
            operators.setdefault(name, {})[slot] = op
        else:
            operators[key] = op
    return InferencePlan(operators, arr("head.w"), arr("head.b"), spec,
                         int(extra["phi"]), cfg,
                         {k: int(v) for k, v in extra["bands"].items()},
                         extra.get("provenance"))


# --------------------------------------------------------------------------
# Compiled-schedule serialization (packed-operator pytree)
# --------------------------------------------------------------------------

_COMPILED_FORMAT = 1
_PC_STATIC = ("stride", "ndy", "ndx", "cin", "w_in", "cout", "w_out")
_PA_STATIC = ("w", "bands", "phi")


def save_compiled_plan(cp: CompiledPlan, directory: str, step: int = 0,
                       keep: int = 3) -> None:
    """Persist a compiled schedule: the packed buffers go through the
    checksummed array store, the static schedule into ``extra`` — a
    restore re-serves the exact buffers (bit-identical logits) with no
    recompile."""
    from repro.checkpoint import CheckpointManager

    arrays: dict[str, np.ndarray] = {"head.w": np.asarray(cp.head_w),
                                     "head.b": np.asarray(cp.head_b)}

    def pc_save(prefix, pc):
        arrays[f"{prefix}.xi"] = np.asarray(pc.xi)
        arrays[f"{prefix}.shift"] = np.asarray(pc.shift)
        return {f: int(getattr(pc, f)) for f in _PC_STATIC}

    def pa_save(prefix, pa):
        arrays[f"{prefix}.cat"] = np.asarray(pa.cat)
        arrays[f"{prefix}.recon_t"] = np.asarray(pa.recon_t)
        return {f: int(getattr(pa, f)) for f in _PA_STATIC}

    stem = cp.stem
    stem_meta: dict[str, Any] = {
        "kind": stem.kind, "cin": stem.cin, "cout": stem.cout,
        "w_in": stem.w_in, "w_out": stem.w_out, "bands_out": stem.bands_out}
    stem_meta["op"] = _op_save("stem.op", stem.op, arrays)
    if stem.kind == "packed":
        stem_meta["conv"] = pc_save("stem.conv", stem.conv)
        stem_meta["asm"] = pa_save("stem.asm", stem.asm)
    blocks_meta = []
    for blk in cp.blocks:
        m: dict[str, Any] = {
            "kind": blk.kind, "name": blk.name, "cin": blk.cin,
            "cout": blk.cout, "w_in": blk.w_in, "w_out": blk.w_out,
            "bands_in": blk.bands_in, "bands_out": blk.bands_out,
            "path": blk.path, "vmem_bytes": blk.vmem_bytes}
        m["ops"] = {slot: _op_save(f"{blk.name}.ops.{slot}", op, arrays)
                    for slot, op in blk.ops.items()}
        if blk.kind == "fused":
            m["conv1"] = pc_save(f"{blk.name}.conv1", blk.conv1)
            m["asm_mid"] = pa_save(f"{blk.name}.asm_mid", blk.asm_mid)
            m["conv2"] = pc_save(f"{blk.name}.conv2", blk.conv2)
            if blk.proj is not None:
                m["proj"] = pc_save(f"{blk.name}.proj", blk.proj)
            m["asm_out"] = pa_save(f"{blk.name}.asm_out", blk.asm_out)
        blocks_meta.append(m)
    extra = {
        "kind": "jpeg_compiled_plan",
        "format": _COMPILED_FORMAT,
        "spec": dict(cp.spec._asdict(), widths=list(cp.spec.widths)),
        "phi": cp.phi,
        "cfg": dataclasses.asdict(cp.cfg),
        "bands": cp.bands,
        "meta": cp.meta,
        "stem": stem_meta,
        "blocks": blocks_meta,
    }
    CheckpointManager(directory, keep=keep).save(step, arrays, extra=extra)


def load_compiled_plan(directory: str, step: int | None = None
                       ) -> CompiledPlan:
    """Restore a :class:`CompiledPlan` saved by :func:`save_compiled_plan`
    (bit-exact: the packed buffers round-trip through the array store)."""
    from repro.checkpoint import CheckpointManager
    from repro.kernels.tiling import PackedAsm, PackedConv

    _, by_path, extra = CheckpointManager(directory).restore_tree(step)
    if extra.get("kind") != "jpeg_compiled_plan":
        raise ValueError(f"{directory} does not hold a compiled plan")
    if extra.get("format") != _COMPILED_FORMAT:
        raise ValueError(
            f"unsupported compiled-plan format {extra.get('format')!r}")

    def arr(key):
        return jnp.asarray(by_path[_leaf_path(key)])

    def pc_load(prefix, meta):
        return PackedConv(arr(f"{prefix}.xi"), arr(f"{prefix}.shift"),
                          **{f: int(meta[f]) for f in _PC_STATIC})

    def pa_load(prefix, meta):
        return PackedAsm(arr(f"{prefix}.cat"), arr(f"{prefix}.recon_t"),
                         **{f: int(meta[f]) for f in _PA_STATIC})

    sm = extra["stem"]
    stem_op = _op_load("stem.op", sm["op"], arr)
    if sm["kind"] == "packed":
        stem = CompiledStem("packed", pc_load("stem.conv", sm["conv"]),
                            pa_load("stem.asm", sm["asm"]), stem_op,
                            int(sm["cin"]), int(sm["cout"]),
                            int(sm["w_in"]), int(sm["w_out"]),
                            int(sm["bands_out"]))
    else:
        stem = CompiledStem("layers", None, None, stem_op,
                            int(sm["cin"]), int(sm["cout"]),
                            int(sm["w_in"]), int(sm["w_out"]),
                            int(sm["bands_out"]))
    blocks = []
    for m in extra["blocks"]:
        common = (m["kind"], m["name"], int(m["cin"]), int(m["cout"]),
                  int(m["w_in"]), int(m["w_out"]), int(m["bands_in"]),
                  int(m["bands_out"]), m["path"])
        ops = {slot: _op_load(f"{m['name']}.ops.{slot}", om, arr)
               for slot, om in m["ops"].items()}
        if m["kind"] == "fused":
            name = m["name"]
            proj = pc_load(f"{name}.proj", m["proj"]) if "proj" in m else None
            blocks.append(CompiledBlock(
                *common, pc_load(f"{name}.conv1", m["conv1"]),
                pa_load(f"{name}.asm_mid", m["asm_mid"]),
                pc_load(f"{name}.conv2", m["conv2"]), proj,
                pa_load(f"{name}.asm_out", m["asm_out"]), ops,
                int(m["vmem_bytes"])))
        else:
            blocks.append(CompiledBlock(*common, ops=ops))
    spec_d = dict(extra["spec"], widths=tuple(extra["spec"]["widths"]))
    return CompiledPlan(stem, tuple(blocks), arr("head.w"), arr("head.b"),
                        resnetlib.ResNetSpec(**spec_d), int(extra["phi"]),
                        dispatchlib.DispatchConfig(**extra["cfg"]),
                        {k: int(v) for k, v in extra["bands"].items()},
                        extra.get("meta"))
