"""Convert-once inference engine: the ``InferencePlan`` artifact.

The paper's deployment story (§4.1 "the map can be precomputed to speed up
inference", §6 sparsity) lands here as a single object.  Building a plan:

* **fuses inference-mode batch norm** into the adjacent conv's Ξ operator
  (``core.batchnorm.fold_batchnorm``): the scale multiplies Ξ's
  output-channel rows at precompute time and the β/μ constant rides on the
  operator as a DC shift — the per-step ``dispatch.batchnorm`` calls
  disappear from the precomputed path entirely;
* **autotunes ``bands`` per layer**: the quantization table already crushed
  high-frequency energy, so an energy budget over ``1/q²`` picks each
  layer's truncation (``bands_for_budget``), optionally refined by a parity
  sweep against the reference full-band path (``autotune_bands``).  The
  global ``DispatchConfig.bands`` knob remains as an override;
* is **serializable** through ``checkpoint.manager.CheckpointManager``
  (``save_plan``/``load_plan``): numeric leaves go into the checksummed
  array store, static structure into the manifest ``extra`` JSON, so a
  serving process restores the plan and never re-explodes at trace time.

``resnet.precompute_operators`` / ``resnet.jpeg_apply_precomputed`` are
thin wrappers over :func:`build_operators` / :func:`apply_operators` (the
unfused, per-step-batchnorm walk kept for training-state parity checks and
as the perf baseline); :func:`build_plan` / :func:`apply_plan` are the
serving path.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import batchnorm as bnlib
from repro.core import dct as dctlib
from repro.core import dispatch as dispatchlib
from repro.core import pooling as poollib
from repro.core import resnet as resnetlib
from repro.parallel.sharding import shard

__all__ = [
    "InferencePlan",
    "qtable_band_energy",
    "bands_for_budget",
    "autotune_bands",
    "operator_keys",
    "build_operators",
    "apply_operators",
    "build_plan",
    "apply_plan",
    "save_plan",
    "load_plan",
]

#: candidate band counts the autotuner moves along (multiples of 8 keep the
#: coefficient axis lane-aligned for the Pallas kernels).
BAND_LADDER = (8, 16, 24, 32, 40, 48, 56, 64)


# --------------------------------------------------------------------------
# Per-layer band autotuning (ROADMAP "Band autotuning")
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def qtable_band_energy(quality: int = 50) -> np.ndarray:
    """Cumulative retained-energy fraction per zigzag prefix length.

    The quantization table divides coefficient ``k`` by ``q[k]``; for a
    flat spectral prior the signal energy surviving quantization scales as
    ``1/q[k]²`` — exactly the "high-frequency energy the qtable already
    crushes".  ``out[b-1]`` is the fraction of that retained energy covered
    by keeping the first ``b`` zigzag coefficients; it is non-decreasing.
    """
    q = dctlib.quantization_table(quality)
    w = 1.0 / (q * q)
    return np.cumsum(w) / np.sum(w)


def bands_for_budget(quality: int, budget: float) -> int:
    """Smallest band count whose cumulative qtable energy ≥ ``budget``.

    Rounded up to a multiple of 8 (lane alignment).  Monotone in
    ``budget``: a tighter (smaller) budget never yields *more* bands.
    """
    if not 0.0 < budget <= 1.0:
        raise ValueError(f"budget must be in (0, 1], got {budget}")
    cum = qtable_band_energy(quality)
    b = int(np.searchsorted(cum, budget - 1e-12) + 1)
    return min(dctlib.NFREQ, ((b + 7) // 8) * 8)


def operator_keys(params: Any, spec: resnetlib.ResNetSpec) -> list[str]:
    """Flat conv-operator keys in forward order: ``stem``, ``s0b0/conv1``…"""
    keys = ["stem"]
    for name, s, cin, w in resnetlib._stages(spec):
        if "proj" in params[name]:
            keys.append(f"{name}/proj")
        keys.append(f"{name}/conv1")
        keys.append(f"{name}/conv2")
    return keys


def autotune_bands(
    params: Any,
    state: Any,
    spec: resnetlib.ResNetSpec,
    *,
    budget: float = 0.95,
    probe_coef: jnp.ndarray | None = None,
    tol: float = 5e-2,
    ladder: tuple[int, ...] = BAND_LADDER,
    phi: int | None = None,
) -> dict[str, int]:
    """Per-layer band assignment from qtable energy + optional parity sweep.

    Every conv operator starts at :func:`bands_for_budget` (the qtable
    energy heuristic — monotone in ``budget``).  With ``probe_coef``
    (a small ``(N, bh, bw, C, 64)`` coefficient batch) the assignment is
    refined against the *reference path at full bands*:

    1. escalate all layers one ladder step while the probe logits disagree
       (top-1) or deviate by more than ``tol`` — the heuristic may be too
       aggressive for a particular network;
    2. one greedy tightening pass, last layer to first: lower each layer
       individually while parity still holds — layers differ in
       sensitivity, which is what makes the result genuinely per-layer.
    """
    base = bands_for_budget(spec.quality, budget)
    keys = operator_keys(params, spec)
    bands = {k: base for k in keys}
    if probe_coef is None:
        return bands

    # The sweep probes many assignments that differ in a single layer, so
    # operators are exploded once per distinct (layer, band) pair and
    # trial plans are assembled from that cache — not rebuilt per probe.
    phi = spec.phi if phi is None else phi
    ref_cfg = dispatchlib.DispatchConfig(path="reference",
                                         bands=dctlib.NFREQ)
    folds = _fold_all(params, state, spec)
    ops_at: dict[int, dict[str, Any]] = {}

    def ops_for(level: int) -> dict[str, Any]:
        if level not in ops_at:
            ops_at[level] = build_operators(params, spec, ref_cfg,
                                            folds=folds, bands=level)
        return ops_at[level]

    def plan_for(assign: dict[str, int]) -> InferencePlan:
        operators: dict[str, Any] = {"stem": ops_for(assign["stem"])["stem"]}
        for name, s, cin, w in resnetlib._stages(spec):
            entry = {}
            for slot in ops_for(assign[f"{name}/conv1"])[name]:
                entry[slot] = ops_for(assign[f"{name}/{slot}"])[name][slot]
            operators[name] = entry
        return InferencePlan(operators, params["head"]["w"],
                             params["head"]["b"], spec, phi, ref_cfg,
                             dict(assign))

    ref = np.asarray(apply_plan(plan_for({k: dctlib.NFREQ for k in keys}),
                                probe_coef))
    ref_top1 = ref.argmax(-1)

    def parity(assign: dict[str, int]) -> bool:
        got = np.asarray(apply_plan(plan_for(assign), probe_coef))
        return (float(np.abs(got - ref).max()) <= tol
                and bool((got.argmax(-1) == ref_top1).all()))

    def bump(b: int) -> int:
        nxt = [l for l in ladder if l > b]
        return nxt[0] if nxt else dctlib.NFREQ

    while not parity(bands) and any(v < dctlib.NFREQ for v in bands.values()):
        bands = {k: bump(v) for k, v in bands.items()}

    for k in reversed(keys):
        while True:
            lower = [l for l in ladder if l < bands[k]]
            if not lower:
                break
            trial = dict(bands)
            trial[k] = lower[-1]
            if not parity(trial):
                break
            bands = trial
    return bands


# --------------------------------------------------------------------------
# Operator construction + the two forward walks
# --------------------------------------------------------------------------


def _resolve_bands(bands: Any, key: str,
                   cfg: dispatchlib.DispatchConfig) -> int:
    if bands is None:
        return cfg.bands
    if isinstance(bands, int):
        return bands
    return int(bands.get(key, cfg.bands))


def build_operators(params: Any, spec: resnetlib.ResNetSpec,
                    cfg: dispatchlib.DispatchConfig, *,
                    folds: dict[str, tuple] | None = None,
                    bands: Any = None) -> dict[str, Any]:
    """Explode every convolution once; returns the operator pytree.

    ``folds`` maps operator keys to ``(scale, shift)`` pairs from
    ``batchnorm.fold_batchnorm`` (fused-BN plans); ``bands`` is None
    (global ``cfg.bands``), an int, or a per-key dict.  Each leaf is a
    :class:`repro.core.dispatch.ConvOperator` with its apply path resolved
    here — apply is a pure table lookup per step.
    """
    folds = folds or {}

    def pc(key, kernel, stride, **kw):
        scale, shift = folds.get(key, (None, None))
        return dispatchlib.precompute_conv(
            kernel, stride, bands=_resolve_bands(bands, key, cfg),
            scale=scale, shift=shift, cfg=cfg, **kw)

    ops: dict[str, Any] = {"stem": pc("stem", params["stem"]["kernel"], 1,
                                      in_scaled=True, quality=spec.quality)}
    for name, s, cin, w in resnetlib._stages(spec):
        blk = params[name]
        entry = {
            "conv1": pc(f"{name}/conv1", blk["conv1"], s),
            "conv2": pc(f"{name}/conv2", blk["conv2"], 1),
        }
        if "proj" in blk:
            entry["proj"] = pc(f"{name}/proj", blk["proj"], s)
        ops[name] = entry
    return ops


def apply_operators(params: Any, state: Any, ops: dict[str, Any],
                    coef: jnp.ndarray, *, spec: resnetlib.ResNetSpec,
                    phi: int | None = None,
                    cfg: dispatchlib.DispatchConfig | None = None
                    ) -> jnp.ndarray:
    """Precomputed-operator inference with *per-step* batch norm.

    The unfused walk — kept as the parity baseline against ``jpeg_apply``
    (it consumes the live ``state``) and as the perf baseline the fused
    :func:`apply_plan` is measured against.  Rejects operators that carry
    a fused batch norm: applying ``state`` on top of them would run BN
    twice and silently corrupt the logits — use :func:`apply_plan`.
    """
    phi = spec.phi if phi is None else phi
    cfg = dispatchlib.resolve_config(cfg)
    stem = ops["stem"]
    if stem.shift is not None or stem.scale is not None:
        raise ValueError(
            "operators carry a fused batch norm (built by build_plan); "
            "applying per-step batch norm on top would run BN twice — "
            "serve them through plan.apply_plan, or build unfused "
            "operators with resnet.precompute_operators")

    def bn(name, h):
        p = bnlib.BatchNormParams(params[name]["gamma"], params[name]["beta"])
        s = bnlib.BatchNormState(state[name]["mean"], state[name]["var"])
        h, _ = dispatchlib.batchnorm(h, p, s, training=False, cfg=cfg)
        return h

    def relu(h):
        return dispatchlib.asm_relu(h, phi, cfg=cfg)

    h = dispatchlib.apply_conv(coef, ops["stem"], cfg=cfg)
    h = relu(bn("stem_bn", h))
    for name, s, cin, w in resnetlib._stages(spec):
        blk, op = params[name], ops[name]
        short = h
        if "proj" in blk:
            short = dispatchlib.apply_conv(h, op["proj"], cfg=cfg)
        h = dispatchlib.apply_conv(h, op["conv1"], cfg=cfg)
        h = relu(bn(name + "_bn1", h))
        h = dispatchlib.apply_conv(h, op["conv2"], cfg=cfg)
        h = bn(name + "_bn2", h)
        h = relu(poollib.residual_add(h, short))
    pooled = poollib.global_avg_pool_jpeg(h)
    return pooled @ params["head"]["w"] + params["head"]["b"]


# --------------------------------------------------------------------------
# The plan artifact
# --------------------------------------------------------------------------


class InferencePlan(NamedTuple):
    """Everything JPEG-domain serving needs, precomputed once.

    ``operators`` carry the fused batch norms (scale folded into Ξ, DC
    shift on the operator) at their per-layer band truncations; batch-norm
    parameters and running statistics are *gone* — only the head weights
    remain as raw parameters.  Closure-only (static metadata is not a
    pytree leaf): close over the plan in a jitted lambda rather than
    passing it as a jit argument.
    """

    operators: dict[str, Any]
    head_w: jnp.ndarray
    head_b: jnp.ndarray
    spec: resnetlib.ResNetSpec
    phi: int
    cfg: dispatchlib.DispatchConfig
    bands: dict[str, int]
    #: how the band assignment was produced ({"bands_mode": "auto" |
    #: "global" | "explicit", ...}) — serving uses it to decide whether a
    #: restored plan satisfies an --autotune-bands request.
    provenance: Any = None

    def __call__(self, coef: jnp.ndarray) -> jnp.ndarray:
        return apply_plan(self, coef)


def build_plan(
    params: Any,
    state: Any,
    spec: resnetlib.ResNetSpec,
    *,
    phi: int | None = None,
    dispatch: dispatchlib.DispatchConfig | None = None,
    bands: Any = None,
    budget: float | None = None,
    probe_coef: jnp.ndarray | None = None,
    eps: float = 1e-5,
) -> InferencePlan:
    """Fuse, autotune, and explode a trained model into an ``InferencePlan``.

    ``bands``: None → the frozen dispatch config's global knob (the
    override path); an int or per-key dict → explicit assignment; the
    string ``"auto"`` (or a ``budget``) → :func:`autotune_bands` from the
    quantization table, refined by a parity sweep when ``probe_coef`` is
    given.
    """
    phi = spec.phi if phi is None else phi
    cfg = dispatchlib.resolve_config(dispatch)
    autotuned = bands == "auto" or budget is not None
    if autotuned:
        bands = autotune_bands(params, state, spec,
                               budget=0.95 if budget is None else budget,
                               probe_coef=probe_coef, phi=phi)
    provenance = {
        "bands_mode": ("auto" if autotuned
                       else "global" if bands is None
                       else "explicit"),
        "budget": budget,
        "probe": probe_coef is not None,
    }
    folds = _fold_all(params, state, spec, eps=eps)
    ops = build_operators(params, spec, cfg, folds=folds, bands=bands)
    resolved = {k: _resolve_bands(bands, k, cfg)
                for k in operator_keys(params, spec)}
    return InferencePlan(ops, params["head"]["w"], params["head"]["b"],
                         spec, phi, cfg, resolved, provenance)


def _fold_all(params: Any, state: Any, spec: resnetlib.ResNetSpec,
              eps: float = 1e-5) -> dict[str, tuple]:
    """(scale, shift) folds for every batch-normed conv, keyed like
    :func:`operator_keys` (proj convs have no BN and get no entry)."""

    def fold(bn_name):
        p = bnlib.BatchNormParams(params[bn_name]["gamma"],
                                  params[bn_name]["beta"])
        s = bnlib.BatchNormState(state[bn_name]["mean"],
                                 state[bn_name]["var"])
        return bnlib.fold_batchnorm(p, s, eps=eps)

    folds = {"stem": fold("stem_bn")}
    for name, s, cin, w in resnetlib._stages(spec):
        folds[f"{name}/conv1"] = fold(name + "_bn1")
        folds[f"{name}/conv2"] = fold(name + "_bn2")
    return folds


def apply_plan(plan: InferencePlan, coef: jnp.ndarray,
               cfg: dispatchlib.DispatchConfig | None = None) -> jnp.ndarray:
    """Serve from a plan: matmuls + ASM only — no batch norm, no explode.

    Each activation runs ASM at its producing layer's band truncation (the
    residual join runs at the wider of its two contributors, since the
    shortcut may carry coefficients the main branch truncated away).
    """
    cfg = plan.cfg if cfg is None else cfg
    ops = plan.operators

    def relu(h, b):
        return dispatchlib.asm_relu(h, plan.phi, cfg=cfg, bands=b)

    h = dispatchlib.apply_conv(coef, ops["stem"], cfg=cfg)
    cur = ops["stem"].bands
    h = relu(h, cur)
    h = shard(h, "batch", None, None, None, None)
    for name, s, cin, w in resnetlib._stages(plan.spec):
        op = ops[name]
        short, short_bands = h, cur
        if "proj" in op:
            short = dispatchlib.apply_conv(h, op["proj"], cfg=cfg)
            short_bands = op["proj"].bands
        h = dispatchlib.apply_conv(h, op["conv1"], cfg=cfg)
        h = relu(h, op["conv1"].bands)
        h = dispatchlib.apply_conv(h, op["conv2"], cfg=cfg)
        cur = max(op["conv2"].bands, short_bands)
        h = relu(poollib.residual_add(h, short), cur)
        h = shard(h, "batch", None, None, None, None)
    pooled = poollib.global_avg_pool_jpeg(h)
    return pooled @ plan.head_w + plan.head_b


# --------------------------------------------------------------------------
# Serialization through the checkpoint manager
# --------------------------------------------------------------------------

_OP_ARRAYS = ("xi", "kernel", "scale", "shift")
_OP_STATIC = ("stride", "bands", "quality", "in_scaled", "out_scaled", "path")
_PLAN_FORMAT = 1


def _flat_ops(plan: InferencePlan) -> dict[str, dispatchlib.ConvOperator]:
    out = {}
    for name, entry in plan.operators.items():
        if isinstance(entry, dict):
            out.update({f"{name}/{slot}": op for slot, op in entry.items()})
        else:
            out[name] = entry
    return out


def _leaf_path(key: str) -> str:
    """The path string CheckpointManager records for flat-dict key ``key``
    (derived through jax itself so renames in DictKey.__str__ can't skew
    the format)."""
    (path, _), = jax.tree_util.tree_flatten_with_path({key: 0})[0]
    return "/".join(str(p) for p in path)


def save_plan(plan: InferencePlan, directory: str, step: int = 0,
              keep: int = 3) -> None:
    """Persist a plan: arrays through the checksummed/atomic checkpoint
    store, static structure in the manifest ``extra`` JSON."""
    from repro.checkpoint import CheckpointManager

    arrays: dict[str, np.ndarray] = {"head.w": np.asarray(plan.head_w),
                                     "head.b": np.asarray(plan.head_b)}
    meta_ops: dict[str, dict[str, Any]] = {}
    for key, op in _flat_ops(plan).items():
        meta_ops[key] = {f: getattr(op, f) for f in _OP_STATIC}
        for f in _OP_ARRAYS:
            val = getattr(op, f)
            meta_ops[key][f"has_{f}"] = val is not None
            if val is not None:
                arrays[f"{key}.{f}"] = np.asarray(val)
    extra = {
        "kind": "jpeg_inference_plan",
        "format": _PLAN_FORMAT,
        "spec": dict(plan.spec._asdict(), widths=list(plan.spec.widths)),
        "phi": plan.phi,
        "cfg": dataclasses.asdict(plan.cfg),
        "bands": plan.bands,
        "provenance": plan.provenance,
        "ops": meta_ops,
    }
    CheckpointManager(directory, keep=keep).save(step, arrays, extra=extra)


def load_plan(directory: str, step: int | None = None) -> InferencePlan:
    """Restore an :class:`InferencePlan` saved by :func:`save_plan`.

    Bit-exact: restored logits equal the pre-save plan's (tests assert
    array equality across all three dispatch paths).
    """
    from repro.checkpoint import CheckpointManager

    _, by_path, extra = CheckpointManager(directory).restore_tree(step)
    if extra.get("kind") != "jpeg_inference_plan":
        raise ValueError(f"{directory} does not hold an inference plan")
    if extra.get("format") != _PLAN_FORMAT:
        raise ValueError(f"unsupported plan format {extra.get('format')!r}")

    def arr(key):
        return jnp.asarray(by_path[_leaf_path(key)])

    spec_d = dict(extra["spec"], widths=tuple(extra["spec"]["widths"]))
    spec = resnetlib.ResNetSpec(**spec_d)
    cfg = dispatchlib.DispatchConfig(**extra["cfg"])
    operators: dict[str, Any] = {}
    for key, meta in extra["ops"].items():
        fields = {f: meta[f] for f in _OP_STATIC}
        for f in _OP_ARRAYS:
            fields[f] = arr(f"{key}.{f}") if meta[f"has_{f}"] else None
        op = dispatchlib.ConvOperator(**fields)
        if "/" in key:
            name, slot = key.split("/", 1)
            operators.setdefault(name, {})[slot] = op
        else:
            operators[key] = op
    return InferencePlan(operators, arr("head.w"), arr("head.b"), spec,
                         int(extra["phi"]), cfg,
                         {k: int(v) for k, v in extra["bands"].items()},
                         extra.get("provenance"))
