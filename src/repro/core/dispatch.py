"""Backend-aware operator dispatch for the JPEG-domain network.

Every JPEG-domain op the model forward needs — convolution, ASM ReLU,
block DCT/IDCT, batch norm — has a registry entry mapping *path* names to
implementations:

* ``reference`` — the pure-jnp ``core.*`` code (XLA, runs everywhere);
* ``pallas``    — the kernels in ``repro.kernels`` (Mosaic-compiled on
  TPU; on other backends the Pallas interpreter is a correctness harness,
  not a perf path, so the pallas entry *delegates to reference* unless
  ``interpret=True`` forces the interpreter — tests do);
* ``factored``  — the never-materialise path (J ∘ C ∘ J̃ applied as its
  factors; O(1) extra memory for arbitrarily wide layers).

Selection per call-site is (1) an explicit override — the ``JPEG_DISPATCH``
env var or :func:`configure`/:func:`override` — then (2) operator size
(above ``MATERIALIZE_LIMIT`` elements the conv goes factored), then (3)
backend (pallas on TPU, reference elsewhere).

The ``bands`` knob (paper §6: "the sparsity of the JPEG format allows for
faster processing") keeps only the first ``bands`` zigzag coefficients.
It threads down into ``explosion_basis`` / ``apply_exploded`` /
``jpeg_conv_pallas`` / ASM so dropped coefficients shrink the matmuls by
``(bands/64)²`` instead of being multiplied as zeros; activations stay
64-wide at op boundaries (zero-padded above the cutoff) so every layer
stays shape-compatible.  ``bands=64`` is bit-exact with the seed code.

Note: dispatch decisions are read at *trace* time.  Configure the path
and bands before ``jax.jit`` compiles a forward; changing the global
config does not retrace already-compiled functions.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import asm as asmlib
from repro.core import batchnorm as bnlib
from repro.core import conv as convlib
from repro.core import dct as dctlib

__all__ = [
    "PATHS", "DispatchConfig", "get_config", "configure", "override",
    "resolve_config", "register", "lookup", "available_paths", "choose_path",
    "ConvOperator", "conv", "precompute_conv", "apply_conv", "asm_relu",
    "batchnorm", "block_dct", "block_idct", "fused_block",
]

PATHS = ("reference", "pallas", "factored")


# --------------------------------------------------------------------------
# Configuration (env defaults + programmatic overrides)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Per-forward dispatch policy.

    ``path``: 'auto' or a forced path name for every op.
    ``bands``: zigzag coefficients kept (1..64); 64 = exact.
    ``materialize_limit``: Ξ element count above which conv goes factored
        (None = ``core.conv.MATERIALIZE_LIMIT``).
    ``interpret``: force the Pallas interpreter off-TPU (tests/validation);
        None = delegate the pallas path to reference off-TPU.
    """

    path: str = "auto"
    bands: int = dctlib.NFREQ
    materialize_limit: int | None = None
    interpret: bool | None = None

    def __post_init__(self):
        if self.path not in ("auto",) + PATHS:
            raise ValueError(f"unknown dispatch path {self.path!r}")
        if not 1 <= self.bands <= dctlib.NFREQ:
            raise ValueError(f"bands must be in [1, {dctlib.NFREQ}]")

    @property
    def limit(self) -> int:
        if self.materialize_limit is not None:
            return self.materialize_limit
        return convlib.MATERIALIZE_LIMIT


def _from_env() -> DispatchConfig:
    return DispatchConfig(
        path=os.environ.get("JPEG_DISPATCH", "auto").strip().lower() or "auto",
        bands=int(os.environ.get("JPEG_BANDS", dctlib.NFREQ)),
    )


# Parsed lazily on first use so a malformed JPEG_DISPATCH/JPEG_BANDS fails
# at the first dispatch call (with the validating ValueError) instead of
# crashing every import of the core package.
_CONFIG: DispatchConfig | None = None


def get_config() -> DispatchConfig:
    global _CONFIG
    if _CONFIG is None:
        _CONFIG = _from_env()
    return _CONFIG


def configure(**changes) -> DispatchConfig:
    """Permanently replace fields of the global config (serve/CLI entry)."""
    global _CONFIG
    _CONFIG = dataclasses.replace(get_config(), **changes)
    return _CONFIG


@contextlib.contextmanager
def override(**changes):
    """Scoped config override (benchmarks / tests)."""
    global _CONFIG
    prev = get_config()
    _CONFIG = dataclasses.replace(prev, **changes)
    try:
        yield _CONFIG
    finally:
        _CONFIG = prev


def resolve_config(cfg: DispatchConfig | None) -> DispatchConfig:
    return get_config() if cfg is None else cfg


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, dict[str, Callable[..., Any]]] = {}


def register(op: str, path: str, fn: Callable[..., Any]) -> None:
    if path not in PATHS:
        raise ValueError(f"unknown path {path!r}")
    _REGISTRY.setdefault(op, {})[path] = fn


def available_paths(op: str) -> tuple[str, ...]:
    return tuple(p for p in PATHS if p in _REGISTRY.get(op, {}))


def lookup(op: str, path: str) -> Callable[..., Any]:
    """Implementation for ``op`` on ``path``; missing paths fall back to
    ``reference`` (e.g. batch norm is bandwidth-bound elementwise work XLA
    already emits optimally — it has no dedicated kernel yet)."""
    impls = _REGISTRY[op]
    return impls.get(path, impls["reference"])


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def choose_path(op: str, cfg: DispatchConfig, *,
                op_elems: int | None = None) -> str:
    """Resolve 'auto' to a concrete path for one call-site."""
    if cfg.path != "auto":
        if cfg.path == "pallas" and op == "conv" and op_elems is not None \
                and op_elems > cfg.limit:
            # A forced-pallas Ξ that cannot be materialised must go factored.
            return "factored"
        return cfg.path
    if op == "conv" and op_elems is not None and op_elems > cfg.limit:
        return "factored"
    if _on_tpu():
        return "pallas"
    return "reference"


def _pallas_delegates(cfg: DispatchConfig) -> bool:
    """Off-TPU, the pallas path runs reference XLA unless interpret forced."""
    return not _on_tpu() and cfg.interpret is not True


# --------------------------------------------------------------------------
# Convolution
# --------------------------------------------------------------------------


class ConvOperator(NamedTuple):
    """A precomputed layer operator with its resolved apply path.

    ``xi`` is the (possibly band-truncated) materialised Ξ; ``kernel`` is
    retained for the factored path (which never forms Ξ).  Closure-only:
    hold it outside jit arguments (``path``/metadata are not pytree leaves).

    ``scale``/``shift`` carry a fused inference-mode batch norm (see
    ``core.batchnorm.fold_batchnorm``): on materialised paths the scale is
    already folded into Ξ's output-channel rows at precompute time (so the
    field is None); the factored path, which never forms Ξ, keeps it and
    applies it per step.  ``shift`` is the DC-coefficient bias added after
    the conv.  ``bands`` is *per-operator* — the plan autotuner may assign
    each layer its own truncation instead of the global knob.

    ``bn_scale`` retains the *original* folded scale even when it was
    already multiplied into Ξ: plan compilation (``core.plan.compile_plan``)
    re-lowers the layer from ``kernel`` for backends where Ξ matmuls are
    not the fastest form, and needs the fold to reproduce the same math.
    :func:`apply_conv` never applies it.
    """

    xi: jnp.ndarray | None
    kernel: jnp.ndarray
    stride: int
    bands: int
    quality: int
    in_scaled: bool
    out_scaled: bool
    path: str
    scale: jnp.ndarray | None = None
    shift: jnp.ndarray | None = None
    bn_scale: jnp.ndarray | None = None


def _conv_reference(coef, kernel, stride, cfg, *, in_scaled, out_scaled,
                    quality):
    xi = convlib.explode(kernel, stride, quality=quality, in_scaled=in_scaled,
                         out_scaled=out_scaled, bands=cfg.bands)
    return convlib.pad_bands(convlib.apply_exploded(coef, xi, stride))


def _conv_pallas(coef, kernel, stride, cfg, *, in_scaled, out_scaled,
                 quality):
    if _pallas_delegates(cfg):
        return _conv_reference(coef, kernel, stride, cfg, in_scaled=in_scaled,
                               out_scaled=out_scaled, quality=quality)
    from repro.kernels import ops as kops

    xi = convlib.explode(kernel, stride, quality=quality, in_scaled=in_scaled,
                         out_scaled=out_scaled, bands=cfg.bands)
    return convlib.pad_bands(kops.jpeg_conv_apply(coef, xi, stride))


def _conv_factored(coef, kernel, stride, cfg, *, in_scaled, out_scaled,
                   quality):
    return convlib._jpeg_conv_factored(
        coef, kernel, stride, quality=quality, in_scaled=in_scaled,
        out_scaled=out_scaled, bands=cfg.bands)


def conv(coef: jnp.ndarray, kernel: jnp.ndarray, stride: int = 1,
         bias: jnp.ndarray | None = None, *, in_scaled: bool = False,
         out_scaled: bool = False, quality: int = 50,
         cfg: DispatchConfig | None = None) -> jnp.ndarray:
    """JPEG-domain convolution through the registry.

    Drop-in for ``core.conv.jpeg_conv``; returns 64-wide coefficients
    (zero above the band cutoff when ``cfg.bands < 64``).
    """
    cfg = resolve_config(cfg)
    path = choose_path("conv", cfg, op_elems=convlib.operator_elems(
        kernel.shape, stride, cfg.bands))
    out = lookup("conv", path)(coef, kernel, stride, cfg,
                               in_scaled=in_scaled, out_scaled=out_scaled,
                               quality=quality)
    return convlib.add_dc_bias(out, bias, out_scaled)


def precompute_conv(kernel: jnp.ndarray, stride: int = 1, *,
                    in_scaled: bool = False, out_scaled: bool = False,
                    quality: int = 50, bands: int | None = None,
                    scale: jnp.ndarray | None = None,
                    shift: jnp.ndarray | None = None,
                    cfg: DispatchConfig | None = None) -> ConvOperator:
    """Explode a layer once for inference (paper §4.1 "can be precomputed").

    The apply path is resolved here — by size, backend, and override — so
    :func:`apply_conv` is a pure table lookup per step.

    ``bands`` overrides ``cfg.bands`` for this operator (per-layer
    autotuning); ``scale``/``shift`` fuse a folded inference batch norm:
    the scale multiplies Ξ's output-channel rows here (materialised paths)
    or is retained for per-step application (factored path); the DC shift
    is always carried on the operator and added by :func:`apply_conv`.
    """
    cfg = resolve_config(cfg)
    bands = cfg.bands if bands is None else bands
    path = choose_path("conv", cfg, op_elems=convlib.operator_elems(
        kernel.shape, stride, bands))
    xi = None
    bn_scale = scale
    if path != "factored":
        xi = convlib.explode(kernel, stride, quality=quality,
                             in_scaled=in_scaled, out_scaled=out_scaled,
                             bands=bands)
        if scale is not None:
            # BN scale folds into the output-channel axis of Ξ
            # (ndy, ndx, Cin, bands, Cout, bands).
            xi = xi * jnp.asarray(scale, xi.dtype)[None, None, None, None, :,
                                                   None]
            scale = None
    return ConvOperator(xi, kernel, stride, bands, quality,
                        in_scaled, out_scaled, path, scale, shift, bn_scale)


def _apply_reference(coef, op: ConvOperator, cfg):
    return convlib.pad_bands(convlib.apply_exploded(coef, op.xi, op.stride))


def _apply_pallas(coef, op: ConvOperator, cfg):
    if _pallas_delegates(cfg):
        return _apply_reference(coef, op, cfg)
    from repro.kernels import ops as kops

    return convlib.pad_bands(kops.jpeg_conv_apply(coef, op.xi, op.stride))


def _apply_factored(coef, op: ConvOperator, cfg):
    return convlib._jpeg_conv_factored(
        coef, op.kernel, op.stride, quality=op.quality,
        in_scaled=op.in_scaled, out_scaled=op.out_scaled, bands=op.bands)


def apply_conv(coef: jnp.ndarray, op: ConvOperator,
               cfg: DispatchConfig | None = None) -> jnp.ndarray:
    """Apply a precomputed operator along its resolved path.

    Honors the operator's fused batch norm: ``scale`` (only present on the
    factored path — materialised Ξ already absorbed it) multiplies every
    output coefficient per channel, ``shift`` adds to DC.
    """
    cfg = resolve_config(cfg)
    out = lookup("conv_apply", op.path)(coef, op, cfg)
    if op.scale is not None:
        out = out * op.scale[None, None, None, :, None]
    if op.shift is not None:
        out = out.at[..., 0].add(op.shift[None, None, None, :])
    return out


# --------------------------------------------------------------------------
# ASM ReLU
# --------------------------------------------------------------------------


def _asm_reference(coef, phi, cfg):
    return asmlib.asm_relu(coef, phi, bands=cfg.bands)


def _asm_pallas(coef, phi, cfg):
    if _pallas_delegates(cfg):
        return _asm_reference(coef, phi, cfg)
    from repro.kernels import ops as kops

    return kops.asm_relu(coef, phi, bands=cfg.bands)


def asm_relu(coef: jnp.ndarray, phi: int = asmlib.EXACT_PHI,
             cfg: DispatchConfig | None = None, *,
             bands: int | None = None) -> jnp.ndarray:
    """``bands`` overrides ``cfg.bands`` for this call (per-layer plans
    run each activation at its layer's autotuned truncation)."""
    cfg = resolve_config(cfg)
    if bands is not None and bands != cfg.bands:
        cfg = dataclasses.replace(cfg, bands=bands)
    path = choose_path("asm_relu", cfg)
    return lookup("asm_relu", path)(coef, phi, cfg)


# --------------------------------------------------------------------------
# Fused residual block (compiled plans — ``core.plan.compile_plan``)
# --------------------------------------------------------------------------


def _fused_reference(x, block, phi, cfg):
    # XLA backends: the block-fused math in its FLOP-optimal lowering —
    # spatial-resident between the block-edge transforms.
    from repro.kernels.fused_block import fused_block_spatial

    return fused_block_spatial(x, block, phi)


def _fused_pallas(x, block, phi, cfg):
    if _pallas_delegates(cfg):
        return _fused_reference(x, block, phi, cfg)
    from repro.kernels import ops as kops

    return kops.fused_block(x, block.conv1, block.asm_mid, block.conv2,
                            block.asm_out, block.proj)


def fused_block(x: jnp.ndarray, block, phi: int, *, path: str | None = None,
                cfg: DispatchConfig | None = None) -> jnp.ndarray:
    """One whole residual block of a compiled plan
    (``core.plan.CompiledBlock``): the Pallas megakernel over the packed
    banded operators on TPU, the spatial-resident XLA lowering elsewhere.
    ``path`` is normally the block's compile-time resolution; None
    re-resolves from ``cfg`` (there is no factored fused kernel — a
    forced-factored config falls back to the reference executor).
    """
    cfg = resolve_config(cfg)
    path = choose_path("fused_block", cfg) if path is None else path
    return lookup("fused_block", path)(x, block, phi, cfg)


# --------------------------------------------------------------------------
# Batch norm (coefficient domain)
# --------------------------------------------------------------------------


def _bn_reference(coef, params, state, cfg, *, training, momentum, eps):
    return bnlib.batchnorm_jpeg(coef, params, state, training=training,
                                momentum=momentum, eps=eps)


def batchnorm(coef: jnp.ndarray, params: bnlib.BatchNormParams,
              state: bnlib.BatchNormState, *, training: bool,
              momentum: float = 0.1, eps: float = 1e-5,
              cfg: DispatchConfig | None = None):
    cfg = resolve_config(cfg)
    path = choose_path("batchnorm", cfg)
    return lookup("batchnorm", path)(coef, params, state, cfg,
                                     training=training, momentum=momentum,
                                     eps=eps)


# --------------------------------------------------------------------------
# Block DCT / IDCT (codec boundary)
# --------------------------------------------------------------------------


def _dct_reference(blocks, quality, cfg):
    from repro.kernels.block_dct import _fwd_operator

    lead = blocks.shape[:-2]
    flat = blocks.reshape(-1, dctlib.NFREQ)
    op = jnp.asarray(_fwd_operator(quality), blocks.dtype)
    return (flat @ op).reshape(*lead, dctlib.NFREQ)


def _dct_pallas(blocks, quality, cfg):
    if _pallas_delegates(cfg):
        return _dct_reference(blocks, quality, cfg)
    from repro.kernels import ops as kops

    return kops.block_dct(blocks, quality)


def _idct_reference(coef, quality, cfg):
    from repro.kernels.block_dct import _inv_operator

    lead = coef.shape[:-1]
    op = jnp.asarray(_inv_operator(quality), coef.dtype)
    out = coef.reshape(-1, dctlib.NFREQ) @ op
    return out.reshape(*lead, dctlib.BLOCK, dctlib.BLOCK)


def _idct_pallas(coef, quality, cfg):
    if _pallas_delegates(cfg):
        return _idct_reference(coef, quality, cfg)
    from repro.kernels import ops as kops

    return kops.block_idct(coef, quality)


def block_dct(blocks: jnp.ndarray, quality: int | None = None,
              cfg: DispatchConfig | None = None) -> jnp.ndarray:
    """(..., 8, 8) pixel blocks -> (..., 64) zigzag coefficients."""
    cfg = resolve_config(cfg)
    return lookup("block_dct", choose_path("block_dct", cfg))(
        blocks, quality, cfg)


def block_idct(coef: jnp.ndarray, quality: int | None = None,
               cfg: DispatchConfig | None = None) -> jnp.ndarray:
    """(..., 64) zigzag coefficients -> (..., 8, 8) pixel blocks."""
    cfg = resolve_config(cfg)
    return lookup("block_idct", choose_path("block_idct", cfg))(
        coef, quality, cfg)


# --------------------------------------------------------------------------
# Registry population.  Missing (op, path) pairs fall back to reference —
# the factored column only differs for conv (the other ops have no
# materialise/factor distinction), and batch norm has no kernel yet.
# --------------------------------------------------------------------------

register("conv", "reference", _conv_reference)
register("conv", "pallas", _conv_pallas)
register("conv", "factored", _conv_factored)

register("conv_apply", "reference", _apply_reference)
register("conv_apply", "pallas", _apply_pallas)
register("conv_apply", "factored", _apply_factored)

register("asm_relu", "reference", _asm_reference)
register("asm_relu", "pallas", _asm_pallas)

register("fused_block", "reference", _fused_reference)
register("fused_block", "pallas", _fused_pallas)

register("batchnorm", "reference", _bn_reference)

register("block_dct", "reference", _dct_reference)
register("block_dct", "pallas", _dct_pallas)

register("block_idct", "reference", _idct_reference)
register("block_idct", "pallas", _idct_pallas)
