"""The JPEG transform as a linear map (paper §3.2).

The *JPEG transform domain* is the output of step 4 of JPEG encoding:
blocked, DCT-transformed, zigzag-ordered, quantization-scaled coefficients
(real-valued — rounding/entropy coding are outside the transform domain).

Layouts
-------
Spatial images are ``(..., H, W)``; their transform-domain representation is
``(..., H/8, W/8, 64)`` — block-row, block-col, zigzag coefficient.  The
leading axes (batch, channels) are untouched.

Coefficient conventions (DESIGN.md §7; the first two are this module's
``scaled`` flag, the third is produced by the codec subsystem):

===========================  ==============================================
convention                   meaning
===========================  ==============================================
``scaled=True``              true step-4 JPEG coefficients (divided by
                             ``q``) for pixels in the network's ~[-1, 1)
                             range — the network input convention
``scaled=False``             plain orthonormal DCT coefficients ("DCT
                             domain"); quantization diagonals folded into
                             the adjacent operators
canonical-qtable-normalized  a *file's* quantized integers rescaled by
                             ``codec.normalize`` into ``scaled=True`` form
                             under THIS repo's canonical table
                             (``dct.quantization_table(quality)``, DC
                             forced to 8): ``v·q_file/(128·q_canon)``.
                             Exact and linear, so one compiled plan serves
                             files with arbitrary quantization tables
===========================  ==============================================

Note the orthonormal 8×8 DCT here coincides with the JPEG standard's DCT
definition, and steps 5+ (rounding, entropy coding) live in
``repro.codec`` (``bitstream``/``encode``) — this module stays the
real-valued transform-domain core.

``jpeg_tensor``/``ijpeg_tensor`` materialise the paper's ``J``/``J̃``
tensors explicitly; they are O((HW)²) and exist for tests and for the
faithful operator-explosion path on small images.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import dct as dctlib

__all__ = [
    "block_image",
    "unblock_image",
    "jpeg_encode",
    "jpeg_decode",
    "jpeg_round_trip_lossy",
    "jpeg_tensor",
    "ijpeg_tensor",
]


def block_image(img: jnp.ndarray, block: int = dctlib.BLOCK) -> jnp.ndarray:
    """``(..., H, W) -> (..., H/b, W/b, b, b)`` — the paper's B tensor."""
    *lead, h, w = img.shape
    if h % block or w % block:
        raise ValueError(f"image ({h}x{w}) not divisible into {block}x{block} blocks")
    img = img.reshape(*lead, h // block, block, w // block, block)
    return jnp.moveaxis(img, -3, -2)


def unblock_image(blocks: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`block_image`."""
    *lead, bh, bw, b1, b2 = blocks.shape
    blocks = jnp.moveaxis(blocks, -2, -3)
    return blocks.reshape(*lead, bh * b1, bw * b2)


def jpeg_encode(
    img: jnp.ndarray,
    *,
    quality: int = 50,
    scaled: bool = True,
    qtable: np.ndarray | None = None,
) -> jnp.ndarray:
    """Steps 1–4 of JPEG encoding: ``(..., H, W) -> (..., H/8, W/8, 64)``."""
    d = jnp.asarray(dctlib.dct_matrix(), img.dtype)
    zz = dctlib.zigzag_permutation()
    blocks = block_image(img)
    coef = jnp.einsum("am,...mn,bn->...ab", d, blocks, d)
    coef = coef.reshape(*coef.shape[:-2], dctlib.NFREQ)[..., zz]
    if scaled:
        q = qtable if qtable is not None else dctlib.quantization_table(quality)
        coef = coef / jnp.asarray(q, coef.dtype)
    return coef


def jpeg_decode(
    coef: jnp.ndarray,
    *,
    quality: int = 50,
    scaled: bool = True,
    qtable: np.ndarray | None = None,
) -> jnp.ndarray:
    """Inverse of :func:`jpeg_encode` (no rounding — exact inverse)."""
    if scaled:
        q = qtable if qtable is not None else dctlib.quantization_table(quality)
        coef = coef * jnp.asarray(q, coef.dtype)
    inv_zz = np.argsort(dctlib.zigzag_permutation())
    coef = coef[..., inv_zz]
    coef = coef.reshape(*coef.shape[:-1], dctlib.BLOCK, dctlib.BLOCK)
    d = jnp.asarray(dctlib.dct_matrix(), coef.dtype)
    blocks = jnp.einsum("am,...ab,bn->...mn", d, coef, d)
    return unblock_image(blocks)


def jpeg_round_trip_lossy(img: jnp.ndarray, *, quality: int = 50) -> jnp.ndarray:
    """Lossy JPEG round trip (with step-5 rounding) — for data simulation."""
    coef = jpeg_encode(img, quality=quality, scaled=True)
    coef = jnp.round(coef)
    return jpeg_decode(coef, quality=quality, scaled=True)


# --------------------------------------------------------------------------
# Explicit J / J~ tensors (tests + faithful explosion path; numpy, small images)
# --------------------------------------------------------------------------


def jpeg_tensor(
    h: int, w: int, *, quality: int = 50, scaled: bool = True
) -> np.ndarray:
    """The paper's ``J`` (Eq. 8) as ``(h, w, h/8, w/8, 64)``: pixels->coeffs."""
    b = dctlib.BLOCK
    r = dctlib.reconstruction_matrix()  # (64 zigzag coef, 64 flat pixel)
    fwd = r.T.copy()  # (pixel, coef): forward DCT in zigzag order
    if scaled:
        fwd = fwd / dctlib.quantization_table(quality)[None, :]
    j = np.zeros((h, w, h // b, w // b, b * b))
    for x in range(h // b):
        for y in range(w // b):
            for m in range(b):
                for n in range(b):
                    j[x * b + m, y * b + n, x, y, :] = fwd[m * b + n]
    return j


def ijpeg_tensor(
    h: int, w: int, *, quality: int = 50, scaled: bool = True
) -> np.ndarray:
    """The paper's ``J̃`` (Eq. 10) as ``(h/8, w/8, 64, h, w)``: coeffs->pixels."""
    b = dctlib.BLOCK
    rec = dctlib.reconstruction_matrix()  # (coef, pixel)
    if scaled:
        rec = rec * dctlib.quantization_table(quality)[:, None]
    jt = np.zeros((h // b, w // b, b * b, h, w))
    for x in range(h // b):
        for y in range(w // b):
            blk = rec.reshape(b * b, b, b)
            jt[x, y, :, x * b : (x + 1) * b, y * b : (y + 1) * b] = blk
    return jt
