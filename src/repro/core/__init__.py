"""The paper's contribution: residual networks in the JPEG transform domain.

Submodules: ``dct`` (transform constants), ``jpeg`` (the linear codec),
``asm`` (Approximated Spatial Masking), ``conv`` (convolution explosion),
``batchnorm``, ``pooling``, ``resnet`` (twin spatial/JPEG models),
``convert`` (model conversion), ``transform_linear`` (generalised folding).
"""
from repro.core import (  # noqa: F401
    asm,
    batchnorm,
    conv,
    convert,
    dct,
    jpeg,
    pooling,
    resnet,
    transform_linear,
)
