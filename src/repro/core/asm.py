"""Approximated Spatial Masking (ASM) — the paper's §4.2 / Algorithm 2.

ASM applies a *piecewise-linear* function to transform-domain blocks:

1. build a cheap spatial approximation from the lowest ``phi`` frequency
   bands (optimal truncation, DCT least-squares theorem);
2. threshold it into binary masks selecting each linear piece;
3. apply each piece's linear action to the *exact* coefficients via the
   harmonic mixing tensor H (Eq. 17) and sum the masked results.

For ReLU (``r(x) = nnm(x) * x``), step 3 collapses to masking — values are
exact wherever the mask is right (paper Fig. 1).

On TPU this is three MXU matmuls per block tile (DESIGN.md §3), not a
sparse einsum:

    S_approx = F @ R_phi          # (tiles, 64) @ (64, 64), rows>phi zeroed
    M        = S_approx > 0
    F'       = ((F @ R) * M) @ R.T   # mask the exact reconstruction

which is algebraically identical to the H-tensor contraction
``F'_{k'} = H^{k p}_{k'} F_k M_p``.

All functions operate on coefficient tensors of shape ``(..., 64)``
(zigzag order) in the *unscaled* (orthonormal DCT) convention.  For true
JPEG-scaled coefficients, the quantization diagonals are folded into the
reconstruction matrices (Eq. 20) — see ``asm_relu(..., qtable=...)``.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import conv as convlib
from repro.core import dct as dctlib

__all__ = [
    "PiecewiseLinear", "RELU", "LEAKY_RELU",
    "approx_spatial", "nonnegative_mask", "asm_relu", "apx_relu",
    "asm_piecewise", "AsmConstants", "asm_constants",
]

EXACT_PHI = dctlib.NBANDS - 1  # 14: all 15 bands -> exact reconstruction


class PiecewiseLinear(NamedTuple):
    """``f(x) = slope_i * x + intercept_i`` on ``[edges[i], edges[i+1])``.

    ``edges`` has ``len(slopes) - 1`` interior breakpoints (monotonic).
    """

    edges: tuple[float, ...]
    slopes: tuple[float, ...]
    intercepts: tuple[float, ...]


RELU = PiecewiseLinear(edges=(0.0,), slopes=(0.0, 1.0), intercepts=(0.0, 0.0))
LEAKY_RELU = PiecewiseLinear(edges=(0.0,), slopes=(0.01, 1.0), intercepts=(0.0, 0.0))


class AsmConstants(NamedTuple):
    """Precomputed matrices closed over by jitted ASM code."""

    recon_phi: np.ndarray  # (64, 64) truncated reconstruction (mask path)
    recon: np.ndarray      # (64, 64) exact reconstruction
    recon_t: np.ndarray    # (64, 64) forward DCT back to zigzag coefficients


def asm_constants(phi: int, qtable: np.ndarray | None = None,
                  bands: int = dctlib.NFREQ) -> AsmConstants:
    """Build ASM constants; folds quantization scaling if ``qtable`` given.

    With a qtable (JPEG-scaled convention, Eq. 20): de-quantization is folded
    into both reconstruction matrices and re-quantization into the forward
    matrix, so callers never touch the tables at runtime.

    ``bands`` (paper §6 sparsity) keeps only the first ``bands`` zigzag
    coefficients: the reconstruction matrices become ``(bands, 64)`` and the
    forward matrix ``(64, bands)``, so truncated activations multiply
    ``bands``-wide operands instead of zero-padded 64-wide ones.
    """
    recon = dctlib.reconstruction_matrix().copy()
    recon_phi = dctlib.truncated_reconstruction_matrix(phi).copy()
    recon_t = recon.T.copy()
    if qtable is not None:
        q = np.asarray(qtable, np.float64)
        recon = q[:, None] * recon
        recon_phi = q[:, None] * recon_phi
        recon_t = recon_t / q[None, :]
    if bands < dctlib.NFREQ:
        recon = recon[:bands]
        recon_phi = recon_phi[:bands]
        recon_t = recon_t[:, :bands]
    return AsmConstants(recon_phi, recon, recon_t)


def approx_spatial(coef: jnp.ndarray, phi: int) -> jnp.ndarray:
    """Truncated spatial reconstruction ``(..., 64 coeff) -> (..., 64 pixel)``."""
    r_phi = jnp.asarray(dctlib.truncated_reconstruction_matrix(phi), coef.dtype)
    return coef @ r_phi


def nonnegative_mask(coef: jnp.ndarray, phi: int) -> jnp.ndarray:
    """The paper's ``annm``: approximate nonnegative mask of the block."""
    return approx_spatial(coef, phi) > 0


def asm_relu(
    coef: jnp.ndarray, phi: int = EXACT_PHI, qtable: np.ndarray | None = None,
    bands: int = dctlib.NFREQ,
) -> jnp.ndarray:
    """ASM ReLU on ``(..., 64)`` zigzag coefficient tensors (Algorithm 2).

    With ``bands < 64`` the input is sliced to the kept coefficients before
    the three matmuls (dropped, not multiplied by zero) and the output is
    zero-padded back to the caller's width.
    """
    nf = coef.shape[-1]
    c = asm_constants(phi, qtable, bands=min(bands, nf))
    if bands < nf:
        coef = coef[..., :bands]
    recon_phi = jnp.asarray(c.recon_phi, coef.dtype)
    recon = jnp.asarray(c.recon, coef.dtype)
    recon_t = jnp.asarray(c.recon_t, coef.dtype)
    mask = (coef @ recon_phi) > 0
    spatial = coef @ recon
    out = jnp.where(mask, spatial, jnp.zeros_like(spatial)) @ recon_t
    return convlib.pad_bands(out, nf)


def apx_relu(
    coef: jnp.ndarray, phi: int = EXACT_PHI, qtable: np.ndarray | None = None
) -> jnp.ndarray:
    """Baseline APX method (paper Fig. 1/4): ReLU *on the approximation*.

    Reconstructs from only ``phi`` bands, applies ReLU to those values, and
    re-encodes.  Unlike ASM this does not preserve correct pixel values.
    """
    c = asm_constants(phi, qtable)
    approx = coef @ jnp.asarray(c.recon_phi, coef.dtype)
    return jnp.maximum(approx, 0.0) @ jnp.asarray(c.recon_t, coef.dtype)


def asm_piecewise(
    coef: jnp.ndarray,
    fn: PiecewiseLinear,
    phi: int = EXACT_PHI,
    qtable: np.ndarray | None = None,
) -> jnp.ndarray:
    """General ASM for any piecewise-linear ``fn`` (paper §4.2, general case).

    Each piece contributes ``(slope_i * x + intercept_i) * mask_i`` where the
    piece masks come from the phi-band approximation.  Intercepts are added
    in the spatial domain (their DCT is the intercept times the DC basis),
    slopes act on the exact reconstruction.
    """
    c = asm_constants(phi, qtable)
    recon_phi = jnp.asarray(c.recon_phi, coef.dtype)
    recon = jnp.asarray(c.recon, coef.dtype)
    recon_t = jnp.asarray(c.recon_t, coef.dtype)
    approx = coef @ recon_phi
    spatial = coef @ recon
    edges = (-np.inf,) + tuple(fn.edges) + (np.inf,)
    out = jnp.zeros_like(spatial)
    for i, (slope, intercept) in enumerate(zip(fn.slopes, fn.intercepts)):
        mask = (approx >= edges[i]) & (approx < edges[i + 1])
        out = out + jnp.where(mask, slope * spatial + intercept, 0.0)
    return out @ recon_t


def spatial_relu_oracle(coef: jnp.ndarray) -> jnp.ndarray:
    """Exact result (decode -> ReLU -> encode), for error measurement."""
    r = jnp.asarray(dctlib.reconstruction_matrix(), coef.dtype)
    return jnp.maximum(coef @ r, 0.0) @ r.T
