"""Convolution explosion — the paper's §4.1 / Algorithm 1, TPU-adapted.

Two implementations of the JPEG-domain convolution operator Ξ = J ∘ C ∘ J̃:

1. ``explode_full`` / ``apply_full`` — the paper's Algorithm 1 verbatim:
   convolve the filter against the decompression tensor J̃ reshaped as a
   batch of images (Eq. 12), re-encode, and keep the full position-dependent
   operator.  O((#blocks)²·64²·Cin·Cout) memory — used as the faithful
   reference and for paper-scale images.

2. ``explosion_basis`` / ``explode`` / ``apply_exploded`` — the production
   path (DESIGN.md §3).  Exploits translation invariance: away from borders
   the operator depends only on the *relative* block offset, and with SAME
   zero-padding the border cases are exactly the interior operator with
   missing neighbours contributing zero.  The operator is assembled from a
   precomputed separable basis

       basis[u, v, dy, dx, k, k']

   (kernel tap (u,v) → block-offset (dy,dx) coefficient mixing), so that for
   filters K of shape (Cout, Cin, r, r):

       Ξ[dy, dx, i, k, o, k'] = Σ_uv K[o, i, u, v] · basis[u, v, dy, dx, k, k']

   This contraction is linear in K — gradients for JPEG-domain *training*
   flow through it with no custom VJP — and ``apply_exploded`` is a sum of
   ``ndy·ndx`` dense (64·Cin → 64·Cout) matmuls per block: MXU-shaped.

Layout: coefficient activations are ``(N, bh, bw, C, 64)`` (channels-last
blocks); filters are ``(Cout, Cin, r, r)``; only odd ``r`` is supported.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import dct as dctlib
from repro.core import jpeg as jpeglib

__all__ = [
    "block_offsets",
    "explosion_basis",
    "explode",
    "apply_exploded",
    "pad_bands",
    "operator_elems",
    "add_dc_bias",
    "jpeg_conv",
    "explode_full",
    "apply_full",
    "spatial_conv",
]


def block_offsets(stride: int, r: int, block: int = dctlib.BLOCK) -> tuple[int, int]:
    """Range ``[d_min, d_max]`` of relative input-block offsets per axis."""
    if r % 2 != 1:
        raise ValueError("only odd receptive fields supported")
    pad = (r - 1) // 2
    d_min = (0 * stride - pad) // block  # floor division
    d_max = ((block - 1) * stride + pad) // block
    return d_min, d_max


@functools.lru_cache(maxsize=None)
def _basis_1d(stride: int, r: int, block: int = dctlib.BLOCK) -> np.ndarray:
    """1-D explosion basis ``(r, ndy, block, block)``.

    ``basis[u, d, a, a']`` maps input frequency ``a`` of the block at
    relative offset ``d + d_min`` to output frequency ``a'``, for the 1-D
    single-tap filter at tap ``u`` (translation ``t = u - pad``):

        out[m'] = in[stride * m' + t]      (zero outside)

    so ``basis[u, d, a, a'] = Σ_{m': blk(m')==d} D[a, pos(m')] D[a', m']``.
    """
    d = dctlib.dct_matrix(block)
    pad = (r - 1) // 2
    d_min, d_max = block_offsets(stride, r, block)
    nd = d_max - d_min + 1
    out = np.zeros((r, nd, block, block))
    for u in range(r):
        t = u - pad
        for mp in range(block):
            src = stride * mp + t
            blk, pos = src // block, src % block
            out[u, blk - d_min] += np.einsum("a,b->ab", d[:, pos], d[:, mp])
    return out


@functools.lru_cache(maxsize=None)
def explosion_basis(
    stride: int,
    r: int,
    quality: int = 50,
    in_scaled: bool = False,
    out_scaled: bool = False,
    bands: int = dctlib.NFREQ,
) -> np.ndarray:
    """2-D explosion basis ``(r, r, ndy, ndx, bands, bands)`` in zigzag order.

    ``in_scaled`` folds the de-quantization diagonal S̃ on the input side;
    ``out_scaled`` folds the re-quantization diagonal S on the output side
    (paper Eq. 20).  Both ``False`` is the orthonormal-DCT internal
    convention (quantization already folded into the first layer).

    ``bands`` (paper §6 sparsity) keeps only the first ``bands`` zigzag
    coefficients on *both* sides of the operator: high-frequency inputs are
    never read and high-frequency outputs never computed, so the downstream
    matmuls shrink by ``(bands/64)²`` instead of multiplying zeros.
    ``bands=64`` is exact.
    """
    if not 1 <= bands <= dctlib.NFREQ:
        raise ValueError(f"bands must be in [1, {dctlib.NFREQ}], got {bands}")
    b1 = _basis_1d(stride, r)
    b = dctlib.BLOCK
    # (u, v, dy, dx, a, a', c, c') -> zigzag (k = (a,c) in, k' = (a',c') out)
    full = np.einsum("udaA,vxcC->uvdxacAC", b1, b1)
    r_, nd = b1.shape[0], b1.shape[1]
    full = full.reshape(r_, r_, nd, nd, b * b, b * b)
    zz = dctlib.zigzag_permutation()
    full = full[..., zz, :][..., zz]
    full = full[..., :bands, :bands]
    q = dctlib.quantization_table(quality)
    if in_scaled:
        full = full * q[:bands, None]
    if out_scaled:
        full = full / q[None, :bands]
    return np.ascontiguousarray(full)


def explode(
    kernel: jnp.ndarray,
    stride: int = 1,
    *,
    quality: int = 50,
    in_scaled: bool = False,
    out_scaled: bool = False,
    bands: int = dctlib.NFREQ,
) -> jnp.ndarray:
    """Exploded JPEG-domain operator ``(ndy, ndx, Cin, bands, Cout, bands)``.

    Linear in ``kernel`` (Cout, Cin, r, r) — differentiable for JPEG-domain
    training (the paper's "more complex gradient" is just this einsum's
    transpose).
    """
    r = kernel.shape[-1]
    basis = jnp.asarray(
        explosion_basis(stride, r, quality, in_scaled, out_scaled, bands),
        kernel.dtype,
    )
    return jnp.einsum("oiuv,uvyxkl->yxikol", kernel, basis)


def pad_bands(coef: jnp.ndarray, nf: int = dctlib.NFREQ) -> jnp.ndarray:
    """Zero-pad the trailing coefficient axis back up to ``nf`` entries."""
    have = coef.shape[-1]
    if have == nf:
        return coef
    pad = [(0, 0)] * (coef.ndim - 1) + [(0, nf - have)]
    return jnp.pad(coef, pad)


def operator_elems(kernel_shape, stride: int, bands: int = dctlib.NFREQ) -> int:
    """Element count of the materialised Ξ for a (Cout, Cin, r, r) kernel —
    the quantity compared against ``MATERIALIZE_LIMIT``."""
    cout, cin, r = kernel_shape[0], kernel_shape[1], kernel_shape[-1]
    d_min, d_max = block_offsets(stride, r)
    nd = d_max - d_min + 1
    return nd * nd * cin * cout * bands * bands


def add_dc_bias(out: jnp.ndarray, bias: jnp.ndarray | None,
                out_scaled: bool = False) -> jnp.ndarray:
    """Per-channel bias ``b`` adds a constant to every pixel, i.e. ``8·b``
    on the orthonormal DC coefficient (``b`` directly when re-quantization
    with q₀ = 8 is folded on the output side)."""
    if bias is None:
        return out
    dc_gain = 1.0 if out_scaled else float(dctlib.BLOCK)
    return out.at[..., 0].add(dc_gain * bias)


def apply_exploded(coef: jnp.ndarray, xi: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Apply an exploded operator to ``(N, bh, bw, Cin, ≥bands)`` coefficients.

    ``out[n, x', y', o, k'] = Σ_{dy,dx,i,k} coef[n, s·x'+dy, s·y'+dx, i, k]
    · xi[dy, dx, i, k, o, k']`` with zero padding outside the block grid —
    exactly the border behaviour of SAME zero-padded spatial convolution.

    If ``xi`` was built with ``bands < 64`` the input is sliced to the kept
    coefficients before the matmuls and the output has ``bands`` trailing
    entries (use :func:`pad_bands` to restore the 64-wide layout).
    """
    ndy, ndx = xi.shape[0], xi.shape[1]
    nf_in = xi.shape[3]
    if coef.shape[-1] > nf_in:
        coef = coef[..., :nf_in]
    n, bh, bw, cin, nf = coef.shape
    d_min_y, _ = _offsets_from(ndy, stride)
    d_min_x, _ = _offsets_from(ndx, stride)
    bh_out, bw_out = bh // stride, bw // stride
    pad_lo_y, pad_hi_y = -d_min_y, (ndy - 1 + d_min_y)
    pad_lo_x, pad_hi_x = -d_min_x, (ndx - 1 + d_min_x)
    padded = jnp.pad(
        coef, ((0, 0), (pad_lo_y, pad_hi_y), (pad_lo_x, pad_hi_x), (0, 0), (0, 0))
    )
    out = None
    for iy in range(ndy):
        for ix in range(ndx):
            # input block index = stride*x' + (iy + d_min_y); shift by pad_lo.
            y0 = iy + d_min_y + pad_lo_y
            x0 = ix + d_min_x + pad_lo_x
            sl = padded[
                :,
                y0 : y0 + stride * bh_out : stride,
                x0 : x0 + stride * bw_out : stride,
            ]
            term = jnp.einsum("nxyik,ikol->nxyol", sl, xi[iy, ix])
            out = term if out is None else out + term
    return out


def _offsets_from(nd: int, stride: int) -> tuple[int, int]:
    """Recover ``(d_min, d_max)`` from the basis offset count.

    Per :func:`block_offsets` with odd r < 16: ``d_min = -1`` iff pad > 0.
    The only supported nd > 1 case with pad == 0 is (r=1, stride=2), where
    offsets are {0, 1}.
    """
    if nd == 1:
        return 0, 0
    if stride == 2 and nd == 2:
        return 0, 1
    return -1, nd - 2


# Above this operator size (elements of Ξ), materialising the exploded
# operator is worse than the factored (transform) application — the paper's
# §6 "efficiency of representation" limit.  3·3·(64·C_in)·(64·C_out) crosses
# it around C_in·C_out ≈ 3.6k (e.g. 64×64 channels).
# Env override JPEG_CONV_MATERIALIZE_LIMIT forces a path for perf A/B runs
# (EXPERIMENTS.md §Perf: set huge for the paper-faithful baseline, 0 for
# the always-factored variant).
import os as _os

MATERIALIZE_LIMIT = int(_os.environ.get("JPEG_CONV_MATERIALIZE_LIMIT",
                                        64 * 1024 * 1024))


def jpeg_conv(
    coef: jnp.ndarray,
    kernel: jnp.ndarray,
    stride: int = 1,
    bias: jnp.ndarray | None = None,
    *,
    in_scaled: bool = False,
    out_scaled: bool = False,
    quality: int = 50,
    bands: int = dctlib.NFREQ,
) -> jnp.ndarray:
    """JPEG-domain convolution: explode + apply, or factored for wide nets.

    The *materialised* path (paper Alg. 1) precomputes Ξ — best for small
    channel counts and the inference-precompute story.  For wide layers the
    operator itself is O(9·64²·C_in·C_out) (38 GB at 512×512 channels!), so
    the *factored* path applies J̃ → C → J without ever forming Ξ:
    mathematically identical (Ξ is exactly that composition), O(1) extra
    memory, and 64× fewer FLOPs.  On TPU the factored form lives in VMEM
    tiles (``repro.kernels.jpeg_conv``); here the paths are selected by
    operator size.  Recorded as the beyond-paper optimisation in
    EXPERIMENTS.md §Perf.

    Bias ``b`` per output channel adds a constant to every pixel, i.e. adds
    ``8·b`` to the orthonormal DC coefficient (``b`` directly in the scaled
    convention with q₀ = 8).
    """
    if operator_elems(kernel.shape, stride, bands) <= MATERIALIZE_LIMIT:
        xi = explode(kernel, stride, quality=quality, in_scaled=in_scaled,
                     out_scaled=out_scaled, bands=bands)
        out = pad_bands(apply_exploded(coef, xi, stride))
    else:
        out = _jpeg_conv_factored(coef, kernel, stride, quality=quality,
                                  in_scaled=in_scaled, out_scaled=out_scaled,
                                  bands=bands)
    return add_dc_bias(out, bias, out_scaled)


def _jpeg_conv_factored(coef, kernel, stride, *, quality, in_scaled,
                        out_scaled, bands=dctlib.NFREQ):
    """Ξ = J ∘ C ∘ J̃ applied as its factors (exact, never forms Ξ).

    coef: (N, bh, bw, Cin, 64) -> (N, bh/s, bw/s, Cout, 64).

    ``bands`` truncates the input and output coefficient sets so the result
    matches the band-truncated materialised operator (here the truncation
    is a zeroing — this path's win is memory, not the §6 sparsity FLOPs).
    """
    if bands < coef.shape[-1]:
        coef = pad_bands(coef[..., :bands])
    img = jpeglib.jpeg_decode(jnp.moveaxis(coef, 3, 1), scaled=in_scaled,
                              quality=quality)
    out = spatial_conv(img, kernel, stride)
    enc = jpeglib.jpeg_encode(out, scaled=out_scaled, quality=quality)
    enc = jnp.moveaxis(enc, 1, 3)
    if bands < enc.shape[-1]:
        enc = pad_bands(enc[..., :bands])
    return enc


# --------------------------------------------------------------------------
# Faithful full-operator path (paper Algorithm 1) — reference & tests
# --------------------------------------------------------------------------


def spatial_conv(
    img: jnp.ndarray, kernel: jnp.ndarray, stride: int = 1,
    bias: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Centered zero-padded spatial conv (PyTorch ``padding=r//2``), NCHW/OIHW.

    Note: XLA's ``"SAME"`` pads asymmetrically for even strides; the
    explosion basis assumes *centered* padding, so we pad explicitly.
    """
    pad = (kernel.shape[-1] - 1) // 2
    out = lax.conv_general_dilated(
        img, kernel, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if bias is not None:
        out = out + bias[None, :, None, None]
    return out


def explode_full(
    kernel: jnp.ndarray, bh: int, bw: int, stride: int = 1,
    *, quality: int = 50, scaled: bool = False,
) -> jnp.ndarray:
    """Paper Algorithm 1: full operator ``(bh, bw, 64, Cin, Cout, bh', bw', 64)``.

    Convolves each J̃ "image" (Eq. 12) with every (o, i) filter slice and
    re-encodes the result.  Memory grows with the block grid squared — use
    only at paper scale (tests, CIFAR-sized images).
    """
    b = dctlib.BLOCK
    h, w = bh * b, bw * b
    cout, cin, r, _ = kernel.shape
    jt = np.asarray(
        jpeglib.ijpeg_tensor(h, w, quality=quality, scaled=scaled), np.float32
    )
    imgs = jnp.asarray(jt.reshape(bh * bw * b * b, 1, h, w), kernel.dtype)
    k2 = kernel.reshape(cout * cin, 1, r, r)
    pad = (r - 1) // 2
    conv = lax.conv_general_dilated(
        imgs, k2, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (bh*bw*64, cout*cin, h/s, w/s)
    enc = jpeglib.jpeg_encode(conv, quality=quality, scaled=scaled)
    enc = enc.reshape(bh, bw, b * b, cout, cin, bh // stride, bw // stride, b * b)
    return jnp.moveaxis(enc, 4, 3)  # (bh, bw, 64, cin, cout, bh', bw', 64)


def apply_full(coef: jnp.ndarray, op: jnp.ndarray) -> jnp.ndarray:
    """Apply a full operator to ``(N, bh, bw, Cin, 64)`` coefficients."""
    return jnp.einsum("nxyik,xykioXYK->nXYoK", coef, op)
