"""JPEG-domain pooling and residual addition (paper §4.4, §4.5).

* Component-wise (residual) addition is identity-cost by linearity.
* Global average pooling reads DC coefficients: the mean over the image is
  the mean of per-block means, and when the feature map is a single block
  it is one unconditional read per channel (paper Fig. 2).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.batchnorm import DC_GAIN

__all__ = ["residual_add", "global_avg_pool_jpeg", "global_avg_pool_spatial"]


def residual_add(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """J(F + G) = J(F) + J(G) — Eq. 25."""
    return a + b


def global_avg_pool_jpeg(coef: jnp.ndarray, *, dc_gain: float = DC_GAIN) -> jnp.ndarray:
    """``(N, bh, bw, C, 64) -> (N, C)``: channel-wise mean via DC reads."""
    return jnp.mean(coef[..., 0], axis=(1, 2)) / dc_gain


def global_avg_pool_spatial(x: jnp.ndarray) -> jnp.ndarray:
    """``(N, C, H, W) -> (N, C)`` — the spatial oracle."""
    return jnp.mean(x, axis=(2, 3))
