"""Plan introspection: where does each microsecond and each FLOP of a
compiled plan go — and does the cost model agree?

Three layers, built on ``core.plan.compiled_steps`` (the compiled
schedule as an explicit step list — the *same* closures the production
walk folds):

* **static attribution** (:mod:`~repro.introspect.attribution`): each
  schedule step lowered alone to optimized HLO, analyzed with
  ``launch.hlo_analysis.analyze_hlo``, joined with band budgets /
  retained energy / executor / VMEM metadata into a :class:`BlockCost`
  table, cross-checked against the whole-module analysis;
* **roofline prediction** (:mod:`~repro.introspect.roofline`):
  pluggable :class:`HardwareProfile` peaks (registry keyed by detected
  backend, ``JPEG_HW_PROFILE``/CLI override) turn each block's
  FLOPs/bytes into a predicted latency and dominant term;
* **measured attribution**: ``core.plan.StepProfile`` (per-step device
  walls, bit-identical logits) and ``serving.grid.GridCell.profile`` /
  :func:`profile_plan_grid` reconcile prediction against reality —
  :func:`predicted_vs_measured` is the headline report,
  ``launch.inspect`` the CLI, :func:`validate_report` the schema
  checker CI enforces.
"""
from repro.core.plan import StepProfile, compiled_steps
from repro.introspect.attribution import (BlockCost, block_costs,
                                          predicted_vs_measured)
from repro.introspect.gridprof import profile_plan_grid
from repro.introspect.report import render_text, validate_report, worst_ratio
from repro.introspect.roofline import (PROFILES, HardwareProfile,
                                       detect_backend, resolve_profile,
                                       roofline)

__all__ = [
    "BlockCost",
    "HardwareProfile",
    "PROFILES",
    "StepProfile",
    "block_costs",
    "compiled_steps",
    "detect_backend",
    "predicted_vs_measured",
    "profile_plan_grid",
    "render_text",
    "resolve_profile",
    "roofline",
    "validate_report",
    "worst_ratio",
]
