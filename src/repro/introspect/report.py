"""Introspection report schema: validation and text rendering.

The JSON report ``introspect.predicted_vs_measured`` produces (and
``launch.inspect --report-out`` writes) is a versioned schema shared by
the CLI, the tests, and the CI ``introspect-smoke`` job —
:func:`validate_report` is the one checker all three call, in the same
spirit as ``serving.trace.validate_trace``.
"""
from __future__ import annotations

import math

from repro.introspect.attribution import REPORT_KIND, REPORT_VERSION

__all__ = [
    "validate_report",
    "worst_ratio",
    "render_text",
]

_TERMS = ("compute", "memory", "collective")

_BLOCK_NUMERIC = ("flops", "bytes", "collective_bytes", "transcendentals",
                  "predicted_us")
_BLOCK_KEYS = _BLOCK_NUMERIC + (
    "name", "kind", "executor", "bands_in", "bands_out", "layer_bands",
    "energy_kept", "vmem_bytes", "measured_us", "ratio", "term", "warnings")
_TOTAL_KEYS = ("flops", "bytes", "predicted_us", "measured_us",
               "unprofiled_wall_us", "reconciliation", "logits_match")


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def validate_report(obj: dict) -> dict:
    """Validate an introspection report; raise ``ValueError`` with every
    violation listed, else return a summary dict.

    Checks: kind/version header; non-empty ``blocks`` with all schema
    keys, non-negative static costs, strictly positive predicted and
    (when present) measured walls, a known roofline ``term``, and a
    consistent ``ratio``; ``totals`` with positive walls and a
    ``reconciliation`` that matches the per-block measured sum against
    the unprofiled wall; a ``meta.hw_profile`` with positive peaks.
    """
    problems: list[str] = []
    if not isinstance(obj, dict):
        raise ValueError("report is not an object")
    if obj.get("kind") != REPORT_KIND:
        problems.append(f"kind {obj.get('kind')!r} != {REPORT_KIND!r}")
    if obj.get("version") != REPORT_VERSION:
        problems.append(f"unsupported version {obj.get('version')!r}")

    meta = obj.get("meta")
    if not isinstance(meta, dict):
        problems.append("meta missing")
    else:
        hw = meta.get("hw_profile")
        if not isinstance(hw, dict) or not all(
                _num(hw.get(k)) and hw.get(k) > 0
                for k in ("peak_flops", "hbm_bw", "link_bw")):
            problems.append("meta.hw_profile missing or non-positive peaks")

    blocks = obj.get("blocks")
    measured_sum = 0.0
    any_measured = False
    if not isinstance(blocks, list) or not blocks:
        problems.append("blocks missing or empty")
        blocks = []
    for i, b in enumerate(blocks):
        if not isinstance(b, dict):
            problems.append(f"block {i}: not an object")
            continue
        tag = f"block {i} ({b.get('name')})"
        for key in _BLOCK_KEYS:
            if key not in b:
                problems.append(f"{tag}: missing {key}")
        for key in _BLOCK_NUMERIC:
            v = b.get(key)
            if key in b and (not _num(v) or v < 0):
                problems.append(f"{tag}: {key} not a finite non-negative "
                                f"number ({v!r})")
        if _num(b.get("predicted_us")) and b["predicted_us"] <= 0:
            problems.append(f"{tag}: predicted_us must be > 0")
        mu = b.get("measured_us")
        if mu is not None:
            if not _num(mu) or mu <= 0:
                problems.append(f"{tag}: measured_us must be > 0 ({mu!r})")
            else:
                any_measured = True
                measured_sum += mu
                r = b.get("ratio")
                pu = b.get("predicted_us")
                if _num(pu) and pu > 0:
                    want = mu / pu
                    if not _num(r) or abs(r - want) > 1e-6 * max(1.0, want):
                        problems.append(
                            f"{tag}: ratio {r!r} != measured/predicted "
                            f"({want:.6g})")
        if b.get("term") not in _TERMS:
            problems.append(f"{tag}: term {b.get('term')!r} not in {_TERMS}")

    totals = obj.get("totals")
    if not isinstance(totals, dict):
        problems.append("totals missing")
        totals = {}
    for key in _TOTAL_KEYS:
        if key not in totals:
            problems.append(f"totals: missing {key}")
    if not isinstance(totals.get("logits_match"), bool):
        problems.append("totals.logits_match is not a bool")
    wall = totals.get("unprofiled_wall_us")
    if _num(wall) and wall > 0 and any_measured:
        want = measured_sum / wall
        rec = totals.get("reconciliation")
        if not _num(rec) or abs(rec - want) > 1e-6 * max(1.0, want):
            problems.append(
                f"totals.reconciliation {rec!r} != per-block measured sum "
                f"/ unprofiled wall ({want:.6g})")
    elif "unprofiled_wall_us" in totals and not (_num(wall) and wall > 0):
        problems.append(
            f"totals.unprofiled_wall_us must be > 0 ({wall!r})")

    if problems:
        raise ValueError("invalid introspect report:\n  "
                         + "\n  ".join(problems[:20]))
    return {
        "blocks": len(blocks),
        "predicted_us": totals.get("predicted_us"),
        "measured_us": totals.get("measured_us"),
        "unprofiled_wall_us": totals.get("unprofiled_wall_us"),
        "reconciliation": totals.get("reconciliation"),
        "worst_ratio": worst_ratio(obj),
        "logits_match": totals.get("logits_match"),
    }


def worst_ratio(report: dict, *, min_frac: float = 0.01) -> float | None:
    """The worst per-block predicted-vs-measured disagreement: max over
    blocks of ``max(ratio, 1/ratio)`` — 1.0 means the roofline model
    nailed every block, in either direction.

    Blocks contributing under ``min_frac`` of the total measured wall
    are skipped: a microsecond-scale head step is pure dispatch
    overhead, and its ratio says nothing about the cost model.
    """
    total = 0.0
    for b in report.get("blocks", []):
        mu = b.get("measured_us")
        if isinstance(mu, (int, float)):
            total += mu
    worst = None
    for b in report.get("blocks", []):
        r = b.get("ratio")
        mu = b.get("measured_us")
        if isinstance(mu, (int, float)) and mu < min_frac * total:
            continue
        if isinstance(r, (int, float)) and r > 0:
            w = max(r, 1.0 / r)
            worst = w if worst is None else max(worst, w)
    return worst


def _fmt_flops(v: float) -> str:
    for unit, div in (("G", 1e9), ("M", 1e6), ("K", 1e3)):
        if v >= div:
            return f"{v / div:.2f}{unit}"
    return f"{v:.0f}"


def render_text(report: dict) -> str:
    """Human-readable table of the per-block predicted-vs-measured rows."""
    meta = report.get("meta", {})
    hw = meta.get("hw_profile", {})
    lines = [
        f"plan introspection — backend={meta.get('backend')} "
        f"hw={hw.get('name')} executor={meta.get('executor') or 'auto'} "
        f"input={tuple(meta.get('input_shape', ()))}",
        f"{'step':<10} {'kind':<7} {'exec':<10} {'bands':>6} "
        f"{'energy':>7} {'flops':>9} {'bytes':>10} {'pred us':>9} "
        f"{'meas us':>9} {'ratio':>6}  term",
    ]
    for b in report.get("blocks", []):
        energy = b.get("energy_kept")
        mu = b.get("measured_us")
        ratio = b.get("ratio")
        lines.append(
            f"{b['name']:<10} {b['kind']:<7} {b['executor']:<10} "
            f"{b['bands_out']:>6} "
            f"{'' if energy is None else f'{energy:.3f}':>7} "
            f"{_fmt_flops(b['flops']):>9} {int(b['bytes']):>10} "
            f"{b['predicted_us']:>9.1f} "
            f"{'' if mu is None else f'{mu:.1f}':>9} "
            f"{'' if ratio is None else f'{ratio:.2f}':>6}  {b['term']}")
    t = report.get("totals", {})
    lines.append(
        f"{'total':<10} {'':<7} {'':<10} {'':>6} {'':>7} "
        f"{_fmt_flops(t.get('flops', 0.0)):>9} "
        f"{int(t.get('bytes', 0)):>10} {t.get('predicted_us', 0.0):>9.1f} "
        f"{t.get('measured_us', 0.0):>9.1f}")
    lines.append(
        f"unprofiled wall {t.get('unprofiled_wall_us', 0.0):.1f}us — "
        f"profiled walls sum to {100 * t.get('reconciliation', 0.0):.1f}% "
        f"of it; logits bit-identical under profiling: "
        f"{t.get('logits_match')}")
    wr = worst_ratio(report)
    if wr is not None:
        lines.append(f"worst per-block |predicted vs measured| ratio: "
                     f"{wr:.2f}x")
    return "\n".join(lines)
