"""Roofline math over pluggable hardware profiles.

This is the promotion of the roofline model that used to live (with
hardcoded TPU v5e constants) in ``benchmarks/roofline.py``: a registry
of :class:`HardwareProfile` peak numbers keyed by name, resolved from —
in priority order — an explicit spec (CLI flag), the ``JPEG_HW_PROFILE``
environment variable, a caller default, or the detected JAX backend.
``benchmarks/roofline.py`` is now a thin shim over this module.

A profile spec is either a registry name (``tpu-v5e``, ``cpu``, ...) or
a custom ``peak_flops,hbm_bw,link_bw`` triple of floats, e.g.
``JPEG_HW_PROFILE=1.97e14,8.19e11,5e10``.

:func:`roofline` turns an HLO cost (FLOPs / anchor bytes / collective
bytes, e.g. from ``launch.hlo_analysis.analyze_hlo``) into the three
roofline terms and the dominant one — ``compute`` (FLOP-bound),
``memory`` (HBM-bound) or ``collective`` (interconnect-bound) — plus
the predicted latency (the max term: perfect overlap is assumed, so
this is a *lower bound* the measured wall is compared against).
"""
from __future__ import annotations

import os
from dataclasses import dataclass

__all__ = [
    "HardwareProfile",
    "PROFILES",
    "detect_backend",
    "resolve_profile",
    "roofline",
]


@dataclass(frozen=True)
class HardwareProfile:
    """Peak rates a roofline prediction divides by.

    ``peak_flops`` — peak dense f32/bf16 FLOP/s per device;
    ``hbm_bw`` — main-memory bandwidth, bytes/s;
    ``link_bw`` — per-device interconnect bandwidth, bytes/s.
    """

    name: str
    peak_flops: float
    hbm_bw: float
    link_bw: float

    def to_json(self) -> dict:
        return {"name": self.name, "peak_flops": self.peak_flops,
                "hbm_bw": self.hbm_bw, "link_bw": self.link_bw}


# Registry of known profiles.  TPU numbers are the published per-chip
# peaks; the ``cpu`` entry is an order-of-magnitude stand-in for a
# few-core AVX host (CI) — roofline predictions there are for *ranking*
# blocks and spotting anomalies, not absolute-latency promises.
PROFILES: dict[str, HardwareProfile] = {
    "tpu-v5e": HardwareProfile("tpu-v5e", 197e12, 819e9, 50e9),
    "tpu-v4": HardwareProfile("tpu-v4", 275e12, 1228e9, 50e9),
    "gpu": HardwareProfile("gpu", 60e12, 1000e9, 25e9),
    "cpu": HardwareProfile("cpu", 100e9, 30e9, 10e9),
}

# jax.default_backend() platform → registry key
_BACKEND_ALIAS = {"tpu": "tpu-v5e", "gpu": "gpu", "cpu": "cpu"}

ENV_VAR = "JPEG_HW_PROFILE"


def detect_backend() -> str:
    """The active JAX platform name (``cpu`` / ``gpu`` / ``tpu``)."""
    import jax

    return jax.default_backend()


def _parse_spec(spec: str) -> HardwareProfile:
    spec = spec.strip()
    if spec in PROFILES:
        return PROFILES[spec]
    parts = [p.strip() for p in spec.split(",")]
    if len(parts) == 3:
        try:
            flops, hbm, link = (float(p) for p in parts)
        except ValueError:
            pass
        else:
            return HardwareProfile("custom", flops, hbm, link)
    raise ValueError(
        f"unknown hardware profile {spec!r}: want one of "
        f"{sorted(PROFILES)} or a 'peak_flops,hbm_bw,link_bw' triple")


def resolve_profile(spec: str | None = None, *,
                    default: str | None = None) -> HardwareProfile:
    """Resolve the hardware profile to predict against.

    Priority: explicit ``spec`` (CLI) > ``JPEG_HW_PROFILE`` env var >
    ``default`` registry name > the detected JAX backend.  ``spec`` and
    the env var accept a registry name or a custom
    ``peak_flops,hbm_bw,link_bw`` triple.
    """
    if spec:
        return _parse_spec(spec)
    env = os.environ.get(ENV_VAR)
    if env:
        return _parse_spec(env)
    if default is not None:
        return PROFILES[default]
    backend = detect_backend()
    return PROFILES[_BACKEND_ALIAS.get(backend, "cpu")]


def roofline(flops: float, bytes_: float, collective_bytes: float,
             profile: HardwareProfile) -> dict:
    """The three roofline terms and the dominant one.

    Returns ``{"compute_s", "memory_s", "collective_s", "predicted_s",
    "term"}`` where ``predicted_s`` is the max term and ``term`` names
    it (``compute`` / ``memory`` / ``collective``).
    """
    terms = {
        "compute": flops / profile.peak_flops,
        "memory": bytes_ / profile.hbm_bw,
        "collective": collective_bytes / profile.link_bw,
    }
    dominant = max(terms, key=lambda k: terms[k])
    return {
        "compute_s": terms["compute"],
        "memory_s": terms["memory"],
        "collective_s": terms["collective"],
        "predicted_s": terms[dominant],
        "term": dominant,
    }
