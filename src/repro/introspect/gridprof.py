"""Profile a warmed serving plan grid: per-cell predicted capacity.

``serve.py --profile-grid`` runs this sweep right after grid warmup,
before traffic: for every *warmed* (tier × bucket × kind) cell it
produces a predicted latency (roofline over the column's per-block
static costs) and a measured wall (the cell's own captured, donated
executable — already compiled, so the sweep adds **zero** post-warmup
grid compiles), turned into per-cell capacities in requests/second.

Per (column, kind) the per-block attribution is computed once at the
largest warmed bucket (the *reference* cell, which also gets a full
per-block measured profile via :meth:`GridCell.profile`); other buckets
scale the predicted cost linearly in the bucket size — exact for the
FLOP term (every GEMM's batch dimension scales with the bucket),
approximate for the byte term (weight bytes don't scale) — and measure
their own whole-cell wall directly.
"""
from __future__ import annotations

from repro.introspect.attribution import block_costs
from repro.introspect.roofline import HardwareProfile, resolve_profile

__all__ = ["profile_plan_grid"]


def profile_plan_grid(grid, *, hw: HardwareProfile | None = None,
                      iters: int = 3, warmup: int = 1) -> dict:
    """Sweep every warmed cell of a ``serving.grid.PlanGrid``.

    Returns ``{"hw_profile", "columns", "cells"}``: per (tier, kind) a
    reference-bucket per-block predicted-vs-measured table, and per cell
    ``{"cell", "tier", "kind", "bucket", "flops", "predicted_us",
    "measured_us", "predicted_req_s", "measured_req_s"}``.  Feed the
    ``cells`` rows to ``PlanGrid.annotate_costs`` /
    ``ServeMetrics.record_predicted_capacity`` to surface them on trace
    spans and the ``serve_predicted_capacity`` gauge family.
    """
    hw = resolve_profile() if hw is None else hw
    columns = []
    cells = []
    for col in grid.distinct:
        by_kind: dict[str, list] = {}
        for (kind, bucket), cell in sorted(col.cells.items(),
                                           key=lambda kv: kv[0][1]):
            by_kind.setdefault(kind, []).append(cell)
        for kind, kind_cells in by_kind.items():
            ref = kind_cells[-1]  # largest warmed bucket
            packed = kind == "bytes"
            blocks, _ = block_costs(
                col.compiled, (ref.bucket, *ref.item_shape),
                executor=col.executor, packed=packed, hw=hw,
                cross_check=False)
            ref_prof = ref.profile(iters=iters, warmup=warmup)
            measured_steps = {s["name"]: s["measured_us"]
                              for s in ref_prof["steps"]}
            for b in blocks:
                mu = measured_steps.get(b.name)
                if mu is not None:
                    b.measured_s = mu / 1e6
            pred_ref_us = sum(b.predicted_s for b in blocks) * 1e6
            flops_ref = sum(b.flops for b in blocks)
            columns.append({
                "tier": col.tier_name,
                "kind": kind,
                "ref_bucket": ref.bucket,
                "blocks": [b.to_json() for b in blocks],
            })
            for cell in kind_cells:
                scale = cell.bucket / ref.bucket
                pred_us = pred_ref_us * scale
                wall_us = (ref_prof["cell_wall_us"] if cell is ref
                           else cell.time_wall(iters=iters) * 1e6)
                cells.append({
                    "cell": cell.name,
                    "tier": col.tier_name,
                    "kind": kind,
                    "bucket": cell.bucket,
                    "flops": flops_ref * scale,
                    "predicted_us": pred_us,
                    "measured_us": wall_us,
                    "predicted_req_s": (cell.bucket / (pred_us / 1e6)
                                        if pred_us > 0 else 0.0),
                    "measured_req_s": (cell.bucket / (wall_us / 1e6)
                                       if wall_us > 0 else 0.0),
                })
    return {"hw_profile": hw.to_json(), "columns": columns, "cells": cells}
