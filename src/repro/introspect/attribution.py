"""Per-block cost attribution over a compiled plan's *optimized* HLO.

The compiled schedule is an explicit step list (``core.plan.
compiled_steps``: stem → one step per residual block → head).  Each step
is lowered and compiled on its own at the chained activation shapes, its
optimized HLO fed through ``launch.hlo_analysis.analyze_hlo`` (the
trip-count-aware text analyzer), and the result joined with the
schedule's own metadata — band budgets, retained qtable energy, the
executor the compiler chose, its VMEM estimate — into one
:class:`BlockCost` row per step.  A whole-module analysis of the same
entry point cross-checks the decomposition: per-block FLOP sums must
agree with the single-module count (XLA only folds/fuses *within* a jit
boundary here, so the sums reconcile to a few percent — validated in
``tests/test_introspect.py``).

:func:`predicted_vs_measured` is the headline driver: static attribution
plus a profiled execution (``core.plan.StepProfile``: per-step device
walls, bit-identical logits) plus the unprofiled whole-schedule wall,
reconciled into the report ``launch.inspect`` renders and CI validates.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import plan as planlib
from repro.introspect.roofline import (HardwareProfile, resolve_profile,
                                       roofline)
from repro.launch.hlo_analysis import analyze_hlo

__all__ = [
    "BlockCost",
    "block_costs",
    "predicted_vs_measured",
]

REPORT_KIND = "introspect_report"
REPORT_VERSION = 1


@dataclass
class BlockCost:
    """One schedule step's static cost row (plus measured wall, when a
    profiled run has been joined in)."""

    name: str
    kind: str                   # "stem" | "fused" | "layers" | "head"
    executor: str               # resolved executor for this step
    flops: float
    bytes: float
    collective_bytes: float
    transcendentals: float
    bands_in: int
    bands_out: int
    layer_bands: dict           # per-layer band budgets inside the step
    energy_kept: float | None   # cumulative qtable energy at bands_out
    vmem_bytes: int
    predicted_s: float
    term: str                   # dominant roofline term
    measured_s: float | None = None
    warnings: list = field(default_factory=list)

    @property
    def ratio(self) -> float | None:
        """measured / predicted (>1: slower than the roofline bound)."""
        if self.measured_s is None or self.predicted_s <= 0:
            return None
        return self.measured_s / self.predicted_s

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "executor": self.executor,
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "transcendentals": self.transcendentals,
            "bands_in": self.bands_in,
            "bands_out": self.bands_out,
            "layer_bands": dict(self.layer_bands),
            "energy_kept": self.energy_kept,
            "vmem_bytes": self.vmem_bytes,
            "predicted_us": self.predicted_s * 1e6,
            "measured_us": (None if self.measured_s is None
                            else self.measured_s * 1e6),
            "ratio": self.ratio,
            "term": self.term,
            "warnings": list(self.warnings),
        }


def _step_executor(cp, step_name: str, executor: str | None,
                   packed: bool) -> tuple[str, str]:
    """(kind, executor label) for one schedule step."""
    path = (cp.meta or {}).get("path", "reference")
    if step_name == "stem":
        st = cp.stem
        if st.kind == "packed":
            from repro.core import dispatch as dispatchlib

            if executor == "gemm" or (
                    path == "pallas"
                    and not dispatchlib._pallas_delegates(cp.cfg)):
                return "stem", "gemm"
            return "stem", "spatial"
        return "stem", "layers"
    if step_name == "head":
        return "head", "xla"
    blk = next(b for b in cp.blocks if b.name == step_name)
    if blk.kind != "fused":
        return "layers", "layers"
    return "fused", "gemm" if executor == "gemm" else blk.path


def _step_bands(cp, step_name: str) -> tuple[int, int, dict, int]:
    """(bands_in, bands_out, per-layer bands, vmem estimate)."""
    if step_name == "stem":
        st = cp.stem
        return st.bands_out, st.bands_out, {"stem": st.bands_out}, 0
    if step_name == "head":
        last = cp.blocks[-1].bands_out if cp.blocks else cp.stem.bands_out
        return last, last, {}, 0
    blk = next(b for b in cp.blocks if b.name == step_name)
    layer_bands = {}
    if blk.ops:
        layer_bands = {slot: int(op.bands) for slot, op in blk.ops.items()
                       if hasattr(op, "bands")}
    return blk.bands_in, blk.bands_out, layer_bands, int(blk.vmem_bytes)


def _plan_quality(cp) -> int | None:
    op = cp.stem.op
    return getattr(op, "quality", None) if op is not None else None


def _lower_hlo(fn, avals) -> str:
    """Optimized HLO text of ``fn`` jitted at the given abstract args."""
    return jax.jit(fn).lower(*avals).compile().as_text()


def block_costs(cp, shape, *, executor: str | None = None,
                packed: bool = False,
                hw: HardwareProfile | None = None,
                cross_check: bool = True,
                total_devices: int = 1):
    """Static per-step cost attribution for a compiled plan.

    ``shape`` is the full input batch shape (``(N, bh, bw, C, 64)``, or
    the tile-packed ``(N, bh, bw, C·w_in)`` with ``packed=True``).  Each
    step of ``core.plan.compiled_steps`` is lowered and compiled alone
    at its chained activation shape and analyzed with ``analyze_hlo``;
    roofline terms come from ``hw`` (default: the resolved hardware
    profile for this backend).

    Returns ``(blocks, whole)``: the :class:`BlockCost` list in schedule
    order and the whole-module ``HloCost`` of the single-jit entry point
    (``None`` with ``cross_check=False``).
    """
    hw = resolve_profile() if hw is None else hw
    steps = planlib.compiled_steps(cp, executor=executor, packed=packed)
    energy = None
    quality = _plan_quality(cp)
    if quality is not None:
        energy = planlib.qtable_band_energy(quality)

    blocks: list[BlockCost] = []
    aval = jax.ShapeDtypeStruct(tuple(int(s) for s in shape), jnp.float32)
    for name, fn in steps:
        hlo = _lower_hlo(fn, (aval,))
        cost = analyze_hlo(hlo, total_devices=total_devices)
        kind, exec_label = _step_executor(cp, name, executor, packed)
        bands_in, bands_out, layer_bands, vmem = _step_bands(cp, name)
        roof = roofline(cost.flops, cost.bytes, cost.collective_bytes, hw)
        blocks.append(BlockCost(
            name=name, kind=kind, executor=exec_label,
            flops=cost.flops, bytes=cost.bytes,
            collective_bytes=cost.collective_bytes,
            transcendentals=cost.transcendentals,
            bands_in=bands_in, bands_out=bands_out,
            layer_bands=layer_bands,
            energy_kept=(None if energy is None or kind == "head"
                         else float(energy[bands_out - 1])),
            vmem_bytes=vmem,
            predicted_s=roof["predicted_s"], term=roof["term"],
            warnings=list(cost.warnings)))
        aval = jax.eval_shape(fn, aval)

    whole = None
    if cross_check:
        apply_fn = (planlib.apply_compiled_packed if packed
                    else planlib.apply_compiled)
        aval0 = jax.ShapeDtypeStruct(tuple(int(s) for s in shape),
                                     jnp.float32)
        hlo = _lower_hlo(lambda x: apply_fn(cp, x, executor=executor),
                         (aval0,))
        whole = analyze_hlo(hlo, total_devices=total_devices)
    return blocks, whole


def predicted_vs_measured(cp, x, *, executor: str | None = None,
                          packed: bool = False,
                          hw: HardwareProfile | None = None,
                          iters: int = 5, warmup: int = 1,
                          total_devices: int = 1) -> dict:
    """The headline report: per-block predicted vs measured latency.

    Static attribution (:func:`block_costs`) joined with a profiled
    execution (per-step device walls via ``core.plan.StepProfile``,
    medians over ``iters`` calls after ``warmup`` discarded ones) and
    the *unprofiled* whole-schedule wall (single jitted entry, medians
    over the same ``iters``).  The report's
    ``totals.reconciliation`` is (sum of per-block measured walls) /
    (unprofiled wall) — the CI bound asserts it stays within ±10% — and
    ``totals.logits_match`` records that the profiled logits were
    bit-identical to the unprofiled ones.
    """
    hw = resolve_profile() if hw is None else hw
    x = jnp.asarray(x, jnp.float32)
    blocks, whole = block_costs(cp, x.shape, executor=executor,
                                packed=packed, hw=hw,
                                total_devices=total_devices)

    apply_fn = (planlib.apply_compiled_packed if packed
                else planlib.apply_compiled)
    prof = planlib.StepProfile()
    for _ in range(max(1, warmup)):
        apply_fn(cp, x, executor=executor, profile=prof)
    prof.reset()
    profiled = None
    for _ in range(max(1, iters)):
        profiled = apply_fn(cp, x, executor=executor, profile=prof)
    measured = prof.summary()

    whole_fn = jax.jit(lambda v: apply_fn(cp, v, executor=executor))
    unprofiled = whole_fn(x)
    jax.block_until_ready(unprofiled)
    walls = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = whole_fn(x)
        jax.block_until_ready(out)
        walls.append(time.perf_counter() - t0)
    unprofiled_wall = statistics.median(walls)
    logits_match = bool(jnp.array_equal(profiled, unprofiled))

    by_name = {b.name: b for b in blocks}
    for name, s in measured.items():
        if name in by_name:
            by_name[name].measured_s = s
    measured_total = sum(measured.values())
    sum_flops = sum(b.flops for b in blocks)
    sum_bytes = sum(b.bytes for b in blocks)

    return {
        "kind": REPORT_KIND,
        "version": REPORT_VERSION,
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "input_shape": list(x.shape),
            "packed": bool(packed),
            "executor": executor,
            "iters": int(iters),
            "hw_profile": hw.to_json(),
        },
        "blocks": [b.to_json() for b in blocks],
        "totals": {
            "flops": sum_flops,
            "bytes": sum_bytes,
            "predicted_us": sum(b.predicted_s for b in blocks) * 1e6,
            "measured_us": measured_total * 1e6,
            "unprofiled_wall_us": unprofiled_wall * 1e6,
            "reconciliation": (measured_total / unprofiled_wall
                               if unprofiled_wall > 0 else float("inf")),
            "whole_flops": None if whole is None else whole.flops,
            "whole_bytes": None if whole is None else whole.bytes,
            "static_flops_ratio": (
                None if whole is None or whole.flops == 0
                else sum_flops / whole.flops),
            "logits_match": logits_match,
        },
    }
