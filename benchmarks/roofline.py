"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(the dry-run HLO is the per-device SPMD module, so per-device quantities
come straight out of the trip-count-aware analyzer).  Also reported:
MODEL_FLOPS = 6·N(active)·D (train) / 2·N·D (inference) and the useful-
compute ratio MODEL_FLOPS / (HLO_FLOPs × devices), plus the dominant term
and a rule-derived note on what would move it.

The hardware peaks live in :mod:`repro.introspect.roofline` (one registry
for this benchmark, ``launch.inspect``, and ``serve --profile-grid``);
this module keeps the dry-run artifacts on the TPU v5e profile by
default — the artifacts describe TPU modules regardless of the analysis
host — overridable via ``$JPEG_HW_PROFILE``.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES, get_config
from repro.introspect.roofline import resolve_profile, roofline

_PROFILE = resolve_profile(default="tpu-v5e")
PEAK_FLOPS = _PROFILE.peak_flops
HBM_BW = _PROFILE.hbm_bw
LINK_BW = _PROFILE.link_bw

ARTIFACTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def param_counts(cfg) -> tuple[float, float]:
    """(total, active) parameter counts from the config (analytic)."""
    if cfg.family == "jpeg_resnet":
        n = 0.0
        cin = cfg.in_channels
        widths = list(cfg.widths)
        n += widths[0] * cin * 9
        prev = widths[0]
        for w in widths:
            for b in range(cfg.blocks_per_stage):
                n += w * prev * 9 + w * w * 9 + (w * prev if prev != w else 0)
                prev = w
        n += prev * cfg.num_classes
        return n, n
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    n = v * d * (1 if cfg.tie_embeddings else 2)
    from repro.models.transformer import layer_kinds
    expert_total = 0.0
    for mixer, ffn in layer_kinds(cfg):
        if mixer == "attn":
            n += d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
        elif mixer == "mamba":
            di = cfg.expand * d
            dtr = -(-d // 16)
            n += d * 2 * di + di * (dtr + 2 * cfg.d_state) + dtr * di \
                + di * cfg.d_state + di * d + cfg.d_conv * di
        elif mixer == "rwkv":
            n += 5 * d * d + d * (5 * 32) + d * 64 + 64 * d
        if ffn == "dense":
            n += 3 * d * f if cfg.family != "audio" else 2 * d * f
        elif ffn == "moe":
            layer_experts = cfg.n_experts * 3 * d * f
            n += layer_experts + d * cfg.n_experts
            expert_total += layer_experts
        elif ffn == "rwkv_cm":
            n += d * f + f * d + d * d
    if cfg.encoder_decoder:
        # encoder stack + the decoder's cross-attention projections
        n += cfg.n_encoder_layers * (d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
                                     + 2 * d * f)
        n += cfg.n_layers * (d * cfg.q_dim * 2 + d * cfg.kv_dim * 2)
    active = n
    if cfg.n_experts and cfg.experts_per_token:
        active = n - expert_total * (1 - cfg.experts_per_token / cfg.n_experts)
    return float(n), float(active)


def model_flops(cfg, shape) -> float | None:
    """6·N_active·D for train, 2·N_active·D for inference (global)."""
    total, active = param_counts(cfg)
    if cfg.family == "jpeg_resnet":
        if shape.kind != "train":
            return None
        # conv nets: ~2·params·pixels per position is meaningless; use
        # 6 · MACs: approximate MACs = sum over layers of k²·cin·cout·H·W
        # folded into param_counts × spatial positions at full res / 4 avg.
        positions = (cfg.image_size // 8) ** 2 * 64
        return 6.0 * total * positions / 4 * shape.global_batch / 1.0
    if cfg.encoder_decoder and shape.kind == "decode":
        # decode touches decoder params only (encoder ran at prefill)
        d, f = cfg.d_model, cfg.d_ff
        enc_params = cfg.n_encoder_layers * (
            d * cfg.q_dim * 2 + d * cfg.kv_dim * 2 + 2 * d * f)
        return 2.0 * (active - enc_params) * shape.global_batch
    if cfg.encoder_decoder:
        enc, dec = shape.seq_len, max(shape.seq_len // 8, 8)
        tokens = (enc + dec) * shape.global_batch
    elif shape.kind == "decode":
        tokens = shape.global_batch
    else:
        tokens = shape.seq_len * shape.global_batch
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active * tokens


def note_for(bottleneck: str, cfg, shape) -> str:
    if bottleneck == "collective":
        if cfg.n_experts:
            return ("shrink the MoE TP all-reduce (expert-parallel a2a or "
                    "wider expert sharding) / overlap with expert compute")
        return ("overlap the DP gradient reduce-scatter with backward and "
                "keep TP collectives inside the layer (latency-hiding)")
    if bottleneck == "memory":
        if shape.kind == "decode":
            return ("decode is KV-bound: quantize the cache (int8) or batch "
                    "more sequences per step to amortise cache reads")
        return "fuse elementwise chains and keep activations bf16"
    return "compute-bound: increase arithmetic intensity only via bigger tiles"


def rows(mesh_filter: str | None = None) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "*.json"))):
        r = json.load(open(path))
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        arch, shape_name = r["arch"], r["shape"]
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        row = {"arch": arch, "shape": shape_name, "mesh": r["mesh"],
               "status": r["status"]}
        if r["status"] != "ok":
            out.append(row)
            continue
        hc = r["hlo_cost"]
        n_dev = r["devices"]
        # Memory term: trip-count-aware, TPU-fusion-modeled bytes (see
        # repro.launch.hlo_analysis — non-fusable ops' operands+outputs).
        roof = roofline(hc["flops"], hc["bytes"], hc["collective_bytes"],
                        _PROFILE)
        compute_s = roof["compute_s"]
        memory_s = roof["memory_s"]
        coll_s = roof["collective_s"]
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        bottleneck = roof["term"]
        mf = model_flops(cfg, shape)
        ratio = (mf / (hc["flops"] * n_dev)) if mf else None
        frac = compute_s / max(terms.values()) if max(terms.values()) else 0.0
        row.update({
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "bottleneck": bottleneck,
            "model_flops": mf, "useful_ratio": ratio,
            "roofline_fraction": frac,
            "mem_gb": (r["memory"]["argument_bytes"]
                       + r["memory"]["temp_bytes"]) / 1e9,
            "note": note_for(bottleneck, cfg, shape),
        })
        out.append(row)
    return out


def write_markdown(path: str, mesh: str = "single") -> None:
    rs = rows(mesh)
    lines = [
        "| arch | shape | compute s | memory s | collective s | bottleneck "
        "| useful ratio | roofline frac | mem GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — |")
            continue
        ratio = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck']} | {ratio} | {r['roofline_fraction']:.2f} | "
            f"{r['mem_gb']:.1f} |")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def run(emit) -> None:
    ok = 0
    for r in rows("single"):
        if r["status"] != "ok":
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, r["status"])
            continue
        ok += 1
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.2f}")
    emit("roofline/cells_ok", 0.0, str(ok))
