"""Paper Table 1: model-conversion accuracy parity.

The paper trains 100 spatial models per dataset and shows identical
spatial/JPEG test accuracy to ~1e-6.  CPU-scaled: N seeds × a small
ResNet on the synthetic corpus; we report both accuracies and the max
|deviation| in accuracy and logits.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import convert as CV
from repro.core import resnet as R
from benchmarks.common import eval_accuracy, time_fn, train_spatial_resnet

N_SEEDS = 3
SPEC = R.ResNetSpec(widths=(8, 12, 16), num_classes=10)


def run(emit) -> None:
    acc_dev, logit_dev = 0.0, 0.0
    accs = []
    for seed in range(N_SEEDS):
        params, state = train_spatial_resnet(SPEC, steps=100, batch=32,
                                             seed=seed)
        sp_fwd = jax.jit(lambda x: R.spatial_apply(
            params, state, x, training=False, spec=SPEC)[0])
        model, dev = CV.convert_and_verify(
            params, state, SPEC,
            jax.random.normal(jax.random.PRNGKey(0), (4, 3, 32, 32)) * 0.3)
        logit_dev = max(logit_dev, dev)
        jp_fwd = jax.jit(model.__call__)
        acc_sp = eval_accuracy(sp_fwd, 4, 32, SPEC)
        acc_jp = eval_accuracy(jp_fwd, 4, 32, SPEC, jpeg=True)
        accs.append((acc_sp, acc_jp))
        acc_dev = max(acc_dev, abs(acc_sp - acc_jp))
    mean_sp = float(np.mean([a for a, _ in accs]))
    mean_jp = float(np.mean([b for _, b in accs]))
    emit("table1/spatial_accuracy", 0.0, f"{mean_sp:.4f}")
    emit("table1/jpeg_accuracy", 0.0, f"{mean_jp:.4f}")
    emit("table1/max_accuracy_deviation", 0.0, f"{acc_dev:.2e}")
    emit("table1/max_logit_deviation", 0.0, f"{logit_dev:.2e}")
