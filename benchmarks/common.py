"""Shared benchmark helpers."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import jpeg as J
from repro.core import resnet as R
from repro.data.synthetic import image_batch


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds per call (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def time_pair(fn_a, fn_b, *args, warmup: int = 1,
              iters: int = 3) -> tuple[float, float]:
    """Interleaved A/B timing: median microseconds for each of two
    functions, sampled alternately so machine-load drift hits both sides
    of a ratio equally — use for speedup rows that feed the perf guard."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ta)), float(np.median(tb))


def train_spatial_resnet(spec: R.ResNetSpec, steps: int, batch: int,
                         seed: int, lr: float = 1e-2, momentum: float = 0.9):
    """Train the paper's small spatial ResNet on synthetic images."""
    params, state = R.init_resnet(jax.random.PRNGKey(seed), spec)
    vel = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(params, vel, state, x, y):
        def loss_fn(p):
            logits, st = R.spatial_apply(p, state, x, training=True, spec=spec)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1)), st
        (l, st), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        vel = jax.tree.map(lambda v, gg: momentum * v + gg, vel, g)
        params = jax.tree.map(lambda p, v: p - lr * v, params, vel)
        return params, vel, st, l

    for i in range(steps):
        d = image_batch(seed, i, batch, spec_image_size(spec),
                        spec.in_channels, spec.num_classes)
        params, vel, state, l = step(params, vel, state,
                                     jnp.asarray(d["images"]),
                                     jnp.asarray(d["labels"]))
    return params, state


def spec_image_size(spec: R.ResNetSpec) -> int:
    # input reduces by 2 per extra stage; the paper uses 32x32 -> 1 block
    return 8 * (2 ** (len(spec.widths) - 1))


def eval_accuracy(apply_fn, n_batches: int, batch: int, spec: R.ResNetSpec,
                  seed: int = 1234, jpeg: bool = False) -> float:
    hits, total = 0, 0
    for i in range(n_batches):
        d = image_batch(seed, 10_000 + i, batch, spec_image_size(spec),
                        spec.in_channels, spec.num_classes)
        x = jnp.asarray(d["images"])
        if jpeg:
            x = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality,
                                           scaled=True), 1, 3)
        logits = apply_fn(x)
        hits += int((jnp.argmax(logits, -1) == jnp.asarray(d["labels"])).sum())
        total += batch
    return hits / total
