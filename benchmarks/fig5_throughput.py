"""Paper Fig. 5: training and inference throughput, spatial vs JPEG domain.

The paper's headline: JPEG-domain inference is notably faster (no
decompression, precomputed operators); training is marginally faster.  On
CPU we measure the same quantities end-to-end, *including* the JPEG
decompression step for the spatial model (its inputs are compressed files
— decoding is part of its serving cost, exactly the paper's point).

Modes (``--modes``, default all):

* ``spatial``  — spatial-from-JPEG vs materialised/factored JPEG inference;
* ``dispatch`` — the pallas path + global §6 band-truncation sweep;
* ``plan``     — the convert-once ``InferencePlan`` (fused batch norm,
  per-layer autotuned bands) against PR 1's per-step-batchnorm precomputed
  path;
* ``compiled`` — the compiled plan (``core.plan.compile_plan``): fused
  residual-block steps over tile-packed banded operators, measured against
  the per-layer plan walk at the *same* band assignment — the serving
  configuration;
* ``ingest``   — **bytes → logits**: real baseline JPEG bytes (DRI
  restart markers every MCU, mixed qualities) through the ``repro.codec``
  subsystem — parallel restart-segment entropy decode + per-image
  quantization normalization, never pixels — into the plan walk / the
  compiled schedule's tile-packed stem, decode overlapped with device
  compute (``ingest_pipeline``), vs the spatial decompress-first route
  (sequential scalar decode + IDCT + spatial CNN) — the paper's
  end-to-end serving claim, measured from the wire;
* ``serving``  — the **overload sweep**: a saturating burst of
  single-image requests through the band-elastic runtime
  (``repro.serving``), fixed top-tier configuration vs the elastic QoS
  ladder that degrades bands under load — throughput, per-request
  latency percentiles, tier switches, and top-1 agreement of every
  request the elastic run served at the top tier;
* ``grid``     — the **plan-grid A/B**: a mixed-occupancy request stream
  (singles, partial batches, saturated bursts) through the identical
  single-tier scheduler twice — pre-grid pad-to-``max_batch`` capture
  (``buckets=(batch,)``) vs the aphrodite bucket schedule — isolating
  what the (batch bucket × band tier) capture grid buys: padding waste
  becomes throughput, with zero post-warmup compiles and 100% top-1
  agreement against the per-layer plan walk;
* ``train``    — one SGD step, both domains.

Every row lands in ``BENCH_fig5.json`` tagged with its mode, alongside the
backend, device count, and git SHA, so the perf trajectory is comparable
across PRs regardless of which modes a given run requested (CI uploads the
file as an artifact and ``benchmarks.check_regression`` guards the
speedups):

    PYTHONPATH=src python -m benchmarks.fig5_throughput --reduced \
        --modes plan compiled --out BENCH_fig5.json
"""
from __future__ import annotations

import argparse
import json
import platform
import subprocess
import time

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import convert as CV
from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from benchmarks.common import time_fn, time_pair
from repro.data.synthetic import image_batch

BATCH = 40  # the paper's batch size
SPEC = R.ResNetSpec(widths=(8, 12, 16), num_classes=10)
ALL_MODES = ("spatial", "dispatch", "plan", "compiled", "ingest", "serving",
             "grid", "train")
DEFAULT_OUT = "BENCH_fig5.json"


def _git_sha() -> str | None:
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True, timeout=10,
                              check=True).stdout.strip()
    except Exception:
        return None


def run(emit, *, reduced: bool = False, modes=ALL_MODES,
        out_path: str | None = DEFAULT_OUT) -> dict:
    """Run the selected benchmark modes; returns (and writes) the rows."""
    rows: list[dict] = []
    mode_tag = [None]

    def record(name, us, derived="", speedup=None):
        row = {"name": name, "us_per_call": round(us, 1),
               "derived": derived, "mode": mode_tag[0]}
        if speedup is not None:
            row["speedup"] = round(float(speedup), 3)
        rows.append(row)
        emit(name, us, derived)

    batch = 16 if reduced else BATCH
    iters = 2 if reduced else 3
    params, state = R.init_resnet(jax.random.PRNGKey(0), SPEC)
    d = image_batch(0, 0, batch, 32, 3, 10)
    x = jnp.asarray(d["images"])
    y = jnp.asarray(d["labels"])
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=50, scaled=True), 1, 3)

    if "spatial" in modes:
        mode_tag[0] = "spatial"
        _run_spatial(record, params, state, coef, batch, iters)
    if "dispatch" in modes:
        mode_tag[0] = "dispatch"
        _run_dispatch(record, params, state, coef, batch, iters)
    if "plan" in modes or "compiled" in modes:
        _run_plan(record, params, state, coef, batch, iters, modes, mode_tag)
    if "ingest" in modes:
        mode_tag[0] = "ingest"
        _run_ingest(record, params, state, coef, batch, iters)
    if "serving" in modes:
        mode_tag[0] = "serving"
        _run_serving(record, params, state, coef, batch, reduced)
    if "grid" in modes:
        mode_tag[0] = "grid"
        _run_grid(record, coef, reduced)
    if "train" in modes:
        mode_tag[0] = "train"
        _run_train(record, params, state, coef, y, batch)

    out = {"bench": "fig5", "reduced": reduced, "batch": batch,
           "modes": list(modes), "backend": jax.default_backend(),
           "device_count": jax.device_count(), "git_sha": _git_sha(),
           "python": platform.python_version(), "rows": rows}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(out, f, indent=2)
    return out


def _run_spatial(emit, params, state, coef, batch, iters):
    # ---- inference: JPEG coefficients in, logits out ---------------------
    model = CV.convert(params, state, SPEC, fuse_bn=False)
    jp_infer = jax.jit(model.__call__)

    def sp_infer_from_jpeg(c):
        img = J.jpeg_decode(jnp.moveaxis(c, 3, 1), quality=50, scaled=True)
        return R.spatial_apply(params, state, img, training=False,
                               spec=SPEC)[0]

    sp_infer = jax.jit(sp_infer_from_jpeg)
    t_sp = time_fn(sp_infer, coef, iters=iters)
    t_jp = time_fn(jp_infer, coef, iters=iters)
    emit("fig5/infer_spatial", t_sp, f"img_per_s={batch / (t_sp / 1e6):.1f}")
    emit("fig5/infer_jpeg_materialized", t_jp,
         f"img_per_s={batch / (t_jp / 1e6):.1f}")

    # beyond-paper variant: factored J∘C∘J̃ application (never forms Ξ),
    # selected through the dispatch registry rather than module surgery.
    fact_cfg = DSP.DispatchConfig(path="factored")
    jp_fact = jax.jit(lambda c: R.jpeg_apply(
        params, state, c, training=False, spec=SPEC, dispatch=fact_cfg)[0])
    t_jf = time_fn(jp_fact, coef, iters=iters)
    emit("fig5/infer_jpeg_factored", t_jf,
         f"img_per_s={batch / (t_jf / 1e6):.1f}")
    emit("fig5/infer_speedup_materialized", 0.0, f"{t_sp / t_jp:.2f}x",
         speedup=t_sp / t_jp)
    emit("fig5/infer_speedup_factored", 0.0, f"{t_sp / t_jf:.2f}x",
         speedup=t_sp / t_jf)


def _run_dispatch(emit, params, state, coef, batch, iters):
    # ---- dispatch: pallas path + §6 band truncation -----------------------
    # The paper's sparsity claim as a knob: keep only the first `bands`
    # zigzag coefficients in every operator.  On TPU the pallas path runs
    # the Mosaic kernels; off-TPU it lowers to the same band-truncated
    # matmuls through XLA (the Pallas interpreter is a correctness harness,
    # not a perf path).  Accuracy gate: top-1 agreement with the exact
    # reference on this batch must be 100% for the headline speedup.
    ref_cfg = DSP.DispatchConfig(path="reference", bands=64)
    ref_model = CV.convert(params, state, SPEC, dispatch=ref_cfg,
                           fuse_bn=False)
    ref_infer = jax.jit(ref_model.__call__)
    t_ref = time_fn(ref_infer, coef, iters=iters)
    ref_logits = np.asarray(ref_infer(coef))
    emit("fig5/infer_dispatch_reference", t_ref,
         f"img_per_s={batch / (t_ref / 1e6):.1f}")
    agreeing = []  # (time, bands) at full top-1 agreement
    for bands in (48, 32, 16, 8):
        cfg = DSP.DispatchConfig(path="pallas", bands=bands)
        model = CV.convert(params, state, SPEC, dispatch=cfg, fuse_bn=False)
        fn = jax.jit(model.__call__)
        t_b = time_fn(fn, coef, iters=iters)
        logits = np.asarray(fn(coef))
        agree = float(np.mean(logits.argmax(-1) == ref_logits.argmax(-1)))
        dev = float(np.abs(logits - ref_logits).max())
        emit(f"fig5/infer_dispatch_pallas_b{bands}", t_b,
             f"img_per_s={batch / (t_b / 1e6):.1f} top1_agree={agree:.3f} "
             f"max_logit_dev={dev:.3f}")
        if agree == 1.0:
            agreeing.append((t_b, bands))
    if agreeing:
        t_best, bands_best = min(agreeing)
        emit("fig5/infer_speedup_dispatch_banded", 0.0,
             f"{t_ref / t_best:.2f}x (pallas, bands={bands_best}, "
             f"top1_agree=1.000)", speedup=t_ref / t_best)


def _run_plan(emit, params, state, coef, batch, iters, modes, mode_tag):
    # ---- the convert-once serving engine ---------------------------------
    # Baseline: PR 1's precomputed path — operators baked, but batch norm
    # still applied per step and one global band knob (=64).
    mode_tag[0] = "plan"
    # the plan/compiled speedup ratios feed the CI perf guard
    # (benchmarks.check_regression): sample both sides of each ratio
    # interleaved (time_pair) with enough iterations for a stable median
    # even in --reduced mode — these calls are the cheap ones.
    iters = max(iters, 5)
    base_cfg = DSP.DispatchConfig(path="reference", bands=64)

    # Plan: batch norm fused into Ξ at precompute time, bands autotuned per
    # layer from the quantization table + parity sweep on a probe slice.
    plan = PL.build_plan(params, state, SPEC, dispatch=base_cfg,
                         bands="auto", probe_coef=coef[:4])
    plan_fn = jax.jit(lambda c: PL.apply_plan(plan, c))
    logits = np.asarray(plan_fn(coef))
    bands = sorted(set(plan.bands.values()))

    if "plan" in modes:
        base = CV.convert(params, state, SPEC, dispatch=base_cfg,
                          fuse_bn=False)
        base_fn = jax.jit(base.__call__)
        t_base, t_plan = time_pair(base_fn, plan_fn, coef, iters=iters)
        base_logits = np.asarray(base_fn(coef))
        emit("fig5/infer_precomputed_stepbn", t_base,
             f"img_per_s={batch / (t_base / 1e6):.1f}")
        agree = float(np.mean(logits.argmax(-1) == base_logits.argmax(-1)))
        dev = float(np.abs(logits - base_logits).max())
        emit("fig5/infer_plan_fused_autotuned", t_plan,
             f"img_per_s={batch / (t_plan / 1e6):.1f} top1_agree={agree:.3f} "
             f"max_logit_dev={dev:.3f} bands={'/'.join(map(str, bands))}")
        emit("fig5/infer_speedup_plan", 0.0,
             f"{t_base / t_plan:.2f}x (fused BN, per-layer bands, "
             f"top1_agree={agree:.3f})", speedup=t_base / t_plan)

    if "compiled" in modes:
        # Compiled schedule: fused residual-block steps over tile-packed
        # banded operators, at the *same* per-layer band assignment as the
        # plan walk it is measured against (equal bands, equal math).
        mode_tag[0] = "compiled"
        cp = PL.compile_plan(plan)
        comp_fn = jax.jit(lambda c: PL.apply_compiled(cp, c))
        t_plan, t_comp = time_pair(plan_fn, comp_fn, coef, iters=iters)
        clogits = np.asarray(comp_fn(coef))
        agree = float(np.mean(clogits.argmax(-1) == logits.argmax(-1)))
        dev = float(np.abs(clogits - logits).max())
        n_fused = len(cp.meta["fused"])
        n_layers = len(cp.meta["layers"])  # per-layer *steps*, stem included
        emit("fig5/infer_compiled_fused", t_comp,
             f"img_per_s={batch / (t_comp / 1e6):.1f} top1_agree={agree:.3f} "
             f"max_logit_dev={dev:.4f} fused_blocks={n_fused} "
             f"fallback_steps={n_layers} bands={'/'.join(map(str, bands))}")
        emit("fig5/infer_speedup_compiled", 0.0,
             f"{t_plan / t_comp:.2f}x over plan walk (fused blocks, packed "
             f"operators, top1_agree={agree:.3f})", speedup=t_plan / t_comp)

        # introspection cross-check (informational, unguarded prefixes):
        # per-block predicted-vs-measured over the same compiled schedule
        # — the roofline model's disagreement trends across PRs alongside
        # the guarded speedups
        from repro import introspect

        rep = introspect.predicted_vs_measured(cp, coef, iters=iters)
        for b in rep["blocks"]:
            r = b["ratio"]
            emit(f"fig5/introspect_{b['name']}", b["measured_us"] or 0.0,
                 f"pred_us={b['predicted_us']:.1f} "
                 f"ratio={'' if r is None else f'{r:.2f}'} "
                 f"term={b['term']} exec={b['executor']} "
                 f"bands={b['bands_out']}")
        wr = introspect.worst_ratio(rep)
        t = rep["totals"]
        emit("fig5/predicted_vs_measured_worst_ratio_compiled", 0.0,
             f"{wr:.2f}x worst per-block |predicted vs measured| "
             f"(reconciliation={t['reconciliation']:.3f}, "
             f"logits_match={t['logits_match']})")


def _run_ingest(emit, params, state, coef, batch, iters):
    # ---- bytes → logits: the compressed-ingest serving path ---------------
    # The batch is entropy-encoded to *real* baseline JFIF bytes at a mixed
    # quality rotation (per-image quantization tables, like live traffic)
    # with DRI restart markers every MCU — the segmentation live encoders
    # emit for error resilience and the handle the parallel entropy decoder
    # fans out on.  The transform route runs the serving configuration:
    # batched lockstep segment decode + tile-packed normalize feeding the
    # compiled plan, with decode of batch N+1 overlapped against the device
    # forward of batch N (``codec.ingest_pipeline``).  The spatial route is
    # the decompress-first stack it displaces: per-image sequential entropy
    # decode, IDCT back to pixels, spatial CNN — the paper's "skip the
    # decompression step" claim, measured from the wire at serving
    # concurrency.
    from repro import codec
    from repro.core import dct as dctlib
    from repro.data.synthetic import image_batch

    iters = max(iters, 3)

    def encode_traffic(side, qualities):
        d = image_batch(0, 0, batch, side, 3, 10)
        datas = []
        for i, img in enumerate(d["images"]):
            qt = np.rint(dctlib.quantization_table(
                qualities[i % len(qualities)],
                dc_is_mean=False)).astype(np.int64)
            datas.append(codec.encode_pixels(
                np.clip(img, -1.0, 127.0 / 128.0), qtable=qt,
                restart_interval=1))
        return datas, (side // dctlib.BLOCK, side // dctlib.BLOCK)

    # parity traffic (the committed walk-vs-compiled rows): the fig5
    # batch size at a mixed quality rotation, like live traffic
    datas, grid = encode_traffic(32, (35, 50, 75, 90))
    ikw = dict(quality=SPEC.quality, grid=grid, channels=3)

    def ingest(pack_width=None, parallel=None):
        return codec.ingest_batch(datas, pack_width=pack_width,
                                  with_stats=False, parallel=parallel,
                                  **ikw)[0]

    # plan autotuned from the byte traffic's own energy profile
    full, stats = codec.ingest_batch(datas, **ikw)
    base_cfg = DSP.DispatchConfig(path="reference", bands=64)
    probe = jnp.asarray(full[:4])
    plan = PL.build_plan(params, state, SPEC, dispatch=base_cfg,
                         bands="auto", probe_coef=probe,
                         profile=stats.energy, occupancy=stats.occupancy)
    cp = PL.compile_plan(plan)
    plan_fn = jax.jit(lambda c: PL.apply_plan(plan, c))
    comp_fn = jax.jit(lambda c: PL.apply_compiled_packed(cp, c))
    pack_w = cp.stem.w_in

    def sp_fwd(c):
        img = J.jpeg_decode(jnp.moveaxis(c, 3, 1), quality=SPEC.quality,
                            scaled=True)
        return R.spatial_apply(params, state, img, training=False,
                               spec=SPEC)[0]

    sp_fn = jax.jit(sp_fwd)

    def bytes_walk():
        return plan_fn(jnp.asarray(ingest(parallel=True)))

    def bytes_compiled():
        return comp_fn(jnp.asarray(ingest(pack_width=pack_w, parallel=True)))

    t_walk, t_comp = time_pair(bytes_walk, bytes_compiled, iters=iters)
    agree = float(np.mean(np.asarray(bytes_compiled()).argmax(-1)
                          == np.asarray(bytes_walk()).argmax(-1)))
    bands = sorted(set(plan.bands.values()))
    emit("fig5/ingest_plan_walk", t_walk,
         f"img_per_s={batch / (t_walk / 1e6):.1f}")
    emit("fig5/ingest_compiled", t_comp,
         f"img_per_s={batch / (t_comp / 1e6):.1f} top1_agree={agree:.3f} "
         f"bands={'/'.join(map(str, bands))} pack_w={pack_w}")
    # guarded: both sides share the identical host entropy decode and
    # differ only in the network path, so the ratio is stable enough for
    # the CI perf guard
    emit("fig5/infer_speedup_ingest_compiled", 0.0,
         f"{t_walk / t_comp:.2f}x bytes->logits over plan walk "
         f"(tile-packed ingest, top1_agree={agree:.3f})",
         speedup=t_walk / t_comp)

    # ---- the headline: bytes → logits at serving concurrency -------------
    # Serving traffic: 64x64 at a high-quality rotation.  Live JPEG
    # traffic is predominantly high quality (dense AC streams) — exactly
    # where the entropy decode dominates a decompress-first stack; low
    # qualities make the scalar baseline artificially cheap (near-empty
    # streams).  64 keeps every stride-2 stage 8-divisible for the
    # compiled plan's fused fallback.  The serving network configuration
    # (the compiled plan and its pack width) stays the one autotuned
    # above — serving fixes the plan before the traffic arrives.
    sdatas, sgrid = encode_traffic(64, (75, 85, 90, 95))
    skw = dict(quality=SPEC.quality, grid=sgrid, channels=3)
    sn_bytes = sum(len(x) for x in sdatas)
    n_streams = sum(codec.count_streams([codec.prepare_scan(x)])
                    for x in sdatas)
    _, s_stats = codec.ingest_batch(sdatas, **skw)

    def s_ingest(pack_width=None, parallel=None):
        return codec.ingest_batch(sdatas, pack_width=pack_width,
                                  with_stats=False, parallel=parallel,
                                  **skw)[0]

    # decode rows: the per-image scalar reference vs the parallel
    # restart-segment decoder (lockstep vector decode in-process, sharded
    # worker pool when JPEG_INGEST_WORKERS allows), identical outputs
    t_dseq, t_dpar = time_pair(lambda: s_ingest(pack_w, False),
                               lambda: s_ingest(pack_w, True), iters=iters)
    mb_s = sn_bytes / (t_dpar / 1e6) / 2**20
    emit("fig5/ingest_decode_sequential", t_dseq,
         f"img_per_s={batch / (t_dseq / 1e6):.1f} segments={n_streams}")
    emit("fig5/ingest_decode_only", t_dpar,
         f"img_per_s={batch / (t_dpar / 1e6):.1f} mb_per_s={mb_s:.2f} "
         f"nonzero_per_block={s_stats.mean_nonzero:.1f} "
         f"workers={codec.ingest_workers()} "
         f"decode_speedup={t_dseq / t_dpar:.2f}x")

    # A short request stream (several batches deep) through each route.
    # Transform: overlapped pipeline — parallel segment decode of batch
    # N+1 runs while the device forwards batch N into the compiled plan.
    # Spatial: the decompress-first baseline — each batch entropy-decoded
    # per image by the scalar reference, IDCT'd to pixels, classified by
    # the spatial CNN; strictly sequential, as a decompress-then-infer
    # stack is.
    n_stream = 4

    def transform_stream():
        it = codec.ingest_pipeline([sdatas] * n_stream, depth=2,
                                   pack_width=pack_w, with_stats=False,
                                   parallel=True, **skw)
        out = None
        try:
            for coefb, _ in it:
                out = comp_fn(jnp.asarray(coefb))
        finally:
            it.close()
        return out

    def spatial_stream():
        out = None
        for _ in range(n_stream):
            c, _ = codec.ingest_batch(sdatas, with_stats=False,
                                      parallel=False, **skw)
            out = sp_fn(jnp.asarray(c))
        return out

    t_sp, t_tr = time_pair(spatial_stream, transform_stream, iters=iters)
    t_sp, t_tr = t_sp / n_stream, t_tr / n_stream
    emit("fig5/ingest_spatial_decompress", t_sp,
         f"img_per_s={batch / (t_sp / 1e6):.1f} (sequential decode + "
         f"IDCT + spatial CNN)")
    emit("fig5/ingest_compiled_overlapped", t_tr,
         f"img_per_s={batch / (t_tr / 1e6):.1f} "
         f"(pipeline depth=2, {n_stream}-batch stream)")
    # guarded (see check_regression --prefix): the paper's serving claim —
    # the transform route must actually *win* from the wire
    emit("fig5/ingest_speedup_vs_spatial", 0.0,
         f"{t_sp / t_tr:.2f}x bytes->logits over spatial decompress+"
         f"classify (parallel segment decode, overlapped ingest)",
         speedup=t_sp / t_tr)
    # informational: give the spatial route the *same* parallel decoder
    # and device IDCT — isolates how much of the win is decode
    # parallelism vs skipping the pixel-domain round trip entirely
    def transform_batch():
        return comp_fn(jnp.asarray(s_ingest(pack_w, True)))

    def spatial_shared_decoder():
        return sp_fn(jnp.asarray(s_ingest(parallel=True)))

    t_sh, t_comp2 = time_pair(spatial_shared_decoder, transform_batch,
                              iters=iters)
    emit("fig5/ingest_spatial_shared_decoder", t_sh,
         f"img_per_s={batch / (t_sh / 1e6):.1f} vs_compiled="
         f"{t_sh / t_comp2:.2f}x (parallel decode + device IDCT + "
         f"spatial CNN)")


def _run_serving(emit, params, state, coef, batch, reduced):
    # ---- overload sweep: fixed top tier vs the band-elastic ladder --------
    # A saturating burst of single-image requests (several batches deep, no
    # pacing) hits each configuration; both run the identical scheduler and
    # request stream, so the throughput ratio isolates the QoS policy.  The
    # fixed configuration is a one-rung ladder pinned at the plan's own
    # bands — today's serve default; the elastic configuration degrades
    # bands under queue pressure and recovers as it drains.  The sweep runs
    # the serve-scale network (the reduced jpeg-resnet widths) rather than
    # the tiny fig5 parity spec: band elasticity is a *compute* lever, and
    # on a model small enough for scheduler overhead to dominate the knob
    # has nothing to trade.
    from repro import serving as sv

    spec = R.ResNetSpec(widths=(16, 32, 64), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    base_cfg = DSP.DispatchConfig(path="reference", bands=64)
    # full-band plan: the fixed configuration serves the paper-exact
    # bands=64 operators (the serve default when nothing is autotuned),
    # which is precisely the configuration with headroom to trade
    plan = PL.build_plan(params, state, spec, dispatch=base_cfg)
    plan_fn = jax.jit(lambda c: PL.apply_plan(plan, c))
    ref_logits = np.asarray(plan_fn(coef))
    images = [np.asarray(coef[i]) for i in range(coef.shape[0])]
    n_req = 96 if reduced else 192
    slots = min(4, batch)
    grid = coef.shape[1:3]

    ladder_el = sv.build_ladder(plan, caps=sv.DEFAULT_CAPS)
    # the fixed configuration is exactly the elastic ladder's top rung —
    # reuse the compiled tier instead of paying compile_plan again
    ladder_fx = sv.PlanLadder((ladder_el.tiers[0],), plan, (None,),
                              ladder_el.image_size, ladder_el.vmem_budget)

    def run_config(ladder, tracer=None):
        metrics = sv.ServeMetrics()
        # fixed-bucket capture: this sweep isolates the QoS *tier* policy
        # under a saturated stream, where every batch fills anyway — the
        # bucket schedule is the grid mode's variable, and pinning it
        # keeps the warmup to one cell per tier column
        sched = sv.BandElasticScheduler(ladder, batch=slots,
                                        metrics=metrics, max_pending=n_req,
                                        grid=grid, channels=coef.shape[3],
                                        buckets=(slots,), tracer=tracer)
        with sched:
            sched.warmup(kinds=("coefficients",))
            t0 = time.perf_counter()
            reqs = [sched.submit(images[i % len(images)])
                    for i in range(n_req)]
            sched.drain()
            wall = time.perf_counter() - t0
        return reqs, wall, metrics.report()

    fixed_reqs, fixed_wall, fixed_rep = run_config(ladder_fx)
    el_reqs, el_wall, el_rep = run_config(ladder_el)
    # flight recorder on the identical elastic configuration: the ring is
    # sized to hold the whole run, so the ratio is the *recording* cost
    tracer = sv.Tracer(capacity=1 << 17)
    _, tr_wall, _ = run_config(ladder_el, tracer=tracer)

    # fidelity gate: every request the elastic run served at the top tier
    # must match the per-layer plan walk's top-1 on that image
    top = [(i, r) for i, r in enumerate(el_reqs) if r.tier == "top"]
    agree = float(np.mean([
        np.asarray(r.result()).argmax(-1)
        == ref_logits[i % len(images)].argmax(-1)
        for i, r in top])) if top else 1.0
    tiers_used = sorted({r.tier for r in el_reqs})
    lat_f, lat_e = fixed_rep["latency_ms"], el_rep["latency_ms"]
    tp_f = n_req / fixed_wall
    tp_e = n_req / el_wall

    emit("fig5/serving_fixed_top", fixed_wall / n_req * 1e6,
         f"img_per_s={tp_f:.1f} p50={lat_f['p50_ms']:.0f}ms "
         f"p95={lat_f['p95_ms']:.0f}ms p99={lat_f['p99_ms']:.0f}ms")
    emit("fig5/serving_elastic", el_wall / n_req * 1e6,
         f"img_per_s={tp_e:.1f} p50={lat_e['p50_ms']:.0f}ms "
         f"p95={lat_e['p95_ms']:.0f}ms p99={lat_e['p99_ms']:.0f}ms "
         f"switches={len(el_rep['tier_switches'])} "
         f"tiers={'/'.join(tiers_used)} top1_agree_top={agree:.3f}")
    # guarded once a baseline carrying it is committed (the first run
    # prints as INFO in check_regression); the committed baseline floors
    # this deliberately below the observed range — the ratio is a
    # saturated-throughput A/B on one machine but still noisier than the
    # interleaved time_pair rows
    emit("fig5/infer_speedup_serving_elastic", 0.0,
         f"{tp_e / tp_f:.2f}x saturated throughput over fixed top tier "
         f"(band-elastic QoS, {len(el_rep['tier_switches'])} switches, "
         f"top1_agree_top={agree:.3f})", speedup=tp_e / tp_f)
    # informational (unguarded): the same elastic run with the flight
    # recorder on — recording overhead as a fraction of throughput
    tp_t = n_req / tr_wall
    summ = tracer.summary()
    emit("fig5/serving_trace_overhead", tr_wall / n_req * 1e6,
         f"img_per_s={tp_t:.1f} overhead={(tr_wall / el_wall - 1) * 100:+.1f}% "
         f"events={summ['events']} dropped={summ['dropped']}")


def _run_grid(emit, coef, reduced):
    # ---- plan grid: bucketed capture vs pad-to-max_batch ------------------
    # Mixed-occupancy traffic is where max_batch padding hurts: a trickle
    # of singles, partial batches of 3, and saturated bursts each hit the
    # identical single-tier scheduler (one rung — so the QoS ladder stays
    # out of the measurement) under two capture policies.  The fixed
    # configuration is the pre-grid behaviour, one executable padded to
    # the full slot count; the grid configuration captures the aphrodite
    # bucket schedule (1, 2, 4, 8) and runs every batch in its covering
    # bucket.  Same serve-scale network as the serving sweep: bucketing
    # is a GEMM-width lever, invisible on a model small enough for
    # scheduler overhead to dominate.
    from repro import serving as sv

    spec = R.ResNetSpec(widths=(16, 32, 64), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    plan = PL.build_plan(params, state, spec,
                         dispatch=DSP.DispatchConfig(path="reference",
                                                     bands=64))
    plan_fn = jax.jit(lambda c: PL.apply_plan(plan, c))
    ref_top1 = np.asarray(plan_fn(coef)).argmax(-1)
    images = [np.asarray(coef[i]) for i in range(coef.shape[0])]
    slots = 8
    grid = coef.shape[1:3]
    ladder = sv.build_ladder(plan, caps=(None,))

    trickle = 8 if reduced else 16      # phase 1: singles, fully drained
    groups = 5 if reduced else 10       # phase 2: partial batches of 3
    bursts = 2 if reduced else 4        # phase 3: saturated full batches
    phases = ([[i % len(images)] for i in range(trickle)]
              + [[(g * 3 + j) % len(images) for j in range(3)]
                 for g in range(groups)]
              + [[(b * slots + j) % len(images) for j in range(slots)]
                 for b in range(bursts)])
    flat = [i for p in phases for i in p]
    n_req = len(flat)

    def run_config(buckets, profile=False):
        metrics = sv.ServeMetrics()
        sched = sv.BandElasticScheduler(
            ladder, batch=slots, metrics=metrics, max_pending=n_req,
            grid=grid, channels=coef.shape[3], buckets=buckets)
        reqs, pg = [], None
        with sched:
            sched.warmup(kinds=("coefficients",))
            t0 = time.perf_counter()
            for p in phases:
                batch_reqs = [sched.submit(images[i]) for i in p]
                if len(p) < slots:  # hold occupancy: drain before the next
                    sched.drain()
                reqs += batch_reqs
            sched.drain()
            wall = time.perf_counter() - t0
            if profile:
                # after the timed window, on the warmed grid (captured
                # executables only — no post-warmup compiles recorded)
                from repro import introspect

                pg = introspect.profile_plan_grid(sched.grid_engine,
                                                  iters=2)
        return reqs, wall, metrics.report(), pg

    fx_reqs, fx_wall, fx_rep, _ = run_config((slots,))  # pad-to-max
    gd_reqs, gd_wall, gd_rep, pg = run_config(None, profile=True)

    # fidelity gate: bucket padding must be inert — every grid-served
    # request agrees with the per-layer plan walk's top-1 on its image
    agree = float(np.mean([
        int(np.asarray(r.result()).argmax(-1)) == ref_top1[i]
        for r, i in zip(gd_reqs, flat)]))
    tp_f = n_req / fx_wall
    tp_g = n_req / gd_wall
    emit("fig5/grid_mixed_fixed", fx_wall / n_req * 1e6,
         f"img_per_s={tp_f:.1f} padding={fx_rep['padding_fraction']:.2f} "
         f"buckets=({slots},) "
         f"compiles_post_warmup={fx_rep['compiles_post_warmup']}")
    emit("fig5/grid_mixed_bucketed", gd_wall / n_req * 1e6,
         f"img_per_s={tp_g:.1f} padding={gd_rep['padding_fraction']:.2f} "
         f"buckets={sv.batch_buckets(slots)} "
         f"compiles_post_warmup={gd_rep['compiles_post_warmup']} "
         f"top1_agree={agree:.3f}")
    emit("fig5/grid_throughput_vs_fixed", 0.0,
         f"{tp_g / tp_f:.2f}x mixed-occupancy throughput over "
         f"pad-to-max_batch (padding {fx_rep['padding_fraction']:.2f}"
         f"→{gd_rep['padding_fraction']:.2f}, "
         f"{gd_rep['compiles_post_warmup']} post-warmup compiles, "
         f"top1_agree={agree:.3f})", speedup=tp_g / tp_f)
    # informational: roofline disagreement across the warmed grid's
    # reference cells (per-block, measured on the captured executables)
    from repro import introspect

    wr = introspect.worst_ratio({"blocks": [b for c in pg["columns"]
                                            for b in c["blocks"]]})
    caps = " ".join(f"{c['cell']}={c['predicted_req_s']:.0f}rps"
                    for c in pg["cells"][:4])
    emit("fig5/predicted_vs_measured_worst_ratio_grid", 0.0,
         f"{wr:.2f}x worst per-block |predicted vs measured| over "
         f"{len(pg['columns'])} reference cells ({caps})")


def _run_train(emit, params, state, coef, y, batch):
    # ---- training step ----------------------------------------------------
    @jax.jit
    def sp_train(params, c, y):
        def loss_fn(p):
            img = J.jpeg_decode(jnp.moveaxis(c, 3, 1), quality=50, scaled=True)
            logits, st = R.spatial_apply(p, state, img, training=True,
                                         spec=SPEC)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        g = jax.grad(loss_fn)(params)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)

    @jax.jit
    def jp_train(params, c, y):
        def loss_fn(p):
            logits, st = R.jpeg_apply(p, state, c, training=True, spec=SPEC)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        g = jax.grad(loss_fn)(params)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)

    t_sp_t = time_fn(sp_train, params, coef, y, iters=2)
    t_jp_t = time_fn(jp_train, params, coef, y, iters=2)
    emit("fig5/train_spatial", t_sp_t, f"img_per_s={batch / (t_sp_t / 1e6):.1f}")
    emit("fig5/train_jpeg", t_jp_t, f"img_per_s={batch / (t_jp_t / 1e6):.1f}")
    emit("fig5/train_speedup", 0.0, f"{t_sp_t / t_jp_t:.2f}x",
         speedup=t_sp_t / t_jp_t)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="smaller batch / fewer timing iters (CI smoke)")
    ap.add_argument("--modes", nargs="+", default=list(ALL_MODES),
                    choices=ALL_MODES)
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="JSON results path ('' disables)")
    args = ap.parse_args()
    print("name,us_per_call,derived")

    def emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(emit, reduced=args.reduced, modes=tuple(args.modes),
        out_path=args.out or None)


if __name__ == "__main__":
    main()
