"""Paper Fig. 5: training and inference throughput, spatial vs JPEG domain.

The paper's headline: JPEG-domain inference is notably faster (no
decompression, precomputed operators); training is marginally faster.  On
CPU we measure the same quantities end-to-end, *including* the JPEG
decompression step for the spatial model (its inputs are compressed files
— decoding is part of its serving cost, exactly the paper's point).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import convert as CV
from repro.core import jpeg as J
from repro.core import resnet as R
from benchmarks.common import time_fn
from repro.data.synthetic import image_batch

BATCH = 40  # the paper's batch size
SPEC = R.ResNetSpec(widths=(8, 12, 16), num_classes=10)


def run(emit) -> None:
    params, state = R.init_resnet(jax.random.PRNGKey(0), SPEC)
    d = image_batch(0, 0, BATCH, 32, 3, 10)
    x = jnp.asarray(d["images"])
    y = jnp.asarray(d["labels"])
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=50, scaled=True), 1, 3)

    # ---- inference: JPEG coefficients in, logits out ---------------------
    model = CV.convert(params, state, SPEC)
    jp_infer = jax.jit(model.__call__)

    def sp_infer_from_jpeg(c):
        img = J.jpeg_decode(jnp.moveaxis(c, 3, 1), quality=50, scaled=True)
        return R.spatial_apply(params, state, img, training=False,
                               spec=SPEC)[0]

    sp_infer = jax.jit(sp_infer_from_jpeg)
    t_sp = time_fn(sp_infer, coef)
    t_jp = time_fn(jp_infer, coef)
    emit("fig5/infer_spatial", t_sp, f"img_per_s={BATCH / (t_sp / 1e6):.1f}")
    emit("fig5/infer_jpeg_materialized", t_jp,
         f"img_per_s={BATCH / (t_jp / 1e6):.1f}")

    # beyond-paper variant: factored J∘C∘J̃ application (never forms Ξ)
    import repro.core.conv as conv_mod
    old_limit = conv_mod.MATERIALIZE_LIMIT
    conv_mod.MATERIALIZE_LIMIT = 0
    try:
        jp_fact = jax.jit(lambda c: R.jpeg_apply(
            params, state, c, training=False, spec=SPEC)[0])
        t_jf = time_fn(jp_fact, coef)
    finally:
        conv_mod.MATERIALIZE_LIMIT = old_limit
    emit("fig5/infer_jpeg_factored", t_jf,
         f"img_per_s={BATCH / (t_jf / 1e6):.1f}")
    emit("fig5/infer_speedup_materialized", 0.0, f"{t_sp / t_jp:.2f}x")
    emit("fig5/infer_speedup_factored", 0.0, f"{t_sp / t_jf:.2f}x")

    # ---- training step ----------------------------------------------------
    @jax.jit
    def sp_train(params, c, y):
        def loss_fn(p):
            img = J.jpeg_decode(jnp.moveaxis(c, 3, 1), quality=50, scaled=True)
            logits, st = R.spatial_apply(p, state, img, training=True,
                                         spec=SPEC)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        g = jax.grad(loss_fn)(params)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)

    @jax.jit
    def jp_train(params, c, y):
        def loss_fn(p):
            logits, st = R.jpeg_apply(p, state, c, training=True, spec=SPEC)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        g = jax.grad(loss_fn)(params)
        return jax.tree.map(lambda p, gg: p - 1e-3 * gg, params, g)

    t_sp_t = time_fn(sp_train, params, coef, y, iters=2)
    t_jp_t = time_fn(jp_train, params, coef, y, iters=2)
    emit("fig5/train_spatial", t_sp_t, f"img_per_s={BATCH / (t_sp_t / 1e6):.1f}")
    emit("fig5/train_jpeg", t_jp_t, f"img_per_s={BATCH / (t_jp_t / 1e6):.1f}")
    emit("fig5/train_speedup", 0.0, f"{t_sp_t / t_jp_t:.2f}x")
