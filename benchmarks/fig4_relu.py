"""Paper Fig. 4: ReLU approximation accuracy.

4a — raw block RMSE of ASM vs APX over spatial frequencies 1..15, using the
paper's protocol (random 4×4 blocks box-upscaled to 8×8; the paper uses 10M
blocks, we use 200k on CPU — the curves are already stable at 1e5).

4b — model-conversion accuracy vs phi (spatial-trained weights).
4c — JPEG-domain-trained accuracy vs phi (weights learn to cope).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import asm as A
from repro.core import dct as D
from repro.core import jpeg as J
from repro.core import resnet as R
from benchmarks.common import eval_accuracy, train_spatial_resnet
from repro.data.synthetic import image_batch

N_BLOCKS = 200_000
SPEC = R.ResNetSpec(widths=(8, 12, 16), num_classes=10)


def _paper_blocks(n: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    small = rng.uniform(-1, 1, size=(n, 4, 4))
    big = np.kron(small, np.ones((2, 2)))
    coef = D.dct2(big).reshape(n, 64)[:, D.zigzag_permutation()]
    return jnp.asarray(coef, jnp.float32)


def fig4a(emit) -> None:
    coef = _paper_blocks(N_BLOCKS)
    oracle = A.spatial_relu_oracle(coef)
    asm_rmse = jax.jit(lambda c, phi: jnp.sqrt(jnp.mean(
        (A.asm_relu(c, phi) - oracle) ** 2)), static_argnums=1)
    apx_rmse = jax.jit(lambda c, phi: jnp.sqrt(jnp.mean(
        (A.apx_relu(c, phi) - oracle) ** 2)), static_argnums=1)
    wins = 0
    for phi in range(1, 15):
        e_asm = float(asm_rmse(coef, phi))
        e_apx = float(apx_rmse(coef, phi))
        wins += e_asm <= e_apx
        emit(f"fig4a/phi{phi:02d}", 0.0, f"asm={e_asm:.4f};apx={e_apx:.4f}")
    emit("fig4a/asm_wins", 0.0, f"{wins}/14")


def fig4b(emit) -> None:
    params, state = train_spatial_resnet(SPEC, steps=100, batch=32, seed=0)
    for phi in (2, 6, 10, 14):
        fwd = jax.jit(lambda c, phi=phi: R.jpeg_apply(
            params, state, c, training=False, spec=SPEC, phi=phi)[0])
        acc = eval_accuracy(fwd, 5, 32, SPEC, jpeg=True)
        emit(f"fig4b/conversion_phi{phi:02d}", 0.0, f"acc={acc:.4f}")


def fig4c(emit) -> None:
    """Train *in* the JPEG domain at reduced phi: weights cope (paper §5.3)."""
    for phi in (6, 14):
        spec = R.ResNetSpec(widths=(8, 12, 16), num_classes=10, phi=phi)
        params, state = R.init_resnet(jax.random.PRNGKey(0), spec)

        @jax.jit
        def step(params, state, c, y):
            def loss_fn(p):
                logits, st = R.jpeg_apply(p, state, c, training=True,
                                          spec=spec, phi=phi)
                lp = jax.nn.log_softmax(logits)
                return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1)), st
            (l, st), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params = jax.tree.map(lambda p, gg: p - 8e-3 * gg, params, g)
            return params, st

        for i in range(60):
            d = image_batch(0, i, 32, 32, 3, 10)
            coef = jnp.moveaxis(J.jpeg_encode(jnp.asarray(d["images"]),
                                              quality=50, scaled=True), 1, 3)
            params, state = step(params, state, coef,
                                 jnp.asarray(d["labels"]))
        fwd = jax.jit(lambda c: R.jpeg_apply(params, state, c,
                                             training=False, spec=spec,
                                             phi=phi)[0])
        acc = eval_accuracy(fwd, 5, 32, spec, jpeg=True)
        emit(f"fig4c/jpeg_trained_phi{phi:02d}", 0.0, f"acc={acc:.4f}")


def run(emit) -> None:
    fig4a(emit)
    fig4b(emit)
    fig4c(emit)
