"""Benchmark harness — one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV (harness contract).  Usage:

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run fig5 roofline
"""
from __future__ import annotations

import sys


def main() -> None:
    which = set(sys.argv[1:]) or {"table1", "fig4", "fig5", "roofline"}
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}", flush=True)

    if "table1" in which:
        from benchmarks import table1_conversion
        table1_conversion.run(emit)
    if "fig4" in which:
        from benchmarks import fig4_relu
        fig4_relu.run(emit)
    if "fig5" in which:
        from benchmarks import fig5_throughput
        fig5_throughput.run(emit)
    if "roofline" in which:
        from benchmarks import roofline
        roofline.run(emit)


if __name__ == "__main__":
    main()
