"""Perf guard over the fig5 trajectory: compare a fresh ``BENCH_fig5.json``
against the committed baseline and fail when any shared speedup row
regresses by more than the allowed fraction.

Speedups are same-run *ratios* (e.g. compiled-over-plan on the same
machine), so they are comparable across hosts in a way raw microseconds
are not.  Rows are matched by name against ``--prefix``, a
comma-separated list of name prefixes (default ``fig5/infer_speedup_``
plus ``fig5/ingest_speedup_`` — the bytes→logits serving-concurrency
ratio — and ``fig5/grid_throughput_`` — the plan-grid bucketed-capture
gain on mixed-occupancy traffic); rows present in only one file are
reported
but never compared (modes come and go across PRs).  In particular a row
present only in the *fresh* run — a brand-new benchmark mode, e.g. the
first run of the ``serving`` overload sweep — is **informational**: it
prints as ``INFO new row`` and cannot fail the guard until a baseline
containing it is committed.  The guard still fails whenever the
comparison is empty — no shared rows, or a baseline with no guarded rows
at all (corrupt file / wrong prefix) — a silently-empty comparison must
not pass.

    python -m benchmarks.check_regression baseline.json BENCH_fig5.json \
        --max-regression 0.2
"""
from __future__ import annotations

import argparse
import json
import sys


def speedup_of(row: dict) -> float | None:
    """Numeric speedup of a row: the ``speedup`` field, else the leading
    ``<x>x`` of ``derived`` (older baselines predate the field)."""
    if row.get("speedup") is not None:
        return float(row["speedup"])
    derived = row.get("derived", "")
    head = derived.split("x")[0].strip()
    try:
        return float(head.split()[-1])
    except (ValueError, IndexError):
        return None


def load_speedups(path: str, prefixes: tuple[str, ...]) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    out = {}
    for row in data.get("rows", []):
        if row.get("name", "").startswith(prefixes):
            val = speedup_of(row)
            if val is not None:
                out[row["name"]] = val
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_fig5.json")
    ap.add_argument("fresh", help="freshly measured BENCH_fig5.json")
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="allowed fractional drop below baseline (0.2 = "
                         "fail under 80%% of the committed speedup)")
    ap.add_argument("--prefix",
                    default="fig5/infer_speedup_,fig5/ingest_speedup_,"
                            "fig5/grid_throughput_",
                    help="comma-separated list of guarded row-name "
                         "prefixes")
    args = ap.parse_args()

    prefixes = tuple(p for p in args.prefix.split(",") if p)
    base = load_speedups(args.baseline, prefixes)
    fresh = load_speedups(args.fresh, prefixes)
    compared, failures = 0, []
    for name in sorted(set(base) | set(fresh)):
        if name not in base:
            # a mode's first run: report, never fail — the row becomes
            # guarded once a baseline containing it is committed
            print(f"INFO new row {name}: {fresh[name]:.2f}x "
                  "(not in baseline; informational until committed)")
            continue
        if name not in fresh:
            print(f"SKIP {name}: only in baseline (mode not run)")
            continue
        compared += 1
        floor = base[name] * (1.0 - args.max_regression)
        status = "FAIL" if fresh[name] < floor else "ok"
        print(f"{status:4s} {name}: baseline {base[name]:.2f}x -> "
              f"fresh {fresh[name]:.2f}x (floor {floor:.2f}x)")
        if fresh[name] < floor:
            failures.append(name)
    if not compared:
        # an empty comparison must not pass: a truncated/corrupt baseline
        # or a typo'd --prefix would otherwise wave every regression
        # through with nothing but log noise
        if not base:
            print("FAIL: baseline has no guarded speedup rows "
                  f"(prefix {args.prefix!r}) — corrupt baseline or wrong "
                  "prefix")
        else:
            print("FAIL: baseline speedup rows "
                  f"{sorted(base)} absent from the fresh run")
        sys.exit(1)
    if failures:
        print(f"perf guard failed: {', '.join(failures)}")
        sys.exit(1)
    print(f"perf guard passed ({compared} speedup rows within "
          f"{args.max_regression:.0%} of baseline)")


if __name__ == "__main__":
    main()
