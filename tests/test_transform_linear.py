"""Generalised transform-domain folding (beyond-paper, VLM/audio frontends)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import dct as D
from repro.core import jpeg as J
from repro.core.transform_linear import (
    fold_frontend, fold_patch_embed, unfold_patches_to_blocks,
)


def test_fold_patch_embed_exact(rng):
    """ViT patch embedding over JPEG coefficients == over pixels (exact)."""
    patch, channels, d = 16, 3, 32
    imgs = jnp.asarray(rng.normal(size=(2, channels, 32, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(channels * patch * patch, d)) * 0.05,
                    jnp.float32)
    # pixel-domain embeddings
    patches = unfold_patches_to_blocks(imgs, patch)  # (N, P, C*16*16)
    ref = patches @ w
    # JPEG-domain: encode per patch into (C, 2, 2, 64) coefficient layout
    coef = J.jpeg_encode(imgs, scaled=True)  # (N, C, 4, 4, 64)
    n = imgs.shape[0]
    g = 32 // patch
    pb = patch // 8
    cc = coef.reshape(n, channels, g, pb, g, pb, 64)
    cc = jnp.moveaxis(cc, 4, 3)  # (n, C, g, g, pb, pb, 64)
    cc = jnp.moveaxis(cc, 1, 3)  # (n, g, g, C, pb, pb, 64)
    flat = cc.reshape(n, g * g, channels * pb * pb * 64)
    w_jpeg = fold_patch_embed(w, patch, channels, scaled=True)
    out = flat @ w_jpeg
    np.testing.assert_allclose(out, ref, atol=1e-3)


def test_fold_frontend_orthonormal(rng):
    """Folding an orthonormal analysis map into a following linear layer."""
    a = np.linalg.qr(rng.normal(size=(64, 64)))[0]
    w = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(5, 64)), jnp.float32)
    coeffs = x @ jnp.asarray(a, jnp.float32).T  # analysis
    folded = fold_frontend(jnp.asarray(a, jnp.float32), w)
    np.testing.assert_allclose(coeffs @ folded, x @ w, atol=1e-4)


def test_vlm_jpeg_patch_embed_integration(rng):
    """The internvl2 tower consumes JPEG-domain patch embeddings losslessly:
    fold the (random) patch projection, feed coefficient-embedded vision
    tokens, compare with the pixel path."""
    from repro.configs.base import reduced_config
    from repro.models import build_model

    cfg = reduced_config("internvl2-1b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    patch, channels = 16, 3
    n_patch = cfg.vision_prefix_len
    side = int(np.sqrt(n_patch)) * patch
    imgs = jnp.asarray(rng.normal(size=(2, channels, side, side)) * 0.3,
                       jnp.float32)
    w = jnp.asarray(rng.normal(size=(channels * patch * patch, cfg.d_model))
                    * 0.02, jnp.float32)
    pixel_embeds = unfold_patches_to_blocks(imgs, patch) @ w

    coef = J.jpeg_encode(imgs, scaled=True)
    g = side // patch
    pb = patch // 8
    cc = coef.reshape(2, channels, g, pb, g, pb, 64)
    cc = jnp.moveaxis(cc, 4, 3)
    cc = jnp.moveaxis(cc, 1, 3)
    flat = cc.reshape(2, g * g, channels * pb * pb * 64)
    jpeg_embeds = flat @ fold_patch_embed(w, patch, channels, scaled=True)

    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    out_px, _ = model.forward(params, {"tokens": toks,
                                       "vision_embeds": pixel_embeds})
    out_jp, _ = model.forward(params, {"tokens": toks,
                                       "vision_embeds": jpeg_embeds})
    np.testing.assert_allclose(out_px, out_jp, atol=1e-3)
