"""DCT constants: orthonormality, zigzag, bands, quantization tables."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import dct as D


def test_dct_orthonormal():
    d = D.dct_matrix()
    assert np.allclose(d @ d.T, np.eye(8), atol=1e-12)
    assert np.allclose(d.T @ d, np.eye(8), atol=1e-12)


def test_dct2_idct2_roundtrip(rng):
    x = rng.normal(size=(5, 8, 8))
    assert np.allclose(D.idct2(D.dct2(x)), x, atol=1e-10)


def test_zigzag_is_permutation():
    zz = D.zigzag_permutation()
    assert sorted(zz.tolist()) == list(range(64))
    # JPEG standard: starts DC, then (0,1), (1,0), (2,0), (1,1), (0,2)...
    order = D.zigzag_order()
    assert order[0].tolist() == [0, 0]
    assert order[1].tolist() == [0, 1]
    assert order[2].tolist() == [1, 0]
    assert order[3].tolist() == [2, 0]
    assert order[63].tolist() == [7, 7]


def test_band_structure():
    bands = D.band_of_zigzag()
    # bands are non-decreasing along zigzag order
    assert (np.diff(bands) >= 0).all()
    assert bands[0] == 0 and bands[-1] == 14
    assert D.band_mask(14).all()
    assert D.band_mask(0).sum() == 1


def test_reconstruction_matrix_orthonormal():
    r = D.reconstruction_matrix()
    assert np.allclose(r @ r.T, np.eye(64), atol=1e-12)


def test_truncated_reconstruction_zeroes_high_bands():
    r4 = D.truncated_reconstruction_matrix(4)
    mask = D.band_mask(4)
    assert np.allclose(r4[~mask], 0.0)
    assert not np.allclose(r4[mask], 0.0)


def test_quantization_table_dc_is_mean():
    q = D.quantization_table(50)
    assert q[0] == 8.0  # paper §4.3 convention
    q_noforce = D.quantization_table(50, dc_is_mean=False)
    assert q_noforce[0] == 16.0  # IJG luma DC at quality 50


@pytest.mark.parametrize("quality", [10, 50, 90])
def test_quality_scaling_monotone(quality):
    q_lo = D.quantization_table(max(quality - 9, 1), dc_is_mean=False)
    q_hi = D.quantization_table(quality, dc_is_mean=False)
    assert (q_hi <= q_lo).all()  # higher quality -> smaller steps


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_parseval_property(seed):
    """Orthonormal DCT preserves energy (basis of the paper's Thm. 2)."""
    x = np.random.default_rng(seed).normal(size=(8, 8))
    y = D.dct2(x)
    assert np.isclose((x * x).sum(), (y * y).sum(), rtol=1e-10)


def test_harmonic_mixing_tensor_identity():
    """Masking with an all-ones mask through H is the identity (Eq. 17)."""
    h = D.harmonic_mixing_tensor()
    eye = np.einsum("kpl->kl", h)
    assert np.allclose(eye, np.eye(64), atol=1e-10)
