"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import conv as C
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 64, 1000, 1024])
@pytest.mark.parametrize("phi", [2, 8, 14])
def test_asm_relu_sweep(rng, n, phi):
    x = jnp.asarray(rng.normal(size=(n, 64)), jnp.float32)
    a = ops.asm_relu(x, phi)
    b = ref.asm_relu_ref(x, phi)
    np.testing.assert_allclose(a, b, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_asm_relu_dtypes(rng, dtype):
    x = jnp.asarray(rng.normal(size=(96, 64)), dtype)
    a = ops.asm_relu(x, 14)
    b = ref.asm_relu_ref(x, 14)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol)


@pytest.mark.parametrize("n", [7, 256, 515])
def test_block_dct_roundtrip_sweep(rng, n):
    blk = jnp.asarray(rng.normal(size=(n, 8, 8)), jnp.float32)
    co = ops.block_dct(blk)
    np.testing.assert_allclose(co, ref.block_dct_ref(blk), atol=2e-5)
    back = ops.block_idct(co)
    np.testing.assert_allclose(back, blk, atol=2e-5)


def test_block_dct_quantized(rng):
    blk = jnp.asarray(rng.normal(size=(64, 8, 8)), jnp.float32)
    co = ops.block_dct(blk, quality=50)
    co_ref = ref.block_dct_ref(blk) / jnp.asarray(
        __import__("repro.core.dct", fromlist=["dct"]).quantization_table(50),
        jnp.float32)
    np.testing.assert_allclose(co, co_ref, atol=2e-5)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("cin,cout,grid", [(1, 1, 2), (3, 5, 4), (4, 8, 2)])
def test_jpeg_conv_sweep(rng, stride, cin, cout, grid):
    k = jnp.asarray(rng.normal(size=(cout, cin, 3, 3)) * 0.3, jnp.float32)
    xi = C.explode(k, stride)
    coef = jnp.asarray(rng.normal(size=(2, grid, grid, cin, 64)), jnp.float32)
    a = ops.jpeg_conv_apply(coef, xi, stride)
    b = ref.jpeg_conv_ref(coef, xi, stride)
    np.testing.assert_allclose(a, b, atol=5e-4)


@pytest.mark.parametrize("s,t,h,kvh,hd", [
    (128, 128, 4, 4, 32),   # MHA
    (256, 256, 8, 2, 64),   # GQA
    (96, 96, 4, 1, 32),     # MQA, non-tile-aligned
])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_attention_sweep(rng, s, t, h, kvh, hd, causal, window):
    q = jnp.asarray(rng.normal(size=(2, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, t, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, t, kvh, hd)), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=causal, window=window)
    b = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(a, b, atol=2e-4)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.bfloat16)
    a = ops.flash_attention(q, k, v, causal=True)
    b = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=5e-2)


def test_kernel_matches_model_attention(rng):
    """Pallas flash == the pure-JAX chunked attention used by the models."""
    import repro.models.layers as L
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 32)), jnp.float32)
    a = ops.flash_attention(q, k, v, causal=True)
    b = L.attention(q, k, v, causal=True)
    np.testing.assert_allclose(a, b, atol=2e-4)
