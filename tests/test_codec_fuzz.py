"""Corpus fuzz: every committed fixture, truncated and bit-flipped.

Property: no mutation of a valid JPEG may escape the typed error
contract — decode either succeeds (bit-flips can be semantically
invisible; JPEG carries no checksum) or raises a
:class:`~repro.codec.CodecError` subclass carrying byte-offset context.
Bare ``ValueError``/``IndexError``/hangs are bugs.  The lockstep decoder
must reproduce the scalar decoder's exception for the same broken
stream (the serving isolation path depends on that parity).

Runs under real ``hypothesis`` when installed, else the deterministic
shim in ``tests/_hypothesis_compat.py``.
"""
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.codec import CodecError, decode_bytes
from repro.codec import bitstream as bs
from repro.codec import lockstep as lk

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "codec")
FIXTURES = ("color_q75_dri", "color_q75_dri_trailing_rst",
            "color_q85_420", "gray_q80")
_CACHE: dict[str, bytes] = {}


def _fixture_bytes(name: str) -> bytes:
    if name not in _CACHE:
        with open(os.path.join(FIXDIR, name + ".jpg"), "rb") as f:
            _CACHE[name] = f.read()
    return _CACHE[name]


@settings(max_examples=60)
@given(st.sampled_from(FIXTURES), st.floats(0.001, 0.999))
def test_truncation_always_typed(name, frac):
    """Cutting the file anywhere must raise CodecError — the EOI marker
    is gone, so there is no silent-success path."""
    data = _fixture_bytes(name)
    cut = min(max(1, int(len(data) * frac)), len(data) - 1)
    with pytest.raises(CodecError) as ei:
        decode_bytes(data[:cut])
    err = ei.value
    assert err.offset is None or 0 <= err.offset <= cut
    assert str(err)  # renders, with any offset/marker context inline


@settings(max_examples=60)
@given(st.sampled_from(FIXTURES), st.floats(0.0, 1.0),
       st.integers(0, 7))
def test_bitflip_typed_or_decodes(name, pos_frac, bit):
    """A single bit-flip either decodes (no checksum — a flipped
    coefficient bit is legal data) or raises CodecError.  Anything
    else — bare ValueError, IndexError, wrong shape — is a bug."""
    data = _fixture_bytes(name)
    at = min(2 + int(pos_frac * (len(data) - 4)), len(data) - 3)
    arr = bytearray(data)
    arr[at] ^= 1 << bit
    try:
        out = decode_bytes(bytes(arr))
    except CodecError:
        return
    clean = decode_bytes(data)
    assert out.shape == clean.shape
    assert out.dtype == clean.dtype
    assert np.isfinite(out).all()


@settings(max_examples=40)
@given(st.sampled_from(FIXTURES), st.integers(0, 3))
def test_segment_mutation_scalar_lockstep_parity(name, drop):
    """The lockstep decoder reproduces the scalar decoder's exception —
    same type, same message — for a stream whose entropy-coded bits were
    truncated after header parse."""
    scan = bs.prepare_scan(_fixture_bytes(name))
    keep = max(0, len(scan.segments[-1]) // 4 * drop)
    broken = scan._replace(segments=tuple(
        list(scan.segments[:-1]) + [scan.segments[-1][:keep]]))
    try:
        bs.decode_scan(broken)
        scalar_err = None
    except Exception as e:  # noqa: BLE001 — parity is the property
        scalar_err = e
    try:
        lk.decode_scans([broken])
        lockstep_err = None
    except Exception as e:  # noqa: BLE001
        lockstep_err = e
    if scalar_err is None:
        assert lockstep_err is None
    else:
        assert isinstance(scalar_err, CodecError)
        assert type(lockstep_err) is type(scalar_err)
        assert str(lockstep_err) == str(scalar_err)


def test_error_context_attributes():
    """Structured context survives on the common corruption shapes."""
    data = _fixture_bytes("color_q75_dri")
    with pytest.raises(bs.MarkerError) as ei:
        bs.prepare_scan(b"\x00\x00" + data[2:])
    assert ei.value.offset == 0                    # missing SOI
    with pytest.raises(bs.TruncatedJpegError) as ei:
        bs.prepare_scan(data[:-2])                 # EOI cut off
    assert ei.value.offset is not None
    sos = data.find(b"\xff\xda")
    mutated = bytearray(data)
    ecs = sos + 2 + int.from_bytes(data[sos + 2:sos + 4], "big")
    mutated[ecs + 8:ecs + 10] = b"\xff\xc7"        # unescaped marker
    with pytest.raises(CodecError):
        decode_bytes(bytes(mutated))


def test_isolation_matches_per_image_errors():
    """`ingest_batch(on_error="isolate")` reports, per failed index, the
    same exception type+message the scalar per-image decode raises."""
    from repro.codec import ingest_batch

    datas = [_fixture_bytes(n) for n in FIXTURES]
    datas[1] = datas[1][: len(datas[1]) // 2]
    datas[3] = datas[3][: len(datas[3]) * 3 // 4]
    kw = dict(quality=50, grid=(5, 5), channels=3)
    _, _, errors = ingest_batch(datas, on_error="isolate", **kw)
    assert sorted(errors) == [1, 3]
    for i, err in errors.items():
        with pytest.raises(CodecError) as ei:
            decode_bytes(datas[i], **kw)
        assert type(err) is type(ei.value)
        assert str(err) == str(ei.value)
