"""Convert-once inference engine (``core.plan``): fused BN·Ξ operator
plans, per-layer band autotuning, and plan serialization.

Contracts:

* a fused-BN ``InferencePlan`` matches ``jpeg_apply`` (training=False) at
  φ = EXACT_PHI to ≤1e-4 on every dispatch path — including strided /
  projection blocks and *non-trivial* batch-norm parameters and running
  statistics (the fixture randomises them; identity BN would make the fold
  vacuous);
* save → restore through ``CheckpointManager`` is bit-identical;
* band autotuning is monotone in the energy budget (tighter budget ⇒
  fewer bands, never more);
* the precomputed path's residual join uses ``poollib.residual_add`` and
  agrees with the per-layer path through the projection shortcut.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import asm as A
from repro.core import batchnorm as BN
from repro.core import dct as dctlib
from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R


@pytest.fixture(scope="module")
def setup():
    # widths force a stride-2 + projection block in stages 1 and 2.
    spec = R.ResNetSpec(widths=(8, 16, 24), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    # randomise every BN so the fold carries real scales and shifts
    key = jax.random.PRNGKey(7)
    for name in params:
        if "_bn" in name or name.endswith("bn"):
            k1, k2, k3, k4, key = jax.random.split(key, 5)
            c = params[name]["gamma"].shape[0]
            params[name]["gamma"] = 1.0 + 0.2 * jax.random.normal(k1, (c,))
            params[name]["beta"] = 0.1 * jax.random.normal(k2, (c,))
            state[name]["mean"] = 0.1 * jax.random.normal(k3, (c,))
            state[name]["var"] = 1.0 + 0.3 * jax.random.uniform(k4, (c,))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    ref, _ = R.jpeg_apply(params, state, coef, training=False, spec=spec,
                          phi=A.EXACT_PHI)
    return spec, params, state, coef, np.asarray(ref)


def test_fold_batchnorm_is_inference_bn():
    """fold_batchnorm's (scale, shift) reproduce batchnorm_jpeg exactly."""
    c = 5
    p = BN.BatchNormParams(jnp.asarray([1.2, 0.8, 1.0, 0.5, 2.0]),
                           jnp.asarray([0.1, -0.2, 0.0, 0.3, -0.1]))
    s = BN.BatchNormState(jnp.asarray([0.4, -0.3, 0.0, 0.2, 0.1]),
                          jnp.asarray([1.5, 0.7, 1.0, 2.0, 0.9]))
    coef = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 3, c, 64))
    want, _ = BN.batchnorm_jpeg(coef, p, s, training=False)
    scale, shift = BN.fold_batchnorm(p, s)
    got = coef * scale[None, None, None, :, None]
    got = got.at[..., 0].add(shift[None, None, None, :])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("path", DSP.PATHS)
def test_fused_plan_matches_jpeg_apply(setup, path):
    """Fused-BN plan ≡ per-step network at φ=14 on every dispatch path,
    through strided and projection blocks."""
    spec, params, state, coef, ref = setup
    cfg = DSP.DispatchConfig(path=path, interpret=True)
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    # batch norm is gone from the plan: fused operators carry the shift
    assert plan.operators["stem"].shift is not None
    strided = plan.operators["s1b0"]
    assert strided["conv1"].stride == 2 and "proj" in strided
    got = np.asarray(PL.apply_plan(plan, coef))
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_fused_scale_location_per_path(setup):
    """Materialised paths fold the BN scale into Ξ (field cleared); the
    factored path never forms Ξ and must keep it for per-step apply."""
    spec, params, state, coef, _ = setup
    mat = PL.build_plan(params, state, spec,
                        dispatch=DSP.DispatchConfig(path="reference"))
    assert mat.operators["stem"].xi is not None
    assert mat.operators["stem"].scale is None
    fac = PL.build_plan(params, state, spec,
                        dispatch=DSP.DispatchConfig(path="factored"))
    assert fac.operators["stem"].xi is None
    assert fac.operators["stem"].scale is not None


@pytest.mark.parametrize("path", DSP.PATHS)
def test_plan_serialization_roundtrip(setup, path, tmp_path):
    """save_plan → CheckpointManager → load_plan is bit-identical."""
    spec, params, state, coef, _ = setup
    cfg = DSP.DispatchConfig(path=path, bands=32, interpret=True)
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    before = np.asarray(PL.apply_plan(plan, coef))
    PL.save_plan(plan, str(tmp_path))
    restored = PL.load_plan(str(tmp_path))
    assert restored.cfg == cfg
    assert restored.spec == spec
    assert restored.bands == plan.bands
    assert restored.provenance == plan.provenance
    assert plan.provenance["bands_mode"] == "global"
    after = np.asarray(PL.apply_plan(restored, coef))
    np.testing.assert_array_equal(before, after)


def test_plan_roundtrip_keeps_per_layer_bands(setup, tmp_path):
    spec, params, state, coef, _ = setup
    bands = {k: b for k, b in zip(PL.operator_keys(params, spec),
                                  (64, 56, 48, 40, 32, 48, 56, 40, 64))}
    plan = PL.build_plan(params, state, spec, bands=bands,
                         dispatch=DSP.DispatchConfig(path="reference"))
    PL.save_plan(plan, str(tmp_path))
    restored = PL.load_plan(str(tmp_path))
    assert restored.bands == bands
    np.testing.assert_array_equal(np.asarray(PL.apply_plan(plan, coef)),
                                  np.asarray(PL.apply_plan(restored, coef)))


def test_apply_operators_rejects_fused_ops(setup):
    """Feeding BN-fused plan operators to the per-step walk must fail
    loudly — silently it would apply batch norm twice."""
    spec, params, state, coef, _ = setup
    plan = PL.build_plan(params, state, spec,
                         dispatch=DSP.DispatchConfig(path="reference"))
    with pytest.raises(ValueError, match="fused batch norm"):
        R.jpeg_apply_precomputed(params, state, plan.operators, coef,
                                 spec=spec)


def test_load_plan_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint import CheckpointManager

    CheckpointManager(str(tmp_path)).save(0, {"w": np.ones(3)})
    with pytest.raises(ValueError, match="inference plan"):
        PL.load_plan(str(tmp_path))


def test_band_budget_monotone():
    """Tighter energy budget ⇒ fewer bands, never more (per quality)."""
    for quality in (30, 50, 75):
        picks = [PL.bands_for_budget(quality, b)
                 for b in (0.5, 0.8, 0.9, 0.95, 0.99, 0.999, 1.0)]
        assert picks == sorted(picks), (quality, picks)
        assert picks[-1] == dctlib.NFREQ


def test_autotune_monotone_in_budget(setup):
    """Autotuned per-layer assignment is monotone in the budget too."""
    spec, params, state, *_ = setup
    prev = None
    for budget in (0.6, 0.9, 0.99, 1.0):
        bands = PL.autotune_bands(params, state, spec, budget=budget)
        if prev is not None:
            assert all(prev[k] <= bands[k] for k in bands), (prev, bands)
        prev = bands


def test_autotune_parity_sweep(setup):
    """The probe sweep returns an assignment that actually holds parity
    (top-1 agreement + bounded deviation) against the full-band plan."""
    spec, params, state, coef, _ = setup
    tol = 0.5
    bands = PL.autotune_bands(params, state, spec, budget=0.9,
                              probe_coef=coef, tol=tol)
    ref_cfg = DSP.DispatchConfig(path="reference")
    full = PL.build_plan(params, state, spec, dispatch=ref_cfg)
    tuned = PL.build_plan(params, state, spec, dispatch=ref_cfg, bands=bands)
    a = np.asarray(PL.apply_plan(full, coef))
    b = np.asarray(PL.apply_plan(tuned, coef))
    assert np.abs(a - b).max() <= tol
    assert (a.argmax(-1) == b.argmax(-1)).all()
    # something was actually truncated
    assert min(bands.values()) < dctlib.NFREQ


def test_precomputed_residual_uses_residual_add(setup):
    """Regression for the ``h + short`` vs ``residual_add`` split: the
    precomputed walk goes through ``poollib.residual_add`` like
    ``jpeg_apply``, and the two agree through the projection shortcut."""
    from unittest import mock

    from repro.core import plan as planlib
    from repro.core import pooling as poollib

    spec, params, state, coef, _ = setup
    cfg = DSP.DispatchConfig(path="reference", bands=32)
    ops = R.precompute_operators(params, spec, dispatch=cfg)
    calls = []
    real = poollib.residual_add

    def spy(a, b):
        calls.append(a.shape)
        return real(a, b)

    with mock.patch.object(planlib.poollib, "residual_add", spy):
        pre = R.jpeg_apply_precomputed(params, state, ops, coef, spec=spec,
                                       dispatch=cfg)
    # one residual join per block, including the projection-shortcut ones
    assert len(calls) == len(spec.widths) * spec.blocks_per_stage
    per_layer, _ = R.jpeg_apply(params, state, coef, training=False,
                                spec=spec, dispatch=cfg)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(per_layer),
                               atol=1e-4)


def test_plan_restore_tree_generic(tmp_path):
    """CheckpointManager.restore_tree round-trips a flat dict without a
    template and verifies checksums."""
    from repro.checkpoint import CheckpointManager

    m = CheckpointManager(str(tmp_path))
    arrays = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
              "b": np.ones((4,), np.int32)}
    m.save(3, arrays, extra={"tag": "x"})
    step, by_path, extra = m.restore_tree()
    assert step == 3 and extra == {"tag": "x"}
    assert len(by_path) == 2
    vals = sorted(by_path.items())
    np.testing.assert_array_equal(vals[0][1], arrays["a"])
    np.testing.assert_array_equal(vals[1][1], arrays["b"])
    with pytest.raises(FileNotFoundError):
        m.restore_tree(99)
