"""``hypothesis`` when installed, else a deterministic mini-shim.

The property tests in this suite only use ``@given`` over integer
strategies with a fixed ``@settings(max_examples=...)``.  On a bare
interpreter (no ``hypothesis``) we substitute a seeded sampler that calls
the test body ``max_examples`` times with deterministic draws — weaker
than real shrinking/coverage, but the properties still execute instead of
the whole module failing to collect.  Install ``requirements-dev.txt``
to get the real thing.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by the suite
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(options) -> _Strategy:
            seq = list(options)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    st = _Strategies()

    def settings(*, max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # ``@settings`` may wrap *this* wrapper (it is applied
                # outermost), so read the attribute off ``wrapper`` at
                # call time rather than off ``fn`` at decoration time.
                n = getattr(wrapper, "_max_examples", 10)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    drawn = tuple(s.example(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)

            # Drawn arguments are supplied here, not by pytest: hide the
            # original signature so pytest does not look for fixtures.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper

        return deco
