"""The roofline extractor: trip-count-aware HLO costing."""
import os
import subprocess
import sys

import pytest

from repro.launch.hlo_analysis import HloCost, _split_computations, analyze_hlo

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SAMPLE = """\
HloModule test, entry_computation_layout={()->f32[4,4]{1,0}}

%body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %y = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%i2, %y)
}

%cond (p2: (s32[], f32[4,4])) -> pred[] {
  %p2 = (s32[], f32[4,4]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main () -> f32[4,4] {
  %c = f32[4,4]{1,0} constant(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%zero, %c)
  %w = (s32[], f32[4,4]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_trip_multiplication():
    cost = analyze_hlo(SAMPLE, 1)
    # 5 iterations × (2·4·4·4 dot flops + 16-ish elementwise)
    assert cost.flops >= 5 * 2 * 4 * 4 * 4
    assert cost.flops < 5 * 2 * 4 * 4 * 4 + 5 * 64
    assert not cost.warnings


def test_comment_stripping():
    """Tuple types embed /*index=N*/ comments; the parser must survive."""
    txt = SAMPLE.replace("(s32[], f32[4,4]) tuple",
                         "(s32[], /*index=1*/f32[4,4]) tuple")
    comps = _split_computations(txt)
    assert "body" in comps or "%body" in [k for k in comps]
    cost = analyze_hlo(txt, 1)
    assert cost.flops >= 5 * 2 * 64


def test_collective_accounting():
    txt = """\
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[16,64]{1,0} all-gather(%p), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}
  %sl = f32[16,16]{1,0} slice(%ag), slice={[0:16],[0:16]}
  ROOT %ar = f32[16,16]{1,0} all-reduce(%sl), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    cost = analyze_hlo(txt, 8)
    kinds = {c.kind for c in cost.collectives}
    assert kinds == {"all-gather", "all-reduce"}
    ag = next(c for c in cost.collectives if c.kind == "all-gather")
    assert ag.bytes == 16 * 64 * 4
    assert ag.group_size == 4
    ar = next(c for c in cost.collectives if c.kind == "all-reduce")
    assert ar.bytes == 2 * 16 * 16 * 4  # ring convention: 2× payload
    assert cost.collective_bytes_by_group_size()[4] > 0


def test_json_roundtrip():
    cost = analyze_hlo(SAMPLE, 1)
    j = cost.to_json()
    assert j["flops"] == cost.flops
    assert "collective_bytes" in j
    assert "per_computation" not in j  # only emitted when requested


def test_per_computation_buckets_sum_to_totals():
    cost = analyze_hlo(SAMPLE, 1, per_computation=True)
    per = cost.per_computation
    assert per  # named sub-computation -> HloCost
    for field in ("flops", "bytes", "transcendentals"):
        assert sum(getattr(c, field) for c in per.values()) \
            == pytest.approx(getattr(cost, field)), field
    # the while body's dot FLOPs land (trip-multiplied) in its own bucket
    body = next(v for k, v in per.items() if "body" in k)
    assert body.flops >= 5 * 2 * 4 * 4 * 4


def test_per_computation_collectives_and_json():
    txt = """\
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    cost = analyze_hlo(txt, 8, per_computation=True)
    total = sum(c.collective_bytes for c in cost.per_computation.values())
    assert total == pytest.approx(cost.collective_bytes)
    j = cost.to_json()
    assert set(j["per_computation"]) == set(cost.per_computation)
    ent = next(iter(j["per_computation"].values()))
    assert "flops" in ent and "collective_bytes" in ent


@pytest.mark.slow
def test_matches_xla_cost_analysis_on_unrolled():
    """Ground truth check: on an unrolled loop (no whiles), our dot FLOPs
    must match XLA's cost_analysis within 5%."""
    prog = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys
sys.path.insert(0, {os.path.join(ROOT, 'src')!r})
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
from repro.parallel.compat import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
def unroll(x, ws):
    for i in range(6):
        x = jnp.tanh(x @ ws[i])
    return x.sum()
xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
with mesh:
    c = jax.jit(unroll, in_shardings=(NamedSharding(mesh, P("data", None)),
                                      NamedSharding(mesh, P(None, None, "model")))).lower(xs, ws).compile()
ca = c.cost_analysis()
if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
    ca = ca[0]
xla = ca["flops"]
mine = analyze_hlo(c.as_text(), 8).flops
rel = abs(mine - xla) / xla
print("xla", xla, "mine", mine, "rel", rel)
assert rel < 0.05, (xla, mine)
print("COST_MATCH_OK")
"""
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COST_MATCH_OK" in out.stdout
