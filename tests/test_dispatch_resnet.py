"""End-to-end forward equivalence through the dispatch layer.

At φ = EXACT_PHI the three ways of evaluating the network —
``spatial_apply`` (oracle), ``jpeg_apply`` (per-layer dispatch), and
``jpeg_apply_precomputed`` (baked operators) — must agree to float error
on every dispatch path, and the fixed-seed logits must match the stored
golden values (guards silent re-wiring of the forward).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import asm as A
from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import resnet as R

# Logits of spatial_apply for (spec, PRNGKey(0) params, PRNGKey(1) inputs)
# below, recorded on the CPU float32 build.  Loose tolerance absorbs
# BLAS/platform variation; parity assertions below are the tight contract.
GOLDEN_LOGITS = np.array(
    [[-3.424994, -4.07179, -1.426811, 4.518142, 0.568749, 1.689368,
      -5.056901, -6.78518, -0.950065, 0.262365],
     [-3.508921, -3.963831, -1.189555, 4.418633, 0.468479, 1.457609,
      -4.807414, -6.484397, -0.939328, 0.104704]], np.float32)


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(8, 16, 24), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    spatial, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    return spec, params, state, coef, spatial


def test_spatial_matches_golden(setup):
    *_, spatial = setup
    np.testing.assert_allclose(np.asarray(spatial), GOLDEN_LOGITS, atol=2e-3)


@pytest.mark.parametrize("path", DSP.PATHS)
def test_jpeg_apply_matches_spatial(setup, path):
    spec, params, state, coef, spatial = setup
    cfg = DSP.DispatchConfig(path=path, interpret=True)
    logits, _ = R.jpeg_apply(params, state, coef, training=False, spec=spec,
                             phi=A.EXACT_PHI, dispatch=cfg)
    np.testing.assert_allclose(logits, spatial, atol=1e-4)


@pytest.mark.parametrize("path", DSP.PATHS)
def test_precomputed_matches_spatial(setup, path):
    spec, params, state, coef, spatial = setup
    cfg = DSP.DispatchConfig(path=path, interpret=True)
    ops = R.precompute_operators(params, spec, dispatch=cfg)
    for entry in ops.values():
        leaves = entry.values() if isinstance(entry, dict) else [entry]
        assert all(op.path == path for op in leaves)
    logits = R.jpeg_apply_precomputed(params, state, ops, coef, spec=spec,
                                      phi=A.EXACT_PHI, dispatch=cfg)
    np.testing.assert_allclose(logits, spatial, atol=1e-4)


def test_precomputed_matches_per_layer_banded(setup):
    """Banded inference: precomputed and per-layer agree with each other
    (both are the same truncated network, just different plumbing)."""
    spec, params, state, coef, _ = setup
    cfg = DSP.DispatchConfig(path="reference", bands=32)
    ops = R.precompute_operators(params, spec, dispatch=cfg)
    a = R.jpeg_apply_precomputed(params, state, ops, coef, spec=spec,
                                 dispatch=cfg)
    b, _ = R.jpeg_apply(params, state, coef, training=False, spec=spec,
                        dispatch=cfg)
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_converted_model_keeps_its_dispatch(setup):
    """A ConvertedModel freezes the dispatch config it was converted with:
    its ASM must run banded to match its banded fused operators, even when
    the global config says otherwise."""
    from repro.core import convert as CV
    from repro.core import plan as PL

    spec, params, state, coef, _ = setup
    cfg = DSP.DispatchConfig(path="reference", bands=32)
    model = CV.convert(params, state, spec, dispatch=cfg)
    assert model.dispatch == cfg
    assert model.plan is not None and model.plan.cfg == cfg
    want = PL.apply_plan(model.plan, coef)
    with DSP.override(path="reference", bands=64):
        got = model(coef)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_converted_model_unfused_matches_per_step(setup):
    """fuse_bn=False keeps the PR-1 per-step-batchnorm contract exactly."""
    from repro.core import convert as CV

    spec, params, state, coef, _ = setup
    cfg = DSP.DispatchConfig(path="reference", bands=32)
    model = CV.convert(params, state, spec, dispatch=cfg, fuse_bn=False)
    assert model.plan is None
    want = R.jpeg_apply_precomputed(params, state, model.operators, coef,
                                    spec=spec, dispatch=cfg)
    np.testing.assert_array_equal(np.asarray(model(coef)), np.asarray(want))


def test_banded_accuracy_degrades_gracefully(setup):
    """Fig. 4b analogue for the bands knob: logit deviation from the exact
    network grows smoothly (never jumps) as bands decrease."""
    spec, params, state, coef, spatial = setup
    devs = []
    for bands in (64, 48, 32):
        cfg = DSP.DispatchConfig(path="reference", bands=bands)
        logits, _ = R.jpeg_apply(params, state, coef, training=False,
                                 spec=spec, dispatch=cfg)
        devs.append(float(jnp.abs(logits - spatial).max()))
    assert devs[0] < 1e-4
    assert devs[0] <= devs[1] + 1e-6 <= devs[2] + 2e-6, devs
    # top-1 prediction survives moderate truncation on this batch
    cfg = DSP.DispatchConfig(path="reference", bands=32)
    logits, _ = R.jpeg_apply(params, state, coef, training=False, spec=spec,
                             dispatch=cfg)
    assert (jnp.argmax(logits, -1) == jnp.argmax(spatial, -1)).all()
