"""Flight-recorder tracing (``repro.serving.trace``).

Contracts:

* **bounded ring** — the tracer keeps the newest ``capacity`` events,
  counts every eviction in ``dropped``, and recording stays safe under
  concurrent writers;
* **Perfetto-loadable export** — Chrome trace-event JSON with one pid
  per component track, µs timestamps relative to construction, flow
  pairs carrying the request id; ``validate_trace`` accepts it and
  rejects schema violations and orphan chains;
* **scheduler integration** — a traced serve run closes every request
  chain (admission → queue → terminal instant), links each completed
  request to exactly one device-dispatch span, and the per-stage span
  sums reconcile with the report's ``device_wall_s``/``ingest_wall_s``
  (within 5%: the spans *are* the recorded intervals).
"""
import json
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro import serving as SV
from repro.serving.trace import NULL_TRACER, Tracer, validate_trace


class FakeClock:
    """Deterministic monotonic clock: advances only on ``tick``."""

    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt
        return self.t


# --------------------------------------------------------------------------
# Ring buffer
# --------------------------------------------------------------------------


def test_ring_keeps_newest_and_counts_drops():
    clk = FakeClock()
    tr = Tracer(capacity=4, clock=clk)
    for i in range(10):
        tr.instant("scheduler", f"ev{i}", t=clk.tick())
    evs = tr.events()
    assert len(evs) == 4
    assert tr.dropped == 6
    # a flight recorder keeps the end of the story, not the beginning
    assert [e[3] for e in evs] == ["ev6", "ev7", "ev8", "ev9"]
    assert tr.export()["otherData"]["dropped"] == 6


def test_ring_capacity_validation():
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_span_timestamps_relative_microseconds():
    clk = FakeClock(t=50.0)
    tr = Tracer(clock=clk)          # construction reads t0 = 50.0
    t0 = clk.tick(1.0)              # 51.0 -> ts = 1s
    t1 = clk.tick(0.25)             # 51.25 -> dur = 0.25s
    tr.span("device", "device-dispatch", t0, t1, args={"n": 2})
    (ev,) = [e for e in tr.export()["traceEvents"] if e["ph"] == "X"]
    assert ev["ts"] == pytest.approx(1e6)
    assert ev["dur"] == pytest.approx(0.25e6)
    assert ev["args"] == {"n": 2}


def test_span_negative_interval_clamped():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.span("device", "x", clk() + 5.0, clk())  # t1 < t0
    (ev,) = [e for e in tr.export()["traceEvents"] if e["ph"] == "X"]
    assert ev["dur"] == 0.0


def test_export_pids_and_process_metadata():
    tr = Tracer(clock=FakeClock())
    tr.instant("request", "complete", t=100.0, tid=3)
    tr.instant("scheduler", "tier-switch", t=100.0)
    out = tr.export()
    meta = {e["args"]["name"]: e["pid"]
            for e in out["traceEvents"]
            if e["ph"] == "M" and e["name"] == "process_name"}
    # pids follow canonical track order regardless of recording order
    assert meta == {"scheduler": 1, "request": 2}
    by_cat = {e["cat"]: e["pid"] for e in out["traceEvents"]
              if e["ph"] == "i"}
    assert by_cat == {"scheduler": 1, "request": 2}


def test_flow_pair_export():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.flow(7, ("request", 7, clk.tick()), ("device", 0, clk.tick()))
    s, f = [e for e in tr.export()["traceEvents"] if e["ph"] in "sf"]
    assert s["ph"] == "s" and f["ph"] == "f"
    assert s["id"] == f["id"] == 7
    assert f["bp"] == "e"
    assert s["cat"] == f["cat"] == "flow"


def test_summary_counts_by_name():
    tr = Tracer(clock=FakeClock())
    tr.instant("scheduler", "reject", t=100.0)
    tr.instant("scheduler", "reject", t=100.0)
    tr.span("device", "device-dispatch", 100.0, 100.5)
    s = tr.summary()
    assert s["enabled"] and s["events"] == 3 and s["dropped"] == 0
    assert s["by_name"] == {"scheduler/reject": 2,
                            "device/device-dispatch": 1}


def test_thread_hammer_never_loses_accounting():
    """N writers race the ring: every record is either retained or
    counted as dropped — no event vanishes silently."""
    tr = Tracer(capacity=512)
    n_threads, per_thread = 8, 1000

    def hammer(k):
        for i in range(per_thread):
            tr.instant("scheduler", "ev", tid=k, args={"i": i})

    ts = [threading.Thread(target=hammer, args=(k,))
          for k in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.events()
    assert len(evs) == 512
    assert tr.dropped == n_threads * per_thread - 512


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    NULL_TRACER.span("device", "x", 0.0, 1.0)
    NULL_TRACER.instant("device", "y")
    NULL_TRACER.flow(1, ("a", 0, 0.0), ("b", 0, 0.0))
    assert NULL_TRACER.events() == []
    assert NULL_TRACER.summary()["events"] == 0
    assert NULL_TRACER.now() == 0.0


def test_jax_profile_none_is_noop():
    with SV.jax_profile(None):
        pass
    with SV.jax_profile(""):
        pass


# --------------------------------------------------------------------------
# validate_trace
# --------------------------------------------------------------------------


def _chain(tr, clk, rid):
    """Record one complete request chain on ``tr``."""
    t_sub = clk.tick()
    t_enq = clk.tick(0.01)
    tr.span("request", "admission", t_sub, t_enq, tid=rid)
    t_take = clk.tick(0.1)
    tr.span("request", "queue", t_enq, t_take, tid=rid)
    t1 = clk.tick(0.2)
    tr.span("device", "device-dispatch", t_take, t1,
            args={"rids": [rid], "n": 1})
    tr.flow(rid, ("request", rid, t_take), ("device", 0, t_take))
    tr.instant("request", "complete", t=t1, tid=rid)


def test_validate_accepts_closed_chains():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    for rid in (1, 2, 3):
        _chain(tr, clk, rid)
    summ = validate_trace(tr.export())
    assert summ["requests"] == summ["complete"] == 3
    assert summ["open_chains"] == []
    assert summ["dropped"] == 0
    assert summ["spans_by_name"]["request/admission"] == 3
    assert summ["device_span_s"] == pytest.approx(0.6, rel=1e-3)


def test_validate_rejects_orphan_chain():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    _chain(tr, clk, 1)
    tr.span("request", "admission", clk.tick(), clk.tick(), tid=9)
    with pytest.raises(ValueError, match="orphan"):
        validate_trace(tr.export())
    summ = validate_trace(tr.export(), require_closed=False)
    assert summ["open_chains"] == [9]


def test_validate_rejects_complete_without_dispatch_membership():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    t0, t1 = clk.tick(), clk.tick()
    tr.span("request", "admission", t0, t1, tid=5)
    tr.span("request", "queue", t1, clk.tick(), tid=5)
    tr.instant("request", "complete", t=clk.tick(), tid=5)
    with pytest.raises(ValueError, match="device-dispatch"):
        validate_trace(tr.export())


def test_validate_rejects_chain_without_admission():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.instant("request", "complete", t=clk.tick(), tid=4)
    with pytest.raises(ValueError, match="without admission"):
        validate_trace(tr.export())


def test_validate_rejects_schema_violations():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"foo": []})
    good = Tracer(clock=FakeClock()).export()
    bad = json.loads(json.dumps(good))
    bad["traceEvents"].append({"name": "x", "ph": "Q", "ts": 0.0,
                               "pid": 1, "tid": 0})
    with pytest.raises(ValueError, match="bad ph"):
        validate_trace(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["traceEvents"].append({"name": "x", "ph": "X", "ts": 0.0,
                                "pid": 1, "tid": 0, "dur": -5.0})
    with pytest.raises(ValueError, match="dur"):
        validate_trace(bad2)


# --------------------------------------------------------------------------
# Scheduler integration
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    cfg = DSP.DispatchConfig(path="reference")
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    ladder = SV.build_ladder(plan, caps=(None, 16))
    return spec, coef, plan, ladder


def _sched(ladder, coef, tracer, **kw):
    kw.setdefault("batch", 2)
    kw.setdefault("grid", tuple(coef.shape[1:3]))
    kw.setdefault("channels", int(coef.shape[3]))
    return SV.BandElasticScheduler(ladder, tracer=tracer, **kw)


def _jpeg_traffic(n, seed=0):
    from repro.codec import encode_pixels
    from repro.core import dct as dctlib

    rng = np.random.default_rng(seed)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    return [encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt) for _ in range(n)]


def test_traced_run_closes_chains_and_reconciles_walls(setup, tmp_path):
    """The acceptance run: mixed traffic through a traced scheduler →
    every chain closes, every completed request sits in exactly one
    device-dispatch span, and span sums match the metrics walls ≤5%."""
    spec, coef, plan, ladder = setup
    tracer = SV.Tracer()
    n_coef, n_bytes = 6, 6
    with _sched(ladder, coef, tracer) as s:
        s.warmup()
        reqs = [s.submit(np.asarray(coef[i % coef.shape[0]]))
                for i in range(n_coef)]
        reqs += [s.submit(d, kind="bytes") for d in _jpeg_traffic(n_bytes)]
        outs = [r.result(timeout=120) for r in reqs]
    assert all(np.isfinite(o).all() for o in outs)

    path = tmp_path / "trace.json"
    tracer.write(str(path))
    with open(path) as f:
        obj = json.load(f)
    summ = validate_trace(obj)
    assert summ["dropped"] == 0
    assert summ["complete"] == n_coef + n_bytes
    assert summ["requests"] == n_coef + n_bytes
    assert summ["open_chains"] == []
    assert summ["failed"] == summ["shed"] == 0
    # each batch leaves one batch-form + one device-dispatch + one
    # pad/stage span; bytes batches add ingest-decode spans
    by = summ["spans_by_name"]
    assert by["scheduler/batch-form"] == by["device/device-dispatch"]
    assert by["device/pad/stage"] == by["device/device-dispatch"]
    assert by["ingest/ingest-decode"] >= 1
    assert summ["flows"] == 2 * (n_coef + n_bytes)

    rep = s.metrics.report()
    # the device-dispatch spans record the *identical* intervals
    # record_batch accumulates, so the sums agree to rounding; 5% is the
    # acceptance bound
    assert summ["device_span_s"] == pytest.approx(
        rep["device_wall_s"], rel=0.05)
    assert summ["ingest_span_s"] == pytest.approx(
        rep["ingest_wall_s"], rel=0.05, abs=1e-3)


def test_traced_shed_and_fail_close_their_chains(setup):
    """Expired and poisoned requests still terminate their trace chains
    (shed/fail instants) — no orphans on the unhappy paths."""
    spec, coef, plan, ladder = setup
    tracer = SV.Tracer()
    with _sched(ladder, coef, tracer) as s:
        ok = s.submit(np.asarray(coef[0]))
        expired = s.submit(np.asarray(coef[1]), deadline_s=-0.001)
        bad = s.submit(b"not a jpeg scan", kind="bytes")
        assert np.isfinite(ok.result(timeout=60)).all()
        with pytest.raises(SV.DeadlineExceeded):
            expired.result(timeout=60)
        with pytest.raises(SV.RequestFailed):
            bad.result(timeout=60)
        s.drain()
    summ = validate_trace(tracer.export())
    assert summ["open_chains"] == []
    assert summ["shed"] == 1
    assert summ["failed"] == 1
    assert summ["complete"] == 1


def test_traced_overload_marks_tier_switches(setup):
    """Tier switches surface as scheduler-track instants carrying the
    from/to tiers, alongside the metrics timeline."""
    from repro.serving.qos import QosPolicy

    spec, coef, plan, ladder3 = setup
    ladder = SV.build_ladder(plan, caps=(None, 32, 16))
    tracer = SV.Tracer()
    policy = QosPolicy(high_depth=1.5, low_depth=0.5, hysteresis=1)
    with _sched(ladder, coef, tracer, policy=policy, max_pending=64) as s:
        reqs = [s.submit(np.asarray(coef[i % coef.shape[0]]))
                for i in range(24)]
        s.drain(timeout=120)
    assert all(r is not None and r.done() for r in reqs)
    switches = [e for e in tracer.events()
                if e[0] == "i" and e[3] == "tier-switch"]
    assert switches, "overload burst must trace tier-switch instants"
    assert len(switches) == len(s.metrics.tier_switches)
    assert all({"from", "to", "reason"} <= set(e[6]) for e in switches)
    summ = validate_trace(tracer.export())
    assert summ["complete"] == 24 and summ["open_chains"] == []


def test_untraced_scheduler_records_nothing(setup):
    spec, coef, plan, ladder = setup
    with _sched(ladder, coef, None) as s:
        assert s.tracer is NULL_TRACER
        r = s.submit(np.asarray(coef[0]))
        assert np.isfinite(r.result(timeout=60)).all()
    assert s.tracer.events() == []
