"""Model conversion (paper §4.6 / Table 1): spatial == JPEG to float error."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import convert as CV
from repro.core import jpeg as J
from repro.core import resnet as R


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(8, 16, 24), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 32, 32)) * 0.5
    return spec, params, state, x


def _coef(x, spec):
    return jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True), 1, 3)


def test_inference_parity(setup):
    """Paper Table 1: same logits to within float error at exact ReLU."""
    spec, params, state, x = setup
    sp, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    jp, _ = R.jpeg_apply(params, state, _coef(x, spec), training=False,
                         spec=spec)
    assert np.allclose(sp, jp, atol=1e-4)


def test_training_mode_parity(setup):
    spec, params, state, x = setup
    sp, st_sp = R.spatial_apply(params, state, x, training=True, spec=spec)
    jp, st_jp = R.jpeg_apply(params, state, _coef(x, spec), training=True,
                             spec=spec)
    assert np.allclose(sp, jp, atol=1e-4)
    for k in st_sp:
        assert np.allclose(st_sp[k]["mean"], st_jp[k]["mean"], atol=1e-5)
        assert np.allclose(st_sp[k]["var"], st_jp[k]["var"], atol=1e-4)


def test_convert_and_verify(setup):
    spec, params, state, x = setup
    model, dev = CV.convert_and_verify(params, state, spec, x)
    assert dev < 1e-4
    # precomputed-operator inference path agrees as well
    logits = model(_coef(x, spec))
    sp, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    assert np.allclose(logits, sp, atol=1e-4)


def test_conversion_degrades_gracefully_with_phi(setup):
    """Paper Fig. 4b: accuracy degrades smoothly as phi decreases."""
    spec, params, state, x = setup
    sp, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    devs = []
    for phi in (14, 10, 6):
        jp, _ = R.jpeg_apply(params, state, _coef(x, spec), training=False,
                             spec=spec, phi=phi)
        devs.append(float(jnp.max(jnp.abs(sp - jp))))
    assert devs[0] < 1e-4
    assert devs[0] <= devs[1] + 1e-6 <= devs[2] + 2e-6


def test_jpeg_training_step_reduces_loss(setup):
    """Training *in* the JPEG domain (paper §5.3 Fig. 4c regime)."""
    spec, params, state, x = setup
    coef = _coef(x, spec)
    labels = jnp.arange(4) % 10

    def loss_fn(p):
        logits, _ = R.jpeg_apply(p, state, coef, training=True, spec=spec)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=1))

    l0, g = jax.value_and_grad(loss_fn)(params)
    p1 = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    l1 = loss_fn(p1)
    assert float(l1) < float(l0)


def test_from_torch_layout(setup):
    spec, params, state, x = setup
    tensors = {}
    tensors["stem.weight"] = np.asarray(params["stem"]["kernel"])
    for name in ("stem_bn",):
        tensors[f"{name}.weight"] = np.asarray(params[name]["gamma"])
        tensors[f"{name}.bias"] = np.asarray(params[name]["beta"])
        tensors[f"{name}.running_mean"] = np.asarray(state[name]["mean"])
        tensors[f"{name}.running_var"] = np.asarray(state[name]["var"])
    for name, s, cin, w in R._stages(spec):
        tensors[f"{name}.conv1.weight"] = np.asarray(params[name]["conv1"])
        tensors[f"{name}.conv2.weight"] = np.asarray(params[name]["conv2"])
        if "proj" in params[name]:
            tensors[f"{name}.proj.weight"] = np.asarray(params[name]["proj"])
        for bn in ("bn1", "bn2"):
            key = f"{name}_{bn}"
            tensors[f"{name}.{bn}.weight"] = np.asarray(params[key]["gamma"])
            tensors[f"{name}.{bn}.bias"] = np.asarray(params[key]["beta"])
            tensors[f"{name}.{bn}.running_mean"] = np.asarray(state[key]["mean"])
            tensors[f"{name}.{bn}.running_var"] = np.asarray(state[key]["var"])
    tensors["head.weight"] = np.asarray(params["head"]["w"]).T
    tensors["head.bias"] = np.asarray(params["head"]["b"])
    p2, s2 = CV.from_torch_layout(tensors, spec)
    jp, _ = R.jpeg_apply(p2, s2, _coef(x, spec), training=False, spec=spec)
    sp, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    assert np.allclose(jp, sp, atol=1e-4)
