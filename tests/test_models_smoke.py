"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement), plus decode-vs-
forward consistency for every cache kind."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import base as C
from repro.data import synthetic
from repro.models import build_model, input_specs
from repro.models import transformer as T

ALL_ARCHS = [
    "granite-3-2b", "granite-moe-3b-a800m", "internvl2-1b", "jamba-v0.1-52b",
    "jpeg-resnet", "mistral-nemo-12b", "mixtral-8x7b", "rwkv6-7b",
    "smollm-360m", "starcoder2-3b", "whisper-small",
]


def _smoke_batch(cfg, batch=2, seq=32):
    if cfg.family == "jpeg_resnet":
        from repro.data.pipeline import jpeg_iterator
        it = jpeg_iterator(0, batch, cfg.image_size, cfg.in_channels,
                           cfg.num_classes)
        return {k: jnp.asarray(v) for k, v in next(it).items()}
    shape = C.ShapeConfig("smoke", seq, batch, "train")
    b = input_specs(cfg, shape, dryrun=False)
    tb = synthetic.token_batch(0, 0, batch, seq, cfg.vocab_size)
    tl = b["tokens"].shape[1]
    b["tokens"] = tb["tokens"][:, :tl]
    if "labels" in b:
        b["labels"] = tb["tokens"][:, 1:tl + 1]
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_registry_covers_assignment():
    assert set(ALL_ARCHS) <= set(C.list_archs())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_train_step_smoke(arch):
    cfg = C.reduced_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch
    grads = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)[0]))(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch
    # one SGD step reduces nothing catastrophic (params stay finite)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = jax.jit(model.loss_fn)(new_params, batch)
    assert np.isfinite(float(loss2)), arch


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a != "jpeg-resnet"])
def test_arch_forward_shapes(arch):
    cfg = C.reduced_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    batch.pop("labels", None)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b",
                                  "jamba-v0.1-52b", "rwkv6-7b"])
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(C.reduced_config(arch), capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    S = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks}, training=False)
    cache = model.init_cache(2, S)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - dec))) / (
        float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 3e-4, (arch, rel)


def test_prefill_then_decode_matches_forward():
    """Prefill produces a cache that decode continues correctly from."""
    cfg = C.reduced_config("smollm-360m")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 1), 0,
                              cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks}, training=False)
    last, cache = model.prefill(params, {"tokens": toks[:, :S]}, pad_to=S + 4)
    assert np.allclose(last[:, 0], full[:, S - 1], atol=2e-4 * float(
        jnp.max(jnp.abs(full))))
    lg, cache = model.decode_step(params, cache, {"tokens": toks[:, S:S + 1]})
    rel = float(jnp.max(jnp.abs(lg[:, 0] - full[:, S]))) / float(
        jnp.max(jnp.abs(full)))
    assert rel < 3e-4


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor, overflow tokens are dropped (not NaN)."""
    cfg = dataclasses.replace(C.reduced_config("mixtral-8x7b"),
                              capacity_factor=0.25)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    loss, _ = model.loss_fn(params, batch)
    assert np.isfinite(float(loss))


def test_pattern_period():
    assert T.pattern_period(C.reduced_config("smollm-360m")) == 1
    jamba = C.get_config("jamba-v0.1-52b")
    assert T.pattern_period(jamba) == 8
    kinds = T.layer_kinds(jamba)
    assert sum(1 for m, _ in kinds if m == "attn") == 4   # 1:7 interleave
    assert sum(1 for _, f in kinds if f == "moe") == 16   # every other layer
