"""Regenerate the committed codec golden fixtures.

Two tiny JPEGs are committed alongside their entropy-decoded coefficient
``.npz`` files:

* ``gray_q80.jpg``     — 40×56 grayscale, quality 80, 4:4:4 (trivially);
* ``color_q85_420.jpg`` — 48×48 3-component, quality 85, 4:2:0 chroma;
* ``color_q75_dri.jpg`` — 48×48 3-component, quality 75, 4:2:0, with DRI
  restart markers every MCU row (the parallel-decode segmentation);
* ``color_q75_dri_trailing_rst.jpg`` — the same stream with an extra
  restart marker inserted immediately before EOI, a benign shape some
  encoders emit (an empty trailing segment the decoder must tolerate).

Both are encoded by **PIL/libjpeg** (an independent implementation) from
deterministic closed-form images, so the bitstreams pin real-world JFIF
output.  The ``.npz`` holds the quantized zigzag coefficients our decoder
extracts; at generation time they are cross-validated against libjpeg's
own pixel decode (dequantize + exact IDCT must match PIL's output to
within its integer rounding), after which the committed arrays serve as
the bit-exact regression reference for ``repro.codec.bitstream``.

    PYTHONPATH=src python tests/fixtures/codec/make_fixtures.py
"""
import io
import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))


def det_image(h: int, w: int, c: int = 1) -> np.ndarray:
    """Deterministic closed-form test image, values in [0, 255]."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    planes = []
    for k in range(c):
        z = (np.sin(xx * (0.23 + 0.11 * k)) * np.cos(yy * 0.17)
             + 0.5 * np.sin((xx + 2 * yy) * 0.061 * (k + 1))
             + 0.002 * (xx - w / 2) * (yy - h / 2) / (1 + k))
        z = (z - z.min()) / (z.max() - z.min())
        planes.append(np.rint(z * 255.0))
    return np.stack(planes) if c > 1 else planes[0]


def validate(data: bytes, dec) -> None:
    """Cross-check our decode against PIL's pixel decode (luma plane)."""
    from PIL import Image

    import jax.numpy as jnp
    from repro.core import jpeg as J

    pim = Image.open(io.BytesIO(data))
    if pim.mode != "L":
        pim.draft("YCbCr", None)
        ref = np.asarray(pim.convert("YCbCr"), np.float64)[..., 0]
    else:
        ref = np.asarray(pim, np.float64)
    deq = dec.coefficients[0] * dec.qtable(0).astype(np.float64)
    own = np.asarray(J.jpeg_decode(jnp.asarray(deq[None]),
                                   scaled=False))[0] + 128.0
    own = np.clip(own, 0, 255)[: dec.height, : dec.width]
    err = float(np.abs(own - ref).max())
    assert err < 1.0, f"decoder disagrees with libjpeg: max err {err}"
    print(f"  cross-validated vs PIL pixels: max err {err:.3f}")


def save(name: str, data: bytes) -> None:
    from repro.codec import bitstream as bs

    dec = bs.decode_jpeg(data)
    validate(data, dec)
    with open(os.path.join(HERE, name + ".jpg"), "wb") as f:
        f.write(data)
    arrays = {"width": dec.width, "height": dec.height,
              "restart_interval": dec.restart_interval}
    for i, comp in enumerate(dec.components):
        arrays[f"coef{i}"] = dec.coefficients[i]
        arrays[f"qtable{i}"] = dec.qtable(i)
        arrays[f"sampling{i}"] = np.array([comp.h, comp.v])
    np.savez(os.path.join(HERE, name + ".npz"), **arrays)
    print(f"  wrote {name}.jpg ({len(data)} bytes) + {name}.npz")


def main() -> None:
    from PIL import Image

    print("gray_q80 (40x56, quality 80):")
    im = Image.fromarray(np.uint8(det_image(40, 56)), "L")
    buf = io.BytesIO()
    im.save(buf, "JPEG", quality=80)
    save("gray_q80", buf.getvalue())

    print("color_q85_420 (48x48, quality 85, 4:2:0):")
    rgb = np.uint8(det_image(48, 48, 3)).transpose(1, 2, 0)
    im = Image.fromarray(rgb, "RGB")
    buf = io.BytesIO()
    im.save(buf, "JPEG", quality=85, subsampling=2)
    save("color_q85_420", buf.getvalue())

    print("color_q75_dri (48x48, quality 75, 4:2:0, DRI each MCU row):")
    im = Image.fromarray(np.uint8(det_image(48, 48, 3)).transpose(1, 2, 0),
                         "RGB")
    buf = io.BytesIO()
    im.save(buf, "JPEG", quality=75, subsampling=2, restart_marker_rows=1)
    data = buf.getvalue()
    save("color_q75_dri", data)

    print("color_q75_dri_trailing_rst (extra RST before EOI):")
    from repro.codec import bitstream as bs

    n_seg = len(bs.prepare_scan(data).segments)
    nxt = 0xD0 + (n_seg - 1) % 8  # next restart marker in the 8-cycle
    assert data.endswith(b"\xff\xd9")
    patched = data[:-2] + bytes([0xFF, nxt]) + b"\xff\xd9"
    ref = bs.decode_jpeg(data)
    got = bs.decode_jpeg(patched)
    for a, b in zip(ref.coefficients, got.coefficients):
        assert np.array_equal(a, b), "trailing RST changed coefficients"
    save("color_q75_dri_trailing_rst", patched)


if __name__ == "__main__":
    main()
