"""Fault-isolation chaos suite (``repro.serving`` + codec isolation).

Contracts under injected disaster:

* a malformed request fails *alone* — typed ``RequestFailed`` at stage
  ``"codec"`` with the ``CodecError`` cause attached — while every
  healthy request in the same batch serves with unchanged predictions;
* an executor fault fails only its batch after the bounded retry, and
  the scheduler keeps serving;
* the circuit breaker walks closed → open (fast-rejecting with
  ``ServiceUnavailable``) → half-open → closed, all visible in the
  metrics timeline;
* killing an ingest-pool worker surfaces as a supervised respawn
  (``pool_restarts``), never as a failed or hung request;
* a dying worker can no longer deadlock ``close()`` against an ingest
  thread blocked on the bounded decoded queue (PR-8 regression).

All injection is deterministic in ``(seed, request index)`` via
``repro.serving.faults`` — reruns corrupt the same bytes the same way.
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.codec import (CodecError, encode_pixels, ingest_batch,
                         ingest as ingestlib)
from repro.core import dct as dctlib
from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro import serving as SV
from repro.serving.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serving.qos import QosPolicy


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    key = jax.random.PRNGKey(7)
    for name in params:
        if "_bn" in name or name.endswith("bn"):
            k1, k2, k3, k4, key = jax.random.split(key, 5)
            c = params[name]["gamma"].shape[0]
            params[name]["gamma"] = 1.0 + 0.2 * jax.random.normal(k1, (c,))
            params[name]["beta"] = 0.1 * jax.random.normal(k2, (c,))
            state[name]["mean"] = 0.1 * jax.random.normal(k3, (c,))
            state[name]["var"] = 1.0 + 0.3 * jax.random.uniform(k4, (c,))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    cfg = DSP.DispatchConfig(path="reference")
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    return spec, params, state, coef, plan


def _sched(plan, coef, **kw):
    ladder = kw.pop("ladder", None) or SV.build_ladder(plan,
                                                       caps=(None, 16))
    kw.setdefault("batch", 2)
    kw.setdefault("grid", tuple(coef.shape[1:3]))
    kw.setdefault("channels", int(coef.shape[3]))
    return SV.BandElasticScheduler(ladder, **kw)


def _jpeg_traffic(n, seed=0):
    rng = np.random.default_rng(seed)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    return [encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt) for _ in range(n)]


#: a breaker that never trips — for tests about containment, not tripping
def _lenient():
    return SV.BreakerPolicy(max_consecutive=10_000, min_samples=10_000)


# --------------------------------------------------------------------------
# ingest_batch isolation (unit)
# --------------------------------------------------------------------------


def test_ingest_isolation_survivors_and_errors():
    datas = _jpeg_traffic(5, seed=2)
    clean, _ = ingest_batch(datas, quality=75, grid=(2, 2))
    bad = dict(datas=list(datas))["datas"]
    bad[1] = bad[1][: len(bad[1]) // 3]          # truncated — EOI gone
    bad[3] = bad[3][:2] + b"\x00" * 8 + bad[3][2:]  # garbage after SOI
    batch, stats, errors = ingest_batch(bad, quality=75, grid=(2, 2),
                                        on_error="isolate")
    assert sorted(errors) == [1, 3]
    assert all(isinstance(e, CodecError) for e in errors.values())
    # survivors stack in original order, bit-identical to the clean decode
    assert batch.shape[0] == 3
    np.testing.assert_array_equal(batch, clean[[0, 2, 4]])


def test_ingest_isolation_all_failed_empty_batch():
    datas = [d[: len(d) // 2] for d in _jpeg_traffic(3, seed=4)]
    batch, stats, errors = ingest_batch(datas, quality=75, grid=(2, 2),
                                        on_error="isolate")
    assert sorted(errors) == [0, 1, 2]
    assert batch.shape == (0, 2, 2, 3, 64)
    assert batch.dtype == np.float32


def test_ingest_isolation_rejects_unknown_mode():
    with pytest.raises(ValueError, match="on_error"):
        ingest_batch(_jpeg_traffic(1), on_error="explode")


# --------------------------------------------------------------------------
# deterministic fault placement
# --------------------------------------------------------------------------


def test_fault_injection_is_deterministic():
    datas = _jpeg_traffic(24, seed=6)
    spec = FaultSpec(seed=11, corrupt_rate=0.4)
    a, b = FaultInjector(spec), FaultInjector(spec)
    out_a = [a.corrupt(i, d) for i, d in enumerate(datas)]
    out_b = [b.corrupt(i, d) for i, d in enumerate(datas)]
    assert a.corrupted == b.corrupted
    assert a.corrupted and len(a.corrupted) < len(datas)
    assert out_a == out_b
    for i, d in enumerate(datas):  # non-corrupt indices pass untouched
        if i not in a.corrupted:
            assert out_a[i] == d


def test_guaranteed_fail_modes_always_raise():
    """truncate/marker mutations must *always* produce a CodecError —
    the chaos harness counts on corrupt == failed."""
    from repro.codec import decode_bytes

    datas = _jpeg_traffic(8, seed=8)
    inj = FaultInjector(FaultSpec(seed=5, corrupt_rate=1.0))
    for i, d in enumerate(datas):
        mutated = inj.corrupt(i, d)
        assert mutated != d
        with pytest.raises(CodecError):
            decode_bytes(mutated, quality=75, grid=(2, 2))
    assert sorted(inj.corrupted) == list(range(8))


# --------------------------------------------------------------------------
# scheduler containment
# --------------------------------------------------------------------------


def test_corrupt_requests_contained_healthy_parity(setup):
    """Corrupt bytes fail typed at stage "codec"; every healthy request
    in the same burst keeps its fault-free predictions."""
    spec, params, state, coef, plan = setup
    datas = _jpeg_traffic(8, seed=10)
    # pin the selector at the top tier in both runs — this test is about
    # fault containment parity, not QoS degradation under the burst
    calm = QosPolicy(high_depth=1e9, low_depth=0.5)

    with _sched(plan, coef, breaker=_lenient(), policy=calm) as s:
        want = [s.submit(d, kind="bytes").result(timeout=60)
                for d in datas]

    inj = FaultInjector(FaultSpec(seed=21, corrupt_rate=0.4))
    sent = [inj.corrupt(i, d) for i, d in enumerate(datas)]
    assert inj.corrupted and len(inj.corrupted) < len(datas)

    with _sched(plan, coef, breaker=_lenient(), policy=calm,
                faults=inj) as s:
        reqs = [s.submit(d, kind="bytes") for d in sent]
        for i, r in enumerate(reqs):
            if i in inj.corrupted:
                with pytest.raises(SV.RequestFailed) as ei:
                    r.result(timeout=60)
                assert ei.value.stage == "codec"
                assert isinstance(ei.value.__cause__, CodecError)
            else:
                got = r.result(timeout=60)
                np.testing.assert_allclose(got, want[i], atol=1e-5)
                assert int(np.argmax(got)) == int(np.argmax(want[i]))
        health = s.health()
    assert health["worker_alive"] and health["ingest_alive"]
    assert health["breaker"]["state"] == "closed"  # codec never feeds it
    assert (s.metrics.failures_total()["codec"] == len(inj.corrupted))


def test_executor_fault_contained_and_retried(setup):
    """An injected executor fault burns the retry then fails only its
    batch; the next dispatch serves normally."""
    spec, params, state, coef, plan = setup
    inj = FaultInjector(FaultSpec(executor_fail_batches=(0, 1)))
    s = _sched(plan, coef, breaker=_lenient(), faults=inj,
               executor_retries=1)
    try:
        doomed = s.submit(np.asarray(coef[0]))   # dispatch 0: in window
        with pytest.raises(SV.RequestFailed) as ei:
            doomed.result(timeout=60)
        assert ei.value.stage == "executor"
        assert isinstance(ei.value.__cause__, InjectedFault)
        ok = s.submit(np.asarray(coef[1]))       # dispatch 1: outside window
        assert np.isfinite(ok.result(timeout=60)).all()
        assert s.metrics.failures_total()["executor"] == 1
        assert s.health()["worker_alive"]
    finally:
        s.close()


def test_transient_executor_fault_retry_succeeds(setup):
    """A fault that clears before the retry budget leaves *no* failed
    requests and no breaker failure."""
    spec, params, state, coef, plan = setup
    calls = []

    class Flaky:
        def on_ingest(self, reqs):
            pass

        def on_execute(self, seq, reqs):
            calls.append(seq)
            if len(calls) == 1:
                raise InjectedFault("first attempt only")

    with _sched(plan, coef, breaker=_lenient(), faults=Flaky(),
                executor_retries=1) as s:
        r = s.submit(np.asarray(coef[0]))
        assert np.isfinite(r.result(timeout=60)).all()
    assert calls == [0, 0]  # same dispatch seq, attempted twice
    assert s.metrics.failures_total().get("executor", 0) == 0


def test_ingest_infra_failure_contained(setup):
    """Infrastructure dying under a whole decode batch fails only that
    batch (stage "ingest"); the ingest thread keeps draining."""
    spec, params, state, coef, plan = setup
    datas = _jpeg_traffic(4, seed=12)
    boom = RuntimeError("decode infrastructure down")
    orig = ingestlib.ingest_batch
    fails = [True]

    def flaky(batch_datas, **kw):
        if fails and fails.pop():
            raise boom
        return orig(batch_datas, **kw)

    with _sched(plan, coef, breaker=_lenient()) as s:
        with pytest.MonkeyPatch.context() as mp:
            mp.setattr(ingestlib, "ingest_batch", flaky)
            first = [s.submit(d, kind="bytes") for d in datas[:2]]
            for r in first:
                with pytest.raises(SV.RequestFailed) as ei:
                    r.result(timeout=60)
                assert ei.value.stage == "ingest"
                assert ei.value.__cause__ is boom
            rest = [s.submit(d, kind="bytes") for d in datas[2:]]
            for r in rest:
                assert np.isfinite(r.result(timeout=60)).all()
        health = s.health()
    assert health["ingest_alive"] and health["worker_alive"]
    assert s.metrics.failures_total()["ingest"] == 2


def test_breaker_trips_fast_rejects_then_recovers(setup):
    """closed → open (ServiceUnavailable at submit) → half-open → closed,
    each transition on the metrics timeline."""
    spec, params, state, coef, plan = setup
    policy = SV.BreakerPolicy(max_consecutive=1, min_samples=10_000,
                              open_s=0.2, half_open_successes=1)
    inj = FaultInjector(FaultSpec(executor_fail_batches=(0, 1)))
    s = _sched(plan, coef, breaker=policy, faults=inj,
               executor_retries=0)
    try:
        r = s.submit(np.asarray(coef[0]))
        with pytest.raises(SV.RequestFailed):
            r.result(timeout=60)
        # breaker opened on the failed dispatch: fast-reject, typed
        with pytest.raises(SV.ServiceUnavailable):
            s.submit(np.asarray(coef[0]))
        assert s.health()["breaker"]["state"] == "open"
        assert s.metrics.failures_total()["rejected-open-breaker"] == 1
        time.sleep(0.25)                     # open timer expires
        probe = s.submit(np.asarray(coef[0]))  # admitted as the probe
        assert np.isfinite(probe.result(timeout=60)).all()
        deadline = time.monotonic() + 5.0
        while (s.health()["breaker"]["state"] != "closed"
               and time.monotonic() < deadline):
            time.sleep(0.01)
        assert s.health()["breaker"]["state"] == "closed"
        hops = [(e["from"], e["to"]) for e in s.metrics.breaker_timeline()]
        assert hops == [("closed", "open"), ("open", "half_open"),
                        ("half_open", "closed")]
    finally:
        s.close()


def test_pool_kill_supervised_respawn(setup):
    """SIGKILLing an ingest-pool worker mid-run surfaces as a supervised
    respawn — requests still complete, ``pool_restarts`` ticks."""
    spec, params, state, coef, plan = setup
    datas = _jpeg_traffic(8, seed=14)
    with pytest.MonkeyPatch.context() as mp:
        mp.setenv("JPEG_INGEST_WORKERS", "2")
        try:
            # warm the shared pool so there is a live worker to murder
            ingestlib.ingest_batch(datas[:2], quality=75, grid=(2, 2))
            assert ingestlib._POOL is not None
            before = ingestlib.pool_restarts()
            inj = FaultInjector(FaultSpec(kill_worker_before_batch=1))
            with _sched(plan, coef, breaker=_lenient(), faults=inj,
                        batch=4) as s:
                reqs = [s.submit(d, kind="bytes") for d in datas]
                for r in reqs:
                    assert np.isfinite(r.result(timeout=120)).all()
                assert s.health()["pool_restarts"] >= 1
            assert inj.killed_pid is not None
            assert ingestlib.pool_restarts() > before
            assert s.metrics.failures_total().get("ingest", 0) == 0
        finally:
            ingestlib.shutdown_pool()


# --------------------------------------------------------------------------
# close() deadlock regression
# --------------------------------------------------------------------------


class _Die(BaseException):
    """Worker-killing poison: *not* an Exception, so no retry, no
    containment — the worker thread genuinely dies."""


def test_close_survives_worker_death_with_full_decoded_queue(setup):
    """PR-8 regression: the worker dies while the ingest thread is
    blocked on the bounded decoded queue.  Before the fix the ingest
    thread waited forever for queue room and ``close()`` hung on its
    join; now every request resolves and close returns promptly."""
    spec, params, state, coef, plan = setup
    datas = _jpeg_traffic(10, seed=16)
    release = threading.Event()

    class Poison:
        def on_ingest(self, reqs):
            pass

        def on_execute(self, seq, reqs):
            release.wait(timeout=30)  # hold dispatch until the queue jams
            raise _Die("worker killed by chaos harness")

    s = _sched(plan, coef, batch=1, breaker=_lenient(), faults=Poison())
    try:
        reqs = [s.submit(d, kind="bytes") for d in datas]
        # let the ingest thread fill the decoded queue to its cap and
        # block; only then kill the worker
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            with s._lock:
                jammed = (len(s._decoded) >= s._decoded_cap
                          and s._ingesting > 0)
            if jammed:
                break
            time.sleep(0.005)
        release.set()
        for r in reqs:
            with pytest.raises(BaseException):
                r.result(timeout=30)
            assert r.error() is not None

        done = threading.Event()

        def closer():
            try:
                s.close()
            except BaseException:
                pass  # close re-raises the worker's death — fine
            done.set()

        t = threading.Thread(target=closer, daemon=True)
        t.start()
        assert done.wait(timeout=30), "close() deadlocked"
        assert not s._ingest_thread.is_alive()
        assert not s._worker.is_alive()
    finally:
        release.set()
