"""End-to-end behaviour: training drives loss down, conversion serves,
preemption-resume is bit-consistent."""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))


@pytest.mark.slow
def test_jpeg_resnet_training_learns(tmp_path):
    """Train the paper's network end-to-end on synthetic JPEG data: the
    loss must drop well below chance (ln 10 ≈ 2.30)."""
    metrics = os.path.join(str(tmp_path), "m.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "jpeg-resnet",
         "--reduced", "--steps", "60", "--batch", "16", "--lr", "3e-3",
         "--ckpt-dir", os.path.join(str(tmp_path), "ck"),
         "--ckpt-every", "0", "--log-every", "10",
         "--metrics-out", metrics],
        capture_output=True, text=True, env=ENV, timeout=1500)
    assert out.returncode == 0, out.stderr[-2000:]
    m = json.load(open(metrics))
    losses = dict(m["losses"])
    assert losses[max(losses)] < losses[0], m["losses"]
    assert losses[max(losses)] < 2.2, m["losses"]


@pytest.mark.slow
def test_lm_training_learns(tmp_path):
    metrics = os.path.join(str(tmp_path), "m.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--reduced", "--steps", "40", "--batch", "8", "--seq", "64",
         "--lr", "2e-3", "--ckpt-dir", os.path.join(str(tmp_path), "ck"),
         "--ckpt-every", "0", "--log-every", "10", "--metrics-out", metrics],
        capture_output=True, text=True, env=ENV, timeout=1500)
    assert out.returncode == 0, out.stderr[-2000:]
    m = json.load(open(metrics))
    losses = dict(m["losses"])
    assert losses[max(losses)] < losses[0] - 0.3, m["losses"]


@pytest.mark.slow
def test_preemption_and_resume(tmp_path):
    """SIGTERM mid-training checkpoints and exits 0; a restart resumes from
    the saved step (fault-tolerance contract)."""
    ck = os.path.join(str(tmp_path), "ck")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--reduced", "--steps", "4000", "--batch", "4", "--seq", "32",
         "--ckpt-dir", ck, "--ckpt-every", "5", "--log-every", "5"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True, env=ENV)
    t0 = time.time()
    seen = ""
    while time.time() - t0 < 420:
        line = proc.stdout.readline()
        seen += line
        if "step 10" in line or "step 15" in line:
            break
    proc.send_signal(signal.SIGTERM)
    rc = proc.wait(timeout=300)
    assert rc == 0, seen[-2000:]

    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--reduced", "--steps", "1", "--batch", "4", "--seq", "32",
         "--ckpt-dir", ck, "--log-every", "1"],
        capture_output=True, text=True, env=ENV, timeout=600)
    assert "resumed from step" in out.stdout, out.stdout[-1500:]


@pytest.mark.slow
def test_serve_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-360m",
         "--reduced", "--batch", "2", "--requests", "4", "--max-new", "6"],
        capture_output=True, text=True, env=ENV, timeout=900)
    assert out.returncode == 0, out.stderr[-1500:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["completed"] == 4
    assert result["tokens_per_s"] > 0


def test_conversion_pipeline_end_to_end(rng):
    """Train a spatial model briefly, convert, serve JPEG inputs — predicted
    classes identical between domains (the paper's deployment story)."""
    from repro.core import convert as CV
    from repro.core import jpeg as J
    from repro.core import resnet as R
    from repro.data.synthetic import image_batch

    spec = R.ResNetSpec(widths=(8, 12, 16), num_classes=4)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    data = image_batch(0, 0, 24, 32, 3, 4)
    x, y = jnp.asarray(data["images"]), jnp.asarray(data["labels"])

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            logits, st = R.spatial_apply(p, state, x, training=True, spec=spec)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1)), st
        (l, st), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
        return params, st, l

    for _ in range(5):
        params, state, l = step(params, state)

    model, dev = CV.convert_and_verify(params, state, spec, x[:8])
    assert dev < 1e-3
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True), 1, 3)
    pred_jpeg = jnp.argmax(model(coef), -1)
    logits_sp, _ = R.spatial_apply(params, state, x, training=False, spec=spec)
    pred_sp = jnp.argmax(logits_sp, -1)
    assert bool(jnp.all(pred_jpeg == pred_sp))
