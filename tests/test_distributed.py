"""Distributed behaviour on forced host devices (subprocess isolation —
XLA_FLAGS must be set before jax initialises, so each test runs a small
program in a fresh interpreter)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_prog(body: str, devices: int = 8, timeout: int = 420) -> str:
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {os.path.join(ROOT, 'src')!r})\n"
        + body
    )
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_train_step_runs_sharded():
    """A real (tiny) train step executes on a 2×2 mesh and loss decreases."""
    out = run_prog("""
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import MeshConfig, RunConfig, ShapeConfig, TrainConfig, reduced_config
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_train_step
from repro.models.registry import build_model
from repro.parallel.sharding import AxisRules, sharding_rules
from repro.data import synthetic

cfg = reduced_config("smollm-360m")
mesh = make_test_mesh(2, 2)
rules = AxisRules.default(False, data=2, model=2).with_mesh(mesh)
shape = ShapeConfig("t", 32, 4, "train")
run = RunConfig(model=cfg, shape=shape, train=TrainConfig(grad_accum=2, learning_rate=1e-2),
                mesh=MeshConfig(data=2, model=2))
model = build_model(cfg)
with mesh, sharding_rules(rules):
    b = build_train_step(model, run, mesh, rules)
    params = b.init_fns[0](jax.random.PRNGKey(0))
    opt = b.init_fns[1](params)
    step = jax.jit(b.step_fn, in_shardings=b.in_shardings, out_shardings=b.out_shardings)
    tb = synthetic.token_batch(0, 0, 4, 32, cfg.vocab_size)
    batch = {"tokens": jnp.asarray(tb["tokens"][:, :32]),
             "labels": jnp.asarray(tb["tokens"][:, 1:33])}
    losses = []
    for i in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
print("first", losses[0], "last", losses[-1])
assert losses[-1] < losses[0], losses
print("TRAIN_SHARDED_OK")
""")
    assert "TRAIN_SHARDED_OK" in out


@pytest.mark.slow
def test_moe_shard_map_matches_pjit():
    out = run_prog("""
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import reduced_config
from repro.models import moe as M
from repro.parallel.compat import make_mesh
from repro.parallel.sharding import AxisRules, sharding_rules

cfg = dataclasses.replace(reduced_config("mixtral-8x7b"), capacity_factor=8.0)
params = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
out_ref, aux_ref = M.moe_ffn(x, params, cfg)
mesh = make_mesh((2, 4), ("data", "model"))
rules = AxisRules.default(False, data=2, model=4).with_mesh(mesh)
with mesh, sharding_rules(rules):
    out_sm, aux_sm = jax.jit(lambda x, p: M.moe_ffn(x, p, cfg))(x, params)
assert float(jnp.max(jnp.abs(out_ref - out_sm))) < 2e-5
assert abs(float(aux_ref) - float(aux_sm)) < 1e-5
print("MOE_EP_OK")
""")
    assert "MOE_EP_OK" in out


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    out = run_prog("""
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.compat import make_mesh
from repro.parallel.pipeline import pipelined_apply, stack_stage_params, bubble_fraction

mesh = make_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
stages = [{"w": jax.random.normal(jax.random.fold_in(key, i), (16, 16)) * 0.3}
          for i in range(4)]
params = stack_stage_params(stages)

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

mb = jax.random.normal(jax.random.PRNGKey(7), (6, 8, 16))  # 6 microbatches
with mesh:
    out = pipelined_apply(stage_fn, params, mb, mesh)

# sequential oracle
ref = mb
for s in stages:
    ref = stage_fn(s, ref)
err = float(jnp.max(jnp.abs(out - ref)))
print("pp err", err, "bubble", bubble_fraction(4, 6))
assert err < 1e-5
print("PP_OK")
""")
    assert "PP_OK" in out


@pytest.mark.slow
def test_collectives_helpers():
    out = run_prog("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import shard_map, make_mesh
from repro.parallel.collectives import hierarchical_psum, psum_compressed, ring_all_gather

mesh = make_mesh((2, 4), ("pod", "data"))

def f(x):
    a = hierarchical_psum(x, "data", "pod")
    b = psum_compressed(x, ("pod", "data"))
    g = ring_all_gather(x, "data")
    return a, b, g

x = jnp.arange(8.0).reshape(8, 1)
fn = shard_map(f, mesh=mesh, in_specs=P(("pod", "data"), None),
               out_specs=(P(("pod","data"), None), P(("pod","data"), None), P(("pod","data"), None, None)) if False else (P(("pod","data"), None), P(("pod","data"), None), P(("pod","data"), None, None)), check_vma=False)
a, b, g = fn(x)
assert np.allclose(a, x.sum()), a
assert np.allclose(b, x.sum(), atol=0.5)  # bf16-compressed
print("COLL_OK")
""")
    assert "COLL_OK" in out


@pytest.mark.slow
def test_elastic_restore_across_mesh_sizes():
    """Checkpoint saved unsharded restores under a different mesh."""
    out = run_prog("""
import tempfile
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from repro.parallel.compat import make_mesh

tree = {"w": jnp.arange(64.0).reshape(8, 8)}
d = tempfile.mkdtemp()
m = CheckpointManager(d)
m.save(1, tree)
for shape, axes in [((2, 4), ("data", "model")), ((4, 2), ("data", "model"))]:
    mesh = make_mesh(shape, axes)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    step, restored, _ = m.restore_latest(tree, sh)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.mesh.shape == dict(zip(axes, shape))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
