"""Convolution explosion: exact equivalence with spatial convolution."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import conv as C
from repro.core import jpeg as J


def _to_jpeg_layout(x):
    return jnp.moveaxis(J.jpeg_encode(x, scaled=False), 1, 3)


def _from_jpeg_layout(c):
    return J.jpeg_decode(jnp.moveaxis(c, 3, 1), scaled=False)


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("r", [1, 3, 5])
def test_explosion_matches_spatial(rng, stride, r):
    k = jnp.asarray(rng.normal(size=(4, 3, r, r)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 3, 16, 16)), jnp.float32)
    spatial = C.spatial_conv(x, k, stride)
    out = C.jpeg_conv(_to_jpeg_layout(x), k, stride)
    assert np.allclose(_from_jpeg_layout(out), spatial, atol=1e-4)


def test_scaled_input_convention(rng):
    """Input layer: de-quantization folded into the operator (Eq. 20)."""
    k = jnp.asarray(rng.normal(size=(2, 3, 3, 3)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 3, 24, 24)), jnp.float32)
    coef_scaled = jnp.moveaxis(J.jpeg_encode(x, scaled=True), 1, 3)
    out = C.jpeg_conv(coef_scaled, k, 1, in_scaled=True)
    spatial = C.spatial_conv(x, k, 1)
    assert np.allclose(_from_jpeg_layout(out), spatial, atol=1e-4)


def test_bias_on_dc(rng):
    k = jnp.asarray(rng.normal(size=(2, 3, 3, 3)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(2,)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 3, 16, 16)), jnp.float32)
    out = C.jpeg_conv(_to_jpeg_layout(x), k, 1, bias=b)
    spatial = C.spatial_conv(x, k, 1, bias=b)
    assert np.allclose(_from_jpeg_layout(out), spatial, atol=1e-4)


def test_full_operator_matches_basis(rng):
    """Paper Algorithm 1 (full position-dependent operator) == basis path."""
    k = jnp.asarray(rng.normal(size=(2, 3, 3, 3)) * 0.3, jnp.float32)
    x = _to_jpeg_layout(jnp.asarray(rng.normal(size=(2, 3, 16, 16)), jnp.float32))
    for stride in (1, 2):
        op = C.explode_full(k, 2, 2, stride, scaled=False)
        a = C.apply_full(x, op)
        b = C.jpeg_conv(x, k, stride)
        assert np.allclose(a, b, atol=1e-4), stride


def test_gradient_equivalence(rng):
    """The conversion is exact for *training* too: dL/dK agrees across
    domains (the paper's 'more complex gradient' is the same gradient)."""
    k = jnp.asarray(rng.normal(size=(2, 3, 3, 3)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 3, 16, 16)), jnp.float32)
    coef = _to_jpeg_layout(x)

    def loss_spatial(kk):
        return jnp.sum(C.spatial_conv(x, kk, 1) ** 2)

    def loss_jpeg(kk):
        return jnp.sum(C.jpeg_conv(coef, kk, 1) ** 2)

    # Parseval: sum of squares is preserved by the orthonormal transform,
    # so the losses and their gradients must agree.
    g1 = jax.grad(loss_spatial)(k)
    g2 = jax.grad(loss_jpeg)(k)
    assert np.allclose(g1, g2, atol=1e-2, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_linearity_in_kernel(seed):
    """explode(aK1 + bK2) == a explode(K1) + b explode(K2)."""
    r = np.random.default_rng(seed)
    k1 = jnp.asarray(r.normal(size=(2, 2, 3, 3)), jnp.float32)
    k2 = jnp.asarray(r.normal(size=(2, 2, 3, 3)), jnp.float32)
    lhs = C.explode(2.0 * k1 - 0.5 * k2, 1)
    rhs = 2.0 * C.explode(k1, 1) - 0.5 * C.explode(k2, 1)
    assert np.allclose(lhs, rhs, atol=1e-5)


def test_block_offsets():
    assert C.block_offsets(1, 3) == (-1, 1)
    assert C.block_offsets(2, 3) == (-1, 1)
    assert C.block_offsets(1, 1) == (0, 0)
    assert C.block_offsets(2, 1) == (0, 1)
    with pytest.raises(ValueError):
        C.block_offsets(1, 4)
