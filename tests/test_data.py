"""Data pipeline: determinism, exact resume, shapes, prefetch."""
import numpy as np

from repro.data import (
    DataIterator, image_iterator, jpeg_iterator, prefetch, token_iterator,
)
from repro.data.synthetic import token_batch, unigram_entropy


def test_token_determinism():
    a = token_iterator(7, 4, 16, 100)
    b = token_iterator(7, 4, 16, 100)
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
        np.testing.assert_array_equal(ba["labels"], bb["labels"])


def test_iterator_resume_exactly_once():
    it = token_iterator(3, 2, 8, 50)
    next(it); next(it)
    state = it.state_dict()
    third = next(it)
    it2 = token_iterator(3, 2, 8, 50)
    it2.load_state_dict(state)
    third_again = next(it2)
    np.testing.assert_array_equal(third["tokens"], third_again["tokens"])


def test_labels_shift():
    it = token_iterator(0, 2, 16, 64)
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_bigram_structure_learnable():
    """The injected bigram signal means labels are partially predictable."""
    b = token_batch(0, 0, 64, 128, 512)
    toks = b["tokens"]
    follow = (toks[:, :-1] * 7 + 3) % 510
    hit = (toks[:, 1:] == follow).mean()
    assert 0.35 < hit < 0.7  # ~0.5 by construction
    assert unigram_entropy(512) > 0


def test_image_batch_shapes_and_classes():
    it = image_iterator(0, 4, 32, 3, 10)
    b = next(it)
    assert b["images"].shape == (4, 3, 32, 32)
    assert b["labels"].shape == (4,)
    assert b["images"].dtype == np.float32
    assert np.abs(b["images"]).max() <= 1.5


def test_jpeg_iterator_coefficients():
    it = jpeg_iterator(0, 2, 32, 3, 10)
    b = next(it)
    assert b["coefficients"].shape == (2, 4, 4, 3, 64)
    # energy compaction: low-frequency coefficients dominate
    c = np.abs(b["coefficients"])
    assert c[..., :8].mean() > c[..., 32:].mean()


def test_jpeg_iterator_lossy_differs():
    a = next(jpeg_iterator(0, 2, 16, 3, 10, lossy=False))
    b = next(jpeg_iterator(0, 2, 16, 3, 10, lossy=True))
    assert not np.allclose(a["coefficients"], b["coefficients"])
    np.testing.assert_array_equal(b["coefficients"],
                                  np.round(b["coefficients"]))


def test_prefetch_preserves_order():
    it = token_iterator(1, 2, 8, 50)
    direct = [next(token_iterator(1, 2, 8, 50)) for _ in range(1)]
    pre = prefetch(iter([direct[0], direct[0]]), depth=2)
    out = list(pre)
    assert len(out) == 2
