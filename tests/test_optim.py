"""Optimizers, schedules, gradient transforms."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (
    accumulate_microbatches, adamw, clip_by_global_norm, compress_grads,
    global_norm, lion, make_optimizer, make_schedule, sgd,
)


def _rosenbrock_like(opt, steps=400, lr=0.08):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(4.0)}

    def loss(p):
        return jnp.sum((p["w"] - 1.0) ** 2) + (p["b"] + 2.0) ** 2

    state = opt.init(params)
    for t in range(steps):
        g = jax.grad(loss)(params)
        # linear decay — sign-step optimizers (lion) need a schedule to
        # stop oscillating around the optimum, like production configs.
        lr_t = lr * (1.0 - t / steps)
        params, state = opt.update(g, state, params, jnp.asarray(lr_t))
    return float(loss(params))


@pytest.mark.parametrize("name", ["adamw", "sgd", "lion"])
def test_optimizers_converge(name):
    opt = make_optimizer(name, weight_decay=0.0)
    final = _rosenbrock_like(opt)
    assert final < 0.05, (name, final)


def test_adamw_weight_decay_shrinks_weights():
    opt = adamw(weight_decay=0.5)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    g = {"w": jnp.zeros((4,))}
    p2, _ = opt.update(g, state, params, jnp.asarray(0.1))
    assert float(p2["w"][0]) < 1.0


def test_adamw_bf16_master_weights():
    """bf16 params keep an fp32 master: tiny updates are not lost."""
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.inner["master"]["w"].dtype == jnp.float32
    for _ in range(3):
        g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
        params, state = opt.update(g, state, params, jnp.asarray(1e-4))
    assert params["w"].dtype == jnp.bfloat16
    assert float(state.inner["master"]["w"][0]) != 1.0


def test_clipping():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(global_norm(clipped)), 1.0, rtol=1e-3)
    assert float(norm) > 1.0
    small = {"a": jnp.full((4,), 0.01)}
    same, _ = clip_by_global_norm(small, 1.0)
    assert np.allclose(same["a"], small["a"])


def test_compression():
    g = {"a": jnp.asarray([1.00390625, 2.0])}
    c = compress_grads(g, "bf16")
    assert c["a"].dtype == jnp.bfloat16
    assert compress_grads(g, "none") is g
    with pytest.raises(ValueError):
        compress_grads(g, "int3")


def test_accumulation_matches_full_batch():
    w = jnp.asarray([1.0, -2.0, 0.5])
    batch = {"x": jax.random.normal(jax.random.PRNGKey(0), (8, 3)),
             "y": jax.random.normal(jax.random.PRNGKey(1), (8,))}

    def loss(w, b):
        return jnp.mean((b["x"] @ w - b["y"]) ** 2)

    l_full, g_full = jax.value_and_grad(loss)(w, batch)
    l_acc, g_acc = accumulate_microbatches(loss, w, batch, 4)
    assert np.isclose(float(l_full), float(l_acc), rtol=1e-6)
    np.testing.assert_allclose(g_full, g_acc, rtol=1e-5)


@pytest.mark.parametrize("name", ["cosine", "linear", "constant"])
def test_schedules(name):
    fn = make_schedule(name, 1e-3, 10, 100)
    assert float(fn(0)) <= 1e-4 + 1e-9 or name == "constant"
    assert np.isclose(float(fn(10)), 1e-3, rtol=1e-5)
    if name != "constant":
        assert float(fn(99)) < 1e-3
    # monotone warmup
    vals = [float(fn(s)) for s in range(10)]
    assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:]))
