"""End-to-end ingest: bytes → logits parity, tile-packed entry, the
real-file iterator, the prefetch lifecycle fix, and empirical-profile
band autotuning."""
import argparse
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dct as dctlib
from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro.codec import bitstream as bs
from repro.codec import encode as enc
from repro.codec import ingest as ing
from repro.data import pipeline as pipe

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "codec")
SPEC = R.ResNetSpec(in_channels=3, widths=(4, 6, 8), num_classes=10)
GRID = (4, 4)  # 3 stages -> 32x32 input


@pytest.fixture(scope="module")
def small_plan():
    params, state = R.init_resnet(jax.random.PRNGKey(0), SPEC)
    cfg = DSP.DispatchConfig(path="reference", bands=64)
    return PL.build_plan(params, state, SPEC, dispatch=cfg)


def _fixture_bytes(name="gray_q80"):
    with open(os.path.join(FIXDIR, name + ".jpg"), "rb") as f:
        return f.read()


def test_bytes_to_logits_parity_against_pixel_route(small_plan):
    """Acceptance: a committed fixture through the bytes-in path matches
    the reference route (pixel decode → jpeg_encode → plan walk)."""
    data = _fixture_bytes()
    # bytes route: entropy decode -> normalize -> plan walk (no pixels)
    coef = ing.decode_bytes(data, quality=SPEC.quality, grid=GRID,
                            channels=3)
    logits = np.asarray(PL.apply_plan(small_plan, jnp.asarray(coef[None])))

    # reference route: decode the file to pixels, crop the same window
    # fit_grid used, re-encode with core.jpeg, walk the same plan
    dec = bs.decode_jpeg(data)
    deq = dec.coefficients[0] * dec.qtable(0).astype(np.float64)
    px = np.asarray(J.jpeg_decode(jnp.asarray(deq[None]), scaled=False))[0]
    px = px / 128.0  # network pixel convention
    by, bx = dec.coefficients[0].shape[:2]
    oy, ox = ((by - GRID[0]) // 2) * 8, ((bx - GRID[1]) // 2) * 8
    px = px[oy: oy + GRID[0] * 8, ox: ox + GRID[1] * 8]
    ref_coef = np.asarray(J.jpeg_encode(jnp.asarray(px[None]),
                                        quality=SPEC.quality, scaled=True))
    ref_coef = np.repeat(np.moveaxis(ref_coef, 0, 2)[None], 3, axis=3)
    assert np.abs(coef[None] - ref_coef).max() < 1e-4
    ref_logits = np.asarray(PL.apply_plan(small_plan,
                                          jnp.asarray(ref_coef)))
    assert np.abs(logits - ref_logits).max() < 1e-3
    assert (logits.argmax(-1) == ref_logits.argmax(-1)).all()


def test_compiled_packed_entry_matches_full_width(small_plan):
    cp = PL.compile_plan(small_plan, image_size=32)
    datas = [_fixture_bytes("gray_q80"), _fixture_bytes("color_q85_420")]
    full, _ = ing.ingest_batch(datas, quality=SPEC.quality, grid=GRID,
                               channels=3)
    packed, _ = ing.ingest_batch(datas, quality=SPEC.quality, grid=GRID,
                                 channels=3, pack_width=cp.stem.w_in)
    assert packed.shape == (2, 4, 4, 3 * cp.stem.w_in)
    a = np.asarray(PL.apply_compiled(cp, jnp.asarray(full)))
    b = np.asarray(PL.apply_compiled_packed(cp, jnp.asarray(packed)))
    assert np.abs(a - b).max() < 1e-5


def test_compiled_packed_rejects_wrong_width(small_plan):
    cp = PL.compile_plan(small_plan, image_size=32)
    bad = jnp.zeros((1, 4, 4, 3 * (cp.stem.w_in + 8)))
    with pytest.raises(ValueError):
        PL.apply_compiled_packed(cp, bad)


def test_ingest_stats_and_merge():
    datas = [_fixture_bytes("gray_q80")] * 2
    _, s1 = ing.ingest_batch(datas, quality=50, grid=GRID, channels=3)
    assert s1.images == 2 and s1.blocks == 2 * 4 * 4 * 3
    assert (s1.energy >= 0).all()
    assert ((0 <= s1.occupancy) & (s1.occupancy <= 1)).all()
    assert s1.occupancy[0] > s1.occupancy[-1]  # energy compaction
    merged = ing.merge_stats([s1, s1])
    assert merged.images == 4
    assert np.allclose(merged.energy, s1.energy)
    assert ing.merge_stats([]).images == 0


def test_bands_for_profile_monotone_and_empirical():
    lowpass = np.zeros(64)
    lowpass[:8] = 1.0
    assert PL.bands_for_profile(lowpass, 0.95) == 8
    flat = np.ones(64)
    assert PL.bands_for_profile(flat, 0.95) == 64
    prev = 64
    profile = 1.0 / (np.arange(64) + 1.0) ** 2
    for budget in (0.999, 0.99, 0.9, 0.5, 0.1):
        b = PL.bands_for_profile(profile, budget)
        assert b <= prev
        prev = b
    with pytest.raises(ValueError):
        PL.bands_for_profile(np.zeros(64), 0.9)
    with pytest.raises(ValueError):
        PL.bands_for_profile(-np.ones(64), 0.9)


def test_autotune_uses_empirical_profile(capsys):
    params, state = R.init_resnet(jax.random.PRNGKey(1), SPEC)
    lowpass = np.zeros(64)
    lowpass[:16] = 1.0
    occ = np.zeros(64)
    occ[:24] = 0.5
    bands = PL.autotune_bands(params, state, SPEC, profile=lowpass,
                              occupancy=occ)
    assert set(bands.values()) == {16}
    out = capsys.readouterr().out
    assert "energy_kept" in out and "occupancy_dropped" in out


def test_jpeg_file_iterator_checkpoint_semantics(tmp_path):
    it = pipe.jpeg_file_iterator(FIXDIR, batch=3, grid=GRID, channels=3,
                                 seed=7)
    b0, b1 = next(it), next(it)
    assert b0["coefficients"].shape == (3, 4, 4, 3, 64)
    assert b0["labels"].tolist() == [-1, -1, -1]
    # restore from the two-integer checkpoint state and replay
    it2 = pipe.jpeg_file_iterator(FIXDIR, batch=3, grid=GRID, channels=3,
                                  seed=0)
    it2.load_state_dict({"seed": 7, "step": 1})
    assert np.array_equal(next(it2)["coefficients"], b1["coefficients"])


def test_jpeg_file_iterator_packed_and_labels():
    it = pipe.jpeg_file_iterator(
        FIXDIR, batch=2, grid=GRID, channels=3, seed=1,
        label_fn=lambda p: len(os.path.basename(p)), pack_width=16)
    b = next(it)
    assert b["coefficients"].shape == (2, 4, 4, 3 * 16)
    assert (b["labels"] > 0).all()
    with pytest.raises(ValueError):
        pipe.jpeg_file_iterator([], batch=1, grid=GRID)


def test_serve_bytes_in_path(tmp_path):
    """The committed fixtures served through launch/serve.py's bytes-in
    request path, end to end (plan built, compiled, tile-packed ingest)."""
    from repro.launch.serve import serve_jpeg_resnet

    ns = argparse.Namespace(
        arch="jpeg-resnet", reduced=True, batch=2, requests=2, ctx=0,
        max_new=1, seed=0, dispatch=None, bands=None,
        plan_dir=str(tmp_path / "plan"), autotune_bands=False,
        compiled=None, ingest="bytes", jpeg_dir=FIXDIR)
    out = serve_jpeg_resnet(ns)
    assert out["completed"] == 2
    assert out["ingest"] == "bytes"
    assert out["plan"]["compiled"]
    assert out["ingest_stats"]["images"] >= 2
    assert out["ingest_stats"]["bytes_in"] > 0


# ---------------------------------------------------------------------------
# prefetch lifecycle (the producer-thread leak fix)
# ---------------------------------------------------------------------------


def _thread_names():
    return {t.name for t in threading.enumerate()}


def test_prefetch_joins_thread_on_early_close():
    produced = []

    def source():
        for i in range(10_000):
            produced.append(i)
            yield i

    before = threading.active_count()
    gen = pipe.prefetch(source(), depth=2)
    assert next(gen) == 0
    gen.close()  # consumer walks away mid-stream
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == before, "producer thread leaked"
    n = len(produced)
    time.sleep(0.05)
    assert len(produced) == n, "producer kept running after close"


def test_prefetch_exhaustion_joins_thread():
    before = threading.active_count()
    assert list(pipe.prefetch(iter(range(5)), depth=2)) == [0, 1, 2, 3, 4]
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == before


def test_prefetch_propagates_source_exception():
    def source():
        yield 1
        raise RuntimeError("boom")

    gen = pipe.prefetch(source(), depth=2)
    assert next(gen) == 1
    with pytest.raises(RuntimeError, match="boom"):
        next(gen)


def test_prefetch_consumer_exception_joins_thread():
    before = threading.active_count()
    with pytest.raises(ValueError):
        for i in pipe.prefetch(iter(range(1000)), depth=2):
            raise ValueError("consumer failed")
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() == before
