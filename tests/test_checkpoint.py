"""Fault-tolerant checkpointing: atomicity, corruption fallback, retention."""
import json
import os
import shutil

import numpy as np
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step_count": jnp.asarray(7)}


def test_roundtrip(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(5, tree, extra={"data_state": {"seed": 0, "step": 9}})
    restored = m.restore_latest(tree)
    assert restored is not None
    step, out, extra = restored
    assert step == 5
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    assert out["params"]["b"].dtype == np.asarray(tree["params"]["b"]).dtype
    assert extra["data_state"]["step"] == 9


def test_retention(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, tree)
    assert m.steps() == [3, 4]


def test_corruption_fallback(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=5)
    m.save(1, tree)
    m.save(2, tree)
    # corrupt the newest checkpoint's array file
    with open(os.path.join(str(tmp_path), "step_2", "arrays.npz"), "r+b") as f:
        f.seek(100)
        f.write(b"\xde\xad\xbe\xef" * 8)
    restored = m.restore_latest(tree)
    assert restored is not None and restored[0] == 1  # fell back


def test_tmp_dir_never_shadows(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=5)
    m.save(1, tree)
    # a crashed mid-write leaves a .tmp dir — must be ignored
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert m.steps() == [1]
    assert m.restore_latest(tree)[0] == 1


def test_async_save(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, tree, blocking=False)
    m.wait()
    assert m.steps() == [1]


def test_restore_missing_leaf_raises(tmp_path, tree):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, tree)
    bigger = dict(tree)
    bigger["extra_leaf"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        m.restore(1, bigger)
