"""Codec conformance: golden-fixture entropy decode + bitstream errors.

The committed fixtures (``tests/fixtures/codec``) are PIL/libjpeg-encoded
files whose entropy-decoded coefficients were cross-validated against
libjpeg's own pixel output at generation time (see ``make_fixtures.py``);
here the decode must reproduce them **bit-exactly**, and — when PIL is
installed — the dequantize+IDCT of our integers must still match PIL's
pixel decode to within its integer rounding.
"""
import io
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import dct as dctlib
from repro.core import jpeg as J
from repro.codec import bitstream as bs

try:
    from PIL import Image

    HAVE_PIL = True
except ModuleNotFoundError:
    HAVE_PIL = False

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures", "codec")
FIXTURES = ("gray_q80", "color_q85_420", "color_q75_dri",
            "color_q75_dri_trailing_rst")


def _load(name):
    with open(os.path.join(FIXDIR, name + ".jpg"), "rb") as f:
        data = f.read()
    golden = np.load(os.path.join(FIXDIR, name + ".npz"))
    return data, golden


@pytest.mark.parametrize("name", FIXTURES)
def test_golden_decode_bit_exact(name):
    data, golden = _load(name)
    dec = bs.decode_jpeg(data)
    assert dec.width == int(golden["width"])
    assert dec.height == int(golden["height"])
    assert dec.restart_interval == int(golden["restart_interval"])
    for i, comp in enumerate(dec.components):
        assert np.array_equal(dec.coefficients[i], golden[f"coef{i}"]), \
            f"component {i} coefficients differ from golden"
        assert np.array_equal(dec.qtable(i), golden[f"qtable{i}"])
        assert (comp.h, comp.v) == tuple(golden[f"sampling{i}"])


@pytest.mark.parametrize("name", FIXTURES)
def test_golden_matches_libjpeg_pixels(name):
    """Dequantize + exact IDCT of our integers == libjpeg's pixel decode
    (to within libjpeg's integer rounding) — independent conformance."""
    if not HAVE_PIL:
        pytest.skip("PIL not installed")
    data, _ = _load(name)
    dec = bs.decode_jpeg(data)
    pim = Image.open(io.BytesIO(data))
    if pim.mode == "L":
        ref = np.asarray(pim, np.float64)
    else:
        pim.draft("YCbCr", None)
        ref = np.asarray(pim.convert("YCbCr"), np.float64)[..., 0]
    deq = dec.coefficients[0] * dec.qtable(0).astype(np.float64)
    own = np.asarray(J.jpeg_decode(jnp.asarray(deq[None]),
                                   scaled=False))[0] + 128.0
    own = np.clip(own, 0, 255)[: dec.height, : dec.width]
    assert np.abs(own - ref).max() < 1.0


def test_fixture_shapes_and_sampling():
    _, golden = _load("color_q85_420")
    # 4:2:0: luma on the full 6x6 grid, chroma on 3x3
    assert golden["coef0"].shape == (6, 6, 64)
    assert golden["coef1"].shape == (3, 3, 64)
    assert tuple(golden["sampling0"]) == (2, 2)
    assert tuple(golden["sampling1"]) == (1, 1)


def test_blocks_reports_unpadded_dims():
    data, _ = _load("gray_q80")
    dec = bs.decode_jpeg(data)
    assert dec.blocks(0) == (5, 7)  # 40x56


def test_not_a_jpeg():
    with pytest.raises(bs.JpegError):
        bs.decode_jpeg(b"PNG not a jpeg")


def test_truncated_stream():
    data, _ = _load("gray_q80")
    with pytest.raises(bs.JpegError):
        bs.decode_jpeg(data[: len(data) // 2])


def test_progressive_rejected_loudly():
    if not HAVE_PIL:
        pytest.skip("PIL not installed")
    im = Image.fromarray(np.uint8(np.arange(64 * 64).reshape(64, 64) % 256),
                         "L")
    buf = io.BytesIO()
    im.save(buf, "JPEG", quality=75, progressive=True)
    with pytest.raises(bs.UnsupportedJpegError) as e:
        bs.decode_jpeg(buf.getvalue())
    msg = str(e.value)
    assert "progressive" in msg
    # the rejection is *friendly*: it names what IS supported and points
    # at the roadmap item tracking the extension
    assert "SOF0" in msg and "SOF1" in msg and "ROADMAP" in msg


def _patch_sof_marker(data: bytes, to: int) -> bytes:
    """Rewrite the fixture's SOF0 marker byte — the parser rejects at the
    marker, before any entropy decoding, so the rest may stay stale."""
    at = data.index(b"\xff\xc0")
    return data[:at + 1] + bytes([to]) + data[at + 2:]


@pytest.mark.parametrize("marker,expect", [
    (0xC2, "progressive"),
    (0xC3, "lossless"),
    (0xC9, "arithmetic-coded sequential"),
    (0xCA, "arithmetic-coded progressive"),
])
def test_unsupported_sof_variants_named(marker, expect):
    data, _ = _load("gray_q80")
    with pytest.raises(bs.UnsupportedJpegError) as e:
        bs.decode_jpeg(_patch_sof_marker(data, marker))
    msg = str(e.value)
    assert expect in msg
    assert "SOF0" in msg and "SOF1" in msg and "ROADMAP" in msg


def test_arithmetic_dac_marker_rejected():
    # a DAC (arithmetic conditioning) segment is only legal in arithmetic
    # streams; reject it on sight with the same friendly pointer
    stream = b"\xff\xd8" + b"\xff\xcc\x00\x04\x00\x01" + b"\xff\xd9"
    with pytest.raises(bs.UnsupportedJpegError) as e:
        bs.decode_jpeg(stream)
    msg = str(e.value)
    assert "arithmetic" in msg and "SOF0" in msg and "ROADMAP" in msg


def test_trailing_restart_marker_tolerated_bit_exact():
    """A restart marker emitted right before EOI (an empty trailing
    segment) is a benign shape some encoders produce — the decode must
    match the unpatched stream exactly."""
    data, _ = _load("color_q75_dri")
    patched, _ = _load("color_q75_dri_trailing_rst")
    assert len(patched) == len(data) + 2  # exactly one extra marker
    ref = bs.decode_jpeg(data)
    got = bs.decode_jpeg(patched)
    assert got.restart_interval == ref.restart_interval
    for a, b in zip(ref.coefficients, got.coefficients):
        assert np.array_equal(a, b)


def test_genuine_restart_mismatch_still_loud():
    data, _ = _load("color_q75_dri")
    n_seg = len(bs.prepare_scan(data).segments)
    nxt = bytes([0xFF, 0xD0 + (n_seg - 1) % 8])
    body, eoi = data[:-2], data[-2:]
    # a *non-empty* surplus segment is data the DRI accounting cannot
    # place — not the benign empty-trailing shape
    with pytest.raises(bs.JpegError, match="restart markers disagree"):
        bs.decode_jpeg(body + nxt + b"\x12\x34" + eoi)
    # two trailing restart markers are past any benign tolerance
    nxt2 = bytes([0xFF, 0xD0 + n_seg % 8])
    with pytest.raises(bs.JpegError, match="restart markers disagree"):
        bs.decode_jpeg(body + nxt + nxt2 + eoi)


def test_huffman_lut_canonical_codes():
    # two codes: '0' -> 5, '10' -> 9 (canonical assignment)
    counts = np.zeros(16, np.int64)
    counts[0], counts[1] = 1, 1
    t = bs.build_huffman_lut(counts, np.array([5, 9]))
    assert t.lut[0b0000000000000000] == (5 << 8) | 1
    assert t.lut[0b0111111111111111] == (5 << 8) | 1
    assert t.lut[0b1000000000000000] == (9 << 8) | 2
    assert t.lut[0b1011111111111111] == (9 << 8) | 2
    assert t.lut[0b1100000000000000] == -1  # unassigned prefix


def test_bad_dht_rejected():
    counts = np.zeros(16, np.int64)
    counts[0] = 3  # three 1-bit codes cannot exist
    with pytest.raises(bs.JpegError):
        bs.build_huffman_lut(counts, np.array([1, 2, 3]))
