"""Backend-dispatch layer: path parity, auto-sizing, band truncation.

The contract under test: for any operator the three registry paths —
``reference`` (pure jnp), ``pallas`` (kernel bodies via the interpreter),
``factored`` (J ∘ C ∘ J̃ never materialised) — produce the same numbers,
and the ``bands`` knob is exact at 64 and degrades monotonically below.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import asm as A
from repro.core import conv as C
from repro.core import dispatch as DSP
from repro.core import jpeg as J


def _cfg(path, **kw):
    # interpret=True so the pallas path runs the real kernel bodies through
    # the Pallas interpreter on CPU instead of delegating to reference.
    return DSP.DispatchConfig(path=path, interpret=True, **kw)


def _smooth_coef(rng, n=2, c=3, hw=16):
    """Box-upscaled random images: JPEG-like low-frequency energy."""
    small = rng.uniform(-1, 1, size=(n, c, hw // 8, hw // 8))
    x = jnp.asarray(np.kron(small, np.ones((8, 8))), jnp.float32)
    return x, jnp.moveaxis(J.jpeg_encode(x, scaled=False), 1, 3)


# --------------------------------------------------------------------------
# Conv parity across the three paths
# --------------------------------------------------------------------------


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("r", [1, 3, 5])
def test_conv_parity_sweep(rng, stride, r):
    k = jnp.asarray(rng.normal(size=(4, 3, r, r)) * 0.3, jnp.float32)
    coef = jnp.asarray(rng.normal(size=(2, 4, 4, 3, 64)), jnp.float32)
    outs = {p: DSP.conv(coef, k, stride, cfg=_cfg(p)) for p in DSP.PATHS}
    assert outs["reference"].shape == outs["pallas"].shape == outs["factored"].shape
    np.testing.assert_allclose(outs["reference"], outs["pallas"], atol=1e-4)
    np.testing.assert_allclose(outs["reference"], outs["factored"], atol=1e-4)


def test_conv_parity_wide_channels_crossing_limit(rng):
    """A wide layer whose Ξ crosses MATERIALIZE_LIMIT: auto must go
    factored and still match the (forced) materialised reference."""
    k = jnp.asarray(rng.normal(size=(16, 16, 3, 3)) * 0.1, jnp.float32)
    coef = jnp.asarray(rng.normal(size=(1, 2, 2, 16, 64)), jnp.float32)
    op_elems = 3 * 3 * 16 * 16 * 64 * 64
    auto = DSP.DispatchConfig(path="auto", materialize_limit=op_elems - 1)
    assert DSP.choose_path("conv", auto, op_elems=op_elems) == "factored"
    out_auto = DSP.conv(coef, k, 1, cfg=auto)
    out_ref = DSP.conv(coef, k, 1, cfg=_cfg("reference"))
    np.testing.assert_allclose(out_auto, out_ref, atol=1e-3)


def test_precompute_resolves_paths(rng):
    k = jnp.asarray(rng.normal(size=(4, 3, 3, 3)), jnp.float32)
    op = DSP.precompute_conv(k, 1, cfg=_cfg("reference"))
    assert op.path == "reference" and op.xi is not None
    op = DSP.precompute_conv(k, 1, cfg=DSP.DispatchConfig(
        path="auto", materialize_limit=0))
    assert op.path == "factored" and op.xi is None
    # forced pallas above the limit must degrade to factored, not OOM
    op = DSP.precompute_conv(k, 1, cfg=DSP.DispatchConfig(
        path="pallas", materialize_limit=0))
    assert op.path == "factored"


def test_apply_conv_matches_direct(rng):
    k = jnp.asarray(rng.normal(size=(4, 3, 3, 3)) * 0.3, jnp.float32)
    coef = jnp.asarray(rng.normal(size=(2, 4, 4, 3, 64)), jnp.float32)
    for p in DSP.PATHS:
        cfg = _cfg(p)
        op = DSP.precompute_conv(k, 2, cfg=cfg)
        a = DSP.apply_conv(coef, op, cfg=cfg)
        b = DSP.conv(coef, k, 2, cfg=cfg)
        np.testing.assert_allclose(a, b, atol=1e-5), p


# --------------------------------------------------------------------------
# Band truncation (paper §6 sparsity)
# --------------------------------------------------------------------------


def test_conv_bands_64_exact(rng):
    k = jnp.asarray(rng.normal(size=(4, 3, 3, 3)) * 0.3, jnp.float32)
    _, coef = _smooth_coef(rng)
    exact = DSP.conv(coef, k, 1, cfg=_cfg("reference", bands=64))
    for p in DSP.PATHS:
        out = DSP.conv(coef, k, 1, cfg=_cfg(p, bands=64))
        np.testing.assert_allclose(out, exact, atol=1e-4)


def test_conv_bands_monotone_degradation(rng):
    k = jnp.asarray(rng.normal(size=(4, 3, 3, 3)) * 0.3, jnp.float32)
    _, coef = _smooth_coef(rng)
    exact = DSP.conv(coef, k, 1, cfg=_cfg("reference"))
    errs = []
    for bands in (64, 48, 32, 16, 8):
        out = DSP.conv(coef, k, 1, cfg=_cfg("reference", bands=bands))
        errs.append(float(jnp.abs(out - exact).max()))
    assert errs[0] < 1e-5  # bands=64 is the identity truncation
    for lo, hi in zip(errs, errs[1:]):
        assert hi >= lo - 1e-6, errs  # fewer bands never helps


def test_conv_bands_parity_across_paths(rng):
    """All three paths implement the *same* truncated operator."""
    k = jnp.asarray(rng.normal(size=(4, 3, 3, 3)) * 0.3, jnp.float32)
    _, coef = _smooth_coef(rng)
    for bands in (32, 16):
        ref = DSP.conv(coef, k, 1, cfg=_cfg("reference", bands=bands))
        assert float(jnp.abs(ref[..., bands:]).max()) == 0.0
        for p in ("pallas", "factored"):
            out = DSP.conv(coef, k, 1, cfg=_cfg(p, bands=bands))
            np.testing.assert_allclose(out, ref, atol=1e-4)


def test_asm_bands_parity_and_monotone(rng):
    _, coef = _smooth_coef(rng)
    exact = DSP.asm_relu(coef, 14, cfg=_cfg("reference"))
    errs = []
    for bands in (64, 32, 16):
        ref = DSP.asm_relu(coef, 14, cfg=_cfg("reference", bands=bands))
        pal = DSP.asm_relu(coef, 14, cfg=_cfg("pallas", bands=bands))
        np.testing.assert_allclose(ref, pal, atol=2e-5)
        errs.append(float(jnp.abs(ref - exact).max()))
    assert errs[0] < 1e-6
    for lo, hi in zip(errs, errs[1:]):
        assert hi >= lo - 1e-6, errs


# --------------------------------------------------------------------------
# The other registry ops
# --------------------------------------------------------------------------


def test_asm_relu_parity(rng):
    coef = jnp.asarray(rng.normal(size=(3, 4, 64)), jnp.float32)
    for phi in (6, 14):
        a = DSP.asm_relu(coef, phi, cfg=_cfg("reference"))
        b = DSP.asm_relu(coef, phi, cfg=_cfg("pallas"))
        np.testing.assert_allclose(a, b, atol=2e-5)
        np.testing.assert_allclose(a, A.asm_relu(coef, phi), atol=1e-6)


def test_block_dct_parity_and_roundtrip(rng):
    blocks = jnp.asarray(rng.normal(size=(5, 8, 8)), jnp.float32)
    for q in (None, 50):
        a = DSP.block_dct(blocks, q, cfg=_cfg("reference"))
        b = DSP.block_dct(blocks, q, cfg=_cfg("pallas"))
        np.testing.assert_allclose(a, b, atol=2e-5)
        back = DSP.block_idct(a, q, cfg=_cfg("pallas"))
        np.testing.assert_allclose(back, blocks, atol=2e-5)


def test_batchnorm_falls_back_to_reference(rng):
    from repro.core import batchnorm as BN

    coef = jnp.asarray(rng.normal(size=(2, 2, 2, 3, 64)), jnp.float32)
    p, s = BN.init_batchnorm(3)
    a, _ = DSP.batchnorm(coef, p, s, training=True, cfg=_cfg("reference"))
    b, _ = DSP.batchnorm(coef, p, s, training=True, cfg=_cfg("pallas"))
    np.testing.assert_allclose(a, b, atol=0)
    assert DSP.available_paths("batchnorm") == ("reference",)


# --------------------------------------------------------------------------
# Config plumbing
# --------------------------------------------------------------------------


def test_override_is_scoped():
    base = DSP.get_config()
    with DSP.override(path="factored", bands=32) as cfg:
        assert DSP.get_config() is cfg
        assert cfg.path == "factored" and cfg.bands == 32
    assert DSP.get_config() is base


def test_config_validation():
    with pytest.raises(ValueError):
        DSP.DispatchConfig(path="mosaic")
    with pytest.raises(ValueError):
        DSP.DispatchConfig(bands=0)
    with pytest.raises(ValueError):
        DSP.DispatchConfig(bands=65)


def test_env_parsing(monkeypatch):
    monkeypatch.setenv("JPEG_DISPATCH", "factored")
    monkeypatch.setenv("JPEG_BANDS", "24")
    cfg = DSP._from_env()
    assert cfg.path == "factored" and cfg.bands == 24


def test_registry_rejects_unknown_path():
    with pytest.raises(ValueError):
        DSP.register("conv", "cuda", lambda *a: None)
