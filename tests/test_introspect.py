"""Plan introspection engine (``repro.introspect``).

Contracts:

* **static attribution decomposes** — the per-step FLOP sum of
  ``block_costs`` agrees with the whole-module ``analyze_hlo`` count to
  a few percent (XLA folds/fuses only *within* a jit boundary here),
  and every step carries positive FLOPs;
* **roofline picks the dominant term** — compute-, memory-, and
  collective-bound synthetic inputs each select their term, and
  profile resolution honours spec > ``$JPEG_HW_PROFILE`` > default >
  detected backend (including the ``"flops,hbm,link"`` custom triple);
* **profiling is honest** — per-step device walls sum to within ±10%
  of the *unprofiled* whole-schedule wall, and the profiled logits are
  bit-identical to the unprofiled ones;
* **the report schema is enforced** — ``validate_report`` accepts the
  produced report and rejects targeted mutations (the same checker the
  CI ``introspect-smoke`` job runs);
* **grid profiling is inert** — ``GridCell.profile`` returns logits
  bit-identical to the cell's normal ``__call__`` and the sweep feeds
  the ``serve_predicted_capacity`` gauge family.
"""
import copy

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro import introspect
from repro import serving as SV
from repro.introspect.roofline import PROFILES, HardwareProfile

EXECUTOR = None if jax.default_backend() == "tpu" else "gemm"


@pytest.fixture(scope="module")
def setup():
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    plan = PL.build_plan(params, state, spec,
                         dispatch=DSP.DispatchConfig(path="reference"))
    cp = PL.compile_plan(plan)
    return spec, coef, plan, cp


@pytest.fixture(scope="module")
def report(setup):
    _, coef, _, cp = setup
    return introspect.predicted_vs_measured(cp, coef, executor=EXECUTOR,
                                            iters=3)


# --------------------------------------------------------------------------
# Static attribution
# --------------------------------------------------------------------------


def test_block_costs_sum_cross_check(setup):
    _, coef, _, cp = setup
    blocks, whole = introspect.block_costs(cp, coef.shape,
                                           executor=EXECUTOR)
    assert [b.name for b in blocks] == (
        ["stem"] + [b.name for b in cp.blocks] + ["head"])
    for b in blocks:
        assert b.flops > 0, b.name
        assert b.bytes > 0, b.name
        assert b.predicted_s > 0, b.name
    total = sum(b.flops for b in blocks)
    assert whole.flops > 0
    # per-step lowering loses only boundary folding, never real work
    assert total == pytest.approx(whole.flops, rel=0.05)


def test_block_costs_metadata(setup):
    _, coef, plan, cp = setup
    blocks, _ = introspect.block_costs(cp, coef.shape, executor=EXECUTOR,
                                       cross_check=False)
    by_name = {b.name: b for b in blocks}
    assert by_name["stem"].kind == "stem"
    assert by_name["head"].kind == "head"
    for blk in cp.blocks:
        row = by_name[blk.name]
        assert row.bands_out == blk.bands_out
        if blk.kind == "fused":
            assert row.layer_bands  # conv1/conv2(/proj) budgets
            assert row.vmem_bytes == blk.vmem_bytes
    # energy_kept is the cumulative qtable energy at the step's band cut
    for b in blocks:
        if b.name == "head":
            assert b.energy_kept is None
        else:
            assert 0.0 < b.energy_kept <= 1.0 + 1e-9


# --------------------------------------------------------------------------
# Roofline
# --------------------------------------------------------------------------


def test_roofline_term_selection():
    hw = PROFILES["tpu-v5e"]
    r = introspect.roofline(1e15, 1e3, 0.0, hw)
    assert r["term"] == "compute"
    assert r["predicted_s"] == pytest.approx(1e15 / hw.peak_flops)
    r = introspect.roofline(1e3, 1e12, 0.0, hw)
    assert r["term"] == "memory"
    r = introspect.roofline(1e3, 1e3, 1e12, hw)
    assert r["term"] == "collective"
    assert r["predicted_s"] == pytest.approx(1e12 / hw.link_bw)


def test_resolve_profile_priority(monkeypatch):
    # spec wins over env; env wins over default; default over detection
    monkeypatch.setenv("JPEG_HW_PROFILE", "tpu-v4")
    assert introspect.resolve_profile("gpu").name == "gpu"
    assert introspect.resolve_profile().name == "tpu-v4"
    monkeypatch.delenv("JPEG_HW_PROFILE")
    assert introspect.resolve_profile(default="tpu-v5e").name == "tpu-v5e"
    detected = introspect.resolve_profile()
    assert detected.name in PROFILES
    # custom "flops,hbm,link" triple
    hw = introspect.resolve_profile("1e12, 2e11, 5e10")
    assert isinstance(hw, HardwareProfile)
    assert hw.name == "custom"
    assert hw.peak_flops == pytest.approx(1e12)
    assert hw.link_bw == pytest.approx(5e10)
    with pytest.raises(ValueError):
        introspect.resolve_profile("not-a-profile")


# --------------------------------------------------------------------------
# Measured attribution
# --------------------------------------------------------------------------


def test_predicted_vs_measured_reconciles(setup):
    _, _, plan, _ = setup
    # serve-scale widths: on the tiny parity spec, per-step dispatch
    # overhead would dominate and the walls could not reconcile
    spec = R.ResNetSpec(widths=(16, 32, 64), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 3, 32, 32)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    big = PL.compile_plan(PL.build_plan(
        params, state, spec, dispatch=DSP.DispatchConfig(path="reference")))
    last = None
    for _attempt in range(3):  # shared-CI jitter: pass on any clean sample
        rep = introspect.predicted_vs_measured(big, coef,
                                               executor=EXECUTOR, iters=5)
        assert rep["totals"]["logits_match"] is True
        last = rep["totals"]["reconciliation"]
        if abs(last - 1.0) <= 0.10:
            break
    else:
        pytest.fail(f"per-step walls never reconciled: last={last:.3f}")
    for b in rep["blocks"]:
        assert b["measured_us"] is not None and b["measured_us"] > 0


def test_report_blocks_measured(report):
    for b in report["blocks"]:
        assert b["predicted_us"] > 0
        assert b["measured_us"] is not None and b["measured_us"] > 0
        assert b["ratio"] == pytest.approx(
            b["measured_us"] / b["predicted_us"])
    assert report["totals"]["logits_match"] is True


# --------------------------------------------------------------------------
# Report schema
# --------------------------------------------------------------------------


def test_validate_report_accepts(report):
    summary = introspect.validate_report(report)
    assert summary["blocks"] == len(report["blocks"])
    assert summary["logits_match"] is True
    assert summary["worst_ratio"] is not None and summary["worst_ratio"] >= 1


@pytest.mark.parametrize("mutate,frag", [
    (lambda r: r.update(kind="nope"), "kind"),
    (lambda r: r.update(version=99), "version"),
    (lambda r: r.pop("blocks"), "blocks missing"),
    (lambda r: r["blocks"][0].pop("flops"), "missing flops"),
    (lambda r: r["blocks"][0].update(flops=-1.0), "flops"),
    (lambda r: r["blocks"][0].update(predicted_us=0.0), "predicted_us"),
    (lambda r: r["blocks"][0].update(term="magic"), "term"),
    (lambda r: r["blocks"][0].update(ratio=123.0), "ratio"),
    (lambda r: r["totals"].update(reconciliation=9.9), "reconciliation"),
    (lambda r: r["totals"].update(logits_match="yes"), "logits_match"),
    (lambda r: r["meta"].pop("hw_profile"), "hw_profile"),
])
def test_validate_report_rejects(report, mutate, frag):
    bad = copy.deepcopy(report)
    mutate(bad)
    with pytest.raises(ValueError, match=frag):
        introspect.validate_report(bad)


def test_worst_ratio_skips_dispatch_noise():
    blocks = [
        {"name": "big", "measured_us": 990.0, "predicted_us": 900.0,
         "ratio": 1.1},
        # sub-1% of the wall: pure dispatch overhead, ratio meaningless
        {"name": "tiny", "measured_us": 5.0, "predicted_us": 0.01,
         "ratio": 500.0},
    ]
    assert introspect.worst_ratio({"blocks": blocks}) == pytest.approx(1.1)
    # but a genuinely heavy outlier is kept
    blocks[1]["measured_us"] = 500.0
    assert introspect.worst_ratio({"blocks": blocks}) == pytest.approx(500.0)


def test_render_text(report):
    text = introspect.render_text(report)
    assert "stem" in text and "head" in text
    assert "logits bit-identical under profiling: True" in text


# --------------------------------------------------------------------------
# Grid profiling
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def grid(setup):
    _, coef, plan, _ = setup
    ladder = SV.build_ladder(plan, caps=(None, 32))
    g = SV.PlanGrid(ladder, batch=4, grid=coef.shape[1:3],
                    channels=coef.shape[3], executor=EXECUTOR)
    g.warmup(kinds=("coefficients",))
    return g, coef


def test_grid_cell_profile_parity(grid):
    g, coef = grid
    cell = g.distinct[0].cells[("coefficients", 4)]
    rows = [np.asarray(coef[i]) for i in range(3)]  # partial: pad to 4
    want = np.asarray(cell(rows))
    prof = cell.profile(rows, iters=2)
    assert np.array_equal(prof["logits"], want)
    assert prof["bucket"] == 4
    names = [s["name"] for s in prof["steps"]]
    assert names[0] == "stem" and names[-1] == "head"
    assert all(s["measured_us"] > 0 for s in prof["steps"])
    assert prof["cell_wall_us"] > 0


def test_profile_plan_grid_sweep(grid):
    g, _ = grid
    pg = introspect.profile_plan_grid(g, iters=2)
    assert pg["hw_profile"]["peak_flops"] > 0
    cells = {c["cell"]: c for c in pg["cells"]}
    # every warmed cell appears, capacities positive, flops scale with
    # the bucket within a column
    for col in g.distinct:
        for (kind, bucket), cell in col.cells.items():
            row = cells[cell.name]
            assert row["predicted_req_s"] > 0
            assert row["measured_req_s"] > 0
            assert row["bucket"] == bucket
    by_tier = {}
    for c in pg["cells"]:
        by_tier.setdefault((c["tier"], c["kind"]), []).append(c)
    for rows in by_tier.values():
        rows = sorted(rows, key=lambda c: c["bucket"])
        f0 = rows[0]["flops"] / rows[0]["bucket"]
        for c in rows[1:]:
            assert c["flops"] / c["bucket"] == pytest.approx(f0)
    # reference columns carry measured per-block walls
    for col in pg["columns"]:
        assert any(b["measured_us"] for b in col["blocks"])


def test_grid_costs_annotation(grid):
    g, _ = grid
    pg = introspect.profile_plan_grid(g, iters=1)
    g.annotate_costs({c["cell"]: {"flops": c["flops"],
                                  "predicted_us": c["predicted_us"]}
                      for c in pg["cells"]})
    name = pg["cells"][0]["cell"]
    cost = g.cost_for(name)
    assert cost["flops"] > 0 and cost["predicted_us"] > 0
    assert g.cost_for("no/such/cell") is None


def test_predicted_capacity_gauge():
    m = SV.ServeMetrics()
    m.record_predicted_capacity("top/bytes/b4", 123.456)
    m.record_predicted_capacity("b32/bytes/b1", 77.0)
    text = m.metrics_text()
    assert "# TYPE serve_predicted_capacity gauge" in text
    assert 'serve_predicted_capacity{cell="top/bytes/b4"} 123.456' in text
    rep = m.report()
    assert rep["predicted_capacity_req_s"]["b32/bytes/b1"] == 77.0
    # absent until recorded: the family never exports empty
    assert "serve_predicted_capacity" not in SV.ServeMetrics().metrics_text()
