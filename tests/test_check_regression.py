"""Perf-guard behavior (``benchmarks.check_regression``): fresh-only rows
are informational, baseline-only rows skip, shared rows guard, and an
empty *baseline* cannot crash a first run."""
import json
import sys

import pytest

from benchmarks import check_regression as cr


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"rows": rows}))
    return str(p)


def _row(name, speedup):
    return {"name": name, "speedup": speedup, "derived": f"{speedup}x"}


def _run(monkeypatch, base, fresh):
    monkeypatch.setattr(sys, "argv",
                        ["check_regression", base, fresh])
    cr.main()


def test_new_fresh_row_is_informational(tmp_path, monkeypatch, capsys):
    base = _write(tmp_path, "base.json",
                  [_row("fig5/infer_speedup_plan", 2.0)])
    fresh = _write(tmp_path, "fresh.json",
                   [_row("fig5/infer_speedup_plan", 2.0),
                    _row("fig5/infer_speedup_serving", 1.9)])
    _run(monkeypatch, base, fresh)  # must not raise SystemExit
    out = capsys.readouterr().out
    assert "INFO new row fig5/infer_speedup_serving" in out
    assert "perf guard passed" in out


def test_regression_still_fails(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json",
                  [_row("fig5/infer_speedup_plan", 2.0)])
    fresh = _write(tmp_path, "fresh.json",
                   [_row("fig5/infer_speedup_plan", 1.0)])
    with pytest.raises(SystemExit):
        _run(monkeypatch, base, fresh)


def test_baseline_rows_all_missing_from_fresh_fails(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json",
                  [_row("fig5/infer_speedup_plan", 2.0)])
    fresh = _write(tmp_path, "fresh.json",
                   [_row("fig5/infer_speedup_new", 3.0)])
    with pytest.raises(SystemExit):
        _run(monkeypatch, base, fresh)


def test_empty_baseline_fails(tmp_path, monkeypatch, capsys):
    """A baseline with zero guarded rows (corrupt file, wrong prefix)
    must fail — an empty comparison cannot wave regressions through."""
    base = _write(tmp_path, "base.json", [])
    fresh = _write(tmp_path, "fresh.json",
                   [_row("fig5/infer_speedup_serving", 1.9)])
    with pytest.raises(SystemExit):
        _run(monkeypatch, base, fresh)
    out = capsys.readouterr().out
    assert "INFO new row" in out  # new rows still report before the FAIL
