"""Band-elastic serving runtime (``repro.serving``).

Contracts:

* **ladder tiers are exact** — a tier derived by prefix-slicing the base
  plan's operators produces logits *bit-identical* to independently
  building + compiling a plan at the capped band assignment;
* **ladder save/restore** round-trips bit-exactly through
  ``CheckpointManager``; a manifest saved against a different plan is
  rejected loudly;
* **scheduler lifecycle** mirrors the PR-4 ``prefetch`` contract: close
  drains by default, a non-draining close fails queued requests with
  ``SchedulerClosed``, and a worker crash re-raises at every waiter and
  at ``close()`` instead of hanging;
* **QoS policy** degrades under queue pressure / deadline pressure and
  recovers on drain, each only after ``hysteresis`` consecutive signals.
"""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch as DSP
from repro.core import jpeg as J
from repro.core import plan as PL
from repro.core import resnet as R
from repro import serving as SV
from repro.serving.qos import QosPolicy, TierSelector


@pytest.fixture(scope="module")
def setup():
    # two stages -> one strided projection block; 16x16 input = 2x2 blocks
    spec = R.ResNetSpec(widths=(6, 8), num_classes=10)
    params, state = R.init_resnet(jax.random.PRNGKey(0), spec)
    key = jax.random.PRNGKey(7)
    for name in params:
        if "_bn" in name or name.endswith("bn"):
            k1, k2, k3, k4, key = jax.random.split(key, 5)
            c = params[name]["gamma"].shape[0]
            params[name]["gamma"] = 1.0 + 0.2 * jax.random.normal(k1, (c,))
            params[name]["beta"] = 0.1 * jax.random.normal(k2, (c,))
            state[name]["mean"] = 0.1 * jax.random.normal(k3, (c,))
            state[name]["var"] = 1.0 + 0.3 * jax.random.uniform(k4, (c,))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 3, 16, 16)) * 0.5
    coef = jnp.moveaxis(J.jpeg_encode(x, quality=spec.quality, scaled=True),
                        1, 3)
    cfg = DSP.DispatchConfig(path="reference")
    plan = PL.build_plan(params, state, spec, dispatch=cfg)
    return spec, params, state, coef, plan


# --------------------------------------------------------------------------
# Plan ladder
# --------------------------------------------------------------------------


@pytest.mark.parametrize("cap", [48, 32, 16])
def test_tier_bit_identical_to_independent_compile(setup, cap):
    """A derived tier == build_plan at the capped bands, compiled — both
    the plan walk and the compiled schedule, to the bit."""
    spec, params, state, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, cap))
    tier = ladder.tiers[1]
    assert tier.bands == {k: min(v, cap) for k, v in plan.bands.items()}

    indep = PL.build_plan(params, state, spec, dispatch=plan.cfg,
                          bands=dict(tier.bands))
    got_walk = np.asarray(PL.apply_plan(tier.plan, coef))
    want_walk = np.asarray(PL.apply_plan(indep, coef))
    assert np.array_equal(got_walk, want_walk)

    indep_cp = PL.compile_plan(indep)
    got = np.asarray(PL.apply_compiled(tier.compiled, coef))
    want = np.asarray(PL.apply_compiled(indep_cp, coef))
    assert np.array_equal(got, want)


def test_top_tier_is_the_base_plan(setup):
    spec, params, state, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 32))
    assert ladder.top.plan is plan
    assert ladder.top.cap is None
    np.testing.assert_array_equal(
        np.asarray(PL.apply_compiled(ladder.top.compiled, coef)),
        np.asarray(PL.apply_compiled(PL.compile_plan(plan), coef)))


def test_redundant_caps_share_compiled_schedules(setup):
    """Caps at or above the plan's own band assignment collapse onto the
    previous tier and share its CompiledPlan object outright."""
    spec, params, state, coef, plan = setup
    assert max(plan.bands.values()) == 64
    ladder = SV.build_ladder(plan, caps=(None, 64, 32))
    assert len(ladder) == 3
    assert ladder.tiers[1].shared_with == 0
    assert ladder.tiers[1].compiled is ladder.tiers[0].compiled
    assert ladder.tiers[2].shared_with is None


def test_ladder_caps_validation(setup):
    *_, plan = setup
    with pytest.raises(ValueError):
        SV.build_ladder(plan, caps=(32, None))     # None must come first
    with pytest.raises(ValueError):
        SV.build_ladder(plan, caps=(None, 24, 32))  # must decrease
    with pytest.raises(ValueError):
        SV.build_ladder(plan, caps=(None, 20))      # not a multiple of 8


def test_ladder_save_restore_roundtrip(setup, tmp_path):
    spec, params, state, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 32, 16))
    d = str(tmp_path / "plan")
    SV.save_ladder(ladder, d)
    restored = SV.load_ladder(d)
    assert restored.caps == ladder.caps
    for t0, t1 in zip(ladder.tiers, restored.tiers):
        assert t0.name == t1.name and t0.bands == t1.bands
        np.testing.assert_array_equal(
            np.asarray(PL.apply_compiled(t0.compiled, coef)),
            np.asarray(PL.apply_compiled(t1.compiled, coef)))


def test_stale_ladder_manifest_rejected(setup, tmp_path):
    """A ladder manifest saved against a different plan must not silently
    serve different math."""
    spec, params, state, coef, plan = setup
    d = str(tmp_path / "plan")
    SV.save_ladder(SV.build_ladder(plan, caps=(None, 32)), d)
    other = PL.build_plan(params, state, spec, dispatch=plan.cfg, bands=24)
    with pytest.raises(ValueError, match="stale"):
        SV.load_ladder(d, plan=other)


# --------------------------------------------------------------------------
# Scheduler
# --------------------------------------------------------------------------


def _sched(plan, coef, **kw):
    ladder = kw.pop("ladder", None) or SV.build_ladder(plan,
                                                       caps=(None, 16))
    kw.setdefault("batch", 2)
    kw.setdefault("grid", tuple(coef.shape[1:3]))
    kw.setdefault("channels", int(coef.shape[3]))
    return SV.BandElasticScheduler(ladder, **kw)


def test_scheduler_results_match_compiled_plan(setup):
    spec, params, state, coef, plan = setup
    # a watermark the burst can't reach pins the selector at the top tier
    # (this test is about result parity, not the QoS policy)
    calm = QosPolicy(high_depth=1e9, low_depth=0.5)
    with _sched(plan, coef, policy=calm) as s:
        # the runtime serves the band-elastic (transform-domain GEMM)
        # executor off-TPU — compare against the same lowering
        want = np.asarray(PL.apply_compiled(PL.compile_plan(plan), coef,
                                            executor=s.executor))
        reqs = [s.submit(np.asarray(coef[i]))
                for i in range(coef.shape[0])]
        got = np.stack([r.result(timeout=60) for r in reqs])
    # single-tier pressure never builds with batch 2 and 6 requests
    # submitted inline — everything should have served at the top tier
    assert all(r.tier == "top" for r in reqs)
    np.testing.assert_allclose(got, want, atol=1e-5)
    # and the top tier's GEMM executor must keep top-1 parity with the
    # per-layer plan walk (the serve path's fidelity gate)
    walk = np.asarray(PL.apply_plan(plan, coef))
    assert (got.argmax(-1) == walk.argmax(-1)).all()


def test_scheduler_close_drains_pending(setup):
    spec, params, state, coef, plan = setup
    s = _sched(plan, coef)
    reqs = [s.submit(np.asarray(coef[i % coef.shape[0]]))
            for i in range(7)]
    s.close()  # drain=True: everything completes before the join
    assert all(r.done() for r in reqs)
    assert all(r.result() is not None for r in reqs)
    assert s.metrics.report()["requests"] == 7


def test_scheduler_close_without_drain_fails_pending(setup):
    spec, params, state, coef, plan = setup
    s = _sched(plan, coef)
    # stall the worker by submitting from a paused queue: grab the lock so
    # the worker cannot pop, enqueue, then close(drain=False)
    with s._lock:
        reqs = []
        for i in range(5):
            r = SV.ServeRequest(1000 + i, "coefficients",
                                np.asarray(coef[0]), None)
            s._queues["coefficients"].append(r)
            reqs.append(r)
        s._stop = True
        s._drain = False
        s._work.notify_all()
    s._worker.join(timeout=30)
    assert not s._worker.is_alive()
    for r in reqs:
        assert r.done()
        with pytest.raises(SV.SchedulerClosed):
            r.result()
    with pytest.raises(SV.SchedulerClosed):
        s.submit(np.asarray(coef[0]))


def test_scheduler_worker_exception_contained(setup):
    """A crash in the forward fails only its own batch — with
    RequestFailed carrying the cause — and the scheduler keeps serving
    (the PR-8 fault-isolation contract; the old behaviour poisoned the
    scheduler and every future submission)."""
    spec, params, state, coef, plan = setup
    # an always-failing executor would trip the breaker (by design);
    # this test is about containment, so hold the breaker wide open
    lenient = SV.BreakerPolicy(max_consecutive=10_000, min_samples=10_000)
    s = _sched(plan, coef, breaker=lenient, executor_retries=1)
    boom = RuntimeError("forward exploded")
    originals = {}
    for ex in {id(e): e for e in s._execs}.values():
        originals[id(ex)] = ex.coef_fn

    calls = []

    def bad_fn(_):
        calls.append(1)
        raise boom

    for ex in {id(e): e for e in s._execs}.values():
        ex.coef_fn = bad_fn
    r = s.submit(np.asarray(coef[0]))
    with pytest.raises(SV.RequestFailed) as ei:
        r.result(timeout=30)
    assert ei.value.stage == "executor"
    assert ei.value.__cause__ is boom
    # the bounded retry ran: original attempt + 1 retry
    assert len(calls) == 2
    # the scheduler survived: restore the executor and serve normally
    for ex in {id(e): e for e in s._execs}.values():
        ex.coef_fn = originals[id(ex)]
    r2 = s.submit(np.asarray(coef[0]))
    assert r2.result(timeout=60) is not None
    assert s.metrics.failures_total().get("executor", 0) >= 1
    assert s.health()["worker_alive"]
    s.close()  # no re-raise: the failure was contained, not fatal


def test_scheduler_admission_control(setup):
    """Over max_pending queued requests, submit() rejects (returns None)
    and the rejection lands in the metrics."""
    spec, params, state, coef, plan = setup
    s = _sched(plan, coef, max_pending=2)
    gate = threading.Event()
    for ex in {id(e): e for e in s._execs}.values():
        inner = ex.coef_fn

        def gated(c, _inner=inner):
            gate.wait(timeout=60)  # hold the worker mid-batch
            return _inner(c)

        ex.coef_fn = gated
    results = [s.submit(np.asarray(coef[0])) for _ in range(8)]
    accepted = [r for r in results if r is not None]
    n_rejected = results.count(None)
    # the worker can absorb at most one in-flight batch (2 slots) beyond
    # the 2-deep queue before admission control kicks in
    assert n_rejected >= 4
    gate.set()
    s.close()
    assert all(r.done() for r in accepted)
    assert s.metrics.report()["rejected"] == n_rejected


def test_scheduler_deadline_misses_recorded(setup):
    spec, params, state, coef, plan = setup
    with _sched(plan, coef) as s:
        # unwarmed: the first batch pays its jit compile, so a short
        # deadline is still live at dequeue but gone by completion — a
        # served-but-missed request
        r = s.submit(np.asarray(coef[0]), deadline_s=0.2)
        # while a request already expired when the worker sees it is shed
        # at dequeue with DeadlineExceeded, never burning a batch slot
        r2 = s.submit(np.asarray(coef[1]), deadline_s=-0.001)
        assert np.isfinite(r.result(timeout=60)).all()
        with pytest.raises(SV.DeadlineExceeded):
            r2.result(timeout=60)
        s.drain()
    rep = s.metrics.report()
    assert rep["deadline_misses"] >= 1
    assert rep["deadline_miss_rate"] > 0
    assert rep["deadline_shed"] == 1


def test_scheduler_sheds_expired_bytes_before_decode(setup):
    """An expired bytes request is shed at ingest dequeue — the codec is
    never invoked for it (the decode would be wasted work)."""
    from repro.codec import encode_pixels, ingest as ingestlib
    from repro.core import dct as dctlib

    spec, params, state, coef, plan = setup
    rng = np.random.default_rng(1)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    data = encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt)
    calls = []
    orig = ingestlib.ingest_batch

    def spy(datas, **kw):
        calls.append(len(list(datas)))
        return orig(datas, **kw)

    with _sched(plan, coef) as s:
        with pytest.MonkeyPatch.context() as mp:
            mp.setattr(ingestlib, "ingest_batch", spy)
            r = s.submit(data, kind="bytes", deadline_s=-0.001)
            with pytest.raises(SV.DeadlineExceeded):
                r.result(timeout=60)
            s.drain()
    assert calls == []
    assert s.metrics.report()["deadline_shed"] == 1


def _jpeg_traffic(n, seed=0):
    from repro.codec import encode_pixels
    from repro.core import dct as dctlib

    rng = np.random.default_rng(seed)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    return [encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt) for _ in range(n)]


def test_scheduler_decodes_bytes_off_worker(setup):
    """Entropy decode never runs inline in the execute worker: every
    ingest_batch call lands on the dedicated ingest thread, and the
    worker only sees already-decoded coefficient batches."""
    from repro.codec import ingest as ingestlib

    spec, params, state, coef, plan = setup
    threads = []
    orig = ingestlib.ingest_batch

    def spy(datas, **kw):
        threads.append(threading.current_thread().name)
        return orig(datas, **kw)

    with _sched(plan, coef) as s:
        with pytest.MonkeyPatch.context() as mp:
            mp.setattr(ingestlib, "ingest_batch", spy)
            reqs = [s.submit(d, kind="bytes")
                    for d in _jpeg_traffic(4)]
            reqs += [s.submit(np.asarray(coef[i % coef.shape[0]]))
                     for i in range(4)]
            outs = [r.result(timeout=60) for r in reqs]
    assert all(np.isfinite(o).all() for o in outs)
    assert threads and set(threads) == {"scheduler-ingest"}


def test_scheduler_ingest_wall_split_from_device_wall(setup):
    """The QoS tier EMA sees device wall only; host decode wall is
    reported separately (bytes-heavy traffic must not poison the
    selector with cost no band tier can reduce)."""
    spec, params, state, coef, plan = setup
    with _sched(plan, coef) as s:
        observed = []
        orig = s.selector.observe
        s.selector.observe = (
            lambda t, w, **kw: (observed.append(w), orig(t, w, **kw))[1])
        for d in _jpeg_traffic(6, seed=2):
            s.submit(d, kind="bytes")
        s.drain()
    rep = s.metrics.report()
    assert rep["ingest_wall_s"] > 0
    assert rep["device_wall_s"] > 0
    assert rep["ingest"]["wall_s"] == rep["ingest_wall_s"]
    # every observation fed to the EMA is a device wall: they sum to the
    # reported device total, none contains the decode wall
    assert observed and abs(sum(observed) - rep["device_wall_s"]) < 1e-6


def test_scheduler_mixed_ingest_queues(setup):
    """bytes and coefficients requests interleave; batches stay
    kind-homogeneous and every request completes with sane logits."""
    from repro.codec import encode_pixels
    from repro.core import dct as dctlib

    spec, params, state, coef, plan = setup
    rng = np.random.default_rng(0)
    qt = np.rint(dctlib.quantization_table(
        75, dc_is_mean=False)).astype(np.int64)
    datas = [encode_pixels(
        np.clip(rng.normal(0, 0.3, (3, 16, 16)), -1.0, 127.0 / 128.0),
        qtable=qt) for _ in range(3)]
    with _sched(plan, coef) as s:
        reqs = []
        for i in range(3):
            reqs.append(s.submit(np.asarray(coef[i])))
            reqs.append(s.submit(datas[i], kind="bytes"))
        outs = [r.result(timeout=60) for r in reqs]
    assert all(np.isfinite(o).all() for o in outs)
    assert all(o.shape == (spec.num_classes,) for o in outs)
    assert {r.kind for r in reqs} == {"coefficients", "bytes"}


def test_scheduler_overload_degrades_then_serves_everything(setup):
    """A saturating burst forces tier degradation (switch events with
    queue-depth reasons); every request still completes."""
    spec, params, state, coef, plan = setup
    ladder = SV.build_ladder(plan, caps=(None, 32, 16))
    policy = QosPolicy(high_depth=1.5, low_depth=0.5, hysteresis=1)
    with _sched(plan, coef, ladder=ladder, batch=2,
                policy=policy, max_pending=64) as s:
        reqs = [s.submit(np.asarray(coef[i % coef.shape[0]]))
                for i in range(24)]
        s.drain(timeout=120)
    assert all(r is not None and r.done() for r in reqs)
    switches = s.metrics.tier_switches
    assert switches, "overload burst must trigger tier degradation"
    assert any("queue depth" in sw["reason"] for sw in switches)
    assert len({r.tier for r in reqs}) > 1


# --------------------------------------------------------------------------
# QoS policy (deterministic unit tests — no threads, no clocks)
# --------------------------------------------------------------------------


def test_selector_degrades_with_hysteresis():
    events = []
    sel = TierSelector(3, QosPolicy(high_depth=2.0, hysteresis=2),
                       on_switch=lambda *a: events.append(a))
    assert sel.select(pending=32, batch=4) == 0  # 1st overload signal
    assert sel.select(pending=32, batch=4) == 1  # 2nd -> degrade
    assert sel.select(pending=32, batch=4) == 1
    assert sel.select(pending=32, batch=4) == 2  # bottoms out
    assert sel.select(pending=32, batch=4) == 2  # stays at the floor
    assert len(events) == 2
    assert events[0][1:3] == ("0", "1")


def test_selector_recovers_on_drain_with_hysteresis():
    sel = TierSelector(2, QosPolicy(high_depth=2.0, low_depth=0.5,
                                    hysteresis=2))
    sel.tier = 1
    assert sel.select(pending=1, batch=4) == 1   # 1st drained signal
    assert sel.select(pending=1, batch=4) == 0   # 2nd -> recover
    assert sel.select(pending=1, batch=4) == 0   # already at top


def test_selector_hysteresis_resets_on_mixed_signals():
    sel = TierSelector(2, QosPolicy(high_depth=2.0, hysteresis=2))
    sel.select(pending=32, batch=4)              # overload x1
    sel.select(pending=4, batch=4)               # normal — resets streak
    assert sel.select(pending=32, batch=4) == 0  # overload x1 again
    assert sel.select(pending=32, batch=4) == 1


def test_selector_deadline_slack_triggers_degradation():
    sel = TierSelector(2, QosPolicy(hysteresis=1))
    sel.observe(0, batch_wall_s=0.5)  # tier 0 takes ~500ms per batch
    # queue is short, but the head cannot make its 100ms deadline
    assert sel.select(pending=2, batch=4, head_slack_s=0.1) == 1


def test_selector_recovery_respects_deadline_margin():
    sel = TierSelector(2, QosPolicy(hysteresis=1, recover_margin=1.5))
    sel.tier = 1
    sel.observe(0, batch_wall_s=0.5)
    sel.observe(1, batch_wall_s=0.05)
    # drained queue, but climbing back would blow the head deadline
    assert sel.select(pending=1, batch=4, head_slack_s=0.2) == 1
    # with slack, recovery proceeds
    assert sel.select(pending=1, batch=4, head_slack_s=5.0) == 0


def test_metrics_percentiles_shape():
    rep = SV.percentiles([0.010, 0.020, 0.030, 0.100])
    assert rep["n"] == 4
    assert rep["p50_ms"] == pytest.approx(25.0, abs=1.0)
    assert rep["p99_ms"] <= rep["max_ms"] == pytest.approx(100.0)
    assert SV.percentiles([]) == {"n": 0}
