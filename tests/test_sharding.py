"""Sharding-rule inference (pure logic — no devices required)."""
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, get_config
from repro.parallel.sharding import (
    AxisRules, batch_pspec, cache_pspec, param_pspec, sharding_rules,
    zero1_pspec,
)


def _rules(multi=False):
    return AxisRules.default(multi)


def test_param_pspec_dense():
    cfg = get_config("mistral-nemo-12b")
    with sharding_rules(_rules()):
        # stacked (periods, d, q_dim) input-side projection -> last dim
        assert param_pspec("blocks/pos0/attn/q_proj", (40, 5120, 4096), cfg) \
            == P(None, None, "model")
        # output-side projection -> contraction dim
        assert param_pspec("blocks/pos0/attn/o_proj", (40, 4096, 5120), cfg) \
            == P(None, "model", None)
        # embedding -> vocab dim
        assert param_pspec("embed", (131072, 5120), cfg) == P("model", None)
        # norms replicate
        assert param_pspec("blocks/pos0/ln1", (40, 5120), cfg) == P(None, None)
        assert param_pspec("ln_f", (5120,), cfg) == P(None)


def test_param_pspec_moe_zero3():
    cfg = get_config("mixtral-8x7b")
    with sharding_rules(_rules()):
        assert param_pspec("blocks/pos0/moe/w_gate", (32, 8, 4096, 14336),
                           cfg) == P(None, None, "data", "model")
        assert param_pspec("blocks/pos0/moe/w_out", (32, 8, 14336, 4096),
                           cfg) == P(None, None, "model", "data")
        assert param_pspec("blocks/pos0/moe/router", (32, 4096, 8), cfg) \
            == P(None, None, None)


def test_param_pspec_uneven_dim_replicates():
    cfg = get_config("jpeg-resnet")
    with sharding_rules(_rules()):
        # head (512, 1000): 1000 not divisible by 16 -> replicate
        assert param_pspec("head/w", (512, 1000), cfg) == P(None, None)


def test_zero1_adds_data_axis():
    cfg = get_config("mistral-nemo-12b")
    rules = _rules()
    with sharding_rules(rules):
        base = param_pspec("blocks/pos0/attn/q_proj", (40, 5120, 4096), cfg)
        z = zero1_pspec(base, (40, 5120, 4096), rules)
        assert z == P(None, "data", "model")
        # no double-sharding when data already used (ZeRO-3 experts)
        moe = param_pspec("blocks/pos0/moe/w_gate", (32, 8, 4096, 14336),
                          get_config("mixtral-8x7b"))
        assert zero1_pspec(moe, (32, 8, 4096, 14336), rules) == moe


def test_batch_pspec_divisibility():
    rules = _rules(multi=True)
    assert batch_pspec(rules, 256) == ("pod", "data")
    assert batch_pspec(rules, 16) == ("pod",) or batch_pspec(rules, 16) == ("pod", )
    assert batch_pspec(rules, 1) == ()
    single = _rules()
    assert batch_pspec(single, 128) == ("data",)
    assert batch_pspec(single, 3) == ()


def test_cache_pspec_long_context():
    rules = _rules(multi=True)
    baxes, seq = cache_pspec(rules, 1)
    assert baxes == ()
    assert set(seq) == {"pod", "data", "model"}
    baxes, seq = cache_pspec(rules, 256)
    assert baxes == ("pod", "data")
    assert seq == ("model",)


def test_shard_noop_without_rules():
    import jax.numpy as jnp
    from repro.parallel.sharding import shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x
