"""JPEG-domain batch norm (Alg. 3) and pooling equivalences."""
import numpy as np
import jax.numpy as jnp

from repro.core import jpeg as J
from repro.core.batchnorm import (
    BatchNormParams, BatchNormState, batchnorm_jpeg, batchnorm_spatial,
    init_batchnorm,
)
from repro.core.pooling import (
    global_avg_pool_jpeg, global_avg_pool_spatial, residual_add,
)


def _layouts(rng, shape=(8, 4, 16, 16)):
    x = jnp.asarray(rng.normal(size=shape) * 2 + 0.5, jnp.float32)
    coef = jnp.moveaxis(J.jpeg_encode(x, scaled=False), 1, 3)
    return x, coef


def test_batchnorm_training_equivalence(rng):
    x, coef = _layouts(rng)
    params, state = init_batchnorm(4)
    sp, st_sp = batchnorm_spatial(x, params, state, training=True)
    jp, st_jp = batchnorm_jpeg(coef, params, state, training=True)
    back = J.jpeg_decode(jnp.moveaxis(jp, 3, 1), scaled=False)
    assert np.allclose(back, sp, atol=1e-4)
    assert np.allclose(st_jp.running_mean, st_sp.running_mean, atol=1e-6)
    assert np.allclose(st_jp.running_var, st_sp.running_var, atol=1e-5)


def test_batchnorm_inference_equivalence(rng):
    x, coef = _layouts(rng)
    params = BatchNormParams(jnp.asarray([1.5, 0.5, 2.0, 1.0]),
                             jnp.asarray([0.1, -0.2, 0.0, 0.3]))
    state = BatchNormState(jnp.asarray([0.5, 0.1, -0.3, 0.0]),
                           jnp.asarray([1.2, 0.8, 2.0, 1.5]))
    sp, _ = batchnorm_spatial(x, params, state, training=False)
    jp, st2 = batchnorm_jpeg(coef, params, state, training=False)
    back = J.jpeg_decode(jnp.moveaxis(jp, 3, 1), scaled=False)
    assert np.allclose(back, sp, atol=1e-4)
    assert st2 is state  # running stats untouched at inference


def test_mean_variance_theorem(rng):
    """Paper Thm. 2 as realised by the implementation's statistics."""
    x, coef = _layouts(rng, shape=(16, 1, 8, 8))
    params, state = init_batchnorm(1)
    _, st = batchnorm_jpeg(coef, params, state, training=True, momentum=1.0)
    assert np.allclose(st.running_mean, np.asarray(x).mean(), atol=1e-6)
    assert np.allclose(st.running_var, np.asarray(x).var(), atol=1e-5)


def test_global_avg_pool(rng):
    x, coef = _layouts(rng)
    assert np.allclose(global_avg_pool_spatial(x),
                       global_avg_pool_jpeg(coef), atol=1e-6)


def test_residual_add_linearity(rng):
    x1, c1 = _layouts(rng)
    x2, c2 = _layouts(np.random.default_rng(1))
    lhs = residual_add(c1, c2)
    rhs = jnp.moveaxis(J.jpeg_encode(x1 + x2, scaled=False), 1, 3)
    assert np.allclose(lhs, rhs, atol=1e-5)


import numpy as np  # noqa: E402
